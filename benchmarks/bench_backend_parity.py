"""Backend parity benchmark: analytic simulator vs real-JAX engine backend.

Three measurements on a small topology (reduced CPU-testable model), all
driven through the shared ControlPlane:

1. **Router-decision agreement** — the parity scenarios replayed on both
   backends; reports the fraction of identical (worker, overlap) decisions
   (must be 1.0) and compares PoA-hat structure: both backends should sit
   in the below-saturation regime (PoA-hat ≈ 1 plateau) under the
   serialized parity load.

2. **Warm vs cold prefill** — the engine's block-granular prefix cache on a
   warm-heavy workload against the identical run with the cache disabled:
   measured prefill FLOPs and jitted wall time must drop warm vs cold
   (real prefix reuse, not just an accounting trick).

3. **Cache-affinity routing vs round-robin on TTFT** — the same warm-heavy
   stream under ω=1.0 KV routing vs round-robin: affinity keeps repeats on
   the block-resident worker, so the per-non-resident-block transfer
   charge (and any resumed prefill) shows up as a TTFT win.

Usage::

    PYTHONPATH=src python -m benchmarks.bench_backend_parity [--smoke]

Output: CSV rows + reports/benchmarks/BENCH_backend_parity.json.
"""
from __future__ import annotations

import argparse
import statistics
import time

from benchmarks.common import emit, save_json


def _reduced_model():
    import jax
    import jax.numpy as jnp
    from repro.configs import get_reduced
    from repro.models import build_model
    cfg = get_reduced("phi4-mini-3.8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), jnp.bfloat16)
    return model, params


def _decision_agreement(model, params, smoke: bool) -> dict:
    from repro.serving.scenarios import build_backend, parity_scenarios
    out = {}
    all_names = parity_scenarios()
    names = all_names[:2] if smoke else all_names
    for name in names:
        t0 = time.perf_counter()
        sim = build_backend(name, backend="analytic", seed=0)
        res_a = sim.run()
        reqs_a = sorted(res_a.completed, key=lambda r: r.rid)
        dec_a = [(r.rid, r.decode_worker, round(r.overlap, 12))
                 for r in reqs_a]
        poa_a = [p["poa"] for p in res_a.poll_log if p["poa"] == p["poa"]]

        eng = build_backend(name, backend="engine", seed=0,
                            model=model, params=params)
        res_e = eng.run()
        dec_e = [(i, w, round(ov, 12)) for i, w, ov in res_e.decisions]
        poa_e = eng.cluster.poa.current_poa(eng.cluster._now())

        # denominator covers BOTH lists: surplus decisions on either side
        # (e.g. an engine retry logged as a placement) count as disagreement
        agree = sum(a == b for a, b in zip(dec_a, dec_e)) \
            / max(len(dec_a), len(dec_e), 1)
        dt = (time.perf_counter() - t0) * 1e6
        out[name] = dict(
            n=len(dec_a), agreement=agree,
            # timestamps stripped: sim-time vs wall-time are
            # incommensurable, the transition order is the observable
            regimes_equal=(
                [(a, b) for _, a, b in sim.detector.transitions]
                == [(a, b) for _, a, b in res_e.regime_transitions]),
            analytic_poa_mean=(sum(poa_a) / len(poa_a)) if poa_a else None,
            engine_poa=poa_e if poa_e == poa_e else None,
            reused_blocks=res_e.prefill_stats["reused_blocks"],
            total_blocks=res_e.prefill_stats["total_blocks"])
        emit(f"parity_{name}", dt / max(len(dec_a), 1),
             f"agreement={agree:.2f};n={len(dec_a)};"
             f"regimes_equal={out[name]['regimes_equal']}")
    return out


def _warm_vs_cold(model, params, smoke: bool) -> dict:
    """Prefill-engine micro-benchmark with prompts long enough that compute
    dominates dispatch: a warm-heavy template stream with the prefix cache
    on vs off.  FLOPs drop by construction (suffix-only compute); wall time
    must drop too — that is the 'real reuse, not accounting' check."""
    from repro.serving.engine import PrefillEngine
    from repro.serving.workload import template_tokens
    n_prompt = 192 if smoke else 384
    reps = 6 if smoke else 12
    vocab = model.cfg.vocab_size
    stream = [[t % vocab for t in template_tokens(tpl, n_prompt)]
              for tpl in ((0, 1) * reps)]
    runs = {}
    for label, cache_entries in (("cold", 0), ("warm", 16)):
        eng = PrefillEngine(model, params, max_len=n_prompt + 8,
                            cache_entries=cache_entries)
        eng.warmup([n_prompt], suffix_lengths=[1])
        for toks in stream:
            eng.prefill(toks)
        runs[label] = eng.stats.as_dict()
    cold, warm = runs["cold"], runs["warm"]
    flops_ratio = warm["flops"] / max(cold["flops"], 1e-9)
    wall_ratio = warm["wall_s"] / max(cold["wall_s"], 1e-9)
    emit("parity_warm_vs_cold_prefill",
         warm["wall_s"] / max(warm["requests"], 1) * 1e6,
         f"flops_ratio={flops_ratio:.3f};wall_ratio={wall_ratio:.3f};"
         f"reused={warm['reused_blocks']}/{warm['total_blocks']}")
    return dict(cold=cold, warm=warm, flops_ratio=flops_ratio,
                wall_ratio=wall_ratio)


def _kv_vs_round_robin(model, params, smoke: bool) -> dict:
    from repro.serving.scenarios import build_backend
    n = 15 if smoke else 27
    out = {}
    for policy in ("kv", "round_robin"):
        # 3-cycle template stream on 2 workers: round-robin smears each
        # template across the pool (no accidental parity alignment), so
        # affinity's saved KV movement shows up against it.  The per-block
        # transfer charge is set to a cross-node interconnect cost (10 ms /
        # block — the NIXL hop the CPU in-process copy doesn't pay), large
        # enough that the routing-policy difference dominates CPU wall
        # noise in the mean.
        eng = build_backend("parity-2d-warm", backend="engine", seed=0,
                            model=model, params=params, n=n,
                            templates=(0, 1, 0), routing_policy=policy,
                            kv_transfer_per_block=0.010)
        res = eng.run()
        ttfts = res.ttfts()
        out[policy] = dict(
            mean_ttft=statistics.mean(ttfts),
            p95_ttft=sorted(ttfts)[int(0.95 * (len(ttfts) - 1))],
            transferred_blocks=sum(res.transferred_blocks),
            reused_blocks=res.prefill_stats["reused_blocks"])
    kv, rr = out["kv"], out["round_robin"]
    win = rr["mean_ttft"] / max(kv["mean_ttft"], 1e-9)
    emit("parity_kv_vs_rr_ttft", kv["mean_ttft"] * 1e6,
         f"kv_mean={kv['mean_ttft']*1e3:.2f}ms;"
         f"rr_mean={rr['mean_ttft']*1e3:.2f}ms;speedup={win:.2f}x;"
         f"kv_moved={kv['transferred_blocks']}blk;"
         f"rr_moved={rr['transferred_blocks']}blk")
    out["rr_over_kv_mean_ttft"] = win
    return out


def run(smoke: bool = False, strict: bool = False) -> dict:
    """``strict=True`` (the CLI / CI path) raises on a gate violation;
    the aggregate ``benchmarks.run`` sweep calls with ``strict=False`` so
    one regression reports its row without aborting the other benches."""
    model, params = _reduced_model()
    payload = {
        "agreement": _decision_agreement(model, params, smoke),
        "warm_vs_cold": _warm_vs_cold(model, params, smoke),
        "kv_vs_rr": _kv_vs_round_robin(model, params, smoke),
    }
    ok = (all(v["agreement"] == 1.0 for v in payload["agreement"].values())
          and payload["warm_vs_cold"]["flops_ratio"] < 1.0
          and payload["warm_vs_cold"]["wall_ratio"] < 1.0
          and payload["kv_vs_rr"]["rr_over_kv_mean_ttft"] > 1.0)
    payload["ok"] = ok
    save_json("BENCH_backend_parity", payload)
    emit("parity_overall", 0.0, f"ok={ok}")
    if strict and not ok:
        raise RuntimeError("backend parity benchmark FAILED "
                           "(see rows above)")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fewer scenarios/requests: CI bit-rot guard")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    try:
        run(smoke=args.smoke, strict=True)
    except RuntimeError as e:
        raise SystemExit(str(e)) from e


if __name__ == "__main__":
    main()
