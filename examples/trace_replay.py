"""Trace replay: record a workload as JSONL, replay it through the cluster.

Writes a synthetic trace following the scenario subsystem's JSONL schema
(one object per line: ``t`` required; ``template``, ``input_tokens``,
``output_tokens`` optional), loads it back with
``WorkloadConfig.from_trace_file``, and replays it on the registry's
heterogeneous mixed-generation decode pool.

    PYTHONPATH=src python examples/trace_replay.py [trace.jsonl]
"""
import json
import sys
import tempfile
from dataclasses import replace

from repro.serving.scenarios import example_trace_records, get_scenario
from repro.serving.workload import WorkloadConfig


def main():
    if len(sys.argv) > 1:
        path = sys.argv[1]
    else:
        path = tempfile.mkstemp(suffix=".jsonl", prefix="trace-")[1]
        with open(path, "w") as f:
            for rec in example_trace_records(n=200, horizon_s=60.0):
                f.write(json.dumps(rec) + "\n")
        print(f"wrote synthetic trace: {path}")

    workload = WorkloadConfig.from_trace_file(path)
    print(f"loaded {len(workload.trace)} requests "
          f"spanning {workload.total_duration():.1f}s")

    # replay on the heterogeneous pool from the registry (the cluster comes
    # from the scenario; the workload is the replayed trace)
    scenario = get_scenario("hetero-decode-mixed")
    sim = replace(scenario, workload=workload).build(seed=0)
    res = sim.run()

    s = res.overall()
    print(f"\ncluster: {scenario.cluster.name} "
          f"1P/{scenario.cluster.num_decode}D (mixed-generation pool, "
          f"caps={[w.decode_cap for w in scenario.cluster.worker_specs]})")
    print(f"completed {len(res.completed)} requests")
    print(f"TTFT P99 {s.ttft_p99*1000:7.1f}ms  ITL P99 {s.itl_p99*1000:6.2f}ms"
          f"  throughput {s.rps:5.1f} rps  PoA-hat {s.poa:.2f}")
    print(f"peak decode occupancy per worker: {sim.peak_decode_running}")


if __name__ == "__main__":
    main()
