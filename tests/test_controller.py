"""Adaptive controller: Table 2 regime→(τ,ω) mapping, Algorithm 1 metrics,
dual-frontend zero-downtime switch."""

from repro.core.controller import (AdaptiveRouter, DualFrontend, REGIME_PARAMS)
from repro.core.router import KvPushRouter, KvRouterConfig
from repro.core.saturation import DetectorConfig, Regime, SaturationDetector


def test_table2_parameters():
    assert REGIME_PARAMS[Regime.BELOW] == KvRouterConfig(
        temperature=0.0, overlap_weight=1.0)
    assert REGIME_PARAMS[Regime.TRANSITION] == KvRouterConfig(
        temperature=0.7, overlap_weight=1.0)
    # conjectural row (flagged in the paper, implemented for completeness)
    assert REGIME_PARAMS[Regime.SATURATED] == KvRouterConfig(
        temperature=0.8, overlap_weight=0.1)


def _controller(adaptive=True):
    det = SaturationDetector(DetectorConfig(theta1=0.3, theta2=2.0,
                                            alpha=1.0, hysteresis_k=1))
    return AdaptiveRouter(router=KvPushRouter(2), detector=det,
                          adaptive=adaptive)


def test_regime_gated_params_applied():
    c = _controller()
    c.route(list(range(64)), now=0.0)
    assert c.metrics.gauge("game_router_temperature").value == 0.0
    c.poll(5.0, 5.0)  # jump straight to SATURATED
    c.route(list(range(64)), now=6.0)
    assert c.metrics.gauge("game_router_temperature").value == 0.8
    assert c.metrics.gauge("game_overlap_weight").value == 0.1
    assert c.metrics.gauge("game_saturation_state").value == 2


def test_static_mode_ignores_regime():
    c = _controller(adaptive=False)
    c.poll(5.0, 5.0)
    c.route(list(range(64)), now=6.0)
    assert c.metrics.gauge("game_router_temperature").value == 0.0


def test_route_forwards_now_so_ttl_expiry_fires():
    """Regression: ``route`` used to drop ``now`` when calling
    ``best_worker``, so the indexer evaluated TTL freshness at t=0 and
    cache claims never expired through the adaptive controller."""
    c = _controller(adaptive=False)
    r = c.router
    r.indexer.ttl = 2.0
    tokens = list(range(64))
    r.on_schedule(0, tokens, now=0.0)    # worker 0 warm for these tokens
    r.workers[0].active_blocks = 5       # slightly busier than worker 1
    # fresh claim: affinity (ω·20 saved) outweighs the load gap
    w, ov = c.route(tokens, now=1.0)
    assert (w, ov) == (0, 1.0)
    # claim expired: the stale cache must not attract the request anymore
    w, ov = c.route(tokens, now=10.0)
    assert (w, ov) == (1, 0.0)


def test_routing_cost_histogram_populated():
    c = _controller()
    for i in range(5):
        c.route(list(range(64)), now=float(i))
    assert c.metrics.histogram("game_routing_cost").count(5.0) == 5


def test_dual_frontend_switch_and_recovery():
    df = DualFrontend()
    assert df.active_port == 8000
    df.on_regime(Regime.TRANSITION, now=10.0)
    assert df.active_port == 8001 and df.switch_time == 10.0
    assert df.active_config().temperature == 0.7
    df.on_regime(Regime.BELOW, now=50.0)
    assert df.active_port == 8000


def test_metrics_export_text():
    c = _controller()
    c.route(list(range(64)), now=0.0)
    text = c.metrics.export_text(now=0.0)
    for name in ("game_saturation_state", "game_router_temperature",
                 "game_routing_cost"):
        assert name in text
