"""Pure-jnp oracle for single-token cached decode attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def decode_attention_ref(q, k, v, lengths):
    """q: (B,H,hd); k,v: (B,T,K,hd); lengths: (B,) valid KV entries.
    Returns (B,H,hd).  Rows with ``length == 0`` (a fully masked sequence —
    e.g. an inactive continuous-batching slot) return zeros, matching the
    Pallas kernel's empty-softmax convention."""
    b, h, hd = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    qf = q.astype(jnp.float32).reshape(b, kh, g, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bkgh,btkh->bkgt", qf, kf) / np.sqrt(hd)
    mask = jnp.arange(t)[None, None, None, :] < lengths[:, None, None, None]
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkh->bkgh", probs, vf)
    out = jnp.where(lengths[:, None, None, None] > 0, out, 0.0)
    return out.reshape(b, h, hd).astype(q.dtype)
