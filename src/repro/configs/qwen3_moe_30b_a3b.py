"""Qwen3-30B-A3B — MoE 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2_048,
    num_heads=32,
    num_kv_heads=4,
    d_ff=768,
    vocab_size=151_936,
    head_dim=64,
    activation="swiglu",
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=768),
    subquadratic=False,
    source="hf:Qwen/Qwen3-30B-A3B; hf",
)
