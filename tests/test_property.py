"""Hypothesis property tests over the system's invariants."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional test dependency; pip install -e '.[test]' to enable")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.kvbm import KVBlockManager
from repro.core.poa import hungarian, hungarian_jv
from repro.core.radix import KvIndexer
from repro.core.router import KvPushRouter, KvRouterConfig
from repro.core.saturation import DetectorConfig, Regime, SaturationDetector
from repro.training.compression import dequantize_int8, quantize_int8

tok_lists = st.lists(st.integers(0, 500), min_size=16, max_size=120)


@settings(max_examples=40, deadline=None)
@given(tokens=tok_lists, workers=st.integers(1, 5))
def test_overlap_scores_in_unit_interval(tokens, workers):
    ix = KvIndexer()
    ix.insert(0, tokens)
    scores = ix.overlap_scores(tokens, list(range(workers)))
    assert all(0.0 <= s <= 1.0 for s in scores)
    assert scores[0] == 1.0 or len(tokens) < ix.block_size


@settings(max_examples=40, deadline=None)
@given(tokens=tok_lists, extra=tok_lists)
def test_overlap_monotone_under_insert(tokens, extra):
    ix = KvIndexer()
    ix.insert(0, tokens)
    before = ix.overlap_scores(extra, [0])[0]
    ix.insert(0, extra)
    after = ix.overlap_scores(extra, [0])[0]
    assert after >= before


@settings(max_examples=30, deadline=None)
@given(loads=st.lists(st.integers(0, 100), min_size=2, max_size=6),
       tau=st.floats(0.0, 2.0), omega=st.floats(0.0, 1.0))
def test_router_always_returns_healthy_worker(loads, tau, omega):
    r = KvPushRouter(len(loads), KvRouterConfig(temperature=tau,
                                                overlap_weight=omega))
    for i, l in enumerate(loads):
        r.workers[i].active_blocks = l
    r.set_health(0, False)
    if len(loads) > 1:
        w, ov, overlaps = r.best_worker(list(range(64)))
        assert w != 0
        assert 0.0 <= ov <= 1.0
        assert len(overlaps) == len(loads) - 1


@settings(max_examples=30, deadline=None)
@given(ops=st.lists(st.tuples(st.integers(0, 30), st.booleans()),
                    min_size=1, max_size=120),
       cap=st.integers(1, 8))
def test_kvbm_capacity_invariant(ops, cap):
    kv = KVBlockManager({"G1": cap, "G2": cap, "G3": cap})
    for block, is_access in ops:
        if is_access:
            kv.access(block)
        else:
            kv.allocate(block)
    for t in ("G1", "G2", "G3"):
        assert kv.tier_usage[t] <= kv.capacity[t]
    assert sum(kv.tier_usage.values()) == len(kv.blocks)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 4), st.integers(4, 7), st.integers(0, 10_000))
def test_hungarian_never_worse_than_greedy(n, m, seed):
    rng = np.random.default_rng(seed)
    cost = rng.random((n, m))
    idx = hungarian(cost)
    hung = cost[np.arange(n), idx].sum()
    # greedy row-by-row assignment
    used = set()
    greedy = 0.0
    for i in range(n):
        j = min((j for j in range(m) if j not in used),
                key=lambda j, i=i: cost[i, j])
        used.add(j)
        greedy += cost[i, j]
    assert hung <= greedy + 1e-9
    jv = hungarian_jv(cost)
    assert abs(cost[np.arange(n), jv].sum() - hung) < 1e-9


@settings(max_examples=40, deadline=None)
@given(st.lists(st.floats(-1e3, 1e3, allow_nan=False), min_size=1,
                max_size=64))
def test_int8_quantization_error_bound(xs):
    import jax.numpy as jnp
    x = jnp.asarray(xs, jnp.float32)
    q, scale = quantize_int8(x)
    err = np.max(np.abs(np.asarray(dequantize_int8(q, scale) - x)))
    assert err <= float(scale) / 2 + 1e-5


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(0.0, 20.0, allow_nan=False), min_size=3,
                max_size=40))
def test_detector_regime_monotone_in_ewma(vals):
    """Whatever the sample path, the reported regime must match the EWMA
    against the thresholds up to hysteresis lag (never inverted order)."""
    d = SaturationDetector(DetectorConfig(theta1=1.0, theta2=5.0, alpha=0.5,
                                          hysteresis_k=1, epsilon=0.0))
    for i, v in enumerate(vals):
        regime = d.observe(v, 5.0 * i)
        if d.ewma >= 5.0:
            assert regime == Regime.SATURATED
        elif d.ewma < 1.0 and regime == Regime.SATURATED:
            raise AssertionError("saturated while EWMA below θ1")
