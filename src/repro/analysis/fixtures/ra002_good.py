"""RA002 good: the memo is threaded through every hot-path call (or no
memo exists in the function at all, so one hash per call is the price)."""


def route_request(router, req):
    hashes = tuple(req.hashes)
    worker, overlap, _ = router.best_worker(req.tokens, now=0.0,
                                            hashes=hashes)
    router.on_schedule(worker, req.tokens, now=0.0, hashes=hashes)
    return worker, overlap


def route_without_memo(router, tokens):
    # no memo in scope: the callee hashes once, which is fine
    return router.best_worker(tokens, now=0.0)
