"""Large-pool hot path: aggregated-walk bit-exactness + scale scenarios.

The aggregated single-walk overlap scoring, the vectorized τ=0 argmin and
the column-deduplicated frozen OPT are *performance* rewrites: every named
scenario must produce request-level identical results with the fast paths
enabled (the default) and disabled (the legacy flags).  That pin is what
lets the rest of the suite keep trusting the calibrated numbers.
"""
import json
import math

import pytest

from repro.serving.scenarios import build_simulator, get_scenario, list_scenarios

# every scenario that predates the scale family rides the legacy pin; the
# scale scenarios join the comparison through the fast variant of scale-64
# (64 workers exercises the vectorized router path the small pools skip)
PRE_EXISTING = [n for n in list_scenarios() if not n.startswith("scale-")]
SCALE = [n for n in list_scenarios() if n.startswith("scale-")]


def _run(name, legacy):
    sim = build_simulator(name, seed=0, fast=True)
    if legacy:
        # the OPT column dedup is pinned separately (it is equal to the
        # dense matrix only up to float summation order on heterogeneous
        # pools — see test_scenario_poa_dedup_matches_dense); the strict
        # request/poll pin covers the overlap walk and the argmin path
        sim.router.indexer.aggregated = False
        sim.router.vectorized = False
    return sim.run()


def _request_view(res):
    return [(r.rid, r.decode_worker, r.submit_t, r.prefill_end, r.finish_t,
             r.overlap, r.overlaps_all, r.onboard_frac, r.onboard_latency)
            for r in res.completed]


def _poll_view(res):
    # json round-trip: NaN PoA values compare equal as the literal "NaN"
    return json.dumps(res.poll_log)


@pytest.mark.parametrize("name", PRE_EXISTING + ["scale-64"])
def test_fast_paths_bit_exact_with_legacy(name):
    fast = _run(name, legacy=False)
    slow = _run(name, legacy=True)
    assert _request_view(fast) == _request_view(slow)
    assert _poll_view(fast) == _poll_view(slow)


@pytest.mark.parametrize("name", ["cache-pressure-hetero", "70b-1p2d-ramp",
                                  "hetero-decode-mixed"])
def test_scenario_poa_dedup_matches_dense(name):
    """End-to-end: the deduped OPT reproduces every dense-path PoA sample
    to float-summation-order precision (homogeneous pools exactly)."""
    a = build_simulator(name, seed=0, fast=True)
    b = build_simulator(name, seed=0, fast=True)
    b.poa.dedup = False
    ra, rb = a.run(), b.run()
    assert [(r.rid, r.decode_worker) for r in ra.completed] == \
        [(r.rid, r.decode_worker) for r in rb.completed]
    for pa, pb in zip(ra.poll_log, rb.poll_log):
        if math.isnan(pa["poa"]):
            assert math.isnan(pb["poa"])
        else:
            assert pa["poa"] == pytest.approx(pb["poa"], rel=1e-12)


def test_registry_includes_scale_family():
    assert len(SCALE) >= 3
    sizes = set()
    for n in SCALE:
        sc = get_scenario(n, fast=True)
        sizes.add(sc.cluster.num_decode)
        assert sc.workload.mode == "open"
        assert sc.workload.num_templates > 5        # Zipf-skewed wide mix
        assert sc.cluster.num_prefill >= 2          # pooled prefill
        full = get_scenario(n)
        assert full.workload.arrival.rate * full.workload.duration_s == \
            pytest.approx(100_000)
    assert {64, 128, 256} <= sizes
    hetero = [n for n in SCALE
              if get_scenario(n, fast=True).cluster.decode_workers]
    assert hetero, "scale family must include a heterogeneous pool"


def test_scale_scenario_uses_vectorized_router():
    sim = build_simulator("scale-64", seed=0, fast=True)
    assert len(sim.router.workers) >= sim.router.VECTORIZE_MIN_WORKERS
    assert sim.router.vectorized and sim.router.indexer.aggregated
    res = sim.run()
    assert len(res.completed) > 0
    # lean mode dropped the per-request O(workers) vectors after PoA
    # accounting, but the PoA window kept its own copies
    assert all(r.overlaps_all == () for r in res.completed)
    assert all(len(c.overlap) == sim.cluster.num_decode
               for c in sim.poa._window)


def test_lean_mode_does_not_change_results():
    a = build_simulator("scale-64", seed=3, fast=True, num_requests=400,
                        lean_completed=False)
    b = build_simulator("scale-64", seed=3, fast=True, num_requests=400,
                        lean_completed=True)
    ra, rb = a.run(), b.run()
    assert [(r.rid, r.decode_worker, r.finish_t) for r in ra.completed] == \
        [(r.rid, r.decode_worker, r.finish_t) for r in rb.completed]
    assert _poll_view(ra) == _poll_view(rb)
    assert any(r.overlaps_all != () for r in ra.completed)


def test_router_load_cache_tracks_direct_state_writes():
    """The vectorized router caches a dense load vector; writing a
    worker's load/health directly (the simulator's metric sync does, and
    so do tests) must invalidate it."""
    from repro.core.router import KvPushRouter
    r = KvPushRouter(32)
    toks = list(range(64))
    w0, _, _ = r.best_worker(toks)
    assert w0 == 0
    for w in range(16):
        r.workers[w].active_blocks = 50          # direct write, no API
    w1, _, _ = r.best_worker(toks)
    assert w1 == 16
    r.workers[16].healthy = False
    w2, _, _ = r.best_worker(toks)
    assert w2 == 17
    r.workers[16].healthy = True
    assert r.best_worker(toks)[0] == 16


def test_scale_fast_smoke_all_sizes():
    """Every scale scenario must complete its fast variant with sane
    bookkeeping at pool sizes of 64-256."""
    for name in SCALE:
        sim = build_simulator(name, seed=0, fast=True)
        res = sim.run()
        assert sim.in_flight == 0
        assert len(res.completed) > 1000
        for p in res.poll_log:
            if p["poa_n"] >= 0.8 * sim.poa.window_count:
                assert math.isfinite(p["poa"]) and p["poa"] > 0.0
