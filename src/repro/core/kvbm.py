"""Hierarchical KV Block Manager (Game 2's mechanism).

Four tiers — G1 GPU HBM, G2 CPU DRAM, G3 local SSD, G4 networked storage —
with the paper's frequency-based eviction policy (§2.2): every block's
frequency starts at 1, doubles on cache hit, and decays by 1 per time-decay
step; blocks with frequency ≥ 2 are promotion-eligible.  Tier access costs
follow Eq. 6 (α_G1 < α_G2 < α_G3 < γ recompute).

``capacity_ratio`` ρ = active blocks / G1 capacity drives the Prop. 5 regime
transition (PoA_KV = 1 below ρ=1; contested above).

Blocks backing an in-flight decode are *pinned* (reference-counted): the
eviction policy never demotes them, so under pin pressure G1 can run over
capacity — that over-subscription is exactly the ρ > 1 contested regime.
``on_g1_evict`` fires whenever a block leaves G1 (demotion or free), the
hook the serving layer uses to keep router overlap claims coherent with
actual HBM residency.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional

TIERS = ("G1", "G2", "G3", "G4")

# Eq. 6 cost constants (seconds per block access) — α_G1 < α_G2 < α_G3 < γ.
TIER_COST = {"G1": 0.0001, "G2": 0.001, "G3": 0.010, "G4": 0.050}
RECOMPUTE_COST = 0.100  # γ — block not cached anywhere


@dataclass
class Block:
    block_id: int
    tier: str
    frequency: float = 1.0
    size: int = 1
    pin_count: int = 0
    seq: int = 0         # allocation order; chains allocate root→leaf
    last_touch: float = 0.0   # last allocate/access time (cache-churn age)


class KVBlockManager:
    """Per-worker hierarchical cache."""

    def __init__(self, capacity: Dict[str, int], worker_id: int = 0,
                 on_g1_evict: Optional[Callable[[int], None]] = None):
        # G4 effectively unbounded
        self.capacity = {"G1": capacity.get("G1", 1024),
                         "G2": capacity.get("G2", 4096),
                         "G3": capacity.get("G3", 16384),
                         "G4": capacity.get("G4", 1 << 40)}
        self.worker_id = worker_id
        self.on_g1_evict = on_g1_evict
        self.blocks: Dict[int, Block] = {}
        self.tier_usage = {t: 0 for t in TIERS}
        self.evictions = 0
        self.promotions = 0
        self.demotions = 0
        self._seq = 0

    # ------------------------------------------------------------- admit ----

    def allocate(self, block_id: int, now: float = 0.0) -> str:
        """New block: admit to G1, evicting (demoting) as needed."""
        if block_id in self.blocks:
            return self.access(block_id, now)
        self._make_room("G1")
        self._seq += 1
        blk = Block(block_id, "G1", frequency=1.0, seq=self._seq,
                    last_touch=now)
        self.blocks[block_id] = blk
        self.tier_usage["G1"] += 1
        return "G1"

    def access(self, block_id: int, now: float = 0.0) -> str:
        """Cache hit: double frequency; promote if eligible (freq ≥ 2).

        Frequency is floored back to 1 before doubling (§2.2: "frequency
        starts at 1, doubles on hit") — without the floor a fully-decayed
        block stays at 0×2=0 forever, permanently ineligible for promotion
        and the eternal eviction victim."""
        blk = self.blocks.get(block_id)
        if blk is None:
            return "MISS"
        blk.last_touch = max(blk.last_touch, now)
        blk.frequency = max(blk.frequency, 1.0) * 2.0
        if blk.tier != "G1" and blk.frequency >= 2.0:
            self._promote(blk)
        return blk.tier

    def onboard(self, block_id: int) -> str:
        """Fetch a resident block into G1 HBM (§8.4 onboarding): promote
        through the hierarchy until it is G1-resident, making room as
        needed.  Decode requires HBM residency, so admission onboards
        every block of the request — a no-op for blocks already in G1."""
        blk = self.blocks.get(block_id)
        if blk is None:
            return "MISS"
        while blk.tier != "G1":
            self._promote(blk)
        return blk.tier

    def admit_blocks(self, block_ids, now: float = 0.0):
        """Admission hot path: allocate-or-touch, pin, and onboard every
        block of a request in one pass — one dict probe per block instead
        of the four of ``allocate``/``access``/``pin``/``onboard``.
        Step-for-step identical to that call sequence (same frequency
        doublings, same promotion order, hence the same victim choices),
        just without re-resolving the block each time."""
        blocks = self.blocks
        for bid in block_ids:
            blk = blocks.get(bid)
            if blk is None:
                # allocate() then access() on the fresh G1 block: the
                # access doubles the starting frequency, nothing promotes
                self._make_room("G1")
                self._seq += 1
                blk = Block(bid, "G1", frequency=2.0, seq=self._seq,
                            pin_count=1, last_touch=now)
                blocks[bid] = blk
                self.tier_usage["G1"] += 1
                continue
            # allocate() on a resident block is an access(); admission
            # then accesses again — two doublings, each promoting one
            # tier when the block sits below G1
            for _ in range(2):
                blk.last_touch = max(blk.last_touch, now)
                blk.frequency = max(blk.frequency, 1.0) * 2.0
                if blk.tier != "G1":
                    self._promote(blk)
            blk.pin_count += 1
            while blk.tier != "G1":   # onboard(): decode needs HBM
                self._promote(blk)

    def access_cost(self, block_id: int) -> float:
        blk = self.blocks.get(block_id)
        if blk is None:
            return RECOMPUTE_COST
        return TIER_COST[blk.tier]

    def free(self, block_id: int):
        blk = self.blocks.pop(block_id, None)
        if blk is not None:
            self.tier_usage[blk.tier] -= 1
            if blk.tier == "G1" and self.on_g1_evict is not None:
                self.on_g1_evict(block_id)

    # ----------------------------------------------------------- pinning ----

    def pin(self, block_id: int):
        """Reference-count a block backing an in-flight decode: pinned
        blocks are never demoted out of their tier."""
        blk = self.blocks.get(block_id)
        if blk is not None:
            blk.pin_count += 1

    def unpin(self, block_id: int):
        blk = self.blocks.get(block_id)
        if blk is not None and blk.pin_count > 0:
            blk.pin_count -= 1

    # ------------------------------------------------------------ policy ----

    def decay(self):
        """One time-decay step: every block's frequency decreases by 1."""
        for blk in self.blocks.values():
            blk.frequency = max(blk.frequency - 1.0, 0.0)

    def _victim(self, tier: str) -> Optional[Block]:
        cands = [b for b in self.blocks.values()
                 if b.tier == tier and b.pin_count == 0]
        if not cands:
            return None
        # Equal-frequency ties evict the deepest (most recently allocated)
        # block first — radix caches evict leaves, keeping the surviving
        # prefix contiguous and therefore onboardable.
        return min(cands, key=lambda b: (b.frequency, -b.seq))

    def _make_room(self, tier: str):
        # When every resident block is pinned there is no victim: the tier
        # runs over capacity (pinned decode state cannot be dropped) — the
        # over-subscribed ρ > 1 regime of Prop. 5.
        while self.tier_usage[tier] >= self.capacity[tier]:
            victim = self._victim(tier)
            if victim is None:
                return
            self._demote(victim)

    def _demote(self, blk: Block):
        idx = TIERS.index(blk.tier)
        if idx + 1 >= len(TIERS):
            self.free(blk.block_id)
            self.evictions += 1
            return
        src = blk.tier
        nxt = TIERS[idx + 1]
        self._make_room(nxt)
        self.tier_usage[blk.tier] -= 1
        blk.tier = nxt
        self.tier_usage[nxt] += 1
        self.demotions += 1
        if nxt != "G1":
            self.evictions += 1
        if src == "G1" and self.on_g1_evict is not None:
            self.on_g1_evict(blk.block_id)

    def _promote(self, blk: Block):
        idx = TIERS.index(blk.tier)
        tgt = TIERS[idx - 1]
        self._make_room(tgt)
        self.tier_usage[blk.tier] -= 1
        blk.tier = tgt
        self.tier_usage[tgt] += 1
        self.promotions += 1

    # ------------------------------------------------------------ audit -----

    def audit(self) -> list:
        """Audit hook (``repro.analysis.sanitize``): verify the manager's
        internal accounting by one read-only pass over the block table.
        Returns a list of violation descriptions (empty when consistent).

        Checked: every block sits in a known tier; ``tier_usage`` matches
        a recount of the block table; no negative pin counts.  (A tier
        over capacity is *not* flagged: over-subscription is legal under
        pin pressure — Prop. 5's ρ > 1 regime — and transiently after an
        unpin until the next admission makes room.)"""
        problems = []
        usage = {t: 0 for t in TIERS}
        for bid, blk in self.blocks.items():
            if blk.tier not in usage:
                problems.append(f"block {bid:#x}: unknown tier {blk.tier!r}")
                continue
            usage[blk.tier] += 1
            if blk.pin_count < 0:
                problems.append(
                    f"block {bid:#x}: negative pin_count {blk.pin_count}")
        for t in TIERS:
            if usage[t] != self.tier_usage[t]:
                problems.append(
                    f"tier {t}: tier_usage says {self.tier_usage[t]}, "
                    f"recount finds {usage[t]}")
        return problems

    # ------------------------------------------------------------ stats -----

    def capacity_ratio(self) -> float:
        """ρ of Prop. 5: active blocks vs G1 capacity."""
        return len(self.blocks) / max(self.capacity["G1"], 1)

    def tier_distribution(self) -> Dict[str, int]:
        return dict(self.tier_usage)
