"""Experiment 2: saturation regime detection — 9-level sweep with the
calibrated detector, finite differences d(TTFT P99)/dC, detection latency."""
from __future__ import annotations

import time

from benchmarks.common import emit, run_sim, save_json

LEVELS = [1, 4, 8, 16, 32, 64, 128, 256, 512]


def run(hold_s: float = 120.0):
    t0 = time.perf_counter()
    out = {}
    for name in ("nemotron-4-340b", "llama-3.1-70b"):
        rows = []
        prev = None
        for c in LEVELS:
            res = run_sim(name, "1P/2D", c, hold_s)
            s = res.overall()
            regime = max(p["regime"] for p in res.poll_log)
            fd = None
            if prev is not None:
                fd = (s.ttft_p99 - prev[1]) / (c - prev[0])
            rows.append(dict(C=c, ttft_p99=s.ttft_p99, poa=s.poa,
                             regime=regime, dttft_dc=fd))
            prev = (c, s.ttft_p99)
        out[name] = rows
        print(f"\n# Exp 2 — detector sweep {name}")
        print(f"{'C':>5} {'TTFT P99':>10} {'PoA':>8} {'d(TTFT)/dC':>11} {'regime':>7}")
        for r in rows:
            fd = f"{r['dttft_dc']:.4f}" if r["dttft_dc"] is not None else "-"
            print(f"{r['C']:>5} {r['ttft_p99']:>9.3f}s {r['poa']:>8.2f} "
                  f"{fd:>11} {r['regime']:>7}")
    save_json("exp2_saturation_detection", out)
    jump = {}
    for name, rows in out.items():
        by_c = {r["C"]: r for r in rows}
        lo = by_c[64]["dttft_dc"] or 1e-9
        hi = by_c[128]["dttft_dc"] or 0.0
        jump[name] = hi / max(lo, 1e-9)
    dt = (time.perf_counter() - t0) * 1e6
    emit("exp2_saturation_detection", dt / (2 * len(LEVELS)),
         f"knee_derivative_jump_340b={jump['nemotron-4-340b']:.0f}x;"
         f"70b={jump['llama-3.1-70b']:.0f}x")
    return out


if __name__ == "__main__":
    run()
