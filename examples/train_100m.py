"""End-to-end training driver: train a ~100M-parameter dense LM for a few
hundred steps on synthetic data with checkpointing.

    PYTHONPATH=src python examples/train_100m.py [--steps 200]
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.training.optimizer import OptimizerConfig
from repro.training.train_loop import TrainConfig, Trainer


def model_100m() -> ModelConfig:
    """~100M params: a scaled-down member of the stablelm family."""
    base = get_config("stablelm-3b")
    return dataclasses.replace(
        base, name="stablelm-100m", num_layers=8, d_model=640, num_heads=10,
        num_kv_heads=10, head_dim=64, d_ff=1_664, vocab_size=32_000)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_train_100m")
    args = ap.parse_args()

    cfg = model_100m()
    n = cfg.param_count() / 1e6
    print(f"model: {cfg.name} ≈ {n:.0f}M params")
    shape = ShapeConfig("train", args.seq, args.batch, "train")
    trainer = Trainer(cfg, shape, TrainConfig(
        opt=OptimizerConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps),
        remat=True, ckpt_dir=args.ckpt, ckpt_every=50, log_every=10))
    hist = trainer.run(args.steps, log=lambda s: print(
        f"step {s['step']:4d} loss={s['loss']:.4f} "
        f"gnorm={s['grad_norm']:.3f} lr={s['lr']:.2e} "
        f"({s['step_time']*1000:.0f} ms)"))
    first = sum(h["loss"] for h in hist[:10]) / 10
    last = sum(h["loss"] for h in hist[-10:]) / 10
    print(f"\nloss: {first:.3f} → {last:.3f} over {args.steps} steps")


if __name__ == "__main__":
    main()
