"""RA001 bad: direct writes to setter-backed WorkerState fields."""


def stale_the_cache(router):
    st = router.workers[0]
    st._active_blocks = 5.0       # bypasses the invalidating setter
    st._healthy = False           # router keeps routing to a dead worker
    st._capacity = 2.0            # normalized loads silently wrong


def aug_assign(state):
    state._active_blocks += 1.0   # augmented writes bypass it too
