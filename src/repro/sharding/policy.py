"""Logical-axis sharding policy with divisibility fallbacks.

Tensors are annotated with *logical* axis names; a ``ShardingPolicy`` maps
them to mesh axes, dropping any assignment whose dimension size is not
divisible by the mesh-axis product (the MaxText-style fallback).  This keeps
one set of model-code annotations valid across all 10 assigned architectures
(whose head counts are not uniformly divisible by the model-parallel degree).

The policy is installed via a context manager and consulted from the model
code through :func:`shard`, which is a no-op when no policy is active (so the
same model code runs unsharded on CPU tests).
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Mapping, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisAssign = Union[None, str, Tuple[str, ...]]

# Default logical → mesh-axis rules.  Order within the tuple matters only for
# readability; divisibility is checked against the product.
LOGICAL_RULES: Mapping[str, AxisAssign] = {
    # data-like axes
    "batch": ("pod", "data"),
    "decode_batch": ("pod", "data"),
    "seq": None,
    "long_seq": ("pod", "data"),     # long_500k: batch=1, shard KV sequence
    # activation feature axes
    "act_embed": None,               # d_model of activations — replicated
    "act_mlp": ("model",),           # TP'd FFN intermediate activations
    "heads": ("model",),
    "head_dim": None,
    # parameter axes
    "embed": ("data",),              # FSDP axis for the non-TP param dim
    "vocab": ("model",),
    "kv_heads": ("model",),
    "kv_head_dim": ("model",),       # fallback when kv_heads % model != 0
    "kv_feature": ("model",),        # fallback axis: flattened K*hd or hd
    "mlp": ("model",),
    "experts": ("model",),
    "expert_mlp": None,
    "ssm_inner": ("model",),
    "ssm_state": None,
    "stack": None,                   # scanned layer dim — never sharded
    "expert_batch": ("data",),       # capacity dim of the MoE dispatch buffer
}


class ShardingPolicy:
    def __init__(self, mesh: Mesh, rules: Optional[Mapping[str, AxisAssign]] = None):
        self.mesh = mesh
        self.rules = dict(LOGICAL_RULES)
        if rules:
            self.rules.update(rules)

    def _axis_size(self, assign: AxisAssign) -> int:
        if assign is None:
            return 1
        if isinstance(assign, str):
            assign = (assign,)
        size = 1
        for a in assign:
            size *= dict(zip(self.mesh.axis_names, self.mesh.devices.shape)).get(a, 1)
        return size

    def spec(self, logical_axes: Sequence[Optional[str]],
             dim_sizes: Optional[Sequence[int]] = None) -> P:
        """PartitionSpec for the given logical axes, with divisibility fallback."""
        parts = []
        used: set = set()
        for i, name in enumerate(logical_axes):
            assign = self.rules.get(name) if name else None
            if assign is None:
                parts.append(None)
                continue
            if isinstance(assign, str):
                assign = (assign,)
            # only mesh axes that exist, are unused, and divide the dim
            assign = tuple(a for a in assign if a in self.mesh.axis_names and a not in used)
            if not assign:
                parts.append(None)
                continue
            if dim_sizes is not None:
                size = dim_sizes[i]
                keep = []
                prod = 1
                for a in assign:
                    asz = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))[a]
                    if size % (prod * asz) == 0:
                        keep.append(a)
                        prod *= asz
                assign = tuple(keep)
            if not assign:
                parts.append(None)
                continue
            used.update(assign)
            parts.append(assign if len(assign) > 1 else assign[0])
        return P(*parts)

    def sharding(self, logical_axes: Sequence[Optional[str]],
                 dim_sizes: Optional[Sequence[int]] = None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical_axes, dim_sizes))


_POLICY: contextvars.ContextVar[Optional[ShardingPolicy]] = contextvars.ContextVar(
    "sharding_policy", default=None)


def current_policy() -> Optional[ShardingPolicy]:
    return _POLICY.get()


@contextlib.contextmanager
def use_policy(policy: Optional[ShardingPolicy]):
    token = _POLICY.set(policy)
    try:
        yield policy
    finally:
        _POLICY.reset(token)


def shard(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Apply a sharding constraint if a policy is active; identity otherwise."""
    policy = _POLICY.get()
    if policy is None:
        return x
    if x.ndim != len(logical_axes):
        return x
    spec = policy.spec(logical_axes, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(policy.mesh, spec))
