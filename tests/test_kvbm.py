"""KVBM: frequency-based eviction exactly as the paper describes (§2.2) —
init 1, ×2 on hit, −1 per decay step, promotion at freq ≥ 2 — plus tier
capacities and the ρ capacity ratio of Prop. 5."""
from repro.core.kvbm import KVBlockManager, TIER_COST, RECOMPUTE_COST


def test_frequency_dynamics():
    kv = KVBlockManager({"G1": 10})
    kv.allocate(1)
    assert kv.blocks[1].frequency == 1.0
    kv.access(1)
    assert kv.blocks[1].frequency == 2.0
    kv.access(1)
    assert kv.blocks[1].frequency == 4.0
    kv.decay()
    assert kv.blocks[1].frequency == 3.0


def test_eviction_demotes_lowest_frequency():
    kv = KVBlockManager({"G1": 2, "G2": 2})
    kv.allocate(1)
    kv.allocate(2)
    kv.access(2)           # block 2 hot
    kv.allocate(3)         # G1 full → demote coldest (block 1)
    assert kv.blocks[1].tier == "G2"
    assert kv.blocks[2].tier == "G1"
    assert kv.blocks[3].tier == "G1"
    assert kv.demotions == 1


def test_promotion_on_hit():
    kv = KVBlockManager({"G1": 1, "G2": 4})
    kv.allocate(1)
    kv.allocate(2)          # 1 demoted to G2
    assert kv.blocks[1].tier == "G2"
    kv.decay()              # freq: 1→0, 2→0
    kv.access(1)            # 0→... doubled stays 0? init handling: 0*2=0 <2
    assert kv.blocks[1].tier == "G2"
    kv.access(1)
    kv.blocks[1].frequency = 4.0
    kv.access(1)            # freq ≥2 → promote (evicting block 2 from G1)
    assert kv.blocks[1].tier == "G1"
    assert kv.blocks[2].tier == "G2"


def test_capacity_cascade_to_lower_tiers():
    kv = KVBlockManager({"G1": 1, "G2": 1, "G3": 1})
    for b in range(4):
        kv.allocate(b)
    tiers = sorted(blk.tier for blk in kv.blocks.values())
    # 4 blocks across G1,G2,G3 + G4
    assert tiers == ["G1", "G2", "G3", "G4"]


def test_tier_cost_ordering():
    assert TIER_COST["G1"] < TIER_COST["G2"] < TIER_COST["G3"] < TIER_COST["G4"] < RECOMPUTE_COST


def test_access_cost_and_miss():
    kv = KVBlockManager({"G1": 4})
    kv.allocate(1)
    assert kv.access_cost(1) == TIER_COST["G1"]
    assert kv.access_cost(999) == RECOMPUTE_COST


def test_capacity_ratio_rho():
    kv = KVBlockManager({"G1": 4})
    for b in range(6):
        kv.allocate(b)
    assert kv.capacity_ratio() == 6 / 4  # ρ > 1 ⇒ contested regime (Prop. 5)


def test_tier_usage_invariant():
    kv = KVBlockManager({"G1": 3, "G2": 3, "G3": 3})
    for b in range(10):
        kv.allocate(b)
        kv.access(b % 3)
    for t, used in kv.tier_usage.items():
        assert used <= kv.capacity[t]
        assert used == sum(1 for blk in kv.blocks.values() if blk.tier == t)
