"""Slot-lifecycle fuzz: random admit/step/release schedules against
``DecodeEngine``.

Invariants pinned:

* **No stale-KV leakage** — a reused slot must never attend to the previous
  occupant's cache rows: every completed request's token stream equals the
  stream of the same request decoded alone in a fresh single-slot engine.
  Stale rows past ``length`` are reachable only through a masking bug, and
  any such leak shifts the greedy stream.
* **max_new contract** — exactly ``max_new`` tokens are generated beyond
  the prefill's first token (the ``len(s.generated) >= s.max_new + 1``
  condition in ``engine.py``), under both decode implementations.
* **reserve() accounting** — a reserved slot is excluded from free_slot
  until admitted or released.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import build_model
from repro.serving.engine import DecodeEngine, PrefillEngine
from repro.serving.workload import template_tokens

# real-model runs (jit compiles per prompt shape): tier-2 only
pytestmark = pytest.mark.slow

MAX_LEN = 96


@pytest.fixture(scope="module")
def reduced_model():
    cfg = get_reduced("phi4-mini-3.8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), jnp.bfloat16)
    return cfg, model, params


def _toks(cfg, template, n=40):
    return [t % cfg.vocab_size for t in template_tokens(template, n)]


@pytest.fixture(scope="module")
def prefilled(reduced_model):
    """Prefill bundles + solo reference streams per (template, len) spec."""
    cfg, model, params = reduced_model
    pre = PrefillEngine(model, params, max_len=MAX_LEN, cache_entries=0)
    out = {}
    for template, n in [(0, 40), (1, 33), (2, 48), (3, 45)]:
        toks = _toks(cfg, template, n)
        logits, caches = pre.prefill(toks)
        out[(template, n)] = (toks, int(np.argmax(logits)), caches)
    return out


def _solo_stream(solo, prefilled, spec, max_new):
    """Reference: the request decoded alone in a (shared) 1-slot engine."""
    toks, first, caches = prefilled[spec]
    solo.admit(0, "solo", caches, first, prompt_len=len(toks),
               max_new=max_new, hashes=())
    stream = [first]
    while solo.active_count:
        for _, tok, _ in solo.step():
            stream.append(tok)
    return stream


@pytest.mark.parametrize("decode_impl", ["pallas", "sdpa"])
def test_slot_lifecycle_fuzz(reduced_model, prefilled, decode_impl):
    """Random admit/step/release schedule: reused slots never leak the
    previous occupant's KV, and every request generates exactly max_new
    tokens beyond the first."""
    _, model, params = reduced_model
    rng = np.random.default_rng(7)
    dec = DecodeEngine(model, params, num_slots=3, max_len=MAX_LEN,
                       decode_impl=decode_impl)
    solo = DecodeEngine(model, params, num_slots=1, max_len=MAX_LEN,
                        decode_impl=decode_impl)
    specs = list(prefilled)
    refs = {}
    live = {}          # rid -> (spec, max_new, stream so far)
    finished = []
    next_id = 0
    for _ in range(60):
        op = rng.random()
        free = dec.free_slot()
        if op < 0.45 and free is not None:
            spec = specs[int(rng.integers(0, len(specs)))]
            max_new = int(rng.integers(1, 6))
            toks, first, caches = prefilled[spec]
            rid = f"r{next_id}"
            next_id += 1
            dec.admit(free, rid, caches, first, prompt_len=len(toks),
                      max_new=max_new, hashes=())
            live[rid] = (spec, max_new, [first])
            if (spec, max_new) not in refs:
                refs[(spec, max_new)] = _solo_stream(
                    solo, prefilled, spec, max_new)
        elif op < 0.55 and dec.active_count:
            # abandon a random active occupant: its slot is released with
            # a partially-advanced cache — the next occupant must not see it
            active = [i for i, s in enumerate(dec.slots) if s.active]
            victim = active[int(rng.integers(0, len(active)))]
            live.pop(dec.slots[victim].request_id)
            dec.release(victim)
        else:
            for rid, tok, done in dec.step():
                live[rid][2].append(tok)
                if done:
                    finished.append((rid, *live.pop(rid)))
    # drain the rest
    while dec.active_count:
        for rid, tok, done in dec.step():
            live[rid][2].append(tok)
            if done:
                finished.append((rid, *live.pop(rid)))
    assert len(finished) >= 8   # the schedule really exercised reuse
    for rid, spec, max_new, stream in finished:
        # exactly max_new generated tokens beyond the first
        assert len(stream) == max_new + 1, (rid, spec, max_new)
        # bit-identical to the solo run: no stale KV from prior occupants
        assert stream == refs[(spec, max_new)], (rid, spec, max_new)


def test_short_occupant_after_long_occupant(reduced_model, prefilled):
    """Directed stale-cache case: a short prompt admitted into a slot whose
    previous occupant wrote KV far past the new occupant's length."""
    _, model, params = reduced_model
    dec = DecodeEngine(model, params, num_slots=1, max_len=MAX_LEN)
    long_spec, short_spec = (2, 48), (1, 33)
    toks, first, caches = prefilled[long_spec]
    dec.admit(0, "long", caches, first, prompt_len=len(toks), max_new=5,
              hashes=())
    while dec.active_count:
        dec.step()
    toks, first, caches = prefilled[short_spec]
    dec.admit(0, "short", caches, first, prompt_len=len(toks), max_new=5,
              hashes=())
    stream = [first]
    while dec.active_count:
        for _, tok, _ in dec.step():
            stream.append(tok)
    solo = DecodeEngine(model, params, num_slots=1, max_len=MAX_LEN)
    assert stream == _solo_stream(solo, prefilled, short_spec, 5)


def test_reserve_excludes_slot_until_admit(reduced_model, prefilled):
    """reserve() claims a slot for a not-yet-prefilled request: free_slot
    skips it, admit fills it, release frees it."""
    _, model, params = reduced_model
    dec = DecodeEngine(model, params, num_slots=2, max_len=MAX_LEN)
    dec.reserve(0, "pending")
    assert dec.free_slot() == 1
    dec.reserve(1, "pending2")
    assert dec.free_slot() is None
    with pytest.raises(AssertionError):
        dec.reserve(0, "clash")
    toks, first, caches = prefilled[(0, 40)]
    dec.admit(0, "pending", caches, first, prompt_len=len(toks), max_new=1,
              hashes=())
    assert dec.slots[0].request_id == "pending"
    out = dec.step()   # only the admitted slot decodes; reserved is skipped
    assert [rid for rid, _, _ in out] == ["pending"]
    assert out[0][2] is True
    assert dec.free_slot() == 0    # done slot auto-released; 1 still reserved
    dec.release(1)
    assert sum(not s.active for s in dec.slots) == 2


def test_max_new_one_and_cap(reduced_model, prefilled):
    """Contract edges: max_new=1 emits exactly one decode token; a request
    near max_len stops at the cache capacity guard."""
    _, model, params = reduced_model
    dec = DecodeEngine(model, params, num_slots=1, max_len=MAX_LEN)
    toks, first, caches = prefilled[(0, 40)]
    dec.admit(0, "one", caches, first, prompt_len=len(toks), max_new=1,
              hashes=())
    out = dec.step()
    assert len(out) == 1 and out[0][2] is True
    assert dec.free_slot() == 0
    # max_len guard: slot stops before overrunning the cache
    dec.admit(0, "cap", caches, first, prompt_len=len(toks),
              max_new=10_000, hashes=())
    n = 0
    while dec.active_count:
        for _, _, done in dec.step():
            n += 1
            if done:
                break
        assert n < MAX_LEN
    assert dec.slots[0].length == 0    # released
    assert n == MAX_LEN - 1 - len(toks)
