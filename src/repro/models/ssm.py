"""Sub-quadratic sequence mixers: Mamba-2-style SSD and xLSTM blocks.

TPU adaptation (see DESIGN.md §3): instead of porting CUDA selective-scan
kernels, the Mamba block uses the Mamba-2 **SSD chunked formulation** —
intra-chunk compute is a small masked matmul (MXU-friendly) and inter-chunk
state flows through a tiny `lax.scan` — and the mLSTM uses an analogous
chunked linear-attention form with log-space gate stabilization.  The sLSTM
keeps its inherently sequential recurrence (`lax.scan` over time).

Both chunked paths are validated against naive per-step recurrences in
``tests/test_ssm.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import runtime_flags as flags
from repro.models.layers import COMPUTE_DTYPE, _init, rmsnorm, rmsnorm_init
from repro.sharding import shard


# =================================================================== Mamba ==

def mamba_init(rng, cfg, dtype):
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    nh = di // s.head_dim
    n = s.d_state
    conv_dim = di + 2 * n
    r = jax.random.split(rng, 6)
    return {
        "norm": rmsnorm_init(d, dtype),
        "w_in": _init(r[0], (d, 2 * di + 2 * n + nh), d ** -0.5, dtype),
        "conv_w": _init(r[1], (s.d_conv, conv_dim), 0.3, dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        # A in [1, 16] → stable decays
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(dtype),
        "D": jnp.ones((nh,), dtype),
        "dt_bias": jnp.full((nh,), -4.0, dtype),  # softplus ≈ 0.018
        "out_norm": rmsnorm_init(di, dtype),
        "w_out": _init(r[2], (di, d), di ** -0.5, dtype),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv. x: (B,S,C), w: (K,C). state: (B,K-1,C) or None.
    Returns (y, new_state) where new_state holds the last K-1 inputs."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    new_state = xp[:, -(k - 1):, :] if k > 1 else state
    return y + b[None, None, :], new_state


def ssd_chunked(x, dt, a_log, b_in, c_in, chunk):
    """Chunked SSD scan.

    x: (B,S,H,P) inputs per head; dt: (B,S,H) step sizes (>0);
    a_log: (H,) log of positive decay rates A (decay = exp(-dt·A));
    b_in/c_in: (B,S,N) shared input/output projections (n_groups=1).
    Returns (y: (B,S,H,P), final_state: (B,H,N,P)).
    """
    bsz, s0, h, p = x.shape
    n = b_in.shape[-1]
    L = min(chunk, s0)
    pad = (-s0) % L
    if pad:
        # dt=0 padding is exact: decay=exp(0)=1 and contribution dt·B·x = 0,
        # so the final state is unaffected by padded steps.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))
    s = s0 + pad
    nc = s // L
    neg_a = -jnp.exp(a_log.astype(jnp.float32))                  # (H,) < 0
    da = dt.astype(jnp.float32) * neg_a[None, None, :]           # (B,S,H) ≤ 0
    da = da.reshape(bsz, nc, L, h)
    lcum = jnp.cumsum(da, axis=2)                                # (B,nc,L,H)

    xc = x.reshape(bsz, nc, L, h, p)
    dtc = dt.reshape(bsz, nc, L, h).astype(jnp.float32)
    bc = b_in.reshape(bsz, nc, L, n)
    cc = c_in.reshape(bsz, nc, L, n)
    tri = jnp.tril(jnp.ones((L, L), jnp.float32))

    def body(state, inp):
        xk, dtk, lk, bk, ck = inp
        # intra-chunk: masked per-head decay attention
        g = jnp.einsum("bin,bjn->bij", ck.astype(jnp.float32),
                       bk.astype(jnp.float32))                   # (B,L,L)
        decay = jnp.exp(lk[:, :, None, :] - lk[:, None, :, :])   # (B,L,L,H) i≥j ⇒ ≤1
        m = g[..., None] * decay * tri[None, :, :, None]
        y_intra = jnp.einsum("bijh,bjh,bjhp->bihp", m, dtk,
                             xk.astype(jnp.float32))
        # inter-chunk: incoming state decayed to each position
        y_inter = jnp.einsum("bin,bhnp->bihp", ck.astype(jnp.float32), state)
        y_inter = y_inter * jnp.exp(lk)[..., None]
        # state update to chunk end
        total = lk[:, -1, :]                                     # (B,H)
        w = jnp.exp(total[:, None, :] - lk) * dtk                # (B,L,H)
        state = state * jnp.exp(total)[..., None, None] + jnp.einsum(
            "bjn,bjh,bjhp->bhnp", bk.astype(jnp.float32), w,
            xk.astype(jnp.float32))
        return state, (y_intra + y_inter).astype(x.dtype)

    state0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    inputs = tuple(jnp.moveaxis(t, 1, 0) for t in (xc, dtc, lcum, bc, cc))
    final_state, ys = jax.lax.scan(body, state0, inputs,
                                   unroll=flags.inner_scan_unroll(nc))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, h, p)
    return y[:, :s0], final_state


def mamba_block(params, x, cfg, *, cache=None):
    """Mamba-2 SSD block. x: (B,S,D). cache: dict(ssm=(B,H,N,P), conv=(B,K-1,C))
    for single-token decode. Returns (out, new_cache_or_None)."""
    s_cfg = cfg.ssm
    bsz, s, d = x.shape
    di = s_cfg.expand * d
    nh = di // s_cfg.head_dim
    p = s_cfg.head_dim
    n = s_cfg.d_state

    xn = rmsnorm(params["norm"], x, cfg.norm_eps)
    proj = jnp.einsum("bsd,dk->bsk", xn, params["w_in"].astype(COMPUTE_DTYPE))
    z, xr, b_in, c_in, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)

    xbc = jnp.concatenate([xr, b_in, c_in], axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    xbc, new_conv = _causal_conv(
        xbc, params["conv_w"].astype(COMPUTE_DTYPE),
        params["conv_b"].astype(COMPUTE_DTYPE), conv_state)
    xbc = jax.nn.silu(xbc)
    xr, b_in, c_in = jnp.split(xbc, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         params["dt_bias"].astype(jnp.float32))   # (B,S,H)
    x_heads = xr.reshape(bsz, s, nh, p)
    x_heads = shard(x_heads, "batch", "seq", "ssm_inner", None)

    new_cache = None
    if cache is not None:
        # single-token recurrent step (S == 1)
        a = jnp.exp(-jnp.exp(params["A_log"].astype(jnp.float32)) * dt[:, 0])  # (B,H)
        state = cache["ssm"]
        upd = jnp.einsum("bn,bh,bhp->bhnp", b_in[:, 0].astype(jnp.float32),
                         dt[:, 0], x_heads[:, 0].astype(jnp.float32))
        state = state * a[..., None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", c_in[:, 0].astype(jnp.float32), state)
        y = y[:, None]                                            # (B,1,H,P)
        new_cache = {"ssm": state, "conv": new_conv}
    else:
        y, final_state = ssd_chunked(x_heads, dt, params["A_log"], b_in, c_in,
                                     s_cfg.chunk)
        new_cache = {"ssm": final_state, "conv": new_conv}

    y = y.astype(COMPUTE_DTYPE) + params["D"].astype(COMPUTE_DTYPE)[None, None, :, None] * x_heads
    y = y.reshape(bsz, s, di)
    y = rmsnorm(params["out_norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    out = jnp.einsum("bsk,kd->bsd", y, params["w_out"].astype(COMPUTE_DTYPE))
    return shard(out, "batch", "seq", "act_embed"), new_cache


def mamba_cache_init(cfg, batch, dtype=jnp.float32):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    nh = di // s.head_dim
    return {
        "ssm": jnp.zeros((batch, nh, s.d_state, s.head_dim), jnp.float32),
        "conv": jnp.zeros((batch, s.d_conv - 1, di + 2 * s.d_state),
                          COMPUTE_DTYPE),
    }


# =================================================================== mLSTM ==

def mlstm_init(rng, cfg, dtype):
    xc = cfg.xlstm
    d = cfg.d_model
    di = xc.proj_factor * d
    h = cfg.num_heads
    hd = di // h
    r = jax.random.split(rng, 8)
    return {
        "norm": rmsnorm_init(d, dtype),
        "w_up": _init(r[0], (d, 2 * di), d ** -0.5, dtype),      # [inner, gate z]
        "wq": _init(r[1], (di, h, hd), di ** -0.5, dtype),
        "wk": _init(r[2], (di, h, hd), di ** -0.5, dtype),
        "wv": _init(r[3], (di, h, hd), di ** -0.5, dtype),
        "w_i": _init(r[4], (d, h), d ** -0.5, dtype),
        "w_f": _init(r[5], (d, h), d ** -0.5, dtype),
        "b_f": jnp.full((h,), 3.0, dtype),                        # open forget gates
        "head_norm": rmsnorm_init(hd, dtype),
        "w_down": _init(r[6], (di, d), di ** -0.5, dtype),
    }


def mlstm_chunked(q, k, v, log_i, log_f, chunk, state=None):
    """Chunked, stabilized mLSTM linear attention.

    q,k,v: (B,S,H,P); log_i: (B,S,H) exponential input gate (pre-exp);
    log_f: (B,S,H) log forget gate (≤ 0, from logsigmoid).
    state: (C: (B,H,P,P), n: (B,H,P), m: (B,H)) or None.
    Returns (h: (B,S,H,P), new_state).  Validated against the per-step
    recurrence oracle in tests.
    """
    bsz, s0, h, p = q.shape
    L = min(chunk, s0)
    pad = (-s0) % L
    if pad:
        # log_i = -1e30 (no contribution), log_f = 0 (no decay) is exact:
        # padded steps leave (C, n, m) unchanged.
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)),
                        constant_values=-1e30)
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
    s = s0 + pad
    nc = s // L
    qf = q.astype(jnp.float32) * (p ** -0.5)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    li = log_i.astype(jnp.float32).reshape(bsz, nc, L, h)
    lf = log_f.astype(jnp.float32).reshape(bsz, nc, L, h)
    fcum = jnp.cumsum(lf, axis=2)                                 # (B,nc,L,H)
    tri = jnp.tril(jnp.ones((L, L), jnp.float32))

    qc = qf.reshape(bsz, nc, L, h, p)
    kc = kf.reshape(bsz, nc, L, h, p)
    vc = vf.reshape(bsz, nc, L, h, p)

    if state is None:
        c0 = jnp.zeros((bsz, h, p, p), jnp.float32)
        n0 = jnp.zeros((bsz, h, p), jnp.float32)
        m0 = jnp.full((bsz, h), -jnp.inf, jnp.float32)
    else:
        c0, n0, m0 = state

    def body(carry, inp):
        c_st, n_st, m_st = carry
        qk, kk, vk, lik, fck = inp                     # fck = cumsum logf
        t = lik - fck                                   # (B,L,H)
        g = jnp.maximum(m_st[:, None, :], jax.lax.cummax(t, axis=1))  # (B,L,H)
        m_i = fck + g
        # intra weights: exp(t_j - g_i) masked j<=i
        w_intra = jnp.exp(t[:, None, :, :] - g[:, :, None, :]) \
            * tri[None, :, :, None]                     # (B,L,L,H)
        sqk = jnp.einsum("bihp,bjhp->bijh", qk, kk)     # (B,L,L,H)
        num = jnp.einsum("bijh,bijh,bjhp->bihp", sqk, w_intra, vk)
        den = jnp.einsum("bijh,bijh->bih", sqk, w_intra)
        # inter contribution (state scaled by exp(m_st - g_i))
        w_state = jnp.exp(m_st[:, None, :] - g)         # (B,L,H)
        num = num + jnp.einsum("bihp,bhpq->bihq", qk, c_st) * w_state[..., None]
        den = den + jnp.einsum("bihp,bhp->bih", qk, n_st) * w_state
        h_out = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_i))[..., None]
        # state update to chunk end
        ftot = fck[:, -1, :]                            # (B,H)
        m_new = jnp.maximum(m_st + ftot, ftot + jnp.max(t, axis=1))
        w_end = jnp.exp(ftot[:, None, :] + t - m_new[:, None, :])  # (B,L,H)
        c_st = c_st * jnp.exp(m_st + ftot - m_new)[..., None, None] + jnp.einsum(
            "bjh,bjhp,bjhq->bhpq", w_end, kk, vk)
        n_st = n_st * jnp.exp(m_st + ftot - m_new)[..., None] + jnp.einsum(
            "bjh,bjhp->bhp", w_end, kk)
        return (c_st, n_st, m_new), h_out

    inputs = tuple(jnp.moveaxis(t, 1, 0) for t in (qc, kc, vc, li, fcum))
    (c_f, n_f, m_f), ys = jax.lax.scan(body, (c0, n0, m0), inputs,
                                       unroll=flags.inner_scan_unroll(nc))
    h_seq = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, h, p)
    return h_seq[:, :s0].astype(q.dtype), (c_f, n_f, m_f)


def mlstm_step(q, k, v, log_i, log_f, state):
    """Exact single-token mLSTM recurrence. q,k,v: (B,H,P); gates: (B,H)."""
    c_st, n_st, m_st = state
    p = q.shape[-1]
    qf = q.astype(jnp.float32) * (p ** -0.5)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    li = log_i.astype(jnp.float32)
    lf = log_f.astype(jnp.float32)
    m_new = jnp.maximum(lf + m_st, li)
    decay = jnp.exp(lf + m_st - m_new)
    inp = jnp.exp(li - m_new)
    c_st = c_st * decay[..., None, None] + inp[..., None, None] * jnp.einsum(
        "bhp,bhq->bhpq", kf, vf)
    n_st = n_st * decay[..., None] + inp[..., None] * kf
    num = jnp.einsum("bhp,bhpq->bhq", qf, c_st)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", qf, n_st)),
                      jnp.exp(-m_new))
    return (num / den[..., None]).astype(q.dtype), (c_st, n_st, m_new)


def mlstm_block(params, x, cfg, *, cache=None):
    xc = cfg.xlstm
    bsz, s, d = x.shape
    h = cfg.num_heads
    di = xc.proj_factor * d
    hd = di // h
    xn = rmsnorm(params["norm"], x, cfg.norm_eps)
    up = jnp.einsum("bsd,dk->bsk", xn, params["w_up"].astype(COMPUTE_DTYPE))
    inner, z = jnp.split(up, 2, axis=-1)
    q = jnp.einsum("bsk,khp->bshp", inner, params["wq"].astype(COMPUTE_DTYPE))
    k = jnp.einsum("bsk,khp->bshp", inner, params["wk"].astype(COMPUTE_DTYPE))
    v = jnp.einsum("bsk,khp->bshp", inner, params["wv"].astype(COMPUTE_DTYPE))
    log_i = jnp.einsum("bsd,dh->bsh", xn, params["w_i"].astype(COMPUTE_DTYPE))
    log_f = jax.nn.log_sigmoid(
        jnp.einsum("bsd,dh->bsh", xn, params["w_f"].astype(COMPUTE_DTYPE)).astype(jnp.float32)
        + params["b_f"].astype(jnp.float32))

    if cache is not None:
        h_out, new_state = mlstm_step(q[:, 0], k[:, 0], v[:, 0],
                                      log_i[:, 0], log_f[:, 0],
                                      (cache["C"], cache["n"], cache["m"]))
        h_seq = h_out[:, None]
    else:
        h_seq, new_state = mlstm_chunked(q, k, v, log_i, log_f, xc.chunk)
    new_cache = {"C": new_state[0], "n": new_state[1], "m": new_state[2]}
    h_seq = rmsnorm(params["head_norm"], h_seq, cfg.norm_eps)
    h_flat = h_seq.reshape(bsz, s, di) * jax.nn.silu(z)
    out = jnp.einsum("bsk,kd->bsd", h_flat, params["w_down"].astype(COMPUTE_DTYPE))
    return shard(out, "batch", "seq", "act_embed"), new_cache


def mlstm_cache_init(cfg, batch):
    h = cfg.num_heads
    hd = cfg.xlstm.proj_factor * cfg.d_model // h
    return {
        "C": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, h, hd), jnp.float32),
        "m": jnp.full((batch, h), -jnp.inf, jnp.float32),
    }


# =================================================================== sLSTM ==

def slstm_init(rng, cfg, dtype):
    d = cfg.d_model
    h = cfg.num_heads
    hd = d // h
    r = jax.random.split(rng, 10)
    p = {"norm": rmsnorm_init(d, dtype), "head_norm": rmsnorm_init(hd, dtype),
         "w_out": _init(r[8], (d, d), d ** -0.5, dtype)}
    for i, g in enumerate(("z", "i", "f", "o")):
        p[f"w_{g}"] = _init(r[i], (d, d), d ** -0.5, dtype)
        p[f"r_{g}"] = _init(r[4 + i], (h, hd, hd), hd ** -0.5, dtype)
        p[f"b_{g}"] = (jnp.full((d,), 1.0, dtype) if g == "f"
                       else jnp.zeros((d,), dtype))
    return p


def _slstm_step(params, cfg, carry, x_t):
    """carry: (c,n,h,m) each (B,D); x_t: (B,D) pre-projected? No — raw gates
    computed here. x_t: (B, 4D) precomputed input contributions [z,i,f,o]."""
    c, n, hh, m = carry
    d = cfg.d_model
    heads = cfg.num_heads
    hd = d // heads
    hr = hh.reshape(hh.shape[0], heads, hd)

    def rec(g):
        return jnp.einsum("bhi,hij->bhj", hr,
                          params[f"r_{g}"].astype(jnp.float32)).reshape(hh.shape)

    xz, xi, xf, xo = jnp.split(x_t.astype(jnp.float32), 4, axis=-1)
    z = jnp.tanh(xz + rec("z"))
    log_i = xi + rec("i")
    log_f = jax.nn.log_sigmoid(xf + rec("f"))
    o = jax.nn.sigmoid(xo + rec("o"))
    m_new = jnp.maximum(log_f + m, log_i)
    c = jnp.exp(log_f + m - m_new) * c + jnp.exp(log_i - m_new) * z
    n = jnp.exp(log_f + m - m_new) * n + jnp.exp(log_i - m_new)
    h_new = o * c / jnp.maximum(n, 1e-6)
    return (c, n, h_new, m_new), h_new


def slstm_block(params, x, cfg, *, cache=None):
    bsz, s, d = x.shape
    xn = rmsnorm(params["norm"], x, cfg.norm_eps)
    xg = jnp.concatenate(
        [jnp.einsum("bsd,dk->bsk", xn, params[f"w_{g}"].astype(COMPUTE_DTYPE))
         + params[f"b_{g}"].astype(COMPUTE_DTYPE) for g in ("z", "i", "f", "o")],
        axis=-1)
    if cache is not None:
        carry = (cache["c"], cache["n"], cache["h"], cache["m"])
        carry, h_seq = _slstm_step(params, cfg, carry, xg[:, 0])
        h_seq = h_seq[:, None]
    else:
        carry0 = tuple(jnp.zeros((bsz, d), jnp.float32) for _ in range(3)) + (
            jnp.full((bsz, d), -jnp.inf, jnp.float32),)
        carry, hs = jax.lax.scan(
            lambda cr, xt: _slstm_step(params, cfg, cr, xt),
            carry0, jnp.moveaxis(xg, 1, 0))
        h_seq = jnp.moveaxis(hs, 0, 1)
    new_cache = {"c": carry[0], "n": carry[1], "h": carry[2], "m": carry[3]}
    heads = cfg.num_heads
    hd = d // heads
    h_seq = rmsnorm(params["head_norm"],
                    h_seq.reshape(bsz, s, heads, hd), cfg.norm_eps)
    out = jnp.einsum("bsd,dk->bsk", h_seq.reshape(bsz, s, d).astype(COMPUTE_DTYPE),
                     params["w_out"].astype(COMPUTE_DTYPE))
    return shard(out, "batch", "seq", "act_embed"), new_cache


def slstm_cache_init(cfg, batch):
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.zeros((batch, d), jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
        "m": jnp.full((batch, d), -jnp.inf, jnp.float32),
    }
