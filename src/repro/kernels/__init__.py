# Pallas TPU kernels for the serving hot spots: prefill flash attention and
# cached decode attention. Each kernel ships with ops.py (jit'd wrapper with
# CPU interpret fallback) and ref.py (pure-jnp oracle used by the tests).
