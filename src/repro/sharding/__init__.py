from repro.sharding.policy import (  # noqa: F401
    ShardingPolicy, LOGICAL_RULES, current_policy, use_policy, shard,
)
