"""RA006 bad: iterating unordered sets where order reaches decisions."""


def drain_workers(workers):
    for wid in set(workers):             # hash-seed-dependent order
        evict(wid)


def collect(claims):
    return [c for c in {x.key for x in claims}]   # comprehension source


def snapshot(ids):
    return list({i for i in ids})        # list(set) materializes the order
