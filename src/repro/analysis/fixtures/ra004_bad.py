"""RA004 bad: a jitted Pallas wrapper whose kernel-shaping kwargs are
missing from static_argnames — each distinct value recompiles silently,
and a traced value bakes the first call's grid into every call."""
import functools

import jax
from jax.experimental import pallas as pl


@functools.partial(jax.jit, static_argnames=("blk_q",))
def attention(q, k, v, *, blk_q=128, blk_k=128, interpret=None):
    # blk_k and interpret shape the kernel grid but are traced args here
    return pl.pallas_call(_attn_kernel, grid=(q.shape[0] // blk_q,),
                          interpret=interpret)(q, k, v)
