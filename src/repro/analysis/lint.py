"""AST-based repo-specific lint rules (RA001-RA011).

Generic linters cannot see this repo's contracts: that ``WorkerState``
mutations must go through the cache-invalidating property setters, that a
request's block hashes are memoized once and threaded as ``hashes=``
through every router/indexer hop, that jitted/Pallas functions must stay
pure and keep their grid-shaping arguments static, that the analytic
simulator runs on the event clock.  Each rule below encodes one such
contract; each is proven by a good/bad fixture pair under
``repro/analysis/fixtures/`` (``tests/test_analysis_rules.py``).

Suppression: a finding whose source line carries ``ra: allow[RA00x]``
(or ``ra: allow`` for any rule) is dropped — for tests that *deliberately*
violate a contract to prove the runtime sanitizer fires.  ``src/`` must
stay clean without suppressions (CI runs the pass with an empty
allowlist).
"""
from __future__ import annotations

import ast
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Set

# --------------------------------------------------------------- plumbing ---


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    col: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


@dataclass(frozen=True)
class Rule:
    code: str
    title: str
    doc: str
    scope: Callable[[str], bool]
    check: Callable[["Module"], Iterable[Finding]]


class Module:
    """One parsed file plus the lookups the rules share."""

    def __init__(self, path: str, source: str):
        self.path = path.replace("\\", "/")
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        self.parents: Dict[ast.AST, ast.AST] = {}
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                self.parents[child] = node
        # module/class-level function defs by name (for resolving
        # ``jax.jit(fn)`` / ``pl.pallas_call(fn, ...)`` call targets)
        self.defs: Dict[str, ast.FunctionDef] = {
            n.name: n for n in ast.walk(self.tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}

    def finding(self, rule: str, node: ast.AST, message: str) -> Finding:
        return Finding(rule, self.path, getattr(node, "lineno", 1),
                       getattr(node, "col_offset", 0), message)

    def enclosing_function(self, node: ast.AST) -> Optional[ast.AST]:
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.Lambda)):
                return cur
            cur = self.parents.get(cur)
        return None


def dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` → "a.b.c"; None for anything not a pure name chain."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_self(node: ast.AST) -> bool:
    return isinstance(node, ast.Name) and node.id in ("self", "cls")


def _scope_all(path: str) -> bool:
    return True


def _scope_src(path: str) -> bool:
    return "src/repro/" in path or path.startswith("repro/")


def _scope_deterministic(path: str) -> bool:
    """Code the paper's numbers come from: src + benchmarks + examples
    (tests may use their own randomness, e.g. hypothesis)."""
    return (_scope_src(path) or "benchmarks/" in path
            or "examples/" in path)


# ------------------------------------------------------------------ RA001 ---

_SETTER_BACKED = ("_active_blocks", "_healthy", "_capacity")


def _check_ra001(m: Module) -> Iterable[Finding]:
    for node in ast.walk(m.tree):
        targets: List[ast.AST] = []
        if isinstance(node, ast.Assign):
            targets = list(node.targets)
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            targets = [node.target]
        for tgt in targets:
            if (isinstance(tgt, ast.Attribute)
                    and tgt.attr in _SETTER_BACKED
                    and not _is_self(tgt.value)):
                yield m.finding(
                    "RA001", tgt,
                    f"direct write to `{tgt.attr}` bypasses the WorkerState "
                    f"property setter that invalidates the router's cached "
                    f"dense load vector; assign `{tgt.attr.lstrip('_')}` "
                    f"instead")


# ------------------------------------------------------------------ RA002 ---

_MEMO_METHODS = {"best_worker", "overlap_scores", "matched_blocks",
                 "on_schedule", "remove_worker_blocks", "select_worker"}
# `insert`/`route` are common names (list.insert, Flask-ish route);
# only count them against router/indexer/control-plane receivers.
_MEMO_METHODS_GUARDED = {"insert", "route"}
_MEMO_RECEIVERS = ("indexer", "router", "control")


def _binds_hashes(fn: ast.AST) -> bool:
    args = getattr(fn, "args", None)
    if args is not None:
        names = [a.arg for a in args.args + args.kwonlyargs
                 + args.posonlyargs]
        if "hashes" in names or "hs" in names:
            return True
    for node in ast.walk(fn):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)) and node is not fn:
            continue
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name) and tgt.id in ("hashes", "hs"):
                    return True
        if isinstance(node, ast.Attribute) and node.attr == "hashes" \
                and isinstance(node.ctx, ast.Load):
            return True
    return False


def _check_ra002(m: Module) -> Iterable[Finding]:
    memo_fns: Dict[ast.AST, bool] = {}
    for node in ast.walk(m.tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)):
            continue
        name = node.func.attr
        if name in _MEMO_METHODS_GUARDED:
            recv = dotted(node.func.value) or ""
            if not any(r in recv for r in _MEMO_RECEIVERS):
                continue
        elif name not in _MEMO_METHODS:
            continue
        kw = {k.arg for k in node.keywords}
        if "hashes" in kw or None in kw:     # None == **kwargs passthrough
            continue
        fn = m.enclosing_function(node)
        if fn is None:
            continue
        if fn not in memo_fns:
            memo_fns[fn] = _binds_hashes(fn)
        if memo_fns[fn]:
            yield m.finding(
                "RA002", node,
                f"`{name}()` drops the per-request block-hash memo that is "
                f"in scope here; thread it through with `hashes=` so the "
                f"prompt is hashed once per request, not once per hop")


# ------------------------------------------------------------------ RA003 ---

_IMPURE_EXACT = {"time.time", "time.monotonic", "time.perf_counter",
                 "time.process_time", "time.sleep", "datetime.now",
                 "datetime.datetime.now", "os.urandom", "print", "input",
                 "id"}
_IMPURE_PREFIX = ("np.random.", "numpy.random.", "random.")
_MUTATORS = {"append", "extend", "add", "update", "pop", "popitem",
             "setdefault", "clear", "remove", "insert"}


def _jit_like(call_name: Optional[str]) -> bool:
    return call_name in ("jax.jit", "jit", "pjit", "jax.pjit")


def _pallas_like(call_name: Optional[str]) -> bool:
    return call_name is not None and (
        call_name.endswith("pallas_call") or call_name.endswith("_pallas"))


def _jitted_functions(m: Module) -> List[ast.AST]:
    """Functions that run under trace: jit-decorated defs, defs/lambdas
    passed to ``jax.jit``/``pl.pallas_call`` (incl. through
    ``functools.partial``)."""
    out: List[ast.AST] = []
    seen: Set[ast.AST] = set()

    def add(fn: Optional[ast.AST]) -> None:
        if fn is not None and fn not in seen:
            seen.add(fn)
            out.append(fn)

    def resolve(arg: ast.AST) -> Optional[ast.AST]:
        if isinstance(arg, ast.Lambda):
            return arg
        if isinstance(arg, ast.Name):
            return m.defs.get(arg.id)
        if isinstance(arg, ast.Call):        # functools.partial(fn, ...)
            name = dotted(arg.func)
            if name in ("functools.partial", "partial") and arg.args:
                return resolve(arg.args[0])
        return None

    for node in ast.walk(m.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                name = dotted(dec)
                if _jit_like(name):
                    add(node)
                elif isinstance(dec, ast.Call):
                    cname = dotted(dec.func)
                    if _jit_like(cname) or _pallas_like(cname):
                        add(node)
                    elif cname in ("functools.partial", "partial") \
                            and dec.args and _jit_like(dotted(dec.args[0])):
                        add(node)
        elif isinstance(node, ast.Call):
            cname = dotted(node.func)
            if (_jit_like(cname) or _pallas_like(cname)) and node.args:
                add(resolve(node.args[0]))
    return out


def _local_bindings(fn: ast.AST) -> Set[str]:
    bound: Set[str] = set()
    args = getattr(fn, "args", None)
    if args is not None:
        for a in (args.args + args.kwonlyargs + args.posonlyargs):
            bound.add(a.arg)
        if args.vararg:
            bound.add(args.vararg.arg)
        if args.kwarg:
            bound.add(args.kwarg.arg)
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
            bound.add(node.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node is not fn:
            bound.add(node.name)
    return bound


def _check_ra003(m: Module) -> Iterable[Finding]:
    for fn in _jitted_functions(m):
        local = _local_bindings(fn)
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            name = dotted(node.func)
            if name is not None and (
                    name in _IMPURE_EXACT
                    or any(name.startswith(p) for p in _IMPURE_PREFIX)):
                yield m.finding(
                    "RA003", node,
                    f"impure call `{name}()` inside a jit/Pallas-traced "
                    f"function: it runs once at trace time and its value is "
                    f"baked into the compiled computation")
                continue
            # container mutation: only bare statements (`xs.append(v)`) —
            # a consumed result (`a, b = opt.update(...)`) is a computation
            # on a module/object, not a side effect on a captured container
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr in _MUTATORS
                    and isinstance(m.parents.get(node), ast.Expr)
                    and isinstance(node.func.value, ast.Name)
                    and node.func.value.id not in local):
                yield m.finding(
                    "RA003", node,
                    f"mutation `{node.func.value.id}.{node.func.attr}(...)` "
                    f"of a captured container inside a jit/Pallas-traced "
                    f"function: side effects on captures happen at trace "
                    f"time only and silently diverge on cached executions")


# ------------------------------------------------------------------ RA004 ---

_KERNEL_SHAPING = {"blk_q", "blk_k", "blk", "block_q", "block_k",
                   "interpret", "causal", "grid"}


def _jit_static_names(dec: ast.AST) -> Optional[Set[str]]:
    """static_argnames of a jit decorator/call, or None if not jit-like."""
    if _jit_like(dotted(dec)):
        return set()
    if not isinstance(dec, ast.Call):
        return None
    cname = dotted(dec.func)
    is_partial_jit = (cname in ("functools.partial", "partial")
                      and dec.args and _jit_like(dotted(dec.args[0])))
    if not (_jit_like(cname) or is_partial_jit):
        return None
    statics: Set[str] = set()
    for kw in dec.keywords:
        if kw.arg == "static_argnames":
            for el in ast.walk(kw.value):
                if isinstance(el, ast.Constant) and isinstance(el.value, str):
                    statics.add(el.value)
    return statics


def _calls_pallas(fn: ast.AST) -> bool:
    return any(isinstance(n, ast.Call) and _pallas_like(dotted(n.func))
               for n in ast.walk(fn))


def _check_ra004(m: Module) -> Iterable[Finding]:
    for node in ast.walk(m.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        statics: Optional[Set[str]] = None
        for dec in node.decorator_list:
            s = _jit_static_names(dec)
            if s is not None:
                statics = s
        if statics is None or not _calls_pallas(node):
            continue
        shaping = {a.arg for a in node.args.kwonlyargs} & _KERNEL_SHAPING
        missing = sorted(shaping - statics)
        if missing:
            yield m.finding(
                "RA004", node,
                f"jitted Pallas wrapper `{node.name}` takes kernel-shaping "
                f"kwarg(s) {missing} that are not in static_argnames: each "
                f"distinct value must recompile the kernel, and a traced "
                f"value would bake the first call's grid into every call")


# ------------------------------------------------------------------ RA005 ---

_NP_SAMPLERS = {"seed", "rand", "randn", "randint", "random", "choice",
                "shuffle", "permutation", "normal", "uniform", "poisson",
                "exponential", "lognormal", "standard_normal"}
_PY_SAMPLERS = {"random", "randint", "randrange", "choice", "choices",
                "shuffle", "sample", "uniform", "gauss", "betavariate",
                "seed"}


def _check_ra005(m: Module) -> Iterable[Finding]:
    for node in ast.walk(m.tree):
        if not isinstance(node, ast.Call):
            continue
        name = dotted(node.func)
        if name is None:
            continue
        if name in ("random.Random", "np.random.default_rng",
                    "numpy.random.default_rng") \
                and not node.args and not node.keywords:
            yield m.finding(
                "RA005", node,
                f"`{name}()` without a seed draws OS entropy: routing/"
                f"eviction decisions fed from it are unreproducible — pass "
                f"an explicit seed")
            continue
        parts = name.split(".")
        if len(parts) >= 3 and parts[-3] in ("np", "numpy") \
                and parts[-2] == "random" and parts[-1] in _NP_SAMPLERS:
            yield m.finding(
                "RA005", node,
                f"`{name}()` uses numpy's process-global RNG state; use a "
                f"seeded `np.random.default_rng(seed)` stream instead")
        elif len(parts) == 2 and parts[0] == "random" \
                and parts[1] in _PY_SAMPLERS:
            yield m.finding(
                "RA005", node,
                f"`{name}()` uses the process-global `random` module state; "
                f"use a seeded `random.Random(seed)` instance instead")


# ------------------------------------------------------------------ RA006 ---


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        return dotted(node.func) in ("set", "frozenset")
    return False


def _check_ra006(m: Module) -> Iterable[Finding]:
    def hit(node: ast.AST) -> Finding:
        return m.finding(
            "RA006", node,
            "iterating a set: CPython set order is insertion-history- and "
            "hash-seed-dependent, so anything downstream (routing, "
            "eviction, event order) loses determinism — sort it first "
            "(`sorted(...)`)")

    for node in ast.walk(m.tree):
        if isinstance(node, (ast.For, ast.AsyncFor)) \
                and _is_set_expr(node.iter):
            yield hit(node.iter)
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                               ast.GeneratorExp)):
            for gen in node.generators:
                if _is_set_expr(gen.iter):
                    yield hit(gen.iter)
        elif isinstance(node, ast.Call):
            name = dotted(node.func)
            if name in ("list", "tuple", "enumerate", "iter") and node.args \
                    and _is_set_expr(node.args[0]):
                yield hit(node.args[0])


# ------------------------------------------------------------------ RA007 ---

# Load-bearing private state and the one module allowed to touch it.
_PRIVATE_OWNERS = {
    "_state_cache": "core/router.py",       # router's dense load cache
    "_node_by_hash": "core/radix.py",       # radix lookup table
    "_worker_blocks": "core/radix.py",      # radix claim counters
    "_resident": "serving/engine.py",       # decode-worker residency LRU
    "_prefill": "serving/engine.py",        # jitted prompt pass
    "_resume": "serving/engine.py",         # jitted resume pass
    "_best_match": "serving/engine.py",     # prefix-cache walk (LRU-mutating)
    "_template_cache": "serving/simulator.py",
}


def _check_ra007(m: Module) -> Iterable[Finding]:
    for node in ast.walk(m.tree):
        if not isinstance(node, ast.Attribute):
            continue
        owner = _PRIVATE_OWNERS.get(node.attr)
        if owner is None or m.path.endswith(owner) or _is_self(node.value):
            continue
        yield m.finding(
            "RA007", node,
            f"`{node.attr}` is private coherence-critical state of "
            f"`repro/{owner.rsplit('.', 1)[0].replace('/', '.')}"
            f"{''}`; mutating or reading it cross-module bypasses the "
            f"invariants its owner maintains — use the public API")


# ------------------------------------------------------------------ RA008 ---


def _check_ra008(m: Module) -> Iterable[Finding]:
    pins: List[ast.Call] = []
    releases = 0
    for node in ast.walk(m.tree):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
            if node.func.attr in ("pin", "admit_blocks"):
                pins.append(node)
            elif node.func.attr in ("unpin", "free"):
                releases += 1
    if pins and not releases:
        yield m.finding(
            "RA008", pins[0],
            "this module pins KV blocks (`pin`/`admit_blocks`) but never "
            "releases them (`unpin`/`free`): leaked pins make blocks "
            "permanently ineviction-proof and drive G1 into the "
            "over-subscribed regime for the wrong reason")


# ------------------------------------------------------------------ RA009 ---

# Modules that run on the simulated event clock (`now`), where a wall-clock
# read breaks replay determinism.
_EVENT_CLOCK_MODULES = (
    "serving/simulator.py", "serving/workload.py", "core/radix.py",
    "core/router.py", "core/kvbm.py", "core/poa.py", "core/saturation.py",
    "core/planner.py", "core/metrics.py", "core/games.py",
)

_WALL_CLOCK = {"time.time", "time.monotonic", "time.perf_counter",
               "time.process_time", "time.sleep", "datetime.now",
               "datetime.datetime.now"}


def _scope_event_clock(path: str) -> bool:
    return any(path.endswith(mod) for mod in _EVENT_CLOCK_MODULES)


def _check_ra009(m: Module) -> Iterable[Finding]:
    for node in ast.walk(m.tree):
        if isinstance(node, ast.Call) and dotted(node.func) in _WALL_CLOCK:
            yield m.finding(
                "RA009", node,
                f"wall-clock read `{dotted(node.func)}()` in an event-clock "
                f"module: the analytic plane is replay-deterministic only "
                f"if every timestamp derives from the simulated `now`")


# ------------------------------------------------------------------ RA010 ---


def _check_ra010(m: Module) -> Iterable[Finding]:
    for node in ast.walk(m.tree):
        if isinstance(node, ast.Call) \
                and (dotted(node.func) or "").endswith("pallas_call"):
            kw = {k.arg: k.value for k in node.keywords}
            val = kw.get("interpret")
            if val is None:
                yield m.finding(
                    "RA010", node,
                    "`pallas_call` without an `interpret=` kwarg: the kernel "
                    "silently falls back to compiled mode on CPU and fails "
                    "at lowering — thread the platform-derived flag through")
            elif isinstance(val, ast.Constant):
                yield m.finding(
                    "RA010", node,
                    f"`pallas_call(interpret={val.value!r})` hardcodes the "
                    f"execution mode: it must be threaded from the "
                    f"platform guard so TPU runs compiled and CPU runs "
                    f"interpret")
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            statics = None
            for dec in node.decorator_list:
                s = _jit_static_names(dec)
                if s is not None:
                    statics = s
            if statics is None:
                continue
            args = node.args
            kwonly = {a.arg: d for a, d in zip(args.kwonlyargs,
                                               args.kw_defaults)}
            dflt = kwonly.get("interpret")
            if dflt is not None and isinstance(dflt, ast.Constant) \
                    and dflt.value is not None:
                yield m.finding(
                    "RA010", node,
                    f"jitted kernel wrapper `{node.name}` defaults "
                    f"`interpret={dflt.value!r}`: default it to None and "
                    f"derive from the backend (`jax.default_backend()`), so "
                    f"the CPU-interpret guard cannot be skipped by default")


# ------------------------------------------------------------------ RA011 ---

# Authoritative control-plane state a replica-side view may only read at
# sync time (ReplicaStateView.sync) — between syncs every read must come
# from the view's own frozen snapshot fields.
_AUTHORITATIVE_ATTRS = {"router", "indexer", "detector", "policy",
                        "workers", "dual", "planner", "poa"}
_RA011_CLASS_RE = None  # compiled lazily (re import kept local to the rule)


def _replica_view_class(name: str) -> bool:
    global _RA011_CLASS_RE
    if _RA011_CLASS_RE is None:
        import re
        _RA011_CLASS_RE = re.compile(r"^Replica\w*View$")
    return bool(_RA011_CLASS_RE.match(name))


def _enclosing_method_name(m: Module, node: ast.AST,
                           cls: ast.ClassDef) -> Optional[str]:
    cur = m.parents.get(node)
    name = None
    while cur is not None and cur is not cls:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            name = cur.name
        cur = m.parents.get(cur)
    return name


def _check_ra011(m: Module) -> Iterable[Finding]:
    for cls in ast.walk(m.tree):
        if not (isinstance(cls, ast.ClassDef)
                and _replica_view_class(cls.name)):
            continue
        for node in ast.walk(cls):
            if not isinstance(node, ast.Attribute):
                continue
            meth = _enclosing_method_name(m, node, cls)
            if meth == "sync":
                continue               # the one sanctioned authoritative read
            if node.attr == "_plane" and meth not in (None, "__init__"):
                yield m.finding(
                    "RA011", node,
                    f"replica view method `{meth}` reaches through "
                    f"`_plane` to live control-plane state: between syncs "
                    f"a replica may only read its own frozen snapshot "
                    f"fields (move the read into `sync()`)")
            elif node.attr in _AUTHORITATIVE_ATTRS \
                    and not _is_self(node.value):
                where = f"method `{meth}`" if meth else "class body"
                yield m.finding(
                    "RA011", node,
                    f"replica view {where} reads authoritative "
                    f"control-plane state `.{node.attr}` directly; "
                    f"replica-side code must route reads through the "
                    f"StateView snapshot (populate it in `sync()`)")


# ----------------------------------------------------------------- catalog --

RULES: List[Rule] = [
    Rule("RA001", "setter-bypassing WorkerState mutation",
         "Writes to `_active_blocks`/`_healthy`/`_capacity` on anything "
         "but `self` skip the property setters that invalidate the "
         "router's cached dense load vector — the router then routes on a "
         "stale view, which changes the measured game, not just speed.",
         _scope_all, _check_ra001),
    Rule("RA002", "dropped block-hash memo on a hot-path call",
         "Router/indexer entry points accept a `hashes=` memo so each "
         "request's chained block hashes are computed once.  A call that "
         "drops the memo while one is in scope silently re-hashes the "
         "prompt per hop (the pre-PR-4 hot-path regression).",
         _scope_src, _check_ra002),
    Rule("RA003", "impure capture inside a jit/Pallas-traced function",
         "Wall clocks, global RNG, `print`, and mutation of captured "
         "containers execute at trace time only: the first call's value "
         "is baked into the compiled artifact and later calls diverge "
         "without failing any test.",
         _scope_all, _check_ra003),
    Rule("RA004", "kernel-shaping kwargs missing from static_argnames",
         "`blk_*`/`interpret`/`causal` choose the Pallas grid; traced, "
         "they either crash at lowering or freeze the first call's grid "
         "into every subsequent call.",
         _scope_all, _check_ra004),
    Rule("RA005", "unseeded / process-global RNG",
         "Every stochastic choice that feeds routing, eviction, or "
         "workload sampling must come from an explicitly seeded stream; "
         "OS-entropy and process-global state make runs unreproducible "
         "and bit-exactness pins meaningless.",
         _scope_deterministic, _check_ra005),
    Rule("RA006", "iteration over an unordered set",
         "Set iteration order depends on insertion history and the "
         "per-process hash seed: any routing or eviction decision "
         "downstream of it is nondeterministic.  Sort before iterating.",
         _scope_src, _check_ra006),
    Rule("RA007", "cross-module access to coherence-critical private state",
         "`_state_cache`, `_node_by_hash`, `_worker_blocks`, the engine's "
         "jitted callables and caches: their owners maintain invariants "
         "on every mutation.  Touching them from another module bypasses "
         "those invariants (use the public API / audit hooks).",
         _scope_src, _check_ra007),
    Rule("RA008", "KV pins acquired but never released",
         "A module that pins blocks (`pin`/`admit_blocks`) without any "
         "release path (`unpin`/`free`) leaks refcounts: pinned blocks "
         "are eviction-proof, so the leak drives G1 over capacity "
         "permanently.",
         _scope_src, _check_ra008),
    Rule("RA009", "wall-clock read in an event-clock module",
         "The analytic simulator and the core game mechanisms run on the "
         "simulated clock; a `time.*` read there breaks replay "
         "determinism and couples results to host speed.",
         _scope_event_clock, _check_ra009),
    Rule("RA010", "Pallas interpret-mode guard missing or hardcoded",
         "Every `pallas_call` must thread a platform-derived `interpret` "
         "flag (compiled on TPU, interpret elsewhere); a hardcoded or "
         "missing flag either breaks CPU tests or silently runs "
         "interpret-mode on TPU.",
         _scope_all, _check_ra010),
    Rule("RA011", "replica-side read of authoritative control-plane state",
         "`Replica*View` classes are bounded-staleness snapshots: only "
         "`sync()` may read the plane's live router/indexer/detector "
         "state.  Any other method reaching through `_plane` (or stashing "
         "a live `.router`/`.indexer`/... reference) silently reintroduces "
         "fresh reads, and the measured staleness externality becomes a "
         "lie.",
         _scope_all, _check_ra011),
]

_RULES_BY_CODE = {r.code: r for r in RULES}


def rule_catalog() -> str:
    out = []
    for r in RULES:
        out.append(f"{r.code}  {r.title}")
        out.append(f"       {r.doc}")
    return "\n".join(out)


# ------------------------------------------------------------------ runner --

_ALLOW_TOKEN = "ra: allow"


def _suppressed(m: Module, f: Finding) -> bool:
    if not 1 <= f.line <= len(m.lines):
        return False
    line = m.lines[f.line - 1]
    idx = line.find(_ALLOW_TOKEN)
    if idx < 0:
        return False
    rest = line[idx + len(_ALLOW_TOKEN):]
    if not rest.lstrip().startswith("["):
        return True                                   # blanket allow
    codes = rest.lstrip()[1:].split("]", 1)[0]
    return f.rule in {c.strip() for c in codes.split(",")}


def lint_source(path: str, source: str,
                select: Optional[Sequence[str]] = None) -> List[Finding]:
    m = Module(path, source)
    findings: List[Finding] = []
    for rule in RULES:
        if select is not None and rule.code not in select:
            continue
        if not rule.scope(m.path):
            continue
        findings.extend(f for f in rule.check(m) if not _suppressed(m, f))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings


def lint_file(path, select: Optional[Sequence[str]] = None) -> List[Finding]:
    p = Path(path)
    return lint_source(str(p), p.read_text(), select=select)


_SKIP_DIRS = {"__pycache__", ".git", ".ruff_cache", "node_modules"}
# the lint pass never scans its own violation corpus
_FIXTURES = "repro/analysis/fixtures"


def iter_python_files(paths: Sequence[str]) -> List[Path]:
    out: List[Path] = []
    for root in paths:
        p = Path(root)
        if p.is_file() and p.suffix == ".py":
            out.append(p)
            continue
        for f in sorted(p.rglob("*.py")):
            rel = f.as_posix()
            if any(part in _SKIP_DIRS for part in f.parts):
                continue
            if _FIXTURES in rel:
                continue
            out.append(f)
    return out


def lint_paths(paths: Sequence[str],
               select: Optional[Sequence[str]] = None,
               allowlist: Sequence[str] = ()) -> List[Finding]:
    """Lint every .py file under ``paths``.  ``allowlist`` entries are
    ``"RULE path-substring"`` pairs (one per line in the CLI's
    ``--allowlist`` file); a matching finding is dropped."""
    allow = []
    for entry in allowlist:
        entry = entry.strip()
        if not entry or entry.startswith("#"):
            continue
        rule, _, frag = entry.partition(" ")
        allow.append((rule, frag.strip()))
    findings: List[Finding] = []
    for f in iter_python_files(paths):
        for fd in lint_file(f, select=select):
            if any(fd.rule == rule and frag and frag in fd.path
                   for rule, frag in allow):
                continue
            findings.append(fd)
    return findings
