"""Real-model disaggregated serving engines (jitted JAX, CPU-testable).

``PrefillEngine`` runs the prompt pass and emits a per-request KV/state
cache bundle.  It keeps a **block-granular prefix cache** keyed by the same
chained ``block_hashes`` the router/indexer use: when a new prompt shares a
cached prefix (and the model supports resumable prefill — attention-only
stacks), the prompt pass *resumes* from the matched block boundary instead
of recomputing the prefix, so a cache-warm routing decision actually skips
real jitted compute.  Per-call and cumulative stats (reused blocks,
computed suffix tokens, estimated FLOPs, wall time) back the
``benchmarks/bench_backend_parity.py`` warm-vs-cold measurement.

``DecodeEngine`` holds a fixed-slot continuous batch whose per-slot lengths
advance independently (ragged decode with masked cache writes).  Finished
slots are released **inside** :meth:`DecodeEngine.step` — the returned-slot
contract: a ``done=True`` tuple means the slot is already free and
re-admittable in the same tick.  The engine also tracks which KV blocks are
resident (admitted and not yet evicted by the bounded LRU), so the
prefill→decode ``transfer()`` hop can be charged per *non-resident* block —
on a real cluster that hop is a cross-mesh ``jax.device_put`` (the NIXL
analogue); on CPU it degenerates to an in-process copy, so the per-block
charge is what reintroduces the KV-movement cost the routing game is about.
"""
from __future__ import annotations

import functools
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.radix import BLOCK_SIZE, block_hashes
from repro.models.model import Model
from repro.serving.paging import PageAllocator


@dataclass
class PrefillStats:
    """Cumulative prefix-cache + batching accounting (one per engine)."""
    requests: int = 0
    total_blocks: int = 0        # full blocks across all prompts
    reused_blocks: int = 0       # blocks resumed from the prefix cache
    total_tokens: int = 0        # prompt tokens across all prompts
    computed_tokens: int = 0     # suffix tokens actually run through compute
    flops: float = 0.0           # ≈ 2·N_active·computed_tokens
    wall_s: float = 0.0          # jitted prompt-pass wall time
    batches: int = 0             # jitted prompt passes issued (any width)
    batched_requests: int = 0    # requests served by a width>1 pass
    padded_tokens: int = 0       # pad tokens run through compute (overhead)

    def as_dict(self) -> dict:
        return dict(requests=self.requests, total_blocks=self.total_blocks,
                    reused_blocks=self.reused_blocks,
                    total_tokens=self.total_tokens,
                    computed_tokens=self.computed_tokens,
                    flops=self.flops, wall_s=self.wall_s,
                    batches=self.batches,
                    batched_requests=self.batched_requests,
                    padded_tokens=self.padded_tokens)


class PrefillEngine:
    def __init__(self, model: Model, params, max_len: int,
                 cache_entries: int = 16, block_size: int = BLOCK_SIZE,
                 max_batch: int = 8):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.block_size = block_size
        self.cache_entries = cache_entries
        # batched prompt passes: cold prompts bucket into one right-padded
        # ragged pass (lengths vector), resumes group by (start, suffix).
        # Batch widths are padded to powers of two so the jit shape set
        # stays O(log max_batch) per length bucket.
        self.max_batch = max(1, max_batch)
        self._prefill = jax.jit(
            lambda p, batch: model.prefill(p, batch, max_len=max_len))
        self._prefill_batched = jax.jit(
            lambda p, toks, lens: model.prefill_batched(p, toks, lens,
                                                        max_len=max_len))
        # start is traced (one compile per suffix length, not per offset)
        self._resume = jax.jit(model.prefill_resume)
        # prefix cache: full hash chain of a completed prompt pass → its
        # cache bundle (K/V valid for every position of that prompt).  A
        # lookup matches the longest common *prefix* of chains — chained
        # hashes commit to the whole prefix, so chain equality at depth m
        # means token equality over the first m blocks.
        self._cache: "OrderedDict[Tuple[int, ...], object]" = OrderedDict()
        self.stats = PrefillStats()
        # per-token FLOPs estimate: 2·N_active (inference forward pass)
        self._flops_per_token = 2.0 * model.cfg.active_param_count()

    # ------------------------------------------------------ prefix cache ----

    def _best_match(self, hashes: Sequence[int]):
        """One walk over the cache: ``(depth, entry)`` of the deepest
        common-prefix chain (most recently used wins ties); the winner's
        LRU position is refreshed.  Chained hashes commit to their whole
        prefix, so chain equality at depth m means token equality over the
        first m blocks — any entry matching m blocks is a valid K/V donor
        for every resume point inside them."""
        best, donor, key = 0, None, None
        for chain in reversed(self._cache):   # most recent first
            m = 0
            for a, b in zip(chain, hashes):
                if a != b:
                    break
                m += 1
            if m > best:
                best, donor, key = m, self._cache[chain], chain
        if key is not None:
            self._cache.move_to_end(key)
        return best, donor

    def _store(self, hashes: Sequence[int], caches) -> None:
        if not hashes or self.cache_entries <= 0:
            return
        key = tuple(hashes)
        self._cache[key] = caches
        self._cache.move_to_end(key)
        while len(self._cache) > self.cache_entries:
            self._cache.popitem(last=False)

    def clear_cache(self) -> None:
        self._cache.clear()

    def dummy_caches(self, prompt_len: int):
        """A throwaway cache bundle from a zero-token prompt pass of
        ``prompt_len`` — for warmup flows that need a structurally valid
        bundle to drive admit/step compilation, without touching the
        prefix cache or the stats (and without callers reaching into the
        engine's jitted internals)."""
        batch = {"tokens": jnp.zeros((1, prompt_len), jnp.int32)}
        _, caches = self._prefill(self.params, batch)
        return caches

    def _padded_len(self, n: int) -> int:
        """Cold-bucket sequence length: next block multiple when the model
        tolerates right-padding, the exact length otherwise."""
        if self.model.supports_padded_prefill:
            return -(-n // self.block_size) * self.block_size
        return n

    def _width(self, n: int) -> int:
        """Batch width for ``n`` group members: next power of two, capped
        at ``max_batch`` — bounds the jitted shape set to O(log max_batch)
        widths per length bucket."""
        w = 1
        while w < min(n, self.max_batch):
            w *= 2
        return w

    def warmup(self, prompt_lengths: Sequence[int],
               suffix_lengths: Sequence[int] = (),
               batch_sizes: Sequence[int] = (1,)) -> None:
        """Pre-compile the jitted prompt passes for the given prompt (and
        resume-suffix) lengths, without touching the prefix cache or the
        stats — so measured runs and the saturation detector never see
        multi-second XLA compile walls as TTFT.

        ``batch_sizes`` lists the batched-pass widths to pre-compile (each
        rounded to its power-of-two width); the cold ragged pass compiles
        per (width, padded length) and resumes per (width, suffix length).

        Resume compilation is keyed on the suffix length alone (cache
        shapes are fixed at ``max_len`` and ``start`` is traced), so each
        suffix compiles once against one donor instead of once per
        (prompt, suffix) pair."""
        lengths = sorted(set(int(x) for x in prompt_lengths))
        caches = None
        for n in lengths:
            batch = {"tokens": jnp.zeros((1, n), jnp.int32)}
            _, caches = self._prefill(self.params, batch)
        widths = sorted({self._width(max(1, int(b))) for b in batch_sizes})
        for n in sorted({self._padded_len(x) for x in lengths}):
            for w in widths:
                self._prefill_batched(self.params,
                                      jnp.zeros((w, n), jnp.int32),
                                      jnp.ones((w,), jnp.int32))
        if caches is None or not self.model.supports_prefill_resume:
            return
        n_max = lengths[-1]
        suffixes = [s for s in sorted(set(int(x) for x in suffix_lengths))
                    if 0 < s < n_max]
        for w in widths:
            donor = caches if w == 1 else jax.tree.map(
                lambda a, w=w: jnp.concatenate([a] * w, axis=1), caches)
            for s in suffixes:
                self._resume(self.params, donor,
                             jnp.zeros((w, s), jnp.int32),
                             jnp.int32(n_max - s))

    # ----------------------------------------------------------- prefill ----

    def prefill(self, tokens: Sequence[int], extras: Optional[dict] = None,
                hashes: Optional[Sequence[int]] = None):
        """Single-request prompt pass → (last_logits (V,), cache bundle).

        Resumes from the longest cached block prefix when possible; a miss
        (or a model without resumable prefill, or multimodal ``extras``)
        pays the full jitted pass.  Always recomputes at least the last
        token so the returned logits are exact for *this* prompt."""
        resumable = (self.model.supports_prefill_resume and not extras
                     and self.cache_entries > 0)
        if hashes is None and resumable:
            hashes = block_hashes(tokens, self.block_size)
        hashes = tuple(hashes or ())
        start = 0
        donor = None
        if resumable and hashes:
            m, donor = self._best_match(hashes)
            # keep ≥1 suffix token so the pass emits this prompt's logits;
            # the donor matched m full blocks, which covers every position
            # below any start ≤ m·block_size (including a non-boundary
            # start inside the donor's last matched block)
            start = min(m * self.block_size, len(tokens) - 1)
            if start <= 0:
                donor = None
        t0 = time.perf_counter()
        if start > 0:
            suffix = jnp.asarray(tokens[start:], jnp.int32)[None, :]
            logits, caches = self._resume(self.params, donor, suffix,
                                          jnp.int32(start))
        else:
            batch = {"tokens": jnp.asarray(tokens, jnp.int32)[None, :]}
            if extras:
                batch.update({k: jnp.asarray(v)[None]
                              for k, v in extras.items()})
            logits, caches = self._prefill(self.params, batch)
        logits = np.asarray(logits[0])
        wall = time.perf_counter() - t0
        st = self.stats
        st.requests += 1
        st.total_blocks += len(hashes)
        st.reused_blocks += start // self.block_size
        st.total_tokens += len(tokens)
        st.computed_tokens += len(tokens) - start
        st.flops += self._flops_per_token * (len(tokens) - start)
        st.wall_s += wall
        if resumable:
            self._store(hashes, caches)
        return logits, caches

    # --------------------------------------------------- batched prefill ----

    def prefill_many(self, requests: Sequence[Tuple[Sequence[int],
                                                    Optional[dict],
                                                    Optional[Sequence[int]]]]
                     ) -> List[Tuple[np.ndarray, object, int]]:
        """Batched prompt passes across queued requests.

        ``requests``: ``(tokens, extras, hashes)`` triples (``hashes`` may
        be None).  Returns a list aligned with the input order of
        ``(last_logits (V,), cache_bundle, row)`` — ``cache_bundle`` is
        the (possibly shared) batch bundle and ``row`` the request's batch
        row, consumable by :meth:`DecodeEngine.admit` via ``src_row``.

        Grouping: multimodal requests (``extras``) fall back to the
        single-request path; prefix-cache hits group by (resume start,
        suffix length) and run one stacked-donor resume pass; cold prompts
        bucket by padded length (block multiple for models that tolerate
        right-padding, exact length otherwise) and run one right-padded
        ragged pass over the per-row lengths vector.  Identical prompts
        inside one call collapse onto a single batch row.  Every grouped
        pass is pinned logit-comparable to the sequential path by
        ``tests/test_engine_batching.py``."""
        n = len(requests)
        results: List[Optional[Tuple[np.ndarray, object, int]]] = [None] * n
        st = self.stats
        can_resume = self.model.supports_prefill_resume and \
            self.cache_entries > 0
        # --- resolve: dedupe identical prompts, match prefix cache once ---
        cold: dict = {}     # padded_len -> [(idx, tokens, hashes)]
        resume: dict = {}   # (start, plen) -> [(idx, tokens, hashes, donor)]
        alias: List[Tuple[int, int]] = []   # (dup idx, primary idx)
        seen: dict = {}     # tokens tuple -> primary idx
        for i, (tokens, extras, hashes) in enumerate(requests):
            if extras:
                # multimodal inputs carry per-request arrays; keep them on
                # the exact single-request path
                logits, caches = self.prefill(tokens, extras, hashes=hashes)
                results[i] = (logits, caches, 0)
                continue
            key = tuple(tokens)
            if key in seen:
                alias.append((i, seen[key]))
                continue
            seen[key] = i
            resumable = can_resume
            if hashes is None and resumable:
                hashes = block_hashes(tokens, self.block_size)
            hashes = tuple(hashes or ())
            start, donor = 0, None
            if resumable and hashes:
                m, donor = self._best_match(hashes)
                start = min(m * self.block_size, len(tokens) - 1)
                if start <= 0:
                    start, donor = 0, None
            if donor is not None:
                resume.setdefault((start, len(tokens)), []).append(
                    (i, tokens, hashes, donor))
            else:
                cold.setdefault(self._padded_len(len(tokens)), []).append(
                    (i, tokens, hashes))
        # --- cold buckets: one ragged right-padded pass per chunk ---------
        for plen, group in cold.items():
            for c0 in range(0, len(group), self.max_batch):
                self._run_cold_chunk(plen, group[c0:c0 + self.max_batch],
                                     results)
        # --- resume groups: one stacked-donor pass per chunk --------------
        for (start, _), group in resume.items():
            for c0 in range(0, len(group), self.max_batch):
                self._run_resume_chunk(start, group[c0:c0 + self.max_batch],
                                       results)
        for i, j in alias:
            results[i] = results[j]
            st.requests += 1
            st.total_blocks += len(tuple(requests[i][2] or ()))
            st.total_tokens += len(requests[i][0])
        return results  # fully populated: every request hit exactly one path

    def _run_cold_chunk(self, plen: int, group, results) -> None:
        w = self._width(len(group))
        toks = np.zeros((w, plen), np.int32)
        lens = np.ones((w,), np.int32)
        for r, (_, tokens, _) in enumerate(group):
            toks[r, :len(tokens)] = tokens
            lens[r] = len(tokens)
        t0 = time.perf_counter()
        logits, caches = self._prefill_batched(
            self.params, jnp.asarray(toks), jnp.asarray(lens))
        logits = np.asarray(logits)
        wall = time.perf_counter() - t0
        st = self.stats
        st.batches += 1
        st.wall_s += wall
        if len(group) > 1:
            st.batched_requests += len(group)
        # pad overhead: right-padding inside rows + power-of-two pad rows
        st.padded_tokens += int(np.sum(plen - lens[:len(group)])) \
            + (w - len(group)) * plen
        for r, (i, tokens, hashes) in enumerate(group):
            st.requests += 1
            st.total_blocks += len(hashes)
            st.total_tokens += len(tokens)
            st.computed_tokens += len(tokens)
            st.flops += self._flops_per_token * len(tokens)
            results[i] = (logits[r], caches, r)
            if hashes and self.model.supports_prefill_resume \
                    and self.cache_entries > 0:
                self._store(hashes, jax.tree.map(
                    lambda a, r=r: a[:, r:r + 1], caches))

    def _run_resume_chunk(self, start: int, group, results) -> None:
        w = self._width(len(group))
        suffixes = np.stack(
            [np.asarray(tokens[start:], np.int32) for _, tokens, _, _ in group]
            + [np.asarray(group[0][1][start:], np.int32)] * (w - len(group)))
        donors = [d for *_, d in group] + [group[0][3]] * (w - len(group))
        stacked = donors[0] if w == 1 else jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=1), *donors)
        t0 = time.perf_counter()
        logits, caches = self._resume(self.params, stacked,
                                      jnp.asarray(suffixes), jnp.int32(start))
        logits = np.asarray(logits)
        wall = time.perf_counter() - t0
        st = self.stats
        st.batches += 1
        st.wall_s += wall
        if len(group) > 1:
            st.batched_requests += len(group)
        st.padded_tokens += (w - len(group)) * suffixes.shape[1]
        for r, (i, tokens, hashes, _) in enumerate(group):
            st.requests += 1
            st.total_blocks += len(hashes)
            st.reused_blocks += start // self.block_size
            st.total_tokens += len(tokens)
            st.computed_tokens += len(tokens) - start
            st.flops += self._flops_per_token * (len(tokens) - start)
            results[i] = (logits[r], caches, r)
            if hashes:
                self._store(hashes, jax.tree.map(
                    lambda a, r=r: a[:, r:r + 1], caches))


@dataclass
class Slot:
    active: bool = False
    request_id: Optional[str] = None
    length: int = 0
    generated: List[int] = field(default_factory=list)
    max_new: int = 0


PAGED_IMPLS = ("paged", "paged_sdpa")


class DecodeEngine:
    """Fixed-slot continuous batcher around the jitted ragged decode step.

    ``decode_impl`` selects the cached-attention step: ``"pallas"``
    (default) streams the KV cache through the ragged Pallas decode kernel
    on the per-slot lengths vector (TPU-compiled, interpret mode on CPU);
    ``"sdpa"`` keeps the XLA einsum reference path — the two are pinned
    token-stream identical by ``tests/test_engine_batching.py``.

    The paged impls swap the dense per-slot ``max_len`` KV layout for a
    global page pool of ``num_pages`` KV blocks plus a per-slot page table:
    ``"paged"`` runs the Pallas paged-attention kernel (page-table-
    indirected block loads), ``"paged_sdpa"`` gathers the slot's pages into
    a dense view and reuses the XLA causal path.  Admission is then gated
    on *free pages* (:meth:`can_admit`) instead of free slots alone, the
    jitted step grows a slot's table when generation crosses a block
    boundary, and :meth:`release` returns the pages to the free list — so
    the same KV HBM budget sustains many more concurrent short/medium
    requests.  ``num_pages=None`` sizes the pool to the dense worst case
    ``num_slots * ceil(max_len / block)``, where the page gate can never
    bind and the admission stream is identical to the dense layout's."""

    def __init__(self, model: Model, params, num_slots: int, max_len: int,
                 worker_id: int = 0, resident_blocks: int = 4096,
                 decode_impl: str = "pallas",
                 num_pages: Optional[int] = None,
                 page_block: int = BLOCK_SIZE):
        if decode_impl not in ("pallas", "sdpa") + PAGED_IMPLS:
            raise ValueError(f"unknown decode_impl {decode_impl!r}")
        self.model = model
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.worker_id = worker_id
        self.decode_impl = decode_impl
        self.paged = decode_impl in PAGED_IMPLS
        self.slots = [Slot() for _ in range(num_slots)]
        self.tokens = np.zeros((num_slots, 1), np.int32)
        if self.paged:
            if not model.supports_paged_decode:
                raise ValueError(
                    f"{model.cfg.name} has non-attention mixers; paged KV "
                    "needs a pure causal-attention stack")
            self.page_block = page_block
            self.max_pages_per_slot = -(-max_len // page_block)
            if num_pages is None:
                num_pages = num_slots * self.max_pages_per_slot
            self.allocator = PageAllocator(num_pages, page_block)
            self.caches = model.paged_cache_init(num_pages, page_block)
            # page table starts one page wide and widens along the
            # power-of-two ladder as slots grow (each width is one jit
            # specialization of the decode step; warmup can pre-compile
            # the ladder).  Unmapped entries stay 0 — the trash page.
            self.page_table = np.zeros((num_slots, 1), np.int32)
            self._adopt = jax.jit(
                functools.partial(adopt_prefill_pages, block=page_block),
                donate_argnums=0)
        else:
            self.allocator = None
            self.caches = model.cache_init(num_slots, max_len)
        self._decode = jax.jit(
            functools.partial(model.decode, decode_impl=decode_impl),
            donate_argnums=1)
        # KV-block residency (the worker's G1 view): bounded LRU over the
        # block hashes this worker has admitted.  The transfer() hop is
        # charged only for blocks NOT in this set — a cache-warm routing
        # decision ships less KV.
        self.resident_cap = resident_blocks
        self._resident: "OrderedDict[int, None]" = OrderedDict()
        self.transferred_blocks = 0      # cumulative non-resident blocks

    # -------------------------------------------------------------- admit ---

    def free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if not s.active:
                return i
        return None

    def _touch_blocks(self, hashes: Sequence[int]) -> int:
        """Mark ``hashes`` resident (LRU refresh); returns the number of
        blocks that were NOT already resident — the transfer() payload."""
        new = 0
        for h in hashes:
            if h in self._resident:
                self._resident.move_to_end(h)
            else:
                self._resident[h] = None
                new += 1
        while len(self._resident) > self.resident_cap:
            self._resident.popitem(last=False)
        return new

    # ------------------------------------------------------------- paging ---

    def pages_for_request(self, prompt_len: int, max_new: int) -> int:
        """Worst-case page count of a request: prompt + every generated
        token + the admission first-token write, capped by the engine's
        ``max_len`` stop condition."""
        total = min(prompt_len + max_new + 1, self.max_len)
        return self.allocator.pages_for(total)

    def pages_for_prompt(self, prompt_len: int) -> int:
        """Pages mapped at admit time: the prompt plus one position for the
        first generated token's KV write."""
        return self.allocator.pages_for(min(prompt_len + 1, self.max_len))

    def can_admit(self, prompt_len: int, max_new: int) -> bool:
        """Admission gate: dense layouts admit on slots alone; the paged
        layout additionally requires the request's worst-case page count to
        be coverable by pages not promised to already-scheduled slots."""
        if not self.paged:
            return True
        return self.allocator.can_admit(
            self.pages_for_request(prompt_len, max_new))

    def _table_width(self, n_pages: int) -> int:
        """Page-table width holding ``n_pages``: next power of two, capped
        at the ``max_len`` worst case — keeps the jitted decode shape set
        O(log max_pages_per_slot)."""
        w = 1
        while w < n_pages:
            w *= 2
        return min(w, self.max_pages_per_slot)

    def width_ladder(self, total_tokens: Optional[int] = None) -> List[int]:
        """Every page-table width a run can emit, widest bounded by
        ``total_tokens`` (prompt + generated; None = the ``max_len`` worst
        case) — the warmup pre-compile set for the decode step."""
        top = self.max_pages_per_slot if total_tokens is None else \
            self._table_width(self.allocator.pages_for(
                min(total_tokens, self.max_len)))
        ladder, w = [], 1
        while w < top:
            ladder.append(w)
            w *= 2
        ladder.append(top)
        return ladder

    def _widen_table(self, width: int) -> None:
        if width > self.page_table.shape[1]:
            pad = width - self.page_table.shape[1]
            self.page_table = np.pad(self.page_table, ((0, 0), (0, pad)))

    def kv_bytes_held(self) -> int:
        """KV HBM bytes currently committed to requests: dense layouts
        commit every slot's full ``max_len`` rows up front; the paged pool
        commits only mapped pages."""
        if self.paged:
            tokens = self.allocator.used_pages * self.page_block
        else:
            tokens = self.num_slots * self.max_len
        return tokens * kv_token_bytes(self.model)

    def pool_utilization(self) -> float:
        """Fraction of the page pool currently mapped to live slots
        (dense layouts are always fully committed)."""
        if not self.paged:
            return 1.0
        return self.allocator.used_pages / max(1, self.allocator.num_pages)

    # -------------------------------------------------------------- admit ---

    def reserve(self, slot: int, request_id: str,
                prompt_len: Optional[int] = None,
                max_new: int = 0) -> None:
        """Claim ``slot`` for ``request_id`` before its (batched) prefill
        has produced a cache bundle, so a scheduler placing several
        requests in one tick sees consistent ``free_slot`` accounting.
        A reserved-but-unadmitted slot holds no cache state: :meth:`step`
        skips it until :meth:`admit` lands (or :meth:`release` frees
        it).

        On a paged engine, passing ``prompt_len`` also reserves the
        request's worst-case page count, so several reservations in one
        scheduling tick cannot double-count the same free pages (gate with
        :meth:`can_admit` first)."""
        s = self.slots[slot]
        assert not s.active, (slot, s.request_id)
        if self.paged and prompt_len is not None:
            ok = self.allocator.reserve(
                slot, self.pages_for_request(prompt_len, max_new))
            assert ok, (slot, "reserve() without a can_admit() gate")
        s.active = True
        s.request_id = request_id

    def admit(self, slot: int, request_id: str, prefill_caches,
              first_token: int, prompt_len: int, max_new: int,
              hashes: Sequence[int] = (), src_row: int = 0) -> int:
        """Transfer a prefill cache bundle into ``slot`` (the NIXL hop).

        ``src_row`` selects the bundle's batch row (batched prefill hands
        every request of a group the same shared bundle).

        Returns the number of *non-resident* blocks the transfer had to
        move — the per-block charge of the prefill→decode hop.  Blocks
        already resident (an earlier request of the same template landed
        here) ride for free; that asymmetry is the cache-affinity
        externality on the real path.

        Paged engines map the prompt's pages from the free list (plus one
        position for the first token's KV write) and scatter the prefill
        KV into them at block granularity; the rest of the request's
        worst case stays reserved for mid-generation :meth:`step` growth.
        Callers that skipped :meth:`reserve` must gate on
        :meth:`can_admit` — an ungated paged admit raises."""
        if self.paged:
            n_map = self.pages_for_prompt(prompt_len)
            pages = self.allocator.admit(
                slot, n_map, self.pages_for_request(prompt_len, max_new))
            if pages is None:
                raise RuntimeError(
                    f"page pool exhausted admitting {request_id!r} to slot "
                    f"{slot}: gate admission on can_admit()")
            self._widen_table(self._table_width(len(pages)))
            self.page_table[slot, :] = 0
            self.page_table[slot, :len(pages)] = pages
            row = jax.tree.map(
                lambda a: jax.lax.slice_in_dim(a, src_row, src_row + 1,
                                               axis=1), prefill_caches)
            self.caches = self._adopt(self.caches, row,
                                      jnp.asarray(pages, jnp.int32))
        else:
            self.caches = _insert_cache(self.caches, prefill_caches, slot,
                                        self.model, src_row=src_row)
        s = self.slots[slot]
        s.active = True
        s.request_id = request_id
        s.length = prompt_len
        s.generated = [int(first_token)]
        s.max_new = max_new
        self.tokens[slot, 0] = first_token
        moved = self._touch_blocks(hashes)
        self.transferred_blocks += moved
        return moved

    def release(self, slot: int):
        if self.paged:
            self.allocator.release(slot)
            self.page_table[slot, :] = 0
        self.slots[slot] = Slot()
        self.tokens[slot, 0] = 0

    @property
    def active_count(self) -> int:
        return sum(s.active for s in self.slots)

    def warmup(self, table_widths: Optional[Sequence[int]] = None) -> None:
        """Pre-compile the jitted decode step (slots all inactive; whatever
        the pass writes is fully overwritten on the next ``admit``).

        On a paged engine, ``table_widths`` lists the page-table widths to
        pre-compile (each width is its own decode-step shape — the
        page-growth recompile points; see :meth:`width_ladder`).  The live
        table keeps its current width; pre-compiled shapes are hit when
        growth widens it later."""
        lengths = jnp.zeros((self.num_slots,), jnp.int32)
        if not self.paged:
            _, self.caches = self._decode(self.params, self.caches,
                                          jnp.asarray(self.tokens), lengths)
            return
        widths = sorted({int(w) for w in (table_widths or ())}
                        | {self.page_table.shape[1]})
        for w in widths:
            table = jnp.zeros((self.num_slots, w), jnp.int32)
            _, self.caches = self._decode(self.params, self.caches,
                                          jnp.asarray(self.tokens), lengths,
                                          page_table=table)

    # --------------------------------------------------------------- step ---

    def step(self) -> List[Tuple[str, int, bool]]:
        """One batched decode tick. Returns [(request_id, token, done)].

        Returned-slot contract: when ``done`` is True the slot has already
        been released inside this step — it is free for admission in the
        same tick, and callers must NOT call :meth:`release` again."""
        if not any(s.active and s.generated for s in self.slots):
            return []
        # reserved-but-unadmitted slots (active, no first token yet) carry
        # no valid cache state: they decode as length-0 rows and their
        # output is skipped below
        lengths = jnp.asarray([s.length if s.active else 0
                               for s in self.slots], jnp.int32)
        if self.paged:
            # growth pre-pass: this tick writes each admitted slot's KV at
            # position s.length — if that crosses into an unmapped block,
            # map one page from the slot's reservation (and widen the
            # table to the next ladder width when the row is full).
            for i, s in enumerate(self.slots):
                if not s.active or not s.generated:
                    continue
                j = s.length // self.page_block
                if j >= len(self.allocator.owned[i]):
                    page = self.allocator.grow(i)
                    self._widen_table(self._table_width(j + 1))
                    self.page_table[i, j] = page
            logits, self.caches = self._decode(
                self.params, self.caches, jnp.asarray(self.tokens), lengths,
                page_table=jnp.asarray(self.page_table))
        else:
            logits, self.caches = self._decode(
                self.params, self.caches, jnp.asarray(self.tokens), lengths)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        out = []
        for i, s in enumerate(self.slots):
            if not s.active or not s.generated:
                continue
            tok = int(nxt[i])
            s.generated.append(tok)
            s.length += 1
            self.tokens[i, 0] = tok
            done = (len(s.generated) >= s.max_new + 1
                    or s.length >= self.max_len - 1)
            out.append((s.request_id, tok, done))
            if done:
                self.release(i)   # slot is re-admittable this same tick
        return out


def kv_token_bytes(model: Model) -> int:
    """KV HBM bytes per cached token position (all layers, K and V)."""
    cfg = model.cfg
    n_attn = sum(d.mixer == "attn" for d in model.descs) * model.n_periods
    itemsize = jnp.dtype(jnp.bfloat16).itemsize
    return 2 * n_attn * cfg.num_kv_heads * cfg.resolved_head_dim * itemsize


def adopt_prefill_pages(pool, row_bundle, page_ids, *, block: int):
    """Scatter one prefill cache row into freshly mapped pool pages.

    ``pool``: paged cache pytree (leaves ``(P, N, block, K, hd)``);
    ``row_bundle``: a single-row prefill bundle (leaves ``(P, 1, S, K, hd)``
    — callers slice ``src_row`` out first so the jit specializes on the
    page count, not the prefill batch width); ``page_ids``: (n,) int32
    destination pages.  The row's first ``n * block`` positions land in the
    pages in order (right-padded with zeros when the prefill sequence axis
    is shorter; positions past the prompt are masked by length and
    overwritten by decode before any query reaches them)."""
    n = page_ids.shape[0]
    def leaf(d, s):
        src = s[:, 0]                                     # (P, S, ...)
        need = n * block
        if src.shape[1] < need:
            pads = [(0, 0), (0, need - src.shape[1])]
            pads += [(0, 0)] * (src.ndim - 2)
            src = jnp.pad(src, pads)
        blocks = src[:, :need].reshape(
            (src.shape[0], n, block) + src.shape[2:])
        return d.at[:, page_ids].set(blocks.astype(d.dtype))
    return jax.tree.map(leaf, pool, row_bundle)


def _insert_cache(dst, src, slot: int, model: Model, src_row: int = 0):
    """Write row ``src_row`` of a prefill cache bundle into decode slot
    ``slot`` (batched prefill emits multi-row bundles; the sequential path
    keeps row 0).

    Cross-mesh in production: each leaf is device_put to the decode mesh's
    sharding before insertion.
    """
    def leaf(d, s):
        # d: (P, B, ...); s: (P, W, ...) — prefill cache may have a shorter
        # sequence axis than the decode cache; pad on the right.
        if s.shape[2:] != d.shape[2:]:
            pads = [(0, 0), (0, 0)]
            for ds, ss in zip(d.shape[2:], s.shape[2:]):
                pads.append((0, ds - ss))
            s = jnp.pad(s, pads)
        return d.at[:, slot].set(s[:, src_row].astype(d.dtype))
    return jax.tree.map(leaf, dst, src)
