"""Deterministic synthetic data pipeline.

Token streams are generated per (seed, step, host-shard) with a counter-mode
PRNG so every host materializes exactly its slice of the global batch —
restart-safe (the stream is a pure function of the step) and elastic-safe
(resharding only changes which slices a host draws).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # structured synthetic text: Zipf unigrams + short-range copy structure so
    # the LM loss has signal to descend (pure-uniform tokens are unlearnable)
    zipf_a: float = 1.2
    copy_period: int = 7


def _host_slice(global_batch: int, host_id: int, num_hosts: int):
    per = global_batch // num_hosts
    return host_id * per, per


def make_batch(cfg: DataConfig, step: int, host_id: int = 0,
               num_hosts: int = 1) -> dict:
    start, per = _host_slice(cfg.global_batch, host_id, num_hosts)
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, start]))
    ranks = rng.zipf(cfg.zipf_a, size=(per, cfg.seq_len)).astype(np.int64)
    tokens = (ranks % (cfg.vocab_size - 1)) + 1
    # inject copy structure: token[t] = token[t - period] for a random subset
    mask = rng.random((per, cfg.seq_len)) < 0.5
    mask[:, :cfg.copy_period] = False
    shifted = np.roll(tokens, cfg.copy_period, axis=1)
    tokens = np.where(mask, shifted, tokens)
    return {"tokens": jnp.asarray(tokens, jnp.int32)}


def batch_iterator(cfg: DataConfig, start_step: int = 0,
                   host_id: int = 0, num_hosts: int = 1) -> Iterator[dict]:
    step = start_step
    while True:
        yield make_batch(cfg, step, host_id, num_hosts)
        step += 1


def batch_for_model(model_cfg: ModelConfig, shape: ShapeConfig, step: int,
                    seed: int = 0) -> dict:
    """Full model-input batch (including frontend stubs) for a train step."""
    dc = DataConfig(vocab_size=model_cfg.vocab_size, seq_len=shape.seq_len,
                    global_batch=shape.global_batch, seed=seed)
    if model_cfg.family == "vlm":
        dc = DataConfig(vocab_size=model_cfg.vocab_size,
                        seq_len=shape.seq_len - model_cfg.num_patches,
                        global_batch=shape.global_batch, seed=seed)
    batch = make_batch(dc, step)
    rng = np.random.default_rng(np.random.SeedSequence([seed, step, 777]))
    if model_cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(shape.global_batch, model_cfg.num_patches,
                             model_cfg.frontend_dim)), jnp.bfloat16)
    if model_cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(shape.global_batch, shape.seq_len,
                             model_cfg.frontend_dim)), jnp.bfloat16)
    return batch
