"""Saturation detector (Section 6.2).

EWMA of TTFT P99 (Eq. 10):  L̄(t) = α·L(t) + (1−α)·L̄(t−Δ),  α = 0.3,
polled every Δ = 5 s.  Regime classification (Eq. 11) with k-consecutive
hysteresis:

    BELOW       L̄ < θ1
    TRANSITION  θ1 ≤ L̄ < θ2
    SATURATED   L̄ ≥ θ2

Model-specific thresholds (paper §6.2): 70B θ1=0.3 s, θ2=2 s; 340B θ1=1.0 s,
θ2=10 s — recommended as 3–5× the model's baseline TTFT P99.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import List, Optional, Tuple


class Regime(enum.IntEnum):
    BELOW = 0
    TRANSITION = 1
    SATURATED = 2


@dataclass
class DetectorConfig:
    theta1: float = 0.3          # seconds
    theta2: float = 2.0
    alpha: float = 0.3           # EWMA responsiveness
    poll_interval: float = 5.0
    hysteresis_k: int = 2        # consecutive samples to switch regime
    epsilon: float = 0.05        # downward hysteresis margin on θ1

    @classmethod
    def for_model(cls, name: str) -> "DetectorConfig":
        if "340b" in name.lower() or "nemotron" in name.lower():
            return cls(theta1=1.0, theta2=10.0)
        return cls(theta1=0.3, theta2=2.0)

    @classmethod
    def from_baseline_ttft(cls, baseline_p99: float) -> "DetectorConfig":
        """θ1 as ~4× baseline TTFT P99 (paper recommendation), θ2 = 10×θ1."""
        t1 = 4.0 * baseline_p99
        return cls(theta1=t1, theta2=10.0 * t1)


@dataclass
class SaturationDetector:
    config: DetectorConfig = field(default_factory=DetectorConfig)
    ewma: Optional[float] = None
    regime: Regime = Regime.BELOW
    _pending: Optional[Regime] = None
    _pending_count: int = 0
    history: List[Tuple[float, float, int]] = field(default_factory=list)
    transitions: List[Tuple[float, int, int]] = field(default_factory=list)

    def observe(self, ttft_p99: float, now: float) -> Regime:
        """Feed one polled TTFT P99 sample; returns the (possibly new) regime."""
        c = self.config
        if self.ewma is None:
            self.ewma = float(ttft_p99)
        else:
            self.ewma = c.alpha * float(ttft_p99) + (1 - c.alpha) * self.ewma
        raw = self._classify(self.ewma)
        if raw != self.regime:
            if self._pending == raw:
                self._pending_count += 1
            else:
                self._pending = raw
                self._pending_count = 1
            if self._pending_count >= c.hysteresis_k:
                self.transitions.append((now, int(self.regime), int(raw)))
                self.regime = raw
                self._pending = None
                self._pending_count = 0
        else:
            self._pending = None
            self._pending_count = 0
        self.history.append((now, self.ewma, int(self.regime)))
        return self.regime

    def _classify(self, l: float) -> Regime:
        c = self.config
        # downward transitions require dropping ε below the threshold
        if self.regime >= Regime.TRANSITION:
            if l < c.theta1 - c.epsilon:
                return Regime.BELOW
            if l < c.theta2 - c.epsilon and self.regime == Regime.SATURATED:
                return Regime.TRANSITION
            if l >= c.theta2:
                return Regime.SATURATED
            return self.regime
        if l >= c.theta2:
            return Regime.SATURATED
        if l >= c.theta1:
            return Regime.TRANSITION
        return Regime.BELOW
