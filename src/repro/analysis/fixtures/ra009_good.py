"""RA009 good: every timestamp derives from the simulated event clock."""


def on_poll(sim, now):
    sim.poll_log.append(now)


def settle(sim, now, delay):
    return now + delay                   # event time arithmetic only
