"""Empirical Price-of-Anarchy estimator (Section 6.4, Eq. 12).

    PoA(t) = Σ_{q ∈ W(t)} L_q^actual  /  OPT(W(t))

OPT is a hindsight-optimal assignment of the windowed requests to workers,
computed with the Hungarian algorithm on a *frozen-latency* cost matrix
(paper parameters a=0.005, b=0.020, d=0.010, β=2, C_j=64, w_c=0.015 — an
uncalibrated relative-efficiency index, NOT an absolute efficiency ratio).
Because routing is many-to-one, each worker column is replicated up to its
capacity so the one-to-one optimal assignment lower-bounds the many-to-one
optimum.  The index can fall below 1 when the greedy router exploits KV
overlap the frozen matrix approximates imperfectly (paper §9.2 fn. 2).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional, Sequence

import numpy as np

from repro.core.latency import POA_FROZEN, POA_CACHE_WEIGHT, LatencyParams
from repro.core.planner import social_optimum, variational_equilibrium


def hungarian(cost: np.ndarray) -> np.ndarray:
    """Minimum-cost one-to-one assignment; returns col index per row.

    Uses scipy's C implementation when available; falls back to the pure
    JV-style implementation below (each validated against the other and
    against brute force in tests). Rectangular (rows ≤ cols) supported.
    """
    try:
        from scipy.optimize import linear_sum_assignment
        rows, cols = linear_sum_assignment(np.asarray(cost, dtype=np.float64))
        out = np.zeros(cost.shape[0], dtype=np.int64)
        out[rows] = cols
        return out
    except ImportError:
        return hungarian_jv(cost)


def hungarian_jv(cost: np.ndarray) -> np.ndarray:
    """Pure-numpy Jonker–Volgenant shortest augmenting path, O(n³)."""
    cost = np.asarray(cost, dtype=np.float64)
    n, m = cost.shape
    assert n <= m, "need rows <= cols"
    INF = np.inf
    u = np.zeros(n + 1)
    v = np.zeros(m + 1)
    p = np.zeros(m + 1, dtype=np.int64)      # p[j] = row assigned to col j (1-based)
    way = np.zeros(m + 1, dtype=np.int64)
    for i in range(1, n + 1):
        p[0] = i
        j0 = 0
        minv = np.full(m + 1, INF)
        used = np.zeros(m + 1, dtype=bool)
        while True:
            used[j0] = True
            i0 = p[j0]
            delta = INF
            j1 = -1
            cur = cost[i0 - 1, :] - u[i0] - v[1:]
            for j in range(1, m + 1):
                if used[j]:
                    continue
                c = cur[j - 1]
                if c < minv[j]:
                    minv[j] = c
                    way[j] = j0
                if minv[j] < delta:
                    delta = minv[j]
                    j1 = j
            for j in range(m + 1):
                if used[j]:
                    u[p[j]] += delta
                    v[j] -= delta
                else:
                    minv[j] -= delta
            j0 = j1
            if p[j0] == 0:
                break
        while j0:
            j1 = way[j0]
            p[j0] = p[j1]
            j0 = j1
    ans = np.zeros(n, dtype=np.int64)
    for j in range(1, m + 1):
        if p[j] > 0:
            ans[p[j] - 1] = j - 1
    return ans


@dataclass
class CompletedRequest:
    request_id: str
    worker: int
    latency: float               # observed end-to-end latency L_q^actual (s)
    overlap: Sequence[float]     # KV overlap score per worker at routing time
    finish_time: float
    loads: Sequence[float] = ()  # per-worker decode load observed at routing
    # fourth game (both 0.0 when no fabric is attached): realized fabric
    # transfer service incl. link queueing, and the uncongested transfer
    # time of the social optimum's link assignment
    transfer_wait: float = 0.0
    transfer_floor: float = 0.0


@dataclass
class PoATracker:
    """Sliding-window PoA estimator over completed requests.

    The window is bounded both in time (``window_s``) and count
    (``window_count``) — the count bound is what makes the below-saturation
    plateau flat: the frozen OPT always prices the same number of windowed
    requests regardless of arrival rate.

    ``dedup`` enables the large-pool OPT fast path: identical replicated
    worker columns collapse into capacitated columns before the Hungarian
    solve (see :meth:`opt_cost`); the dense legacy matrix is kept behind
    ``dedup=False`` and pinned equal in tests.
    """
    num_workers: int
    window_s: float = 30.0
    window_count: int = 128
    capacity: int = 64                  # C_j column replication per worker
    params: LatencyParams = POA_FROZEN
    cache_weight: float = POA_CACHE_WEIGHT
    capacities: Sequence[float] = ()    # per-worker relative capacity (hetero)
    dedup: bool = True                  # collapse identical OPT columns
    _window: Deque[CompletedRequest] = field(default_factory=deque)
    _last: float = float("nan")

    def _capacity_shares(self) -> Optional[np.ndarray]:
        """Per-worker share of total decode capacity, or None when the pool
        is homogeneous (legacy uniform path, bit-exact with the seed)."""
        if not self.capacities or len(set(self.capacities)) <= 1:
            return None
        caps = np.asarray(self.capacities, dtype=np.float64)
        return caps / caps.sum()

    def record(self, req: CompletedRequest):
        self._window.append(req)
        while len(self._window) > self.window_count:
            self._window.popleft()
        while self._window and (self._window[0].finish_time
                                < req.finish_time - self.window_s):
            self._window.popleft()

    def opt_cost(self, reqs: List[CompletedRequest]) -> float:
        """Hungarian OPT on the frozen cost matrix with capacity-replicated
        worker columns.  Per the paper (§6.4) the matrix freezes latencies
        from the observed allocation, ignoring how redistribution would
        change loads: every worker column carries the Eq. 9 latency at the
        window's balanced per-worker load n̄ = |W|/m, minus the cache-overlap
        credit w_c·o_ij.  OPT therefore lower-bounds the attainable optimum
        (the paper's 'PoA is an upper bound' argument).

        Large-pool path (``dedup=True``): workers whose frozen cost column
        is identical over the whole window — the common case, since most
        workers have zero overlap with most requests and equal balanced
        load — collapse into ONE capacitated column replicated
        min(group capacity, n) times.  The capacitated problem has the
        same optimum as the dense matrix (an assignment never uses more
        than n replicas of interchangeable columns), so both the scipy
        path and the JV fallback solve a matrix whose width scales with
        the number of *distinct* columns instead of workers × capacity."""
        n = len(reqs)
        if n == 0:
            return 0.0
        cap = max(1, min(self.capacity, n))
        w = self.num_workers
        from repro.core.latency import latency
        shares = self._capacity_shares()
        if shares is None:
            # homogeneous: every column carries the Eq. 9 latency at the
            # uniform balanced load n̄ = |W|/m
            base_w = np.full(w, float(latency(np.asarray(n / w), self.params)))
            reps = np.full(w, cap, dtype=np.int64)
        else:
            # heterogeneous: the counterfactual balanced load of worker j is
            # capacity-proportional, n̄_j = |W|·C_j/ΣC, and its column count
            # scales with its share of the replication budget.  A worker with
            # zero capacity (a pool slot currently serving prefill under the
            # Game 1 Planner) contributes no columns at all: the routing
            # counterfactual may only redistribute over live decode workers.
            base_w = np.asarray([float(latency(np.asarray(n * s), self.params))
                                 for s in shares])
            reps = np.round(shares * w * cap).astype(np.int64)
            reps[shares > 0] = np.maximum(1, reps[shares > 0])
        cols = int(reps.sum())
        ov = np.zeros((n, w))
        for i, rq in enumerate(reqs):
            o = np.asarray(rq.overlap, dtype=np.float64)
            if o.shape[0] == w:
                ov[i] = o
        per_w = base_w[None, :] - self.cache_weight * ov   # (n, w)
        floors = np.asarray([rq.transfer_floor for rq in reqs],
                            dtype=np.float64)
        if floors.any():
            # fourth game: even OPT must move each request's non-resident
            # KV once, over uncongested links — a per-request constant
            # added to every column (prices the wire without perturbing
            # the assignment).  Skipped entirely when no fabric ran, so
            # fabric=None stays bit-exact.
            per_w = per_w + floors[:, None]
        scale = 1.0
        if n > cols:
            # truncation: price only the first `cols` requests one-to-one,
            # then scale the per-request optimum back up to the window
            per_w = per_w[:cols]
            scale = n / cols
            n = cols
        if self.dedup:
            # group workers by their exact column bytes (no sort needed;
            # insertion order keeps the solve deterministic)
            cols_t = np.ascontiguousarray(per_w.T)
            groups: dict = {}
            for j in range(cols_t.shape[0]):
                groups.setdefault(cols_t[j].tobytes(), []).append(j)
            first = [g[0] for g in groups.values()]
            group_reps = np.minimum(
                np.asarray([int(reps[g].sum()) for g in groups.values()],
                           dtype=np.int64), n)
            cost = np.repeat(per_w[:, first], group_reps, axis=1)
        else:
            cost = np.repeat(per_w, reps, axis=1)          # (n, cols) dense
        idx = hungarian(cost)
        return float(cost[np.arange(n), idx].sum() * scale)

    def window_size(self, now: Optional[float] = None) -> int:
        reqs = list(self._window)
        if now is not None:
            reqs = [r for r in reqs if r.finish_time >= now - self.window_s]
        return len(reqs)

    def resource_game(self, model, prefill_workers: int, total: int) -> dict:
        """Game 1 counterfactual (Section 9.2): the realized P/D split
        against the Prop. 1 variational equilibrium and Remark 1 social
        optimum of the profiled response curves.

        ``model`` is a :class:`repro.core.planner.ResponseModel` (or any
        object exposing ``v_ttft(gp)`` / ``v_itl(gd)``).  The resource-game
        PoA-hat is the social cost V_TTFT(G_P) + V_ITL(G−G_P) at the
        realized split divided by the cost at the social optimum — 1.0 when
        the Planner's best-response dynamic has landed on the coordinated
        split, rising when selfish pool objectives leave workers
        mis-assigned."""
        ve = variational_equilibrium(model.v_ttft, model.v_itl, total)
        so = social_optimum(model.v_ttft, lambda gd, gp: model.v_itl(gd),
                            total)
        cost = lambda gp: model.v_ttft(gp) + model.v_itl(total - gp)
        c_re, c_so = cost(prefill_workers), cost(so)
        # Additive floor at the Planner's dead-band scale: when the whole
        # curve is sub-violation-rate noise (an idle diurnal trough), the
        # raw ratio of two negligible costs would explode while nothing is
        # actually mis-allocated — smoothed, it reads ≈ 1.
        floor = 1e-4
        poa = (c_re + floor) / (c_so + floor)
        return {"gp": prefill_workers, "gd": total - prefill_workers,
                "ve_gp": ve, "so_gp": so, "poa_resource": poa}

    def network_game(self, now: Optional[float] = None) -> dict:
        """Fourth-game counterfactual: realized transfer wait (fabric
        service incl. shared-link queueing) over the window, against the
        social optimum's link assignment — every transfer priced at its
        uncongested path time (``transfer_floor``).  The ratio is the
        network PoA-hat: 1.0 when no transfer ever queued behind another,
        rising as cache-affinity herding serializes transfers on shared
        NICs.  Floored like :meth:`resource_game`: an idle window with
        negligible wire time reads ≈ 1, not 0/0."""
        reqs = list(self._window)
        if now is not None:
            reqs = [r for r in reqs if r.finish_time >= now - self.window_s]
        wait = sum(r.transfer_wait for r in reqs)
        opt = sum(r.transfer_floor for r in reqs)
        floor = 1e-4
        return {"transfer_wait": wait, "transfer_opt": opt,
                "poa_network": (wait + floor) / (opt + floor),
                "n": len(reqs)}

    def current_poa(self, now: Optional[float] = None) -> float:
        reqs = list(self._window)
        if now is not None:
            reqs = [r for r in reqs if r.finish_time >= now - self.window_s]
        if not reqs:
            return float("nan")
        actual = sum(r.latency for r in reqs)
        opt = self.opt_cost(reqs)
        if opt <= 0:
            return float("nan")
        self._last = actual / opt
        return self._last
