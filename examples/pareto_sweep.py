"""Mini Experiment 4: 4×4 (τ, ω) Pareto sweep at and below saturation.

Reproduces the paper's central 'flatness' finding: router parameters do not
measurably move the PoA below saturation, and start to matter at the knee.

    PYTHONPATH=src python examples/pareto_sweep.py
"""
import numpy as np

from repro.core.router import KvRouterConfig
from repro.serving.scenarios import build_simulator

TAUS = [0.0, 0.3, 0.7, 1.0]
OMEGAS = [0.0, 0.3, 0.7, 1.0]


def sweep(concurrency: int):
    grid = np.zeros((len(TAUS), len(OMEGAS)))
    for i, tau in enumerate(TAUS):
        for j, om in enumerate(OMEGAS):
            sim = build_simulator(
                "70b-1p2d-ramp", concurrency=concurrency, hold_s=60.0,
                router_config=KvRouterConfig(temperature=tau,
                                             overlap_weight=om))
            grid[i, j] = sim.run().overall().poa
    return grid


def show(title, grid):
    print(f"\n{title}")
    print("tau\\omega " + "".join(f"{o:>8}" for o in OMEGAS))
    for i, tau in enumerate(TAUS):
        print(f"{tau:>8} " + "".join(f"{grid[i, j]:>8.2f}"
                                     for j in range(len(OMEGAS))))
    print(f"spread: {grid.max() / grid.min():.2f}x  std: {grid.std():.2f}")


def main():
    below = sweep(64)
    show("PoA at C=64 (below saturation) — expect flat", below)
    at = sweep(128)
    show("PoA at C=128 (saturation knee) — structure emerges", at)
    print(f"\nvariance growth across the knee: "
          f"{at.std() / max(below.std(), 1e-9):.1f}x "
          f"(paper: ~37-58x on the real cluster)")


if __name__ == "__main__":
    main()
