"""Smart Router semantics (Eq. 1/2) + static baselines."""
import collections


from repro.core.router import (KvPushRouter, KvRouterConfig, PowerOfTwoRouter,
                               RandomRouter, RoundRobinRouter)

TOKENS_A = list(range(64))
TOKENS_B = list(range(1000, 1064))


def test_argmin_at_tau_zero():
    r = KvPushRouter(3, KvRouterConfig(temperature=0.0, overlap_weight=1.0))
    r.workers[0].active_blocks = 5
    r.workers[1].active_blocks = 1
    r.workers[2].active_blocks = 9
    w, _, _ = r.best_worker(TOKENS_A)
    assert w == 1


def test_cache_affinity_beats_small_load_gap():
    r = KvPushRouter(2, KvRouterConfig(temperature=0.0, overlap_weight=1.0))
    r.on_schedule(0, TOKENS_A)           # worker 0 warm for A
    r.workers[0].active_blocks = 5       # slightly busier
    r.workers[1].active_blocks = 0
    w, ov, _ = r.best_worker(TOKENS_A)
    assert w == 0 and ov == 1.0          # ω·saved(20) > load gap(5)


def test_omega_zero_disables_affinity():
    r = KvPushRouter(2, KvRouterConfig(temperature=0.0, overlap_weight=0.0))
    r.on_schedule(0, TOKENS_A)
    r.workers[0].active_blocks = 5
    r.workers[1].active_blocks = 0
    w, _, _ = r.best_worker(TOKENS_A)
    assert w == 1                        # pure congestion game


def test_high_temperature_spreads():
    r = KvPushRouter(2, KvRouterConfig(temperature=50.0))
    r.workers[0].active_blocks = 0
    r.workers[1].active_blocks = 10
    counts = collections.Counter(r.best_worker(TOKENS_A)[0]
                                 for _ in range(400))
    assert counts[0] > 100 and counts[1] > 100  # near-uniform


def test_temperature_zero_vs_positive_distribution():
    cfgs = KvRouterConfig(temperature=0.7)
    r = KvPushRouter(2, cfgs)
    r.workers[0].active_blocks = 0
    r.workers[1].active_blocks = 10
    counts = collections.Counter(r.best_worker(TOKENS_A)[0]
                                 for _ in range(400))
    assert counts[0] > counts[1] > 20    # biased but stochastic


def test_unhealthy_workers_excluded():
    r = KvPushRouter(3)
    r.set_health(0, False)
    seen = {r.best_worker(TOKENS_A)[0] for _ in range(20)}
    assert 0 not in seen


def test_router_config_override_per_request():
    r = KvPushRouter(2, KvRouterConfig(temperature=0.0, overlap_weight=1.0))
    r.on_schedule(0, TOKENS_A)
    r.workers[0].active_blocks = 5
    w_default, _, _ = r.best_worker(TOKENS_A)
    w_override, _, _ = r.best_worker(
        TOKENS_A, router_config_override=KvRouterConfig(overlap_weight=0.0))
    assert w_default == 0 and w_override == 1


def test_round_robin_cycles():
    rr = RoundRobinRouter(3)
    assert [rr.best_worker(TOKENS_A)[0] for _ in range(6)] == [0, 1, 2, 0, 1, 2]


def test_baselines_respect_worker_health():
    """RoundRobin/Random must skip unhealthy workers like every other
    policy (they share the KvPushRouter's worker table when built
    from one)."""
    r = KvPushRouter(3)
    rr = RoundRobinRouter(r)
    rnd = RandomRouter(r, seed=1)
    r.set_health(1, False)
    assert [rr.best_worker(TOKENS_A)[0] for _ in range(4)] == [0, 2, 0, 2]
    assert 1 not in {rnd.best_worker(TOKENS_A)[0] for _ in range(50)}
    # standalone baselines manage their own health table
    solo = RoundRobinRouter(3)
    solo.set_health(0, False)
    assert [solo.best_worker(TOKENS_A)[0] for _ in range(4)] == [1, 2, 1, 2]


def test_baselines_share_unified_signature():
    """Every policy accepts best_worker(tokens, router_config_override,
    now) so routing policies are drop-in interchangeable."""
    r = KvPushRouter(2)
    cfg = KvRouterConfig(overlap_weight=0.0)
    for policy in (r, RoundRobinRouter(r), RandomRouter(r, seed=0),
                   PowerOfTwoRouter(r, seed=0)):
        w, ov, overlaps = policy.best_worker(
            TOKENS_A, router_config_override=cfg, now=1.5)
        assert w in (0, 1)
        assert 0.0 <= ov <= 1.0
        assert len(overlaps) == 2


def test_power_of_two_prefers_less_loaded():
    r = KvPushRouter(4)
    for w in range(4):
        r.workers[w].active_blocks = w * 10
    p2c = PowerOfTwoRouter(r, seed=0)
    picks = [p2c.best_worker(TOKENS_A)[0] for _ in range(200)]
    # worker 3 (most loaded) should almost never win
    assert collections.Counter(picks)[3] < 10


def test_on_complete_never_negative():
    r = KvPushRouter(1)
    r.on_complete(0, TOKENS_A)
    assert r.workers[0].active_blocks == 0.0
