"""Pure-jnp oracle for paged decode attention: gather pages to a dense
per-slot view, then run the dense masked-softmax decode oracle."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.decode_attention.ref import decode_attention_ref


def gather_pages(pool, page_table):
    """pool: (N, block, K, hd); page_table: (B, W) int32.  Returns the dense
    per-slot view (B, W*block, K, hd) — positions past a slot's length hold
    whatever the referenced pages hold (callers mask by length)."""
    n, block = pool.shape[0], pool.shape[1]
    table = jnp.clip(page_table.astype(jnp.int32), 0, n - 1)
    b, w = table.shape
    return pool[table].reshape(b, w * block, *pool.shape[2:])


def paged_attention_ref(q, k_pool, v_pool, page_table, lengths):
    """q: (B,H,hd); k_pool, v_pool: (N, block, K, hd); page_table: (B, W);
    lengths: (B,).  Returns (B,H,hd); rows with ``length == 0`` return
    zeros, matching the Pallas kernel's empty-softmax convention."""
    block = k_pool.shape[1]
    w = page_table.shape[1]
    k = gather_pages(k_pool, page_table)
    v = gather_pages(v_pool, page_table)
    lengths = jnp.minimum(lengths.astype(jnp.int32), w * block)
    return decode_attention_ref(q, k, v, lengths)
