"""Sanitizer overhead benchmark.

Measures, per scenario, the end-to-end wall time of an un-instrumented
run against the identical run under ``sanitize=True`` (full coherence
sweeps at every sync/poll boundary plus the per-event guards), asserting
bit-exact outputs along the way — the overhead numbers are only honest if
both runs did exactly the same work.

Also pins the default-off contract: constructing an unsanitized simulator
attaches nothing (no wrapped handlers in the instance dict), so the
sanitizer's cost when disabled is exactly zero per event.

Output: CSV rows on stdout + ``reports/benchmarks/BENCH_sanitizer.json``.

    PYTHONPATH=src python -m benchmarks.bench_sanitizer [--smoke]
    PYTHONPATH=src python -m benchmarks.bench_sanitizer --scenarios scale-64
"""
from __future__ import annotations

import argparse
import os
import time

from benchmarks.common import emit, save_json
from repro.serving.scenarios import build_simulator, list_scenarios

DEFAULT_SCENARIOS = ("scale-64", "70b-1p2d-ramp", "cache-pressure-70b")


def _fingerprint(res):
    return (tuple((r.rid, r.decode_worker, r.finish_t) for r in res.completed),
            repr(res.overall()))


def _wall(name: str, fast: bool, sanitize: bool, repeats: int) -> tuple:
    best, fp = float("inf"), None
    for _ in range(repeats):
        sim = build_simulator(name, seed=0, fast=fast, sanitize=sanitize)
        t0 = time.perf_counter()
        res = sim.run()
        best = min(best, time.perf_counter() - t0)
        fp = _fingerprint(res)
    return best, fp


def bench_scenario(name: str, fast: bool, repeats: int) -> dict:
    base_s, base_fp = _wall(name, fast, sanitize=False, repeats=repeats)
    san_s, san_fp = _wall(name, fast, sanitize=True, repeats=repeats)
    assert base_fp == san_fp, f"{name}: sanitized run diverged"
    ratio = san_s / base_s if base_s > 0 else float("inf")
    emit(f"sanitizer_wall_{name}", san_s * 1e6,
         f"{ratio:.2f}x_of_{base_s * 1e6:.0f}us_base")
    return {"scenario": name, "fast": fast, "base_s": base_s,
            "sanitized_s": san_s, "ratio": ratio}


def bench_default_off(name: str = "scale-64") -> dict:
    """The zero-cost-when-off proof: nothing is attached, so the hot path
    is byte-for-byte the uninstrumented one (same bound methods).  The
    REPRO_SANITIZE env var is held aside so this probes the *default*
    path even inside the CI sanitizer lane."""
    saved = os.environ.pop("REPRO_SANITIZE", None)
    try:
        sim = build_simulator(name, seed=0, fast=True)
    finally:
        if saved is not None:
            os.environ["REPRO_SANITIZE"] = saved
    wrapped = [a for a in ("_route", "_admit_decode", "_on_decode_done",
                           "_on_sync", "_on_poll", "_new_kvbm")
               if a in vars(sim)]
    assert not wrapped and sim.sanitizer is None
    emit("sanitizer_default_off_attachments", 0.0, "zero_wrapped_handlers")
    return {"wrapped_handlers": wrapped}


def run(scenarios, smoke: bool = False) -> dict:
    repeats = 2 if smoke else 3
    results = {"default_off": bench_default_off(),
               "scenarios": [bench_scenario(n, fast=True, repeats=repeats)
                             for n in scenarios]}
    save_json("BENCH_sanitizer", results)
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fewer repeats (CI lane)")
    ap.add_argument("--scenarios", default=",".join(DEFAULT_SCENARIOS),
                    help="comma-separated registry scenario names")
    args = ap.parse_args(argv)
    names = [n.strip() for n in args.scenarios.split(",") if n.strip()]
    unknown = set(names) - set(list_scenarios())
    if unknown:
        ap.error(f"unknown scenario(s): {', '.join(sorted(unknown))}")
    print("name,us_per_call,derived")
    run(names, smoke=args.smoke)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
