"""Smart Router semantics (Eq. 1/2) + static baselines + simhash affinity."""
import collections

import pytest

from repro.core.affinity import SimHashAffinity, simhash64
from repro.core.radix import block_hashes
from repro.core.router import (KvPushRouter, KvRouterConfig, PowerOfTwoRouter,
                               RandomRouter, RoundRobinRouter)

TOKENS_A = list(range(64))
TOKENS_B = list(range(1000, 1064))


def test_argmin_at_tau_zero():
    r = KvPushRouter(3, KvRouterConfig(temperature=0.0, overlap_weight=1.0))
    r.workers[0].active_blocks = 5
    r.workers[1].active_blocks = 1
    r.workers[2].active_blocks = 9
    w, _, _ = r.best_worker(TOKENS_A)
    assert w == 1


def test_cache_affinity_beats_small_load_gap():
    r = KvPushRouter(2, KvRouterConfig(temperature=0.0, overlap_weight=1.0))
    r.on_schedule(0, TOKENS_A)           # worker 0 warm for A
    r.workers[0].active_blocks = 5       # slightly busier
    r.workers[1].active_blocks = 0
    w, ov, _ = r.best_worker(TOKENS_A)
    assert w == 0 and ov == 1.0          # ω·saved(20) > load gap(5)


def test_omega_zero_disables_affinity():
    r = KvPushRouter(2, KvRouterConfig(temperature=0.0, overlap_weight=0.0))
    r.on_schedule(0, TOKENS_A)
    r.workers[0].active_blocks = 5
    r.workers[1].active_blocks = 0
    w, _, _ = r.best_worker(TOKENS_A)
    assert w == 1                        # pure congestion game


def test_high_temperature_spreads():
    r = KvPushRouter(2, KvRouterConfig(temperature=50.0))
    r.workers[0].active_blocks = 0
    r.workers[1].active_blocks = 10
    counts = collections.Counter(r.best_worker(TOKENS_A)[0]
                                 for _ in range(400))
    assert counts[0] > 100 and counts[1] > 100  # near-uniform


def test_temperature_zero_vs_positive_distribution():
    cfgs = KvRouterConfig(temperature=0.7)
    r = KvPushRouter(2, cfgs)
    r.workers[0].active_blocks = 0
    r.workers[1].active_blocks = 10
    counts = collections.Counter(r.best_worker(TOKENS_A)[0]
                                 for _ in range(400))
    assert counts[0] > counts[1] > 20    # biased but stochastic


def test_unhealthy_workers_excluded():
    r = KvPushRouter(3)
    r.set_health(0, False)
    seen = {r.best_worker(TOKENS_A)[0] for _ in range(20)}
    assert 0 not in seen


def test_router_config_override_per_request():
    r = KvPushRouter(2, KvRouterConfig(temperature=0.0, overlap_weight=1.0))
    r.on_schedule(0, TOKENS_A)
    r.workers[0].active_blocks = 5
    w_default, _, _ = r.best_worker(TOKENS_A)
    w_override, _, _ = r.best_worker(
        TOKENS_A, router_config_override=KvRouterConfig(overlap_weight=0.0))
    assert w_default == 0 and w_override == 1


def test_round_robin_cycles():
    rr = RoundRobinRouter(3)
    assert [rr.best_worker(TOKENS_A)[0] for _ in range(6)] == [0, 1, 2, 0, 1, 2]


def test_baselines_respect_worker_health():
    """RoundRobin/Random must skip unhealthy workers like every other
    policy (they share the KvPushRouter's worker table when built
    from one)."""
    r = KvPushRouter(3)
    rr = RoundRobinRouter(r)
    rnd = RandomRouter(r, seed=1)
    r.set_health(1, False)
    assert [rr.best_worker(TOKENS_A)[0] for _ in range(4)] == [0, 2, 0, 2]
    assert 1 not in {rnd.best_worker(TOKENS_A)[0] for _ in range(50)}
    # standalone baselines manage their own health table
    solo = RoundRobinRouter(3)
    solo.set_health(0, False)
    assert [solo.best_worker(TOKENS_A)[0] for _ in range(4)] == [1, 2, 1, 2]


def test_baselines_share_unified_signature():
    """Every policy accepts best_worker(tokens, router_config_override,
    now) so routing policies are drop-in interchangeable."""
    r = KvPushRouter(2)
    cfg = KvRouterConfig(overlap_weight=0.0)
    for policy in (r, RoundRobinRouter(r), RandomRouter(r, seed=0),
                   PowerOfTwoRouter(r, seed=0)):
        w, ov, overlaps = policy.best_worker(
            TOKENS_A, router_config_override=cfg, now=1.5)
        assert w in (0, 1)
        assert 0.0 <= ov <= 1.0
        assert len(overlaps) == 2


def test_power_of_two_prefers_less_loaded():
    r = KvPushRouter(4)
    for w in range(4):
        r.workers[w].active_blocks = w * 10
    p2c = PowerOfTwoRouter(r, seed=0)
    picks = [p2c.best_worker(TOKENS_A)[0] for _ in range(200)]
    # worker 3 (most loaded) should almost never win
    assert collections.Counter(picks)[3] < 10


def test_on_complete_never_negative():
    r = KvPushRouter(1)
    r.on_complete(0, TOKENS_A)
    assert r.workers[0].active_blocks == 0.0


# ------------------------------------------------- simhash affinity ---------


def _templates(n, blocks=6, block=16):
    """n disjoint template prompts, ``blocks`` KV blocks each."""
    return [list(range(t * 10_000, t * 10_000 + blocks * block))
            for t in range(n)]


def test_simhash_exact_agreement_on_template_pool():
    """The acceptance pin for the approximate scorer: on a small pool
    driven by a template workload (every request of a template repeats
    the same prompt) the simhash-bucketed router must make the SAME
    decision with the SAME overlap as the exact radix walk, every time."""
    import random
    exact = KvPushRouter(4, KvRouterConfig(temperature=0.0,
                                           affinity="exact"))
    approx = KvPushRouter(4, KvRouterConfig(temperature=0.0,
                                            affinity="simhash"))
    assert approx.affinity is not None and exact.affinity is None
    temps = _templates(8)
    rng = random.Random(42)
    placed = []
    for i in range(200):
        toks = temps[rng.randrange(len(temps))]
        we, ove, ovse = exact.best_worker(toks, now=float(i))
        wa, ova, ovsa = approx.best_worker(toks, now=float(i))
        assert (we, ove, ovse) == (wa, ova, ovsa), f"diverged at step {i}"
        exact.on_schedule(we, toks, now=float(i))
        approx.on_schedule(wa, toks, now=float(i))
        placed.append((we, toks))
        if len(placed) > 24:               # churn load like completions do
            wd, td = placed.pop(0)
            exact.on_complete(wd, td)
            approx.on_complete(wd, td)
    assert len({w for w, _ in placed}) == 4  # all workers participated


def test_simhash_signature_commits_to_whole_prefix():
    """Chained block hashes: divergence in block 0 flips every later
    feature, so prefixes differing anywhere get different buckets."""
    a = block_hashes(list(range(64)), 16)
    b = block_hashes([1] + list(range(1, 64)), 16)   # first token differs
    aff = SimHashAffinity(block_size=16, prefix_blocks=4)
    assert aff.signature(a) != aff.signature(b)
    assert aff.signature(a) == aff.signature(list(a))    # memo-stable
    assert simhash64([]) == 0


def test_simhash_depth_capped_by_request_length():
    """A worker that cached a LONG prompt over-credits a short same-bucket
    request at most up to the request's own length (documented bias)."""
    aff = SimHashAffinity(block_size=16, prefix_blocks=2)
    long_hs = list(range(100, 108))        # 8 blocks cached
    short_hs = long_hs[:4]                 # same leading 2 blocks → bucket
    aff.insert(0, long_hs, now=0.0)
    assert aff.overlap_depths(short_hs, now=0.0) == {0: 4}
    assert aff.overlap_scores([], [0, 1], hashes=short_hs) == [1.0, 0.0]


def test_simhash_ttl_expires_and_self_cleans():
    aff = SimHashAffinity(block_size=16, prefix_blocks=4, ttl=2.0)
    hs = list(range(8))
    aff.insert(0, hs, now=0.0)
    assert aff.overlap_depths(hs, now=1.0) == {0: 8}
    assert aff.overlap_depths(hs, now=5.0) == {}     # expired
    assert aff._buckets[aff.signature(hs)] == {}     # dropped on read


def test_simhash_deepest_fresh_insert_wins():
    aff = SimHashAffinity(block_size=16, prefix_blocks=4, ttl=10.0)
    hs = list(range(8))
    aff.insert(0, hs, now=0.0)
    aff.insert(0, hs[:5], now=1.0)         # shallower re-insert, still fresh
    assert aff.overlap_depths(hs, now=1.0) == {0: 8}
    aff.insert(0, hs[:5], now=20.0)        # deep entry stale by now: 5 wins
    assert aff.overlap_depths(hs, now=20.0) == {0: 5}


def test_simhash_worker_flip_clears_affinity():
    """Game 1 repartitioning: a worker flipping back into the decode pool
    is cache-cold — add_worker must drop its bucket credit."""
    r = KvPushRouter(2, KvRouterConfig(temperature=0.0, affinity="simhash"))
    r.on_schedule(0, TOKENS_A)
    assert r.best_worker(TOKENS_A)[1] == 1.0
    r.add_worker(0)
    w, ov, _ = r.best_worker(TOKENS_A)
    assert ov == 0.0


def test_unknown_affinity_rejected():
    with pytest.raises(ValueError, match="affinity"):
        KvPushRouter(2, KvRouterConfig(affinity="minhash"))


def test_control_plane_propagates_ttl_to_affinity():
    from repro.serving.control_plane import ControlPlane
    cp = ControlPlane(2, router_config=KvRouterConfig(affinity="simhash"),
                      cache_ttl=7.5)
    assert cp.router.affinity.ttl == 7.5
