"""RA007 good: public audit/helper APIs instead of private attribute pokes."""


def check_router(router):
    return router.cache_coherent()


def warm_caches(engine):
    return engine.dummy_caches(8)


class Indexer:
    def __init__(self):
        self._node_by_hash = {}                  # self-access is fine

    def lookup(self, h):
        return self._node_by_hash.get(h)
