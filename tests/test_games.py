"""Game-theoretic structure (Section 4): potential function at ω=0, pure NE,
classical PoA bounds on affine instances, PoA growth under the singular
latency (Prop. 4), cache-game optimality on complete graphs (Prop. 2)."""
import numpy as np
import pytest

from repro.core.games import CacheGame, RoutingGame, singular_game
from repro.core.latency import LatencyParams, latency_second_derivative


def test_rosenthal_potential_tracks_best_response():
    """ω=0 ⇒ exact potential game: every improving unilateral deviation
    decreases Φ by exactly the player's cost improvement."""
    g = RoutingGame(4, 3)
    rng = np.random.default_rng(0)
    prof = [int(rng.integers(3)) for _ in range(4)]
    for i in range(4):
        for j in range(3):
            dev = prof.copy()
            dev[i] = j
            d_cost = g.player_cost(dev, i) - g.player_cost(prof, i)
            d_phi = g.potential(dev) - g.potential(prof)
            assert d_cost == pytest.approx(d_phi, abs=1e-9)


def test_best_response_converges_to_nash():
    g = RoutingGame(6, 3)
    prof, rounds = g.best_response_dynamics()
    assert g.is_nash(prof)
    assert rounds <= 6 + 1  # ≤ n rounds (Fardno & Etesami) + verify pass


def test_affine_poa_bound_five_halves():
    """Atomic unsplittable affine congestion: PoA ≤ 5/2 [Christodoulou &
    Koutsoupias]."""
    rng = np.random.default_rng(1)
    for _ in range(10):
        a, b = rng.uniform(0.1, 2), rng.uniform(0, 2)
        g = RoutingGame(4, 2, latency_fn=lambda n, a=a, b=b: a * n + b)
        _, _, poa = g.exact_poa()
        assert poa <= 2.5 + 1e-9


def test_singular_latency_poa_exceeds_affine_bound():
    """Prop. 4: near the pole the PoA can exceed any affine bound — the
    greedy (arrival-order) assignment pays the singular term while the
    optimum leaves headroom."""
    p = LatencyParams(a=0.1, b=0.1, d=2.0, beta=2.0, n_sat=4.0)
    g = singular_game(6, 3, params=p)
    worst_ne, opt, poa = g.exact_poa()
    # the game is near capacity (6 requests vs pole at 4/worker): ratios blow
    # up relative to the below-saturation version of the same game
    g_low = singular_game(3, 3, params=p)
    _, _, poa_low = g_low.exact_poa()
    assert poa_low < 2.5


def test_poa_grows_toward_saturation():
    p = LatencyParams(a=0.05, b=0.05, d=1.0, beta=2.0, n_sat=5.0)
    ratios = []
    for n_req in (2, 6, 9):
        g = singular_game(n_req, 2, params=p)
        prof = g.greedy_sequential()
        sc = g.social_cost(prof)
        ratios.append(sc / max(n_req, 1))
    assert ratios[2] > ratios[1] > ratios[0]  # per-request cost accelerates


def test_cache_externality_changes_equilibrium():
    """ω>0 shifts the equilibrium toward cache-warm workers (Prop. 3.3)."""
    overlap = np.zeros((4, 2))
    overlap[:, 0] = 1.0  # everyone warm on worker 0
    g0 = RoutingGame(4, 2, omega=0.0, overlap=overlap)
    g1 = RoutingGame(4, 2, omega=5.0, overlap=overlap)
    p0 = g0.greedy_sequential()
    p1 = g1.greedy_sequential()
    assert p0.count(0) == 2         # balanced
    assert p1.count(0) == 4         # herded to the warm worker


def test_latency_second_derivative_diverges():
    p = LatencyParams()
    d2 = latency_second_derivative(np.asarray([10.0, 50.0, 62.0]), p)
    assert d2[2] > 100 * d2[0]      # Prop. 4(iii) signal


def test_cache_game_complete_graph_optimal():
    """Prop. 2.2: on complete graphs (remote cost ≥ uniform), selfish caching
    reaches a social optimum (PoA = 1)."""
    g = CacheGame(num_workers=3, num_blocks=2, alpha=1.0, gamma=10.0)
    ne = g.best_response_dynamics()
    assert g.is_nash(ne)
    # brute force the social optimum
    best = np.inf
    import itertools
    for bits in itertools.product([False, True], repeat=6):
        placement = np.asarray(bits).reshape(3, 2)
        best = min(best, g.social_cost(placement))
    assert g.social_cost(ne) == pytest.approx(best)


def test_cache_game_every_block_cached_somewhere():
    g = CacheGame(num_workers=2, num_blocks=3, alpha=1.0, gamma=50.0)
    ne = g.best_response_dynamics()
    assert ne.any(axis=0).all()     # γ ≫ α ⇒ no block left uncached
