import os

# Keep tests on a single CPU device (the 512-device flag is set ONLY inside
# repro.launch.dryrun; sub-process tests set their own).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
