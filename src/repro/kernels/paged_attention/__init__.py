from repro.kernels.paged_attention.ops import (  # noqa: F401
    gather_pages, paged_attention)
