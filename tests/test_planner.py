"""Game 1 (P/D allocation): variational equilibrium (Prop. 1) and the
Planner's ±1 best-response dynamic with inertia."""

from repro.core.planner import (Planner, PlannerConfig, social_optimum,
                                variational_equilibrium)


def v_ttft(gp):
    return 100.0 / gp  # strictly convex decreasing


def v_itl(gd):
    return 25.0 / gd


def test_variational_equilibrium_balances_marginals():
    g = variational_equilibrium(v_ttft, v_itl, total=12)
    # analytic: 100/gp² = 25/gd² ⇒ gp = 2·gd ⇒ gp = 8, gd = 4
    assert g == 8


def test_social_optimum_credits_prefill_externality():
    """Remark 1: with a positive externality of prefill on decode, the social
    optimum allocates ≥ the variational equilibrium to prefill."""
    def v_itl_joint(gd, gp):
        return 25.0 / gd + 30.0 / gp  # prefill starves decode when small
    ve = variational_equilibrium(v_ttft, v_itl, total=12)
    so = social_optimum(v_ttft, v_itl_joint, total=12)
    assert so >= ve


def test_planner_moves_toward_equilibrium():
    """Fed the profiled *marginal* improvements (the paper's pre-deployment
    response functions), the ±1 dynamic settles at the variational
    equilibrium of Prop. 1."""
    cfg = PlannerConfig(total_workers=12, adjust_interval=30.0,
                        grace_intervals=0)
    pl = Planner(config=cfg, prefill_workers=2, decode_workers=10)
    t = 0.0
    for _ in range(40):
        t += 31.0
        m_p = v_ttft(pl.prefill_workers) - v_ttft(pl.prefill_workers + 1)
        m_d = v_itl(pl.decode_workers) - v_itl(pl.decode_workers + 1)
        pl.step(t, ttft_violation=m_p, itl_violation=m_d)
    ve = variational_equilibrium(v_ttft, v_itl, total=12)
    assert abs(pl.prefill_workers - ve) <= 1


def test_planner_rate_limited():
    pl = Planner(config=PlannerConfig(adjust_interval=30.0),
                 prefill_workers=1, decode_workers=2)
    assert pl.step(31.0, 1.0, 0.0) == "to_prefill"
    # immediate second call inside the interval: no move
    assert pl.step(40.0, 1.0, 0.0) is None


def test_planner_grace_period_after_decode_assignment():
    cfg = PlannerConfig(adjust_interval=30.0, grace_intervals=3)
    pl = Planner(config=cfg, prefill_workers=3, decode_workers=1)
    assert pl.step(31.0, 0.0, 1.0) == "to_decode"
    # within 3 intervals of grace: frozen even with strong signal
    assert pl.step(80.0, 1.0, 0.0) is None
    assert pl.step(120.0, 1.0, 0.0) is None
    # grace expired (31 + 90 s): the planner may act again
    assert pl.step(130.0, 1.0, 0.0) == "to_prefill"


def test_planner_never_empties_a_pool():
    pl = Planner(config=PlannerConfig(adjust_interval=1.0),
                 prefill_workers=1, decode_workers=1)
    assert pl.step(2.0, 10.0, 0.0) is None  # would empty decode
    assert pl.step(4.0, 0.0, 10.0) is None  # would empty prefill
