"""Minitron-4B — pruned Nemotron (squared-ReLU family). [arXiv:2407.14679; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    family="dense",
    num_layers=32,
    d_model=3_072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=9_216,
    vocab_size=256_000,
    head_dim=128,
    activation="squared_relu",
    subquadratic=False,
    source="arXiv:2407.14679; hf",
)
