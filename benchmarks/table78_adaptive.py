"""Tables 7/8 + Figure 7 / Experiment 3: adaptive vs static routing under a
three-phase load spike (C = 32 → 128 → 32), n=3 iterations per strategy,
on 340B 1P/2D, 70B 1P/2D and 70B 1P/5D."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, save_json
from repro.serving.scenarios import build_simulator

PHASES = ["Below", "Saturated", "Recovery"]
CONFIGS = [("nemotron-4-340b", "1P/2D", "340b-1p2d-spike"),
           ("llama-3.1-70b", "1P/2D", "70b-1p2d-spike"),
           ("llama-3.1-70b", "1P/5D", "70b-1p5d-spike")]


def run(iterations: int = 3):
    t0 = time.perf_counter()
    report = {}
    for model, topo, scenario in CONFIGS:
        report[f"{model} {topo}"] = {}
        print(f"\n# Tables 7/8 — Experiment 3: {model} {topo} "
              f"(scenario {scenario}, n={iterations} iterations)")
        print(f"{'strategy':>9} {'phase':>10} {'PoA':>16} {'TTFT P99 (s)':>16} "
              f"{'ITL P99':>9} {'rps':>6}")
        for adaptive in (False, True):
            tag = "Adaptive" if adaptive else "Static"
            per_phase = {p: dict(poa=[], ttft=[], itl=[], rps=[])
                         for p in range(3)}
            switches = []
            for it in range(iterations):
                sim = build_simulator(scenario, seed=it + 1,
                                      adaptive=adaptive)
                res = sim.run()
                if res.switch_time is not None:
                    switches.append(res.switch_time)
                for p in range(3):
                    s = res.phase_stats(p)
                    per_phase[p]["poa"].append(s.poa)
                    per_phase[p]["ttft"].append(s.ttft_p99)
                    per_phase[p]["itl"].append(s.itl_p99)
                    per_phase[p]["rps"].append(s.rps)
            rows = {}
            for p in range(3):
                d = per_phase[p]
                rows[PHASES[p]] = {
                    k: (float(np.mean(v)), float(np.std(v, ddof=1))
                        if len(v) > 1 else 0.0)
                    for k, v in d.items()}
                poa_m, poa_s = rows[PHASES[p]]["poa"]
                tt_m, tt_s = rows[PHASES[p]]["ttft"]
                print(f"{tag:>9} {PHASES[p]:>10} "
                      f"{poa_m:>8.2f}±{poa_s:<6.2f} "
                      f"{tt_m:>8.3f}±{tt_s:<6.3f} "
                      f"{rows[PHASES[p]]['itl'][0]*1000:>7.2f}ms "
                      f"{rows[PHASES[p]]['rps'][0]:>6.1f}")
            report[f"{model} {topo}"][tag] = dict(
                rows=rows, switch_mean=float(np.mean(switches))
                if switches else None)
    save_json("table78_adaptive", report)
    dt = (time.perf_counter() - t0) * 1e6
    k5 = report["llama-3.1-70b 1P/5D"]
    poa_ratio = (k5["Static"]["rows"]["Saturated"]["poa"][0]
                 / max(k5["Adaptive"]["rows"]["Saturated"]["poa"][0], 1e-9))
    ttft_ratio = (k5["Static"]["rows"]["Saturated"]["ttft"][0]
                  / max(k5["Adaptive"]["rows"]["Saturated"]["ttft"][0], 1e-9))
    emit("table78_adaptive", dt / (len(CONFIGS) * 2 * iterations),
         f"5d_sat_poa_improvement={poa_ratio:.2f}x;"
         f"5d_sat_ttft_improvement={ttft_ratio:.2f}x")
    return report


if __name__ == "__main__":
    run()
