"""Real-model disaggregated serving engines (jitted JAX, CPU-testable).

``PrefillEngine`` runs the prompt pass and emits a per-request KV/state
cache bundle.  It keeps a **block-granular prefix cache** keyed by the same
chained ``block_hashes`` the router/indexer use: when a new prompt shares a
cached prefix (and the model supports resumable prefill — attention-only
stacks), the prompt pass *resumes* from the matched block boundary instead
of recomputing the prefix, so a cache-warm routing decision actually skips
real jitted compute.  Per-call and cumulative stats (reused blocks,
computed suffix tokens, estimated FLOPs, wall time) back the
``benchmarks/bench_backend_parity.py`` warm-vs-cold measurement.

``DecodeEngine`` holds a fixed-slot continuous batch whose per-slot lengths
advance independently (ragged decode with masked cache writes).  Finished
slots are released **inside** :meth:`DecodeEngine.step` — the returned-slot
contract: a ``done=True`` tuple means the slot is already free and
re-admittable in the same tick.  The engine also tracks which KV blocks are
resident (admitted and not yet evicted by the bounded LRU), so the
prefill→decode ``transfer()`` hop can be charged per *non-resident* block —
on a real cluster that hop is a cross-mesh ``jax.device_put`` (the NIXL
analogue); on CPU it degenerates to an in-process copy, so the per-block
charge is what reintroduces the KV-movement cost the routing game is about.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.radix import BLOCK_SIZE, block_hashes
from repro.models.model import Model


@dataclass
class PrefillStats:
    """Cumulative prefix-cache accounting (one instance per engine)."""
    requests: int = 0
    total_blocks: int = 0        # full blocks across all prompts
    reused_blocks: int = 0       # blocks resumed from the prefix cache
    total_tokens: int = 0        # prompt tokens across all prompts
    computed_tokens: int = 0     # suffix tokens actually run through compute
    flops: float = 0.0           # ≈ 2·N_active·computed_tokens
    wall_s: float = 0.0          # jitted prompt-pass wall time

    def as_dict(self) -> dict:
        return dict(requests=self.requests, total_blocks=self.total_blocks,
                    reused_blocks=self.reused_blocks,
                    total_tokens=self.total_tokens,
                    computed_tokens=self.computed_tokens,
                    flops=self.flops, wall_s=self.wall_s)


class PrefillEngine:
    def __init__(self, model: Model, params, max_len: int,
                 cache_entries: int = 16, block_size: int = BLOCK_SIZE):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.block_size = block_size
        self.cache_entries = cache_entries
        self._prefill = jax.jit(
            lambda p, batch: model.prefill(p, batch, max_len=max_len))
        # start is traced (one compile per suffix length, not per offset)
        self._resume = jax.jit(model.prefill_resume)
        # prefix cache: full hash chain of a completed prompt pass → its
        # cache bundle (K/V valid for every position of that prompt).  A
        # lookup matches the longest common *prefix* of chains — chained
        # hashes commit to the whole prefix, so chain equality at depth m
        # means token equality over the first m blocks.
        self._cache: "OrderedDict[Tuple[int, ...], object]" = OrderedDict()
        self.stats = PrefillStats()
        # per-token FLOPs estimate: 2·N_active (inference forward pass)
        self._flops_per_token = 2.0 * model.cfg.active_param_count()

    # ------------------------------------------------------ prefix cache ----

    def _best_match(self, hashes: Sequence[int]):
        """One walk over the cache: ``(depth, entry)`` of the deepest
        common-prefix chain (most recently used wins ties); the winner's
        LRU position is refreshed.  Chained hashes commit to their whole
        prefix, so chain equality at depth m means token equality over the
        first m blocks — any entry matching m blocks is a valid K/V donor
        for every resume point inside them."""
        best, donor, key = 0, None, None
        for chain in reversed(self._cache):   # most recent first
            m = 0
            for a, b in zip(chain, hashes):
                if a != b:
                    break
                m += 1
            if m > best:
                best, donor, key = m, self._cache[chain], chain
        if key is not None:
            self._cache.move_to_end(key)
        return best, donor

    def _store(self, hashes: Sequence[int], caches) -> None:
        if not hashes or self.cache_entries <= 0:
            return
        key = tuple(hashes)
        self._cache[key] = caches
        self._cache.move_to_end(key)
        while len(self._cache) > self.cache_entries:
            self._cache.popitem(last=False)

    def clear_cache(self) -> None:
        self._cache.clear()

    def warmup(self, prompt_lengths: Sequence[int],
               suffix_lengths: Sequence[int] = ()) -> None:
        """Pre-compile the jitted prompt passes for the given prompt (and
        resume-suffix) lengths, without touching the prefix cache or the
        stats — so measured runs and the saturation detector never see
        multi-second XLA compile walls as TTFT.

        Resume compilation is keyed on the suffix length alone (cache
        shapes are fixed at ``max_len`` and ``start`` is traced), so each
        suffix compiles once against one donor instead of once per
        (prompt, suffix) pair."""
        lengths = sorted(set(int(x) for x in prompt_lengths))
        caches = None
        for n in lengths:
            batch = {"tokens": jnp.zeros((1, n), jnp.int32)}
            _, caches = self._prefill(self.params, batch)
        if caches is None or not self.model.supports_prefill_resume:
            return
        n_max = lengths[-1]
        for s in sorted(set(int(x) for x in suffix_lengths)):
            if 0 < s < n_max:
                self._resume(self.params, caches,
                             jnp.zeros((1, s), jnp.int32),
                             jnp.int32(n_max - s))

    # ----------------------------------------------------------- prefill ----

    def prefill(self, tokens: Sequence[int], extras: Optional[dict] = None,
                hashes: Optional[Sequence[int]] = None):
        """Single-request prompt pass → (last_logits (V,), cache bundle).

        Resumes from the longest cached block prefix when possible; a miss
        (or a model without resumable prefill, or multimodal ``extras``)
        pays the full jitted pass.  Always recomputes at least the last
        token so the returned logits are exact for *this* prompt."""
        resumable = (self.model.supports_prefill_resume and not extras
                     and self.cache_entries > 0)
        if hashes is None and resumable:
            hashes = block_hashes(tokens, self.block_size)
        hashes = tuple(hashes or ())
        start = 0
        donor = None
        if resumable and hashes:
            m, donor = self._best_match(hashes)
            # keep ≥1 suffix token so the pass emits this prompt's logits;
            # the donor matched m full blocks, which covers every position
            # below any start ≤ m·block_size (including a non-boundary
            # start inside the donor's last matched block)
            start = min(m * self.block_size, len(tokens) - 1)
            if start <= 0:
                donor = None
        t0 = time.perf_counter()
        if start > 0:
            suffix = jnp.asarray(tokens[start:], jnp.int32)[None, :]
            logits, caches = self._resume(self.params, donor, suffix,
                                          jnp.int32(start))
        else:
            batch = {"tokens": jnp.asarray(tokens, jnp.int32)[None, :]}
            if extras:
                batch.update({k: jnp.asarray(v)[None]
                              for k, v in extras.items()})
            logits, caches = self._prefill(self.params, batch)
        logits = np.asarray(logits[0])
        wall = time.perf_counter() - t0
        st = self.stats
        st.requests += 1
        st.total_blocks += len(hashes)
        st.reused_blocks += start // self.block_size
        st.total_tokens += len(tokens)
        st.computed_tokens += len(tokens) - start
        st.flops += self._flops_per_token * (len(tokens) - start)
        st.wall_s += wall
        if resumable:
            self._store(hashes, caches)
        return logits, caches


@dataclass
class Slot:
    active: bool = False
    request_id: Optional[str] = None
    length: int = 0
    generated: List[int] = field(default_factory=list)
    max_new: int = 0


class DecodeEngine:
    """Fixed-slot continuous batcher around the jitted ragged decode step."""

    def __init__(self, model: Model, params, num_slots: int, max_len: int,
                 worker_id: int = 0, resident_blocks: int = 4096):
        self.model = model
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.worker_id = worker_id
        self.slots = [Slot() for _ in range(num_slots)]
        self.caches = model.cache_init(num_slots, max_len)
        self.tokens = np.zeros((num_slots, 1), np.int32)
        self._decode = jax.jit(model.decode, donate_argnums=1)
        # KV-block residency (the worker's G1 view): bounded LRU over the
        # block hashes this worker has admitted.  The transfer() hop is
        # charged only for blocks NOT in this set — a cache-warm routing
        # decision ships less KV.
        self.resident_cap = resident_blocks
        self._resident: "OrderedDict[int, None]" = OrderedDict()
        self.transferred_blocks = 0      # cumulative non-resident blocks

    # -------------------------------------------------------------- admit ---

    def free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if not s.active:
                return i
        return None

    def _touch_blocks(self, hashes: Sequence[int]) -> int:
        """Mark ``hashes`` resident (LRU refresh); returns the number of
        blocks that were NOT already resident — the transfer() payload."""
        new = 0
        for h in hashes:
            if h in self._resident:
                self._resident.move_to_end(h)
            else:
                self._resident[h] = None
                new += 1
        while len(self._resident) > self.resident_cap:
            self._resident.popitem(last=False)
        return new

    def admit(self, slot: int, request_id: str, prefill_caches,
              first_token: int, prompt_len: int, max_new: int,
              hashes: Sequence[int] = ()) -> int:
        """Transfer a prefill cache bundle into ``slot`` (the NIXL hop).

        Returns the number of *non-resident* blocks the transfer had to
        move — the per-block charge of the prefill→decode hop.  Blocks
        already resident (an earlier request of the same template landed
        here) ride for free; that asymmetry is the cache-affinity
        externality on the real path."""
        self.caches = _insert_cache(self.caches, prefill_caches, slot,
                                    self.model)
        s = self.slots[slot]
        s.active = True
        s.request_id = request_id
        s.length = prompt_len
        s.generated = [int(first_token)]
        s.max_new = max_new
        self.tokens[slot, 0] = first_token
        moved = self._touch_blocks(hashes)
        self.transferred_blocks += moved
        return moved

    def release(self, slot: int):
        self.slots[slot] = Slot()
        self.tokens[slot, 0] = 0

    @property
    def active_count(self) -> int:
        return sum(s.active for s in self.slots)

    def warmup(self) -> None:
        """Pre-compile the jitted decode step (slots all inactive; whatever
        the pass writes is fully overwritten on the next ``admit``)."""
        lengths = jnp.zeros((self.num_slots,), jnp.int32)
        _, self.caches = self._decode(self.params, self.caches,
                                      jnp.asarray(self.tokens), lengths)

    # --------------------------------------------------------------- step ---

    def step(self) -> List[Tuple[str, int, bool]]:
        """One batched decode tick. Returns [(request_id, token, done)].

        Returned-slot contract: when ``done`` is True the slot has already
        been released inside this step — it is free for admission in the
        same tick, and callers must NOT call :meth:`release` again."""
        if self.active_count == 0:
            return []
        lengths = jnp.asarray([s.length if s.active else 0
                               for s in self.slots], jnp.int32)
        logits, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(self.tokens), lengths)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        out = []
        for i, s in enumerate(self.slots):
            if not s.active:
                continue
            tok = int(nxt[i])
            s.generated.append(tok)
            s.length += 1
            self.tokens[i, 0] = tok
            done = (len(s.generated) >= s.max_new + 1
                    or s.length >= self.max_len - 1)
            out.append((s.request_id, tok, done))
            if done:
                self.release(i)   # slot is re-admittable this same tick
        return out


def _insert_cache(dst, src, slot: int, model: Model):
    """Write a (batch=1) prefill cache bundle into decode slot `slot`.

    Cross-mesh in production: each leaf is device_put to the decode mesh's
    sharding before insertion.
    """
    def leaf(d, s):
        # d: (P, B, ...); s: (P, 1, ...) — prefill cache may have a shorter
        # sequence axis than the decode cache; pad on the right.
        if s.shape[2:] != d.shape[2:]:
            pads = [(0, 0), (0, 0)]
            for ds, ss in zip(d.shape[2:], s.shape[2:]):
                pads.append((0, ds - ss))
            s = jnp.pad(s, pads)
        return d.at[:, slot].set(s[:, 0].astype(d.dtype))
    return jax.tree.map(leaf, dst, src)
