"""Radix (prefix) tree over token-block hashes — the KvIndexer.

Tracks which KV cache blocks reside on which workers so the Smart Router can
compute per-worker overlap scores (the positive externality of Game 3).
Blocks are fixed-size token runs; a sequence maps to the list of hashes of
its prefixes, so shared prompt prefixes share leading blocks exactly like
Dynamo's global radix tree.

Large-pool hot path: ``overlap_scores`` does ONE root-to-leaf walk per
request and collects every worker's fresh-prefix depth from the claims on
the path — O(blocks + claims-on-path + workers) instead of the legacy
per-worker walk's O(workers × blocks).  The legacy walk is kept behind
``aggregated=False`` and pinned bit-exact against the aggregated walk over
every pre-existing scenario (tests/test_scale_hotpath.py).

Memory is bounded: nodes carry parent links, invalidation prunes subtrees
that hold no claims, and the ``_node_by_hash`` lookup table shrinks with
the tree instead of growing monotonically.

Claim invariant (prefix closure): a worker's claims always form a
root-connected prefix set — ``insert`` claims whole root-to-leaf paths,
and every invalidation (``remove_worker_block``, ``remove_worker_blocks``,
``clear_worker``) drops the worker's claims on the *entire subtree* below
the invalidated block.  Claims below a dropped block are unreachable by
overlap scoring until the block is re-inserted, and by then the deep KV
may be long demoted — crediting them again on a prefix re-insert was the
router/indexer coherence bug this invariant fixes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

BLOCK_SIZE = 16  # tokens per KV block (vLLM/Dynamo default granularity)


def block_hashes(tokens: Sequence[int], block_size: int = BLOCK_SIZE) -> List[int]:
    """Prefix-chained block hashes: hash_i = H(hash_{i-1}, block_i_tokens)."""
    out: List[int] = []
    h = 0
    n_full = len(tokens) // block_size
    for i in range(n_full):
        blk = tuple(tokens[i * block_size:(i + 1) * block_size])
        h = hash((h,) + blk)
        out.append(h)
    return out


@dataclass
class _Node:
    key: int = 0                       # chained hash (key in parent.children)
    parent: Optional["_Node"] = None
    children: Dict[int, "_Node"] = field(default_factory=dict)
    workers: Dict[int, float] = field(default_factory=dict)  # worker → touch


class KvIndexer:
    """Prefix tree: path = chained block hashes; each node records which
    workers hold that block and when they last touched it.

    ``ttl`` models cache churn: a worker's claim on a block expires if not
    refreshed within ttl seconds (vLLM-style LRU recycling of KV blocks).
    ``ttl=None`` disables expiry (blocks live forever).

    ``aggregated`` selects the single-walk overlap scoring (default); the
    legacy per-worker walk is kept for bit-exactness pinning and perf
    comparison (``benchmarks/bench_scale.py``)."""

    def __init__(self, block_size: int = BLOCK_SIZE,
                 ttl: Optional[float] = None, aggregated: bool = True):
        self.block_size = block_size
        self.ttl = ttl
        self.aggregated = aggregated
        self.root = _Node()
        self._worker_blocks: Dict[int, int] = {}   # worker → claim count
        # Chained hashes are prefix-unique (hash_i commits to the whole
        # prefix), so each hash identifies exactly one tree node — the
        # lookup table single-block invalidation needs.  Entries are
        # dropped when their node is pruned, so the table tracks the live
        # tree instead of every hash ever seen.
        self._node_by_hash: Dict[int, _Node] = {}

    def _fresh(self, node: _Node, worker: int, now: float) -> bool:
        t = node.workers.get(worker)
        if t is None:
            return False
        return self.ttl is None or (now - t) <= self.ttl

    def _cutoff(self, now: float) -> float:
        """Freshness threshold: a claim touched at t is fresh iff
        t >= cutoff (equivalent to the legacy ``now - t <= ttl``)."""
        return float("-inf") if self.ttl is None else now - self.ttl

    # ------------------------------------------------------------ update ----

    def insert(self, worker: int, tokens: Sequence[int], now: float = 0.0,
               hashes: Optional[Sequence[int]] = None):
        hs = block_hashes(tokens, self.block_size) if hashes is None else hashes
        node = self.root
        nbh = self._node_by_hash
        count = self._worker_blocks.get(worker, 0)
        for h in hs:
            child = node.children.get(h)
            if child is None:
                child = _Node(key=h, parent=node)
                node.children[h] = child
                nbh[h] = child
            node = child
            if worker not in node.workers:
                count += 1
            node.workers[worker] = now
        if hs:
            self._worker_blocks[worker] = count

    def _clear_subtree(self, worker: int, top: _Node):
        """Drop ``worker``'s claims on ``top`` and everything below it,
        pruning nodes left with no claims and no children.  Iterative
        (drain-protocol flips after ≥16k-token prompts used to blow the
        recursion limit) and bounded by the worker's claim count: the
        prefix-closure invariant means descending only into claimed
        children visits every claim below ``top``."""
        order = [top]
        stack = [c for c in top.children.values() if worker in c.workers]
        while stack:
            n = stack.pop()
            order.append(n)
            stack.extend(c for c in n.children.values()
                         if worker in c.workers)
        removed = 0
        nbh = self._node_by_hash
        # reversed pre-order processes children before parents, so a chain
        # emptied end-to-end prunes all the way up
        for n in reversed(order):
            if n.workers.pop(worker, None) is not None:
                removed += 1
            if not n.workers and not n.children and n.parent is not None:
                del n.parent.children[n.key]
                nbh.pop(n.key, None)
        node = top.parent
        while (node is not None and node.parent is not None
               and not node.workers and not node.children):
            del node.parent.children[node.key]
            nbh.pop(node.key, None)
            node = node.parent
        if removed:
            left = self._worker_blocks.get(worker, 0) - removed
            if left > 0:
                self._worker_blocks[worker] = left
            else:
                self._worker_blocks.pop(worker, None)

    def remove_worker_block(self, worker: int, block_hash: int):
        """Tier-coherence invalidation: drop ``worker``'s claim on one
        block (identified by its chained hash, e.g. on a KVBM demotion
        out of G1) **and on every block below it**.  Overlap scoring walks
        from the root and stops at the first unclaimed node, so the deeper
        claims are unreachable anyway — but leaving their stale timestamps
        in place meant a later re-insert of just the prefix re-opened the
        walk and credited demoted deep blocks again."""
        node = self._node_by_hash.get(block_hash)
        if node is None:
            return
        self._clear_subtree(worker, node)

    def remove_worker_blocks(self, worker: int, tokens: Sequence[int],
                             hashes: Optional[Sequence[int]] = None):
        """Eviction event: drop this worker from every block of the
        sequence.  Evicting the sequence's first block truncates the
        worker's credited prefix at the root, so (prefix closure) the
        whole subtree behind it is cleared with it."""
        hs = block_hashes(tokens, self.block_size) if hashes is None else hashes
        if not hs:
            return
        node = self.root.children.get(hs[0])
        if node is not None:
            self._clear_subtree(worker, node)

    def clear_worker(self, worker: int):
        """Drop every claim of ``worker`` (Game 1 drain-protocol flush).
        Iterative and bounded by the worker's claim count."""
        for child in list(self.root.children.values()):
            if worker in child.workers:
                self._clear_subtree(worker, child)
        self._worker_blocks.pop(worker, None)

    # ------------------------------------------------------------- query ----

    def matched_blocks(self, worker: int, tokens: Sequence[int],
                       now: float = 0.0,
                       hashes: Optional[Sequence[int]] = None) -> int:
        """Longest fresh prefix (in blocks) of `tokens` cached on `worker`."""
        hs = block_hashes(tokens, self.block_size) if hashes is None else hashes
        node = self.root
        cutoff = self._cutoff(now)
        n = 0
        for h in hs:
            node = node.children.get(h)
            if node is None:
                break
            t = node.workers.get(worker)
            if t is None or t < cutoff:
                break
            n += 1
        return n

    def overlap_scores(self, tokens: Sequence[int], workers: Sequence[int],
                       now: float = 0.0,
                       hashes: Optional[Sequence[int]] = None):
        """o_ij ∈ [0,1]: fresh matched-prefix fraction per worker (Eq. 7).

        Aggregated path: one root-to-leaf walk; at depth i every worker
        whose fresh claims covered blocks 0..i-1 either extends its prefix
        (a fresh claim on this node) or is finished.  Cost is the walk
        plus the claims actually on the path — cold workers cost nothing
        beyond the final output lookup."""
        hs = block_hashes(tokens, self.block_size) if hashes is None else hashes
        total = max(len(hs), 1)
        if not self.aggregated:
            return self._overlap_scores_legacy(hs, workers, now, total)
        depth = self.overlap_depths(hs, now)
        get = depth.get
        return [get(w, 0) / total for w in workers]

    def overlap_depths(self, hashes: Sequence[int], now: float = 0.0
                       ) -> Dict[int, int]:
        """Sparse core of the aggregated walk: fresh contiguous prefix
        depth (in blocks) for every worker with claims on the path —
        workers absent from the result have depth 0.  O(blocks +
        fresh-claims-on-path), independent of pool size; the router's
        vectorized path consumes this directly to skip the dense
        per-worker output list.

        Stale claims encountered on the walk are swept: a TTL-expired
        claim scores zero forever (queries run on the simulator's forward
        clock and only ``insert`` refreshes a claim), so dropping it — and,
        for closure, the worker's whole tail behind it — is invisible to
        scoring but keeps popular chains from accumulating one dead claim
        per worker that ever touched them, which would drag the walk back
        toward O(workers × blocks)."""
        depth: Dict[int, int] = {}
        get = depth.get
        node = self.root
        cutoff = self._cutoff(now)
        i = 0
        for h in hashes:
            node = node.children.get(h)
            if node is None:
                break
            nxt = i + 1
            advanced = 0
            stale = None
            for w, t in node.workers.items():
                if t < cutoff:
                    if stale is None:
                        stale = [w]
                    else:
                        stale.append(w)
                elif get(w, 0) == i:
                    depth[w] = nxt
                    advanced += 1
            if stale:
                for w in stale:
                    self._clear_subtree(w, node)
            if not advanced:
                break   # nobody's prefix reaches this block: deeper nodes
            i = nxt     # cannot extend any contiguous prefix either
        return depth

    def _overlap_scores_legacy(self, hs: Sequence[int],
                               workers: Sequence[int], now: float,
                               total: int):
        """Pre-aggregation per-worker walk, kept verbatim for the
        bit-exactness pin and as the bench_scale comparison baseline."""
        out = []
        for w in workers:
            node = self.root
            n = 0
            for h in hs:
                node = node.children.get(h)
                if node is None or not self._fresh(node, w, now):
                    break
                n += 1
            out.append(n / total)
        return out

    def num_blocks(self, worker: int) -> int:
        return self._worker_blocks.get(worker, 0)

    def snapshot_claims(self, now: float = 0.0) -> Dict[int, Tuple[int, ...]]:
        """Frozen view of every *fresh* claim: block hash → workers whose
        claim on it is fresh at ``now``.  One read-only walk over the whole
        tree (no TTL sweep, unlike ``overlap_depths``) — the bounded-
        staleness replica views snapshot the indexer through this.

        Freshness is prefix-monotone (``insert`` touches a whole
        root-to-leaf path with one timestamp, so a parent is always at
        least as fresh as any child), so the per-hash worker tuples are
        prefix-closed exactly like live claims and a replica can replay
        the ``overlap_depths`` walk against the dict alone."""
        cutoff = self._cutoff(now)
        out: Dict[int, Tuple[int, ...]] = {}
        stack = list(self.root.children.values())
        while stack:
            n = stack.pop()
            ws = tuple(w for w, t in n.workers.items() if t >= cutoff)
            if ws:
                out[n.key] = ws
            stack.extend(n.children.values())
        return out

    def claimed_hashes(self, worker: int) -> List[int]:
        """Audit hook: every block hash ``worker`` currently claims, from
        a read-only tree walk (no TTL sweep — unlike ``overlap_depths``
        this never mutates the tree)."""
        out: List[int] = []
        stack = [c for c in self.root.children.values()
                 if worker in c.workers]
        while stack:
            n = stack.pop()
            out.append(n.key)
            stack.extend(c for c in n.children.values()
                         if worker in c.workers)
        return out

    def audit(self) -> List[str]:
        """Audit hook (``repro.analysis.sanitize``): verify the tree's
        structural invariants by one read-only walk.  Returns a list of
        violation descriptions (empty when consistent).

        Checked: parent links and child keys agree; ``_node_by_hash``
        tracks exactly the live non-root nodes; no unpruned empty node
        (no claims, no children) survives; per-worker claim counts match
        ``_worker_blocks`` exactly (absent == zero); claims are
        prefix-closed (a claim on a node implies a claim on its parent).
        """
        problems: List[str] = []
        counts: Dict[int, int] = {}
        live = 0
        stack = [(self.root, None)]
        while stack:
            node, parent = stack.pop()
            if parent is not None:
                live += 1
                if node.parent is not parent:
                    problems.append(
                        f"node {node.key:#x}: broken parent link")
                if self._node_by_hash.get(node.key) is not node:
                    problems.append(
                        f"node {node.key:#x}: missing/mismatched "
                        f"_node_by_hash entry")
                if not node.workers and not node.children:
                    problems.append(
                        f"node {node.key:#x}: empty node not pruned")
                for w in node.workers:
                    counts[w] = counts.get(w, 0) + 1
                    if parent is not self.root and w not in parent.workers:
                        problems.append(
                            f"node {node.key:#x}: worker {w} claim has no "
                            f"parent claim (prefix closure broken)")
            for key, child in node.children.items():
                if child.key != key:
                    problems.append(
                        f"node under {node.key:#x}: child key {key:#x} != "
                        f"node.key {child.key:#x}")
                stack.append((child, node))
        if live != len(self._node_by_hash):
            problems.append(
                f"_node_by_hash has {len(self._node_by_hash)} entries for "
                f"{live} live nodes (stale entries leak memory)")
        if counts != self._worker_blocks:
            diff = {w: (counts.get(w, 0), self._worker_blocks.get(w, 0))
                    for w in set(counts) | set(self._worker_blocks)
                    if counts.get(w, 0) != self._worker_blocks.get(w, 0)}
            problems.append(
                f"claim counters diverge (worker: actual vs counted) {diff}")
        return problems
