"""Concrete sharding-spec assignment for params, step inputs and KV caches.

Baseline policy (recorded in EXPERIMENTS.md and iterated in §Perf):

* **Parameters / optimizer state** — fully-sharded (FSDP+TP): for every ≥2-D
  leaf, the largest non-stack dim is sharded over ``model`` and the next
  largest over ``data`` (each subject to divisibility). Embedding tables get
  (vocab→model, d_model→data).
* **Step inputs** — batch over ``(pod, data)``.
* **KV caches** — batch over ``(pod, data)``; KV heads over ``model`` when
  divisible, else head_dim over ``model``; for ``long_500k`` (batch=1) the
  cache sequence dim takes the batch axes instead (sequence-sharded KV).
"""
from __future__ import annotations


import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.sharding.policy import ShardingPolicy


def _mesh_size(policy: ShardingPolicy, axis: str) -> int:
    sizes = dict(zip(policy.mesh.axis_names, policy.mesh.devices.shape))
    return sizes.get(axis, 1)


def _data_axes(policy: ShardingPolicy):
    return tuple(a for a in ("pod", "data") if a in policy.mesh.axis_names)


def _fits(policy, size, axes):
    prod = 1
    for a in axes:
        prod *= _mesh_size(policy, a)
    return size % prod == 0 and prod > 1


def param_spec(path: str, shape, policy: ShardingPolicy) -> P:
    """Heuristic FSDP+TP spec for a parameter leaf.

    Rule knob ``_no_fsdp`` (truthy) switches to TP-only parameter sharding
    (no data-axis shard → no per-step parameter all-gathers); used by the
    serving perf variants in §Perf.
    """
    ndim = len(shape)
    parts: list = [None] * ndim
    if ndim <= 1:
        return P(*parts)  # scalars / vectors (norm scales, biases): replicated
    no_fsdp = bool(policy.rules.get("_no_fsdp"))
    is_stacked = ("stack" in path)
    start = 1 if (is_stacked and ndim >= 2) else 0
    da = _data_axes(policy)
    dspec = da if len(da) > 1 else (da[0] if da else None)

    # Megatron-style attention TP (§Perf iteration 4): shard Q/K/V
    # projections on the heads dim (output heads-sharded, zero collectives)
    # and the output projection on its contracting heads dim (psum of the
    # tiny (B,S,D) activation instead of gathering the weight); K/V fall
    # back to head_dim when kv_heads don't divide — which also matches the
    # KV-cache layout, eliminating cache re-gathers in decode.
    name = path.rsplit("[", 1)[-1]
    if ndim - start == 3 and any(t in path for t in
                                 ("'wq'", "'wk'", "'wv'", "'wo'")):
        if "'wo'" in path:
            h_dim, hd_dim, d_dim = start, start + 1, start + 2
        else:
            d_dim, h_dim, hd_dim = start, start + 1, start + 2
        if _fits(policy, shape[h_dim], ("model",)):
            parts[h_dim] = "model"
        elif _fits(policy, shape[hd_dim], ("model",)):
            parts[hd_dim] = "model"
        if not no_fsdp and _fits(policy, shape[d_dim], da):
            parts[d_dim] = dspec
        return P(*parts)
    if path.endswith("embed") and ndim == 2:
        # (vocab, d) or (d, vocab)
        v_dim = 0 if shape[0] > shape[1] else 1
        d_dim = 1 - v_dim
        if _fits(policy, shape[v_dim], ("model",)):
            parts[v_dim] = "model"
        da = _data_axes(policy)
        if not no_fsdp and _fits(policy, shape[d_dim], da):
            parts[d_dim] = da if len(da) > 1 else da[0]
        return P(*parts)
    dims = sorted(range(start, ndim), key=lambda i: -shape[i])
    used = []
    for i in dims:
        if _fits(policy, shape[i], ("model",)) and "model" not in used:
            parts[i] = "model"
            used.append("model")
            break
    if not no_fsdp:
        da = _data_axes(policy)
        for i in dims:
            if parts[i] is None and _fits(policy, shape[i], da):
                parts[i] = da if len(da) > 1 else da[0]
                break
    return P(*parts)


def param_shardings(params, policy: ShardingPolicy):
    flat = jax.tree_util.tree_flatten_with_path(params)[0]

    def leaf(path, x):
        p = jax.tree_util.keystr(path)
        return NamedSharding(policy.mesh, param_spec(p, x.shape, policy))

    return jax.tree_util.tree_map_with_path(leaf, params)


def input_shardings(specs, policy: ShardingPolicy, *, long_context=False):
    """Batch-shard every array input; scalars replicated."""
    da = _data_axes(policy)
    dspec = da if len(da) > 1 else (da[0] if da else None)

    def leaf(x):
        if x.ndim == 0:
            return NamedSharding(policy.mesh, P())
        parts = [None] * x.ndim
        if _fits(policy, x.shape[0], da):
            parts[0] = dspec
        return NamedSharding(policy.mesh, P(*parts))

    return jax.tree.map(leaf, specs)


def cache_shardings(cache, policy: ShardingPolicy, *, long_context=False):
    """Stacked KV/state cache specs (leading dim = scan periods)."""
    da = _data_axes(policy)
    dspec = da if len(da) > 1 else (da[0] if da else None)

    def leaf(path, x):
        key = jax.tree_util.keystr(path)
        parts: list = [None] * x.ndim
        shape = x.shape
        if x.ndim == 0:
            return NamedSharding(policy.mesh, P())
        # dim 0 is the scan/period dim — never sharded
        if any(k in key for k in ("'k'", "'v'", "'xk'", "'xv'")) and x.ndim == 5:
            # (periods, B, T, K, hd)
            if long_context and _fits(policy, shape[2], da):
                parts[2] = dspec            # sequence-sharded KV
            elif _fits(policy, shape[1], da):
                parts[1] = dspec
            if policy.rules.get("_kv_seq_model") and \
                    _fits(policy, shape[2], ("model",)):
                # flash-decoding layout: KV sequence over the model axis —
                # attention reduces over the sharded T with tiny softmax-stat
                # all-reduces instead of re-gathering the cache (§Perf it. 3)
                parts[2] = "model" if parts[2] is None else parts[2]
            elif _fits(policy, shape[3], ("model",)):
                parts[3] = "model"
            elif _fits(policy, shape[4], ("model",)):
                parts[4] = "model"
            return NamedSharding(policy.mesh, P(*parts))
        # generic state: (periods, B, ...) — batch over data, largest feature
        # dim over model
        if x.ndim >= 2 and _fits(policy, shape[1], da):
            parts[1] = dspec
        feat = sorted(range(2, x.ndim), key=lambda i: -shape[i])
        for i in feat:
            if _fits(policy, shape[i], ("model",)):
                parts[i] = "model"
                break
        return NamedSharding(policy.mesh, P(*parts))

    return jax.tree_util.tree_map_with_path(leaf, cache)
