"""Cached decode attention (one new token per sequence) as a Pallas kernel.

Decode is HBM-bandwidth-bound: the kernel's job is to stream the KV cache
through VMEM exactly once at full bandwidth.  Grid = (batch, kv_head,
kv_block); all G query heads of a KV group are processed together as a
(G, hd) tile so the score matmul has an MXU-friendly shape, and the online
softmax state (m, l, acc) carries in VMEM scratch across KV blocks.
Per-sequence valid lengths mask trailing cache entries.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            blk_k: int, sm_scale: float):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[0]
    k_start = ki * blk_k

    @pl.when(k_start < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                # (G, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # (blk_k, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale
        cols = k_start + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(cols < length, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention_pallas(q, k, v, lengths, *, blk_k=256, interpret=False):
    """q: (B,K,G,hd) grouped queries; k,v: (B,T,K,hd); lengths: (B,)."""
    b, kh, g, hd = q.shape
    t = k.shape[1]
    blk_k = min(blk_k, t)
    assert t % blk_k == 0
    grid = (b, kh, t // blk_k)
    sm_scale = 1.0 / np.sqrt(hd)
    kernel = functools.partial(_kernel, blk_k=blk_k, sm_scale=sm_scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b_, h_, k_: (b_,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, hd), lambda b_, h_, k_: (b_, h_, 0, 0)),
            pl.BlockSpec((1, blk_k, 1, hd), lambda b_, h_, k_: (b_, k_, h_, 0)),
            pl.BlockSpec((1, blk_k, 1, hd), lambda b_, h_, k_: (b_, k_, h_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda b_, h_, k_: (b_, h_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kh, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
        interpret=interpret,
    )(lengths, q, k, v)
