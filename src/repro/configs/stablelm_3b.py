"""StableLM-3B — dense MHA (kv=32). [hf:stabilityai/stablelm-2-1_6b; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    num_layers=32,
    d_model=2_560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=6_912,
    vocab_size=50_304,
    head_dim=80,
    activation="swiglu",
    subquadratic=False,
    source="hf:stabilityai/stablelm-2-1_6b; unverified",
)
