"""Trace-schema validation: malformed records fail fast with ValueErrors
that name the record (index or file:line) and field, instead of leaking a
KeyError/TypeError from inside the simulator."""
import math

import pytest

from repro.serving.workload import WorkloadConfig


def test_minimal_record_still_works():
    wl = WorkloadConfig.from_records([{"t": 1.0}])
    assert wl.mode == "trace"
    assert wl.trace[0].t == 1.0
    assert wl.trace[0].template == 0


def test_records_are_sorted_by_arrival():
    wl = WorkloadConfig.from_records([{"t": 2.0}, {"t": 0.5}, {"t": 1.0}])
    assert [e.t for e in wl.trace] == [0.5, 1.0, 2.0]


def test_empty_trace_allowed():
    assert WorkloadConfig.from_records([]).trace == ()


def test_missing_t_names_record_and_field():
    with pytest.raises(ValueError, match=r"record 1.*missing required "
                                         r"field 't'"):
        WorkloadConfig.from_records([{"t": 0.0}, {"template": 2}])


def test_non_numeric_t_rejected():
    with pytest.raises(ValueError, match=r"record 0.*'t' must be a number"):
        WorkloadConfig.from_records([{"t": "0.5"}])
    with pytest.raises(ValueError, match=r"'t' must be a number"):
        WorkloadConfig.from_records([{"t": True}])


def test_negative_and_non_finite_t_rejected():
    for bad in (-0.1, math.inf, math.nan):
        with pytest.raises(ValueError, match=r"finite and >= 0"):
            WorkloadConfig.from_records([{"t": bad}])


def test_non_object_record_rejected():
    with pytest.raises(ValueError, match=r"record 2.*expected an object"):
        WorkloadConfig.from_records([{"t": 0.0}, {"t": 1.0}, [1.0]])


def test_bad_template_rejected():
    with pytest.raises(ValueError, match=r"'template' must be an integer"):
        WorkloadConfig.from_records([{"t": 0.0, "template": "warm"}])
    # negative template ids are legal: sample from popularity
    wl = WorkloadConfig.from_records([{"t": 0.0, "template": -1}])
    assert wl.trace[0].template == -1


@pytest.mark.parametrize("key", ["input_tokens", "output_tokens"])
@pytest.mark.parametrize("bad", [0, -4, 1.5, "128", False])
def test_non_positive_token_counts_rejected(key, bad):
    with pytest.raises(ValueError,
                       match=rf"'{key}' must be a positive integer"):
        WorkloadConfig.from_records([{"t": 0.0, key: bad}])


def test_integral_float_token_count_accepted():
    wl = WorkloadConfig.from_records([{"t": 0.0, "input_tokens": 96.0}])
    assert wl.trace[0].input_tokens == 96


def test_trace_file_roundtrip_with_comments(tmp_path):
    p = tmp_path / "trace.jsonl"
    p.write_text('# comment\n{"t": 0.0, "template": 1}\n\n'
                 '{"t": 0.5, "input_tokens": 64}\n')
    wl = WorkloadConfig.from_trace_file(p)
    assert len(wl.trace) == 2
    assert wl.trace[1].input_tokens == 64


def test_trace_file_json_error_carries_line(tmp_path):
    p = tmp_path / "trace.jsonl"
    p.write_text('{"t": 0.0}\n{"t": oops}\n')
    with pytest.raises(ValueError, match=r"trace.jsonl:2: invalid JSON"):
        WorkloadConfig.from_trace_file(p)


def test_trace_file_schema_error_carries_line(tmp_path):
    p = tmp_path / "trace.jsonl"
    p.write_text('# header\n{"t": 0.0}\n{"t": -3.0}\n')
    with pytest.raises(ValueError, match=r"trace.jsonl:3: 't' must be "
                                         r"finite and >= 0"):
        WorkloadConfig.from_trace_file(p)
