"""RA007 bad: reaching into another module's private state."""


def poke_router_cache(cluster):
    cluster.router._state_cache = None           # owned by core/router.py


def run_prefill(cluster, batch):
    return cluster.prefill._prefill(cluster.prefill.params, batch)


def inspect_claims(indexer, h):
    return indexer._node_by_hash[h].workers      # owned by core/radix.py
