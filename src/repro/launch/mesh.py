"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches JAX device state.  The production target is TPU v5e: one pod =
16×16 = 256 chips as (data=16, model=16); the multi-pod config stacks a
leading "pod" axis over DCN: (pod=2, data=16, model=16) = 512 chips.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires >= prod(shape) local devices)."""
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
