"""KV-aware Smart Router — the mechanism of Game 3.

Per-worker cost (Dynamo Eq. 1):      c_j = ω·b_j^prefill + b_j^active
Worker selection (Eq. 2):            argmin (τ=0)  or  softmax(−c/τ) sample

``b_j^prefill`` — token blocks that would need prefilling on worker j
(total blocks − cached overlap, from the KvIndexer radix tree);
``b_j^active`` — active decode blocks on worker j (load proxy).

``best_worker`` accepts a per-request ``router_config_override`` — the hook
the paper's adaptive controller uses to switch (τ, ω) without restarts —
and a precomputed ``hashes`` memo so the request's block hashes are
computed once per request instead of once per router call.
The sequential greedy assignment this implements is best-response dynamics
in the routing congestion game (paper §4.3).

Large-pool fast path: for τ=0 pools of ``VECTORIZE_MIN_WORKERS`` or more,
the Eq. 1 argmin runs on a cached numpy load vector (rebuilt only when a
worker's load/health/capacity actually changes — ``WorkerState`` fields
are cache-invalidating properties) with elementwise operations in the
same order as the scalar loop, so results are bit-exact with the legacy
path while the per-decision cost drops from O(workers) Python arithmetic
to a handful of C-level vector ops."""
from __future__ import annotations

import math
import random
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.radix import KvIndexer, block_hashes


@dataclass(frozen=True)
class KvRouterConfig:
    overlap_weight: float = 1.0        # ω (kv_overlap_score_weight)
    temperature: float = 0.0           # τ (router_temperature)
    # Overlap scorer: "exact" walks the KvIndexer radix tree; "simhash"
    # scores from the O(1) simhash-bucketed affinity index
    # (repro.core.affinity) — approximate, production-stack style.  The
    # choice is structural (made at router construction); per-request
    # adaptive (τ, ω) overrides do not switch scorers mid-run.
    affinity: str = "exact"            # exact | simhash
    affinity_prefix_blocks: int = 4    # simhash feature window (blocks)


class WorkerState:
    """Mutable routing-table entry.  ``active_blocks``/``healthy``/
    ``capacity`` are properties so a KvPushRouter can invalidate its
    cached dense load view whenever the value actually changes; a
    standalone WorkerState (baseline routers, tests) has no router
    backref and behaves like the plain record it used to be."""

    __slots__ = ("worker_id", "_active_blocks", "_healthy", "_capacity",
                 "_router")

    def __init__(self, worker_id: int, active_blocks: float = 0,
                 healthy: bool = True, capacity: float = 1.0):
        self.worker_id = worker_id
        self._active_blocks = active_blocks
        self._healthy = healthy
        self._capacity = capacity
        self._router: Optional["KvPushRouter"] = None

    def __repr__(self):
        return (f"WorkerState(worker_id={self.worker_id}, "
                f"active_blocks={self._active_blocks}, "
                f"healthy={self._healthy}, capacity={self._capacity})")

    @property
    def active_blocks(self):
        return self._active_blocks

    @active_blocks.setter
    def active_blocks(self, value):
        if value != self._active_blocks:
            self._active_blocks = value
            if self._router is not None:
                self._router._state_cache = None

    @property
    def healthy(self):
        return self._healthy

    @healthy.setter
    def healthy(self, value):
        if value != self._healthy:
            self._healthy = value
            if self._router is not None:
                self._router._state_cache = None

    @property
    def capacity(self):
        return self._capacity

    @capacity.setter
    def capacity(self, value):
        if value != self._capacity:
            self._capacity = value
            if self._router is not None:
                self._router._state_cache = None


class KvPushRouter:
    """The router core; mirrors Dynamo's Python handler semantics."""

    # Pools below this size route through the legacy scalar path — numpy
    # call overhead beats the vector win on the paper's 2–5 worker pools.
    VECTORIZE_MIN_WORKERS = 16

    def __init__(self, num_workers: int, config: Optional[KvRouterConfig] = None,
                 indexer: Optional[KvIndexer] = None, seed: int = 0):
        self.workers: Dict[int, WorkerState] = {}
        self.config = config or KvRouterConfig()
        self.indexer = indexer or KvIndexer()
        self._rng = random.Random(seed)
        self.vectorized = True
        # approximate overlap scorer (config.affinity="simhash"): replaces
        # the radix walk with a bucket lookup on both scoring paths
        self.affinity = None
        if self.config.affinity == "simhash":
            from repro.core.affinity import SimHashAffinity
            self.affinity = SimHashAffinity(
                block_size=self.indexer.block_size,
                prefix_blocks=self.config.affinity_prefix_blocks,
                ttl=self.indexer.ttl)
        elif self.config.affinity != "exact":
            raise ValueError(
                f"unknown affinity {self.config.affinity!r}: "
                f"expected 'exact' or 'simhash'")
        # cached dense routing state:
        # (healthy ids, id→position, loads array, ids ascending?)
        self._state_cache: Optional[
            Tuple[List[int], Dict[int, int], np.ndarray, bool]] = None
        for i in range(num_workers):
            self._enlist(WorkerState(i))

    def _enlist(self, st: WorkerState) -> WorkerState:
        st._router = self
        self.workers[st.worker_id] = st
        self._state_cache = None
        return st

    # ------------------------------------------------------------- costs ----

    # Cache-affinity scale: how much active load (in request units) a full
    # prefix hit is worth in the Eq. 1 cost. Dynamo measures both terms in
    # blocks; we normalize b_active to request units and scale b_prefill so
    # ω=1 affinity competes with realistic load imbalances (calibration
    # liberty recorded in DESIGN.md).
    PREFILL_BLOCK_SCALE = 20.0

    def _normalized_load(self, ids: List[int]) -> List[float]:
        """b_j^active normalized by relative worker capacity.

        Heterogeneous pools (mixed-generation GPUs) expose different
        ``capacity`` values; the load proxy is rescaled so a worker at 50%
        of its slots competes equally regardless of absolute slot count.
        Homogeneous pools (all capacities equal) take the identity path —
        raw block counts — so legacy behavior is bit-exact.
        """
        caps = [self.workers[wid].capacity for wid in ids]
        if len(set(caps)) <= 1:
            return [float(self.workers[wid].active_blocks) for wid in ids]
        ref = sum(caps) / len(caps)
        return [self.workers[wid].active_blocks * (ref / cap)
                for wid, cap in zip(ids, caps)]

    def _dense_state(self) -> Tuple[List[int], Dict[int, int], np.ndarray,
                                    bool]:
        """Healthy ids, id→position map and numpy load vector, rebuilt only
        when some worker's load/health/capacity changed since the last
        decision (in the simulator that's the 1 s metric sync, not every
        request)."""
        cached = self._state_cache
        if cached is None:
            ids = self.healthy_ids()
            cached = self._state_cache = (
                ids,
                {wid: i for i, wid in enumerate(ids)},
                np.asarray(self._normalized_load(ids), dtype=np.float64),
                all(a < b for a, b in zip(ids, ids[1:])))
        return cached

    def costs(self, tokens: Sequence[int],
              config: Optional[KvRouterConfig] = None, now: float = 0.0,
              hashes: Optional[Sequence[int]] = None
              ) -> Tuple[List[int], List[float], List[float]]:
        """Returns (worker_ids, costs c_j, overlap fractions o_j)."""
        cfg = config or self.config
        ids = self.healthy_ids()
        scorer = self.affinity if self.affinity is not None else self.indexer
        overlaps = scorer.overlap_scores(tokens, ids, now, hashes=hashes)
        loads = self._normalized_load(ids)
        costs = []
        for ov, b_active in zip(overlaps, loads):
            b_prefill = self.PREFILL_BLOCK_SCALE * (1.0 - ov)
            costs.append(cfg.overlap_weight * b_prefill + b_active)
        return ids, costs, overlaps

    # ------------------------------------------------------------ select ----

    def best_worker(self, tokens: Sequence[int],
                    router_config_override: Optional[KvRouterConfig] = None,
                    now: float = 0.0,
                    hashes: Optional[Sequence[int]] = None
                    ) -> Tuple[int, float, List[float]]:
        """Returns (worker_id, overlap_score_of_chosen, overlap_per_worker).

        τ=0: deterministic argmin (Eq. 2 limit). τ>0: softmax over costs
        normalized by their spread (Dynamo's τ∈[0,1] operates on normalized
        costs; raw block counts would make any τ≤1 effectively greedy)."""
        cfg = router_config_override or self.config
        if (self.vectorized
                and (self.affinity is not None or self.indexer.aggregated)
                and cfg.temperature <= 0.0
                and len(self.workers) >= self.VECTORIZE_MIN_WORKERS):
            return self._best_worker_vectorized(tokens, cfg, now, hashes)
        ids, costs, overlaps = self.costs(tokens, cfg, now, hashes=hashes)
        if not ids:
            raise RuntimeError("no healthy workers")
        if cfg.temperature <= 0.0 or len(ids) == 1:
            j = min(range(len(ids)), key=lambda i: (costs[i], ids[i]))
        else:
            mn = min(costs)
            spread = max(max(costs) - mn, 1e-9)
            z = [(c - mn) / spread for c in costs]          # ∈ [0, 1]
            ws = [math.exp(-zi / cfg.temperature) for zi in z]
            tot = sum(ws)
            r = self._rng.random() * tot
            acc = 0.0
            j = len(ids) - 1
            for i, w in enumerate(ws):
                acc += w
                if r <= acc:
                    j = i
                    break
        return ids[j], overlaps[j], overlaps

    def _best_worker_vectorized(self, tokens: Sequence[int],
                                cfg: KvRouterConfig, now: float,
                                hashes: Optional[Sequence[int]]
                                ) -> Tuple[int, float, List[float]]:
        """τ=0 argmin on the cached load vector.  The sparse aggregated
        walk yields only the warm workers; the dense overlap vector is
        filled in C.  Elementwise operations run in the exact order of the
        scalar loop (1−o, ×scale, ×ω, +load) and ties go to the smallest
        worker id, so the choice is bit-exact with the legacy path."""
        ids, pos, loads, ids_sorted = self._dense_state()
        if not ids:
            raise RuntimeError("no healthy workers")
        if hashes is None:
            hashes = block_hashes(tokens, self.indexer.block_size)
        total = max(len(hashes), 1)
        ov = np.zeros(len(ids))
        depths = (self.affinity.overlap_depths(hashes, now)
                  if self.affinity is not None
                  else self.indexer.overlap_depths(hashes, now))
        for w, d in depths.items():
            i = pos.get(w)
            if i is not None:
                ov[i] = d / total
        cost = 1.0 - ov
        cost *= self.PREFILL_BLOCK_SCALE
        cost *= cfg.overlap_weight
        cost += loads
        if ids_sorted:
            # np.argmin returns the first minimum; positions ascend with
            # worker id, so this IS the (cost, id) tie-break
            j = int(np.argmin(cost))
        else:
            ties = np.flatnonzero(cost == cost.min())
            j = int(min(ties, key=lambda i: ids[i]))
        return ids[j], float(ov[j]), ov.tolist()

    # --------------------------------------------------------- bookkeeping --

    def cache_coherent(self) -> Optional[str]:
        """Audit hook (``repro.analysis.sanitize``): compare the cached
        dense routing state against a fresh recompute from the worker
        table.  Returns ``None`` when coherent (or when no cache is
        live), else a description of the divergence.  Pure read — never
        rebuilds or invalidates the cache."""
        cached = self._state_cache
        if cached is None:
            return None
        ids, pos, loads, ids_sorted = cached
        fresh_ids = [w for w, st in self.workers.items() if st.healthy]
        if ids != fresh_ids:
            return (f"cached healthy ids {ids} != recomputed {fresh_ids} "
                    f"(a health change bypassed the property setter)")
        if pos != {wid: i for i, wid in enumerate(fresh_ids)}:
            return f"cached id->position map {pos} inconsistent with {ids}"
        fresh = np.asarray(self._normalized_load(fresh_ids), dtype=np.float64)
        if loads.shape != fresh.shape or not np.array_equal(loads, fresh):
            return (f"cached load vector {loads.tolist()} != recomputed "
                    f"{fresh.tolist()} (a load/capacity write bypassed the "
                    f"property setter)")
        if ids_sorted != all(a < b for a, b in zip(ids, ids[1:])):
            return f"cached ids-sorted flag {ids_sorted} wrong for {ids}"
        return None

    def healthy_ids(self) -> List[int]:
        """Worker ids eligible for routing, in the table's stable order —
        the positional universe of ``costs()``/``best_worker()`` overlaps.
        Served from the dense-state cache when valid (any health change
        invalidates it), so per-request callers don't rescan the table.
        Always a fresh list: the cache's own list must never be aliased
        to callers that might mutate it."""
        cached = self._state_cache
        if cached is not None:
            return list(cached[0])
        return [w for w, st in self.workers.items() if st.healthy]

    def add_worker(self, worker_id: int, capacity: float = 1.0) -> WorkerState:
        """(Re-)enlist a worker in the routing table with a clean load view
        — the Game 1 repartitioning path when a prefill-role worker flips
        into the decode pool.  Re-enlisting an id that drained out earlier
        reuses its table slot (keeping positional order stable)."""
        st = self.workers.get(worker_id)
        if st is None:
            st = self._enlist(WorkerState(worker_id))
        st.healthy = True
        st.active_blocks = 0
        st.capacity = max(capacity, 1e-9)
        if self.affinity is not None:
            # a flipped-in worker is cache-cold; stale bucket credit from
            # its previous decode stint must not survive the flip
            self.affinity.clear_worker(worker_id)
        self._state_cache = None
        return st

    def on_schedule(self, worker_id: int, tokens: Sequence[int],
                    decode_blocks: float = 1.0, now: float = 0.0,
                    hashes: Optional[Sequence[int]] = None):
        """Request placed: bump the load proxy and index its KV blocks."""
        st = self.workers[worker_id]
        st.active_blocks += decode_blocks
        if hashes is None and self.affinity is not None:
            hashes = block_hashes(tokens, self.indexer.block_size)
        self.indexer.insert(worker_id, tokens, now, hashes=hashes)
        if self.affinity is not None:
            self.affinity.insert(worker_id, hashes, now)

    def on_complete(self, worker_id: int, tokens: Sequence[int],
                    decode_blocks: float = 1.0):
        st = self.workers[worker_id]
        st.active_blocks = max(st.active_blocks - decode_blocks, 0.0)

    def set_health(self, worker_id: int, healthy: bool):
        self.workers[worker_id].healthy = healthy

    def set_capacity(self, worker_id: int, capacity: float):
        """Declare a worker's relative decode capacity (heterogeneity)."""
        self.workers[worker_id].capacity = max(capacity, 1e-9)


# ------------------------------------------------------ static baselines ----
#
# Every baseline implements the same ``best_worker(tokens,
# router_config_override=None, now=0.0, hashes=None)`` signature as
# KvPushRouter, so routing policies are drop-in interchangeable, and all of
# them skip unhealthy workers (routing to a dead worker is not a baseline,
# it's a bug).  Built from an int they keep a standalone all-healthy worker
# table; built from a KvPushRouter they share its table, so
# ``set_health`` on the router is visible to the baseline.


class _BaselineRouter:
    def __init__(self, workers):
        if isinstance(workers, KvPushRouter):
            self._table = workers.workers
        else:
            self._table = {i: WorkerState(i) for i in range(int(workers))}

    def _healthy_ids(self) -> List[int]:
        ids = [w for w, st in self._table.items() if st.healthy]
        if not ids:
            raise RuntimeError("no healthy workers")
        return ids

    def set_health(self, worker_id: int, healthy: bool):
        self._table[worker_id].healthy = healthy


class RoundRobinRouter(_BaselineRouter):
    """§9.2 counterfactual baseline: cycle over the healthy workers."""

    def __init__(self, workers):
        super().__init__(workers)
        self._i = 0

    def best_worker(self, tokens, router_config_override=None, now=0.0,
                    hashes=None):
        ids = self._healthy_ids()
        w = ids[self._i % len(ids)]
        self._i += 1
        return w, 0.0, [0.0] * len(ids)


class RandomRouter(_BaselineRouter):
    def __init__(self, workers, seed: int = 0):
        super().__init__(workers)
        self._rng = random.Random(seed)

    def best_worker(self, tokens, router_config_override=None, now=0.0,
                    hashes=None):
        ids = self._healthy_ids()
        return ids[self._rng.randrange(len(ids))], 0.0, [0.0] * len(ids)


class PowerOfTwoRouter(_BaselineRouter):
    """Pick two random workers, route to the less loaded (§9.2 baseline)."""

    def __init__(self, router: KvPushRouter, seed: int = 0):
        super().__init__(router)
        self.router = router
        self._rng = random.Random(seed)

    def best_worker(self, tokens, router_config_override=None, now=0.0,
                    hashes=None):
        ids = self._healthy_ids()
        a, b = self._rng.sample(ids, 2) if len(ids) >= 2 else (ids[0], ids[0])
        # compare capacity-normalized utilization so heterogeneous pools
        # don't starve the small workers (ties break to the first pick)
        wa = (self.router.workers[a].active_blocks
              / self.router.workers[a].capacity)
        wb = (self.router.workers[b].active_blocks
              / self.router.workers[b].capacity)
        w = a if wa <= wb else b
        return w, 0.0, [0.0] * len(ids)
