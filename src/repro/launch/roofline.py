"""Roofline report: turn dry-run JSONL records into the EXPERIMENTS.md
§Roofline table (three terms, bottleneck, MODEL_FLOPS ratio, suggestion)."""
from __future__ import annotations

import argparse
import json
import pathlib
from collections import OrderedDict

from repro.launch.hlo_analysis import HBM_BW, ICI_BW, PEAK_FLOPS

SUGGESTIONS = {
    "compute": ("already compute-bound: raise useful-FLOP fraction "
                "(less remat recompute, fewer padded matmuls)"),
    "memory": ("cut HBM traffic: fuse/tile attention (Pallas flash kernel), "
               "seq-shard activations, bf16 collectives"),
    "collective": ("cut link traffic: gather bf16 (not fp32) params, "
                   "2D-shard so gathers shrink, overlap collectives "
                   "with compute"),
}


def load(paths):
    recs = []
    for p in paths:
        for line in pathlib.Path(p).read_text().splitlines():
            if line.strip():
                recs.append(json.loads(line))
    # newest record per (mesh, arch, shape) wins
    dedup = OrderedDict()
    for r in recs:
        if r.get("skipped"):
            continue
        dedup[(r["mesh"], r["arch"], r["shape"])] = r
    return list(dedup.values())


def fmt_row(r):
    rf = r["roofline"]
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {rf['compute_s']:.3g} | {rf['memory_s']:.3g} "
            f"| {rf['collective_s']:.3g} | {rf['bottleneck']} "
            f"| {rf['roofline_fraction']:.2f} "
            f"| {r['useful_flops_ratio']:.2f} "
            f"| {r['memory']['argument_size_in_bytes']/2**30:.1f} "
            f"| {r['memory']['temp_size_in_bytes']/2**30:.1f} |")


HEADER = ("| arch | shape | mesh | compute (s) | memory (s) | collective (s) "
          "| bottleneck | roofline frac | useful-FLOP ratio | args GiB/dev "
          "| temps GiB/dev |\n"
          "|---|---|---|---|---|---|---|---|---|---|---|")


def report(recs, mesh_filter=None):
    lines = [HEADER]
    for r in sorted(recs, key=lambda r: (r["mesh"], r["arch"], r["shape"])):
        if mesh_filter and r["mesh"] != mesh_filter:
            continue
        lines.append(fmt_row(r))
    return "\n".join(lines)


def summarize(recs):
    out = []
    by_bn = {}
    for r in recs:
        by_bn.setdefault(r["roofline"]["bottleneck"], []).append(r)
    for bn, rs in sorted(by_bn.items()):
        out.append(f"- **{bn}-bound**: {len(rs)} cells — {SUGGESTIONS[bn]}")
    worst = sorted(recs, key=lambda r: r["roofline"]["roofline_fraction"])[:5]
    out.append("- worst roofline fractions: " + ", ".join(
        f"{r['arch']}/{r['shape']}@{r['mesh']}"
        f"={r['roofline']['roofline_fraction']:.2f}" for r in worst))
    most_coll = sorted(recs, key=lambda r: -(r["roofline"]["collective_s"]
                                             / max(sum((r["roofline"]["compute_s"],
                                                        r["roofline"]["memory_s"],
                                                        r["roofline"]["collective_s"])),
                                                   1e-12)))[:5]
    out.append("- most collective-bound: " + ", ".join(
        f"{r['arch']}/{r['shape']}@{r['mesh']}" for r in most_coll))
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("jsonl", nargs="+")
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    recs = load(args.jsonl)
    print(f"# Roofline (TPU v5e constants: {PEAK_FLOPS/1e12:.0f} TFLOP/s, "
          f"{HBM_BW/1e9:.0f} GB/s HBM, {ICI_BW/1e9:.0f} GB/s ICI)\n")
    print(report(recs, args.mesh))
    print()
    print(summarize(recs))


if __name__ == "__main__":
    main()
