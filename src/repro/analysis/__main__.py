"""CLI for the repo-specific lint pass.

    python -m repro.analysis src tests benchmarks examples
    python -m repro.analysis --list-rules
    python -m repro.analysis --select RA001,RA003 src
    python -m repro.analysis --allowlist allow.txt src

Exit status 0 when clean, 1 when any finding survives suppression, 2 on
usage errors.  CI runs this over the whole tree with no allowlist.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis.lint import RULES, lint_paths, rule_catalog


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-specific AST lint pass (rules RA001-RA011)")
    ap.add_argument("paths", nargs="*", help="files or directories to lint")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule codes to run (default: all)")
    ap.add_argument("--allowlist", default=None,
                    help="file of 'RULE path-substring' lines to suppress")
    args = ap.parse_args(argv)

    if args.list_rules:
        print(rule_catalog())
        return 0
    if not args.paths:
        ap.print_usage(sys.stderr)
        return 2

    select = None
    if args.select:
        select = [c.strip() for c in args.select.split(",") if c.strip()]
        known = {r.code for r in RULES}
        bad = [c for c in select if c not in known]
        if bad:
            print(f"unknown rule code(s): {', '.join(bad)}", file=sys.stderr)
            return 2

    allowlist = ()
    if args.allowlist:
        allowlist = Path(args.allowlist).read_text().splitlines()

    missing = [p for p in args.paths if not Path(p).exists()]
    if missing:
        print(f"no such path(s): {', '.join(missing)}", file=sys.stderr)
        return 2

    findings = lint_paths(args.paths, select=select, allowlist=allowlist)
    for f in findings:
        print(f.format())
    if findings:
        print(f"\n{len(findings)} finding(s).", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
