"""Game 1 in the serving loop: watch the Planner repartition P/D at runtime.

Runs the ``elastic-70b`` scenario — a unified 6-worker pool that starts
decode-heavy (1P/5D) under stationary closed-loop load — once with the
Planner enabled and once with static roles, and prints the Game 1
observables the simulator logs every poll: per-slot roles, the realized
split against the variational equilibrium of the profiled response curves,
measured SLO-violation rates, and the resource-game PoA-hat next to the
routing PoA-hat.

    PYTHONPATH=src python examples/elastic_repartition.py
"""
from repro.serving.scenarios import build_simulator


def describe(tag: str, planner: bool) -> None:
    sim = build_simulator("elastic-70b", seed=0, fast=True, planner=planner)
    res = sim.run()
    s = res.overall()
    print(f"\n=== {tag} ===")
    print(f"completed={len(res.completed)}  ttft_p99={s.ttft_p99:.3f}s  "
          f"rps={s.rps:.1f}  routing PoA-hat={s.poa:.2f}")
    if not planner:
        print(f"roles pinned at {res.poll_log[0]['roles']} "
              f"(split {res.poll_log[0]['split']})")
        return
    print("t      roles   split  viol(ttft,itl)  ve_gp  poa_resource")
    for p in res.poll_log:
        rg = p.get("resource_game")
        if rg is None:
            continue
        print(f"{p['t']:5.1f}  {p['roles']}  {tuple(p['split'])!s:6s} "
              f"({p['ttft_viol']:.2f},{p['itl_viol']:.2f})        "
              f"{rg['ve_gp']}      {rg['poa_resource']:.2f}")
    print(f"\nrole flips ({len(res.role_flips)}):")
    for t, wid, kind in res.role_flips:
        print(f"  t={t:6.2f}s  worker {wid} -> {kind.split('_')[1]}")
    print("(a worker flipping to decode starts cache-cold, and a draining "
          "worker stops admitting, finishes its decodes, then flushes its "
          "KVBM and KvIndexer claims — the paper's real switching costs)")


def main() -> None:
    describe("static roles (Planner disabled)", planner=False)
    describe("elastic (Planner repartitions every adjust interval)",
             planner=True)


if __name__ == "__main__":
    main()
