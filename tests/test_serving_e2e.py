"""End-to-end disaggregated serving on a real reduced model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import build_model
from repro.serving.disagg import DisaggregatedCluster, ServeRequest
from repro.serving.workload import template_tokens

# real-model end-to-end runs (jit compiles per arch): tier-2 only
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def cluster_setup():
    cfg = get_reduced("phi4-mini-3.8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), jnp.bfloat16)
    return cfg, model, params


def _toks(cfg, template, n=24):
    return [t % cfg.vocab_size for t in template_tokens(template, n)]


def test_all_requests_complete(cluster_setup):
    cfg, model, params = cluster_setup
    cluster = DisaggregatedCluster(model, params, num_decode=2,
                                   slots_per_worker=2, max_len=64)
    for i in range(6):
        cluster.submit(ServeRequest(f"r{i}", _toks(cfg, i % 3),
                                    max_new_tokens=4))
    done = cluster.run_until_done()
    assert len(done) == 6
    assert all(len(r.output) >= 5 for r in done)
    assert all(r.finish_t > r.first_token_t >= r.submit_t >= 0 for r in done)


def test_greedy_continuation_matches_monolithic(cluster_setup):
    """The disaggregated prefill→transfer→decode path must produce the same
    greedy tokens as a monolithic forward pass."""
    cfg, model, params = cluster_setup
    cluster = DisaggregatedCluster(model, params, num_decode=2,
                                   slots_per_worker=2, max_len=64)
    toks = _toks(cfg, 0)
    cluster.submit(ServeRequest("x", toks, max_new_tokens=6))
    done = cluster.run_until_done()
    out = done[0].output
    seq = list(toks)
    for expected in out:
        logits, _ = model.prefill(params, {
            "tokens": jnp.asarray(seq, jnp.int32)[None]})
        assert int(np.argmax(np.asarray(logits[0]))) == expected
        seq.append(expected)


def test_metrics_and_poa_exported(cluster_setup):
    cfg, model, params = cluster_setup
    cluster = DisaggregatedCluster(model, params, num_decode=2,
                                   slots_per_worker=2, max_len=64)
    for i in range(4):
        cluster.submit(ServeRequest(f"m{i}", _toks(cfg, i % 2),
                                    max_new_tokens=3))
    cluster.run_until_done()
    text = cluster.metrics.export_text()
    assert "game_saturation_state" in text
    assert cluster.poa.window_size() == 4


def test_backpressure_requeues(cluster_setup):
    """More requests than total slots: scheduler must retry, not drop."""
    cfg, model, params = cluster_setup
    cluster = DisaggregatedCluster(model, params, num_decode=1,
                                   slots_per_worker=1, max_len=64)
    for i in range(3):
        cluster.submit(ServeRequest(f"b{i}", _toks(cfg, i), max_new_tokens=2))
    done = cluster.run_until_done()
    assert len(done) == 3


def test_cache_affinity_routing(cluster_setup):
    """Repeated template should gravitate to its cache-warm worker."""
    cfg, model, params = cluster_setup
    cluster = DisaggregatedCluster(model, params, num_decode=2,
                                   slots_per_worker=4, max_len=64,
                                   adaptive=False)
    # serialize submissions so affinity has state to exploit
    workers = []
    for i in range(4):
        cluster.submit(ServeRequest(f"a{i}", _toks(cfg, 0), max_new_tokens=2))
        done = cluster.run_until_done()
        workers.append(done[-1].worker)
    assert len(set(workers[1:])) == 1  # locked onto the warm worker
