"""jit'd wrapper for the decode-attention Pallas kernel (interpret on CPU)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import (
    decode_attention_pallas)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("blk_k", "interpret"))
def decode_attention(q, k, v, lengths, *, blk_k=256, interpret=None):
    """q: (B,H,hd); k,v: (B,T,K,hd); lengths: (B,). Returns (B,H,hd).

    Rows with ``length == 0`` return zeros (empty online softmax): the
    serving path hands the kernel the full fixed-slot batch, and inactive
    slots carry length 0 — their output must be finite (it is discarded),
    never NaN."""
    if interpret is None:
        interpret = not _on_tpu()
    b, h, hd = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    blk_k = min(blk_k, max(8, t))
    pad = (-t) % blk_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qg = q.reshape(b, kh, g, hd)
    out = decode_attention_pallas(qg, k, v, lengths.astype(jnp.int32),
                                  blk_k=blk_k, interpret=interpret)
    return out.reshape(b, h, hd)
