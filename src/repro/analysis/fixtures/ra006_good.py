"""RA006 good: sets are sorted before any order-sensitive consumption;
membership tests and set algebra (orderless uses) are fine."""


def drain_workers(workers):
    for wid in sorted(set(workers)):
        evict(wid)


def collect(claims):
    return sorted({x.key for x in claims})


def membership_only(ids, candidates):
    live = set(ids)                      # building a set is fine
    return [c for c in candidates if c in live]   # iterating a list
