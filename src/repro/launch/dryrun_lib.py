"""Dry-run machinery (mesh-agnostic; the CLI in ``dryrun.py`` sets the
512-device XLA flag before importing this).

For every (architecture × input-shape × mesh) cell we build the appropriate
step function (``train_step`` / ``prefill_step`` / ``decode_step``), attach
the baseline shardings from ``repro.sharding.specs``, ``.lower().compile()``
it against ShapeDtypeStruct stand-ins (no allocation), and extract:

  * ``compiled.memory_analysis()``  — per-device bytes (proves it fits)
  * ``compiled.cost_analysis()``    — per-device FLOPs / bytes accessed
  * collective bytes parsed from the post-SPMD HLO text

which feed the §Roofline terms in EXPERIMENTS.md.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config, shape_applicable
from repro.launch import hlo_analysis
from repro.models import build_model
from repro.sharding import ShardingPolicy, use_policy
from repro.sharding.specs import (cache_shardings, input_shardings,
                                  param_shardings)
from repro.training import optimizer as opt_lib

OPT_CFG = opt_lib.OptimizerConfig()


def _memory_dict(mem) -> dict:
    keys = ("generated_code_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "alias_size_in_bytes",
            "temp_size_in_bytes")
    return {k: int(getattr(mem, k)) for k in keys}


def build_step(arch: str, shape_name: str, policy: ShardingPolicy,
               *, remat=True, cfg=None):
    """Returns (fn, args_abstract, in_shardings, donate_argnums, model)."""
    cfg = cfg if cfg is not None else get_config(arch)
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    long_ctx = shape.name == "long_500k"

    if shape.kind == "train":
        params = model.init_abstract(jnp.float32)
        opt = jax.eval_shape(opt_lib.init, params)
        state = {"params": params, "opt": opt}
        batch = model.input_specs(shape)
        p_sh = param_shardings(params, policy)
        state_sh = {"params": p_sh,
                    "opt": {"m": p_sh, "v": p_sh,
                            "step": NamedSharding(policy.mesh, P())}}
        b_sh = input_shardings(batch, policy)

        def train_step(state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: model.train_loss(p, batch, remat=remat))(state["params"])
            new_p, new_opt, stats = opt_lib.update(
                OPT_CFG, state["params"], grads, state["opt"])
            return {"params": new_p, "opt": new_opt}, (loss, stats)

        return train_step, (state, batch), (state_sh, b_sh), (0,), model

    params = model.init_abstract(jnp.bfloat16)
    p_sh = param_shardings(params, policy)

    if shape.kind == "prefill":
        batch = model.input_specs(shape)
        b_sh = input_shardings(batch, policy)

        def prefill_step(params, batch):
            return model.prefill(params, batch)

        return prefill_step, (params, batch), (p_sh, b_sh), (), model

    caches = model.cache_specs(shape)
    c_sh = cache_shardings(caches, policy, long_context=long_ctx)
    inp = model.input_specs(shape)
    t_sh = input_shardings(inp["tokens"], policy)
    s_sh = NamedSharding(policy.mesh, P())

    def decode_step(params, caches, tokens, cur_index):
        return model.decode(params, caches, tokens, cur_index)

    args = (params, caches, inp["tokens"], inp["cur_index"])
    return decode_step, args, (p_sh, c_sh, t_sh, s_sh), (1,), model


def _shallow_config(cfg, model, k: int):
    """Same architecture at depth = k periods (for linear cost extrapolation)."""
    import dataclasses
    over = {"num_layers": model.period * k}
    if cfg.num_encoder_layers:
        over["num_encoder_layers"] = k
    return dataclasses.replace(cfg, **over)


def _compile_once(arch, shape_name, policy, mesh, *, remat, cfg=None):
    fn, args, in_sh, donate, model = build_step(
        arch, shape_name, policy, remat=remat, cfg=cfg)
    t0 = time.time()
    lowered = jax.jit(fn, in_shardings=in_sh,
                      donate_argnums=donate).lower(*args)
    lower_s = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    return compiled, model, lower_s, compile_s


def _extract_costs(compiled, n_dev):
    cost = compiled.cost_analysis() or {}
    flops = float(cost.get("flops", 0.0) or 0.0)
    nbytes = float(cost.get("bytes accessed", 0.0) or 0.0)
    coll = hlo_analysis.collective_bytes(compiled.as_text(), n_dev)
    return flops, nbytes, coll


def run_cell(arch: str, shape_name: str, mesh, *, rules: Optional[dict] = None,
             remat=True, verbose=True, skip_collectives=False) -> dict:
    """One dry-run cell.

    1. Full model, loops rolled: ``.lower().compile()`` proof +
       ``memory_analysis()`` (the deliverable-(e) artifact).
    2. FLOPs / bytes from the jaxpr cost counter (launch/jaxpr_cost.py):
       exact trip-count multiplication of every scan, fast on rolled
       models (XLA's cost_analysis counts a `while` body once).
    3. Collectives from shallow depth-1/depth-2 compiles where only the
       *layer stack* is unrolled (collectives — FSDP gathers, gradient
       reductions — live at layer boundaries, not inside the inner chunk
       scans), extrapolated linearly in depth:
           total = coll(k=1) + (n_periods − 1) · [coll(k=2) − coll(k=1)]
    """
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    if not shape_applicable(cfg, shape):
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "long_500k requires sub-quadratic attention"}
    n_dev = mesh.devices.size
    policy = ShardingPolicy(mesh, rules)
    record = {"arch": arch, "shape": shape_name,
              "mesh": "x".join(str(s) for s in mesh.devices.shape),
              "devices": int(n_dev), "skipped": False}
    from repro.launch import jaxpr_cost
    from repro.models import runtime_flags as flags

    with mesh, use_policy(policy):
        fn, args, in_sh, donate, model = build_step(
            arch, shape_name, policy, remat=remat)
        t0 = time.time()
        lowered = jax.jit(fn, in_shardings=in_sh,
                          donate_argnums=donate).lower(*args)
        record["lower_s"] = round(time.time() - t0, 2)
        t0 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t0, 2)
        record["memory"] = _memory_dict(compiled.memory_analysis())
        full_coll = hlo_analysis.collective_bytes(compiled.as_text(), n_dev)
        del compiled, lowered

        # exact flops/bytes from the jaxpr (global shapes → per-device)
        cost = jaxpr_cost.cost_of(fn, *args)
        flops = cost.flops / n_dev
        nbytes = cost.bytes / n_dev

        coll_total = 0.0
        coll_kinds = {}
        coll_counts = {}
        if not skip_collectives:
            with flags.unroll_for_analysis():
                c1, _, _, _ = _compile_once(
                    arch, shape_name, policy, mesh, remat=remat,
                    cfg=_shallow_config(cfg, model, 1))
                _, _, coll1 = _extract_costs(c1, n_dev)
                del c1
                c2, _, _, _ = _compile_once(
                    arch, shape_name, policy, mesh, remat=remat,
                    cfg=_shallow_config(cfg, model, 2))
                _, _, coll2 = _extract_costs(c2, n_dev)
                del c2
            p = model.n_periods
            coll_total = coll1.total_bytes + (p - 1) * (coll2.total_bytes
                                                        - coll1.total_bytes)
            coll_kinds = {
                k: coll1.bytes_by_kind.get(k, 0.0)
                + (p - 1) * (coll2.bytes_by_kind.get(k, 0.0)
                             - coll1.bytes_by_kind.get(k, 0.0))
                for k in set(coll1.bytes_by_kind) | set(coll2.bytes_by_kind)}
            coll_counts = dict(coll2.counts)

    record["cost"] = {"flops": flops, "bytes_accessed": nbytes,
                      "source": "jaxpr"}
    record["collectives"] = {
        "counts_per_depth2": coll_counts,
        "bytes_by_kind": coll_kinds,
        "total_bytes": coll_total,
        "full_rolled_counts": dict(full_coll.counts),
    }

    class _C:  # lightweight stand-in for roofline_terms
        total_bytes = coll_total
    record["roofline"] = hlo_analysis.roofline_terms(
        {"flops": flops, "bytes accessed": nbytes}, _C)

    mf_dev = model.model_flops(shape) / n_dev
    record["model_flops_per_device"] = mf_dev
    record["useful_flops_ratio"] = (mf_dev / flops) if flops else 0.0
    if verbose:
        r = record["roofline"]
        print(f"[{record['mesh']}] {arch:22s} {shape_name:12s} "
              f"compile={record['compile_s']:6.1f}s "
              f"comp={r['compute_s']:.3e}s mem={r['memory_s']:.3e}s "
              f"coll={r['collective_s']:.3e}s -> {r['bottleneck']}"
              f" frac={r['roofline_fraction']:.2f} "
              f"useful={record['useful_flops_ratio']:.2f}", flush=True)
    return record
