"""Core transformer layers: RMSNorm, RoPE, GQA attention, MLPs.

Pure-JAX parameter-dict style.  Compute runs in bf16 with fp32 softmax and
norms; parameters are stored in the dtype handed to ``init`` (fp32 for
training, bf16 for serving).

Attention supports: causal self-attention (train / prefill), single-token
cached decode, bidirectional encoding, and cross-attention — all with
grouped-query heads.  When ``use_flash`` is set and the call is a pure causal
self-attention, the Pallas flash kernel is used instead of the XLA einsum
path (see ``repro.kernels.flash_attention``).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.models import runtime_flags as flags
from repro.sharding import shard

COMPUTE_DTYPE = jnp.bfloat16


def _init(rng, shape, scale, dtype):
    return (jax.random.normal(rng, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------- norms ----

def rmsnorm_init(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dt)


# ----------------------------------------------------------------- rope ----

def rope_table(positions, head_dim, theta):
    """positions: int32 (...,S) → (cos, sin) each (...,S,head_dim//2) fp32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x, cos, sin):
    """x: (B,S,H,hd); cos/sin: (B,S,half) or (S,half)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    xf = x.astype(jnp.float32)
    x1f, x2f = xf[..., :half], xf[..., half:]
    out = jnp.concatenate([x1f * cos - x2f * sin, x1f * sin + x2f * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------ attention ----

def attention_init(rng, cfg, dtype, cross=False):
    d, h, k, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    r = jax.random.split(rng, 5)
    s_in = d ** -0.5
    s_out = (h * hd) ** -0.5
    p = {
        "norm": rmsnorm_init(d, dtype),
        "wq": _init(r[0], (d, h, hd), s_in, dtype),
        "wk": _init(r[1], (d, k, hd), s_in, dtype),
        "wv": _init(r[2], (d, k, hd), s_in, dtype),
        "wo": _init(r[3], (h, hd, d), s_out, dtype),
    }
    return p


def _sdpa(q, k, v, mask, q_per_kv):
    """q: (B,S,H,hd) — k,v: (B,T,K,hd) — mask broadcastable to (B,K,G,S,T)."""
    b, s, h, hd = q.shape
    kheads = k.shape[2]
    q = q.reshape(b, s, kheads, q_per_kv, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", q, k).astype(jnp.float32)
    scores = scores / np.sqrt(hd)
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(b, s, h, hd)


# Queries are processed in blocks of this length so the (S×T) score matrix is
# never fully materialized — the XLA-path analogue of flash-attention tiling
# (the Pallas kernel is the production TPU path).
Q_CHUNK = 1024


def _sdpa_chunked(q, k, v, qpos, q_per_kv, *, kind, kv_lengths=None,
                  q_chunk=None):
    if q_chunk is None:
        q_chunk = flags.Q_CHUNK_OVERRIDE or Q_CHUNK
    """Memory-bounded attention. kind: 'causal' (kv_pos<=q_pos), 'full',
    or 'length' (kv_pos < kv_lengths). qpos: (B,S) int32 query positions."""
    b, s, h, hd = q.shape
    t = k.shape[1]

    def block(q_blk, qp_blk):
        mask = None
        if kind == "causal":
            kv_pos = jnp.arange(t, dtype=jnp.int32)
            mask = kv_pos[None, None, None, None, :] <= qp_blk[:, None, None, :, None]
        elif kind == "length" and kv_lengths is not None:
            kv_pos = jnp.arange(t, dtype=jnp.int32)
            mask = kv_pos[None, None, None, None, :] < kv_lengths[:, None, None, None, None]
        return _sdpa(q_blk, k, v, mask, q_per_kv)

    if s <= q_chunk:
        return block(q, qpos)
    pad = (-s) % q_chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        qpos = jnp.pad(qpos, ((0, 0), (0, pad)))
    nq = q.shape[1] // q_chunk
    qr = jnp.moveaxis(q.reshape(b, nq, q_chunk, h, hd), 1, 0)
    pr = jnp.moveaxis(qpos.reshape(b, nq, q_chunk), 1, 0)
    _, outs = jax.lax.scan(lambda c, args: (c, block(*args)), None, (qr, pr),
                           unroll=flags.inner_scan_unroll(nq))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, nq * q_chunk, h, hd)
    return out[:, :s]


def attention(params, x, cfg, *, positions=None, kv_cache=None, write_index=None,
              kv_source=None, causal=True, kv_lengths=None, use_rope=True,
              use_flash=False, decode_impl="sdpa", page_table=None):
    """General GQA attention.

    x: (B,S,D) hidden states.
    positions: (S,) or (B,S) int32 query positions (for RoPE + causal mask).
    kv_cache: dict(k=(B,T,K,hd), v=...) — decode / incremental mode. K/V for
        the current tokens are written at ``write_index``; attention spans the
        whole cache masked by position.  Under a paged ``decode_impl`` the
        cache is instead the global page pool dict(k=(N,block,K,hd), v=...)
        indirected through ``page_table``.
    kv_source: (B,T,D) — cross-attention keys/values come from here.
    kv_lengths: (B,) valid KV length per batch row (cross / cache masking).
    decode_impl: "sdpa" (XLA einsum path) or "pallas" — on a single-token
        cached step the Pallas ragged decode-attention kernel streams the KV
        cache once, masked per-row by the (B,) position vector (TPU-compiled;
        interpret mode on CPU).  Multi-token calls always use the XLA path.
        "paged" / "paged_sdpa" use the page-pool layout: "paged" runs the
        Pallas paged-attention kernel (page-table-indirected block loads),
        "paged_sdpa" gathers the slot's pages into a dense view and reuses
        the XLA causal path (bit-compatible with "sdpa", CPU-meaningful).
    page_table: (B, W) int32 page ids per slot (paged decode only).
        Unmapped entries point at the trash page 0 and are masked by length.
    Returns (out, new_kv_cache_or_None).
    """
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    xn = rmsnorm(params["norm"], x, cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", xn, params["wq"].astype(COMPUTE_DTYPE))
    q = shard(q, "batch", "seq", "heads", "head_dim")
    src = xn if kv_source is None else kv_source.astype(xn.dtype)
    k = jnp.einsum("bsd,dhk->bshk", src, params["wk"].astype(COMPUTE_DTYPE))
    v = jnp.einsum("bsd,dhk->bshk", src, params["wv"].astype(COMPUTE_DTYPE))

    if use_rope and kv_source is None:
        if positions is None:
            positions = jnp.arange(s, dtype=jnp.int32)
        cos, sin = rope_table(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    if positions is None:
        qp = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    elif positions.ndim == 1:
        qp = jnp.broadcast_to(positions[None].astype(jnp.int32), (b, s))
    else:
        qp = positions.astype(jnp.int32)

    if kv_cache is not None and decode_impl in ("paged", "paged_sdpa"):
        # Paged single-token decode: the cache is the global page pool
        # (N, block, K, hd); row b's KV position p lives in
        # pool[page_table[b, p // block], p % block].  Write this step's
        # K/V at the slot's current position (inactive rows sit at
        # position 0 with an all-trash table row, so their writes land in
        # the reserved trash page 0 and are masked by length), then attend
        # over the slot's pages up to kv_pos <= q_pos.
        if s != 1:
            raise ValueError("paged decode handles single-token steps only")
        if page_table is None:
            raise ValueError(f"decode_impl={decode_impl!r} needs a page_table")
        k = shard(k, "decode_batch", None, "kv_heads", "kv_head_dim")
        v = shard(v, "decode_batch", None, "kv_heads", "kv_head_dim")
        ck, cv = kv_cache["k"], kv_cache["v"]
        block = ck.shape[1]
        table = jnp.asarray(page_table, jnp.int32)
        pos = qp[:, 0]
        page = table[jnp.arange(b, dtype=jnp.int32), pos // block]
        off = pos % block
        ck = ck.at[page, off].set(k[:, 0].astype(ck.dtype))
        cv = cv.at[page, off].set(v[:, 0].astype(cv.dtype))
        new_cache = {"k": ck, "v": cv}
        lengths = pos + 1
        if decode_impl == "paged":
            from repro.kernels.paged_attention import ops as paged_ops
            out = paged_ops.paged_attention(
                q[:, 0], ck, cv, table, lengths)[:, None]
        else:
            from repro.kernels.paged_attention.ref import gather_pages
            kd = gather_pages(ck, table).astype(COMPUTE_DTYPE)
            vd = gather_pages(cv, table).astype(COMPUTE_DTYPE)
            out = _sdpa_chunked(q, kd, vd, qp, cfg.q_heads_per_kv,
                                kind="causal")
        out = jnp.einsum("bshk,hkd->bsd", out,
                         params["wo"].astype(COMPUTE_DTYPE))
        return shard(out, "batch", "seq", "act_embed"), new_cache

    new_cache = None
    if kv_cache is not None:
        # write current K/V at write_index, attend over the full cache.
        # write_index may be a scalar (aligned batch) or an int32 (B,) vector
        # (ragged continuous batching — masked scatter, S must be 1).
        # Constrain the incoming K/V to the cache's layout first — otherwise
        # XLA's SPMD partitioner resolves the sharding mismatch inside the
        # update by replicating the FULL cache (§Perf iteration 2: this was
        # ~50% of decode collective traffic).
        k = shard(k, "decode_batch", None, "kv_heads", "kv_head_dim")
        v = shard(v, "decode_batch", None, "kv_heads", "kv_head_dim")
        ck, cv = kv_cache["k"], kv_cache["v"]
        widx = jnp.asarray(write_index, jnp.int32) if write_index is not None \
            else jnp.int32(0)
        if widx.ndim == 0:
            ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                              (0, widx, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                              (0, widx, 0, 0))
        else:
            sel = (jnp.arange(ck.shape[1], dtype=jnp.int32)[None, :, None, None]
                   == widx[:, None, None, None])
            ck = jnp.where(sel, k.astype(ck.dtype), ck)
            cv = jnp.where(sel, v.astype(cv.dtype), cv)
        new_cache = {"k": ck, "v": cv}
        k, v = ck.astype(COMPUTE_DTYPE), cv.astype(COMPUTE_DTYPE)

    if kv_cache is not None:
        if decode_impl == "pallas" and s == 1:
            # Ragged single-token decode: one kernel pass over the whole
            # slot batch, each row masked to its own valid prefix
            # (kv_pos <= q_pos  ⇔  kv_pos < q_pos + 1).  The kernel's
            # online softmax runs in fp32 like the _sdpa path's scores.
            from repro.kernels.decode_attention import ops as decode_ops
            lengths = qp[:, 0].astype(jnp.int32) + 1
            out = decode_ops.decode_attention(q[:, 0], k, v, lengths)[:, None]
            out = jnp.einsum("bshk,hkd->bsd", out,
                             params["wo"].astype(COMPUTE_DTYPE))
            return shard(out, "batch", "seq", "act_embed"), new_cache
        kind = "causal"
    elif kv_source is not None:
        kind = "length" if kv_lengths is not None else "full"
    elif causal:
        if use_flash and s == k.shape[1] and s % 128 == 0:
            from repro.kernels.flash_attention import ops as flash_ops
            out = flash_ops.flash_attention(q, k, v, causal=True)
            out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(COMPUTE_DTYPE))
            return shard(out, "batch", "seq", "act_embed"), new_cache
        kind = "causal"
    else:
        kind = "full"

    out = _sdpa_chunked(q, k, v, qp, cfg.q_heads_per_kv, kind=kind,
                        kv_lengths=kv_lengths)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(COMPUTE_DTYPE))
    return shard(out, "batch", "seq", "act_embed"), new_cache


def attention_cache_init(cfg, batch, max_len, dtype=COMPUTE_DTYPE):
    k, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, max_len, k, hd), dtype),
        "v": jnp.zeros((batch, max_len, k, hd), dtype),
    }


def paged_attention_cache_init(cfg, num_pages, block, dtype=COMPUTE_DTYPE):
    """Global KV page pool shared by every decode slot.  ``num_pages`` must
    include the reserved trash page 0 (the engine allocates pool size
    ``allocatable + 1``)."""
    k, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((num_pages, block, k, hd), dtype),
        "v": jnp.zeros((num_pages, block, k, hd), dtype),
    }


# ------------------------------------------------------------------ mlp ----

def mlp_init(rng, cfg, dtype, d_ff=None):
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    r = jax.random.split(rng, 3)
    p = {"norm": rmsnorm_init(d, dtype)}
    if cfg.activation == "swiglu":
        p["wg"] = _init(r[0], (d, f), d ** -0.5, dtype)
    p["wu"] = _init(r[1], (d, f), d ** -0.5, dtype)
    p["wd"] = _init(r[2], (f, d), f ** -0.5, dtype)
    return p


def mlp(params, x, cfg):
    xn = rmsnorm(params["norm"], x, cfg.norm_eps)
    wu = params["wu"].astype(COMPUTE_DTYPE)
    wd = params["wd"].astype(COMPUTE_DTYPE)
    h = jnp.einsum("bsd,df->bsf", xn, wu)
    h = shard(h, "batch", "seq", "act_mlp")
    if cfg.activation == "swiglu":
        g = jnp.einsum("bsd,df->bsf", xn, params["wg"].astype(COMPUTE_DTYPE))
        h = jax.nn.silu(g) * h
    elif cfg.activation == "squared_relu":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    out = jnp.einsum("bsf,fd->bsd", h, wd)
    return shard(out, "batch", "seq", "act_embed")
