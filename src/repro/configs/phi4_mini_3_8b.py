"""Phi-4-mini 3.8B — dense, RoPE SwiGLU GQA. [arXiv:2412.08905; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3_072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=8_192,
    vocab_size=200_064,
    head_dim=128,
    activation="swiglu",
    subquadratic=False,
    source="arXiv:2412.08905; hf",
)
