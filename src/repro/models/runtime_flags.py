"""Global model-execution flags.

``unroll_for_analysis`` — the dry-run sets this so every bounded loop
(layer-stack scan, attention q-chunking, loss chunking, SSD/mLSTM chunk
scans) is fully unrolled in the lowered HLO.  XLA's ``cost_analysis()``
counts a ``while`` body once rather than multiplying by trip count, so
unrolling is what makes the roofline FLOP/byte numbers exact.  (The sLSTM
per-token recurrence stays a loop: its in-loop compute — the small recurrent
block-diagonal matmuls — is <2% of xLSTM model FLOPs; noted in
EXPERIMENTS.md.)

Execution paths (tests, examples, serving) keep loops rolled.
"""
from __future__ import annotations

import contextlib

UNROLL_FOR_ANALYSIS = False


@contextlib.contextmanager
def unroll_for_analysis():
    global UNROLL_FOR_ANALYSIS
    prev = UNROLL_FOR_ANALYSIS
    UNROLL_FOR_ANALYSIS = True
    try:
        yield
    finally:
        UNROLL_FOR_ANALYSIS = prev


def scan_unroll(length: int) -> int:
    """Outer loops (layer stack, encoder stack, CE loss chunks): unrolled in
    analysis mode so per-depth XLA costs and collectives are visible."""
    return length if UNROLL_FOR_ANALYSIS else 1


def inner_scan_unroll(length: int) -> int:
    """Inner chunk loops (SSD/mLSTM chunk scans, attention q-blocks): always
    rolled — tracing/compiling hundreds of unrolled chunk bodies is
    intractable on big models.  Their exact costs come from the jaxpr
    counter (launch/jaxpr_cost.py), which multiplies scan trip counts."""
    return 1


# §Perf knob: overrides layers.Q_CHUNK when set (attention q-block length).
Q_CHUNK_OVERRIDE = None
