"""Discrete-event simulator of a disaggregated serving cluster.

Faithfully wires together the paper's mechanisms — Smart Router (Eq. 1/2),
KvIndexer radix tree, KVBM frequency eviction, PoA tracker (Eq. 12),
saturation detector (Eq. 10/11), adaptive controller (Table 2), Planner —
around an event-driven cluster model with the paper's causal channels:

* requests are routed to a decode worker **at arrival** (Dynamo semantics);
* prefill is the compute-bound bottleneck; prefill work per request shrinks
  with the chosen decode worker's KV overlap (cache-warm routing skips
  recomputation — the §8.4 "redundant prefill recomputation" channel), so
  cache-oblivious spreading costs throughput;
* each decode worker has an admission cap (transfer/batch slots); requests
  bound for a saturated worker stall in its transfer queue — the herding
  pathology that blows up TTFT P99 under static greedy routing;
* template traffic is mildly skewed (realistic popularity), which is what
  lets cache-affinity herding concentrate load.

The cluster model generalizes along three scenario axes (see
``repro.serving.scenarios`` for the named registry): a prefill *pool*
(``num_prefill`` workers draining one shared queue), a possibly
heterogeneous decode pool (per-worker ``DecodeWorkerSpec`` — admission
cap, HBM blocks, ITL, KV-transfer latency — with capacity-normalized
router loads and capacity-weighted PoA counterfactuals), and three
workload modes (closed-loop ramps, open-loop Poisson/burst/diurnal
arrivals, JSONL trace replay).

Closed-loop clients maintain the workload's target concurrency. Calibrated
per model (340B / 70B; Section 7) so the paper's regime structure — PoA
plateau below the knee, first post-knee grid point at C=128, TTFT explosion
with flat ITL, throughput ceilings ≈18/47 rps — emerges from the same
mechanics the paper identifies (prefill-rate × request-residency ≈ C at the
knee). Calibration constants and deviations are logged in EXPERIMENTS.md.
"""
from __future__ import annotations

import heapq
import itertools
import math
from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from repro.core.controller import REGIME_PARAMS, DualFrontend
from repro.core.kvbm import KVBlockManager
from repro.core.metrics import MetricsRegistry
from repro.core.poa import CompletedRequest, PoATracker
from repro.core.radix import block_hashes
from repro.core.router import (KvPushRouter, KvRouterConfig, PowerOfTwoRouter,
                               RandomRouter, RoundRobinRouter)
from repro.core.saturation import DetectorConfig, SaturationDetector
from repro.serving.workload import WorkloadConfig, template_tokens

TEMPLATE_POPULARITY = (0.35, 0.25, 0.20, 0.12, 0.08)


@dataclass(frozen=True)
class DecodeWorkerSpec:
    """Per-decode-worker capacity profile (heterogeneous pools).

    A mixed-generation GPU pool is expressed as a tuple of these: newer
    cards get a larger ``decode_cap``/``g1_blocks`` and smaller
    ``itl_base``; remote nodes get a larger ``kv_transfer``.  The
    ``g2_blocks``/``g3_blocks`` tiers back the hierarchical KVBM (Def. 2):
    blocks demoted out of G1 HBM land in CPU DRAM then local SSD, from
    which they can be onboarded instead of recomputed (§8.4).
    """
    decode_cap: int = 60              # admission slots (transfer/batch)
    g1_blocks: int = 100_000          # HBM KV-block capacity
    g2_blocks: int = 400_000          # CPU-DRAM KV-block capacity
    g3_blocks: int = 1_600_000        # local-SSD KV-block capacity
    itl_base: float = 0.0090          # inter-token latency at low load (s)
    itl_slope: float = 0.000005       # load dependence (bandwidth-bound)
    kv_transfer: float = 0.012        # prefill→decode KV transfer latency (s)


@dataclass(frozen=True)
class ClusterConfig:
    """Calibrated per model/topology (paper Section 7.3/8).

    Homogeneous clusters use the scalar per-worker fields below; a
    heterogeneous decode pool is declared by ``decode_workers`` (a tuple of
    :class:`DecodeWorkerSpec`), which overrides the scalars and pins
    ``num_decode`` to its length.  ``num_prefill > 1`` models a prefill
    pool draining one shared queue.
    """
    name: str = "llama-3.1-70b"
    num_prefill: int = 1
    num_decode: int = 2
    prefill_rate: float = 47.0        # cache-warm requests/s ceiling per worker
    prefill_base: float = 0.015       # pipelined prefill latency component (s)
    miss_penalty: float = 0.65        # extra prefill work on a full cache miss
    itl_base: float = 0.0090          # inter-token latency at low load (s)
    itl_slope: float = 0.000005       # mild load dependence (bandwidth-bound)
    kv_transfer: float = 0.012        # cross-node KV transfer latency (s)
    decode_cap: int = 60              # admission slots per decode worker
    g1_blocks: int = 100_000          # per-decode-worker HBM block capacity
    g2_blocks: int = 400_000          # per-decode-worker CPU-DRAM blocks
    g3_blocks: int = 1_600_000        # per-decode-worker local-SSD blocks
    # Eq. 6 per-block onboarding latencies, α_G1 < α_G2 < α_G3 < α_G4 < γ
    # (a G1 hit is free; γ ≈ miss_penalty/prefill_rate per input block —
    # ~1.7 ms for the 70B defaults — bounds the alphas from above so
    # onboarding is always preferable to redundant recompute).
    alpha_g2: float = 0.0003          # G2→G1 onboarding per block (s)
    alpha_g3: float = 0.0012          # G3→G1 onboarding per block (s)
    alpha_g4: float = 0.0016          # G4→G1 onboarding per block (s)
    service_sigma: float = 0.5        # lognormal service jitter (batching)
    cache_ttl: float = 3.0            # radix-claim freshness (LRU churn model)
    metrics_interval: float = 1.0     # event-plane load-metric staleness (s)
    decode_workers: Tuple[DecodeWorkerSpec, ...] = ()

    def __post_init__(self):
        if self.decode_workers and self.num_decode != len(self.decode_workers):
            object.__setattr__(self, "num_decode", len(self.decode_workers))

    @property
    def worker_specs(self) -> Tuple[DecodeWorkerSpec, ...]:
        """Resolved per-worker specs (homogeneous scalars expanded)."""
        if self.decode_workers:
            return self.decode_workers
        return tuple(DecodeWorkerSpec(
            decode_cap=self.decode_cap, g1_blocks=self.g1_blocks,
            g2_blocks=self.g2_blocks, g3_blocks=self.g3_blocks,
            itl_base=self.itl_base, itl_slope=self.itl_slope,
            kv_transfer=self.kv_transfer) for _ in range(self.num_decode))

    @classmethod
    def for_model(cls, name: str, topology: str = "1P/2D") -> "ClusterConfig":
        np_str, nd_str = topology.split("/")
        npf = int(np_str.rstrip("Pp"))
        nd = int(nd_str.rstrip("Dd"))
        if "340b" in name.lower() or "nemotron" in name.lower():
            return cls(name="nemotron-4-340b", num_prefill=npf, num_decode=nd,
                       prefill_rate=19.0, prefill_base=0.030,
                       itl_base=0.0214, kv_transfer=0.030,
                       decode_cap=58 if nd <= 2 else 30)
        return cls(name="llama-3.1-70b", num_prefill=npf, num_decode=nd,
                   prefill_rate=47.0 if nd <= 2 else 49.0,
                   prefill_base=0.015, itl_base=0.0090,
                   kv_transfer=0.012,
                   decode_cap=56 if nd <= 2 else 30)


@dataclass
class SimRequest:
    rid: int
    template: int
    tokens: List[int]
    output_tokens: int
    submit_t: float = 0.0
    prefill_start: float = 0.0
    prefill_end: float = 0.0
    decode_start: float = 0.0
    finish_t: float = 0.0
    decode_worker: int = -1
    overlap: float = 0.0
    overlaps_all: Tuple[float, ...] = ()
    loads_at_schedule: Tuple[float, ...] = ()
    phase: int = 0
    # tier-coherent cache accounting (quoted at scheduling time)
    hashes: Tuple[int, ...] = ()          # chained KV block hashes
    onboard_frac: float = 0.0             # blocks onboarded from G2/G3/G4
    onboard_latency: float = 0.0          # Eq. 6 onboarding TTFT add (s)

    @property
    def ttft(self) -> float:
        return self.prefill_end - self.submit_t

    @property
    def itl(self) -> float:
        return (self.finish_t - self.decode_start) / max(self.output_tokens, 1)


class Simulator:
    """Event-driven cluster; see module docstring."""

    def __init__(self, cluster: ClusterConfig, workload: WorkloadConfig,
                 router_config: Optional[KvRouterConfig] = None,
                 adaptive: bool = False,
                 detector_config: Optional[DetectorConfig] = None,
                 routing_policy: str = "kv",       # kv|round_robin|random|p2c
                 seed: int = 0,
                 regime_params: Optional[dict] = None):
        self.cluster = cluster
        self.workload = workload
        self.specs = cluster.worker_specs
        self.now = 0.0
        self._events: List[Tuple[float, int, str, object]] = []
        self._eid = itertools.count()
        self.rng = np.random.default_rng(seed)
        # dedicated stream for open-loop arrival sampling so closed-loop
        # runs stay byte-identical to the pre-scenario simulator
        self.arrival_rng = np.random.default_rng([seed, 0xA221])
        # Template popularity: the legacy 5-template mix verbatim (identity
        # path), or a Zipf-skewed extension when the workload asks for a
        # wider template universe (cache-pressure scenarios grow the
        # working set past G1 this way).
        n_templates = workload.num_templates
        if n_templates == len(TEMPLATE_POPULARITY):
            self.template_probs = TEMPLATE_POPULARITY
        else:
            w = [1.0 / (i + 1) ** 0.9 for i in range(n_templates)]
            tot = sum(w)
            self.template_probs = tuple(x / tot for x in w)

        self.router = KvPushRouter(cluster.num_decode,
                                   router_config or KvRouterConfig(),
                                   seed=seed)
        self.router.indexer.ttl = cluster.cache_ttl
        for w, spec in enumerate(self.specs):
            self.router.set_capacity(w, float(spec.decode_cap))
        # Baselines share the router's worker table so health changes
        # propagate to every policy.
        if routing_policy == "round_robin":
            self.policy = RoundRobinRouter(self.router)
        elif routing_policy == "random":
            self.policy = RandomRouter(self.router, seed)
        elif routing_policy == "p2c":
            self.policy = PowerOfTwoRouter(self.router, seed)
        else:
            self.policy = self.router

        self.adaptive = adaptive
        self.detector = SaturationDetector(
            detector_config or DetectorConfig.for_model(cluster.name))
        self.dual = DualFrontend()
        self.regime_params = dict(regime_params or REGIME_PARAMS)
        self.metrics = MetricsRegistry()
        self.poa = PoATracker(num_workers=cluster.num_decode, window_s=30.0,
                              capacities=tuple(float(s.decode_cap)
                                               for s in self.specs))
        # Tier-coherent hierarchical cache: whenever KVBM demotes (or
        # frees) a block out of G1 HBM, the router's overlap claim for it
        # is invalidated, so cache-affinity routing only ever credits
        # G1-resident prefixes (the NetKV coherence channel).
        self.kvbm = [
            KVBlockManager(
                {"G1": spec.g1_blocks, "G2": spec.g2_blocks,
                 "G3": spec.g3_blocks},
                w,
                on_g1_evict=lambda h, _w=w:
                    self.router.indexer.remove_worker_block(_w, h))
            for w, spec in enumerate(self.specs)]

        # prefill pool state
        self.prefill_busy = [False] * cluster.num_prefill
        self.prefill_queue: List[SimRequest] = []
        # decode pool state: running + transfer-stalled per worker
        self.decode_running = [0] * cluster.num_decode
        self.peak_decode_running = [0] * cluster.num_decode
        self.transfer_queue: List[List[SimRequest]] = [
            [] for _ in range(cluster.num_decode)]

        self.in_flight = 0
        self.completed: List[SimRequest] = []
        self._rid = itertools.count()
        self.poll_log: List[dict] = []
        self.switch_time: Optional[float] = None

    # ---------------------------------------------------------- events ------

    def _push(self, t: float, kind: str, payload=None):
        heapq.heappush(self._events, (t, next(self._eid), kind, payload))

    def _committed_load(self, w: int) -> float:
        return self.decode_running[w] + len(self.transfer_queue[w])

    # ---------------------------------------------------------- client ------

    def _maybe_submit(self):
        """Closed-loop client: top the in-flight count up to the target
        (no-op for open-loop/trace workloads, whose target is 0)."""
        target = self.workload.concurrency_at(self.now)
        while self.in_flight < target:
            template = int(self.rng.choice(
                len(self.template_probs), p=self.template_probs))
            self._submit(template, self.workload.input_tokens,
                         self.workload.output_tokens)

    def _on_arrival(self, entry):
        """Open-loop/trace arrival (a TraceEntry): submit unconditionally —
        arrivals do not wait for completions."""
        template = entry.template
        if template < 0:  # open-loop: sample from the popularity skew
            template = int(self.rng.choice(
                len(self.template_probs), p=self.template_probs))
        self._submit(template, entry.input_tokens, entry.output_tokens)

    def _submit(self, template: int, input_tokens: int, output_tokens: int):
        req = SimRequest(rid=next(self._rid), template=template,
                         tokens=template_tokens(template, input_tokens),
                         output_tokens=output_tokens,
                         submit_t=self.now,
                         phase=self.workload.phase_of(self.now))
        self.in_flight += 1
        self._route(req)
        self.prefill_queue.append(req)
        self._dispatch_prefill()

    # ---------------------------------------------------------- routing -----

    def _route(self, req: SimRequest):
        """Decode-worker selection at arrival (Game 3 mechanism)."""
        cfg = self._active_router_config()
        worker, overlap, overlaps = self.policy.best_worker(
            req.tokens, router_config_override=cfg, now=self.now)
        if self.policy is not self.router:
            overlaps = self.router.indexer.overlap_scores(
                req.tokens, list(range(self.cluster.num_decode)), self.now)
            overlap = overlaps[worker]
        req.decode_worker = worker
        req.overlap = overlap
        req.overlaps_all = tuple(overlaps)
        req.loads_at_schedule = tuple(
            self._committed_load(w) for w in range(self.cluster.num_decode))
        req.hashes = tuple(block_hashes(req.tokens))
        fresh = self.router.indexer.matched_blocks(worker, req.tokens,
                                                   self.now)
        req.onboard_frac, req.onboard_latency = self._tier_split(
            worker, req.hashes, fresh)
        self.router.on_schedule(worker, req.tokens, decode_blocks=0.0,
                        now=self.now)

    def _tier_split(self, w: int, hashes: Tuple[int, ...],
                    fresh_blocks: int) -> Tuple[float, float]:
        """Split a request's prefix blocks into G1 hits, onboardable
        lower-tier residents, and true misses (the §8.4 redundant-recompute
        vs. onboarding tradeoff).

        The first ``fresh_blocks`` blocks are the router-credited fresh G1
        prefix (coherent with HBM residency by construction).  Beyond it,
        blocks resident in G2/G3/G4 are onboarded at the per-tier Eq. 6
        latency instead of recomputed.  A block whose indexer claim went
        TTL-stale models vLLM-style HBM recycling: it is recomputed (a
        miss) even if the coarse KVBM still shows it G1-resident — which
        keeps large-G1 runs on the identity path — but recomputation
        restores its KV, so the walk continues through it to deeper
        lower-tier residents.  Lower-tier copies churn on the same
        ``cache_ttl`` clock (G2/G3 are shared caches, not archives): a
        demoted block is onboardable only while still fresh — exactly the
        window in which its G1 copy would have been a free hit — so tier
        pressure can convert free hits into paid onboards but never
        misses into hits.  The chain breaks at the first non-resident
        block: prefill recomputes the entire suffix from a true hole."""
        kv = self.kvbm[w]
        alpha = {"G2": self.cluster.alpha_g2, "G3": self.cluster.alpha_g3,
                 "G4": self.cluster.alpha_g4}
        onboard, latency = 0, 0.0
        for h in hashes[fresh_blocks:]:
            blk = kv.blocks.get(h)
            if blk is None:
                break
            if blk.tier != "G1" and \
                    self.now - blk.last_touch <= self.cluster.cache_ttl:
                onboard += 1
                latency += alpha[blk.tier]
        return onboard / max(len(hashes), 1), latency

    # --------------------------------------------------------- prefill ------

    def _dispatch_prefill(self):
        for w in range(self.cluster.num_prefill):
            if not self.prefill_busy[w] and self.prefill_queue:
                req = self.prefill_queue.pop(0)
                self.prefill_busy[w] = True
                req.prefill_start = self.now
                # cache-warm routing skips recomputation; onboardable
                # G2/G3 blocks are fetched, not recomputed (they pay Eq. 6
                # latency at admission instead); only true misses cost
                # extra prefill work (throughput channel of §8.4).
                miss = max(1.0 - req.overlap - req.onboard_frac, 0.0)
                work = 1.0 + self.cluster.miss_penalty * miss
                sg = self.cluster.service_sigma
                service = (work / self.cluster.prefill_rate) \
                    * float(self.rng.lognormal(-0.5 * sg * sg, sg))
                self._push(self.now + service, "prefill_busy_done", (w, req))

    def _on_prefill_busy_done(self, w: int, req: SimRequest):
        self.prefill_busy[w] = False
        self._dispatch_prefill()
        self._push(self.now + self.cluster.prefill_base, "prefill_compute_done",
                   req)

    def _on_prefill_compute_done(self, req: SimRequest):
        """Prefill finished: KV transfer to the decode worker, subject to its
        admission cap (stalls here are the herding pathology)."""
        w = req.decode_worker
        if self.decode_running[w] >= self.specs[w].decode_cap:
            self.transfer_queue[w].append(req)
            return
        self._admit_decode(req)

    def _admit_decode(self, req: SimRequest):
        w = req.decode_worker
        spec = self.specs[w]
        # onboarding G2/G3 blocks into HBM delays first token by the
        # per-tier Eq. 6 latency (quoted at scheduling) — cheaper than the
        # full-recompute path a true miss pays in prefill work.
        transfer = spec.kv_transfer * (1.0 - req.overlap) \
            + req.onboard_latency
        req.prefill_end = self.now + transfer
        req.decode_start = req.prefill_end
        self.router.indexer.insert(w, req.tokens, self.now)
        kv = self.kvbm[w]
        for h in req.hashes:
            kv.allocate(h, self.now)
            kv.access(h, self.now)
            kv.pin(h)        # active decode state must never be demoted
            kv.onboard(h)    # decode needs HBM residency: pull into G1
        self.decode_running[w] += 1
        self.peak_decode_running[w] = max(self.peak_decode_running[w],
                                          self.decode_running[w])
        itl = spec.itl_base + spec.itl_slope * self.decode_running[w]
        dur = req.output_tokens * itl
        self._push(req.decode_start + dur, "decode_done", req)

    # ---------------------------------------------------------- decode ------

    def _on_decode_done(self, req: SimRequest):
        req.finish_t = self.now
        w = req.decode_worker
        self.decode_running[w] -= 1
        # Release the decode pins: the blocks stay resident (that is the
        # prefix-cache value) but become demotion-eligible again.
        for h in req.hashes:
            self.kvbm[w].unpin(h)
        self.in_flight -= 1
        self.completed.append(req)
        self.metrics.histogram("ttft", window_s=30.0).observe(req.ttft, self.now)
        self.metrics.histogram("itl", window_s=30.0).observe(req.itl, self.now)
        self.poa.record(CompletedRequest(
            request_id=str(req.rid), worker=w,
            latency=req.finish_t - req.submit_t,
            overlap=req.overlaps_all, finish_time=self.now,
            loads=req.loads_at_schedule))
        if self.transfer_queue[w]:
            nxt = self.transfer_queue[w].pop(0)
            self._admit_decode(nxt)
        self._maybe_submit()

    # ------------------------------------------------------- controller -----

    def _active_router_config(self) -> KvRouterConfig:
        if not self.adaptive:
            return self.router.config
        self.dual.on_regime(self.detector.regime, self.now)
        if self.dual.active_port == 8001 and self.switch_time is None:
            self.switch_time = self.dual.switch_time
        return (self.regime_params.get(self.detector.regime)
                or self.router.config)

    def _on_poll(self):
        ttft_p99 = self.metrics.histogram("ttft", window_s=30.0).p99(self.now)
        # include queued-but-unserved head-of-line wait so the detector sees
        # saturation forming (the paper's streamed frontend signal)
        if self.prefill_queue:
            hol = self.now - self.prefill_queue[0].submit_t
            ttft_p99 = max(ttft_p99, hol)
        regime = self.detector.observe(ttft_p99, self.now)
        poa = self.poa.current_poa(self.now)
        self.poll_log.append({
            "t": self.now, "ttft_p99": ttft_p99, "regime": int(regime),
            "poa": poa, "poa_n": self.poa.window_size(self.now),
            "queue": len(self.prefill_queue),
            "decode_load": [self._committed_load(w)
                            for w in range(self.cluster.num_decode)],
            "concurrency": self.workload.concurrency_at(self.now),
            # Game 2 observables: Prop. 5's ρ per worker, tier residency,
            # and the demotion/promotion churn counters.
            "rho": [kv.capacity_ratio() for kv in self.kvbm],
            "tiers": [kv.tier_distribution() for kv in self.kvbm],
            "demotions": [kv.demotions for kv in self.kvbm],
            "promotions": [kv.promotions for kv in self.kvbm],
        })
        for kv in self.kvbm:
            kv.decay()
        nxt = self.now + self.detector.config.poll_interval
        if nxt <= self.workload.total_duration():
            self._push(nxt, "poll")
        elif self.workload.mode != "closed" and self.in_flight > 0:
            # Open-loop/trace arrivals do not wait for completions, so the
            # run drains far past the arrival horizon; keep sampling the
            # detector/PoA/ρ while work is in flight — the overload tail
            # is the regime these modes exist to study.  (Closed-loop
            # keeps the legacy horizon so its outputs stay bit-exact.)
            self._push(nxt, "poll")

    # ------------------------------------------------------------- run ------

    def _on_sync(self):
        """Event-plane metric propagation: the router's load view is a
        periodic snapshot (staleness is what makes greedy τ=0 routing herd
        under saturation — the pathology τ>0 randomization suppresses)."""
        for w in range(self.cluster.num_decode):
            # b_active counts blocks ON the worker; queued NIXL transfers are
            # invisible to the router (incomplete-information pathology).
            self.router.workers[w].active_blocks = self.decode_running[w]
        nxt = self.now + self.cluster.metrics_interval
        if nxt <= self.workload.total_duration() + 30.0 or (
                self.workload.mode != "closed" and self.in_flight > 0):
            self._push(nxt, "sync")

    def run(self) -> "SimResult":
        total = self.workload.total_duration()
        self._push(0.0, "poll")
        self._push(0.0, "sync")
        if self.workload.mode == "closed":
            t = 0.0
            while t < total:  # client ticks follow the ramp
                self._push(t, "tick")
                t += 1.0
        else:  # open-loop/trace: arrivals are pre-materialized events
            for entry in self.workload.arrivals(self.arrival_rng):
                self._push(entry.t, "arrive", entry)
        # Closed-loop keeps the legacy fixed drain margin (in-flight work is
        # bounded by the concurrency target).  Open-loop/trace arrivals don't
        # wait for completions, so overload — the regime these modes exist to
        # study — can queue far more than 60 s of backlog; drain it fully so
        # overall() prices every arrival instead of a survivor subset.
        closed = self.workload.mode == "closed"
        while self._events:
            t, _, kind, payload = heapq.heappop(self._events)
            if closed and t > total + 60.0:  # drain margin
                break
            self.now = t
            if kind == "tick":
                self._maybe_submit()
            elif kind == "arrive":
                self._on_arrival(payload)
            elif kind == "prefill_busy_done":
                self._on_prefill_busy_done(*payload)
            elif kind == "prefill_compute_done":
                self._on_prefill_compute_done(payload)
            elif kind == "decode_done":
                self._on_decode_done(payload)
            elif kind == "poll":
                self._on_poll()
            elif kind == "sync":
                self._on_sync()
        return SimResult(self)


@dataclass
class PhaseStats:
    poa: float
    poa_std: float
    ttft_p99: float
    itl_p99: float
    rps: float
    n: int


class SimResult:
    def __init__(self, sim: Simulator):
        self.sim = sim
        self.completed = sim.completed
        self.poll_log = sim.poll_log
        self.switch_time = sim.switch_time

    def _phase_reqs(self, phase: int) -> List[SimRequest]:
        return [r for r in self.completed if r.phase == phase]

    def phase_stats(self, phase: int) -> PhaseStats:
        reqs = self._phase_reqs(phase)
        polls = [p for p in self.poll_log
                 if self.sim.workload.phase_of(p["t"]) == phase]
        # exclude warm-up polls whose Eq. 12 window has not filled yet (the
        # denominator is count-normalized); keep all polls when the load is
        # too low to ever fill it (the paper's dagger-marked artifact rows).
        full = [p for p in polls
                if p.get("poa_n", 0) >= 0.8 * self.sim.poa.window_count]
        polls_used = full if full else polls
        poas = [p["poa"] for p in polls_used if p["poa"] == p["poa"]]
        if not reqs:
            return PhaseStats(float("nan"), 0.0, 0.0, 0.0, 0.0, 0)
        ttfts = sorted(r.ttft for r in reqs)
        itls = sorted(r.itl for r in reqs)
        p99 = lambda xs: xs[min(len(xs) - 1, max(0, math.ceil(0.99 * len(xs)) - 1))]
        dur = (max(r.finish_t for r in reqs) - min(r.submit_t for r in reqs))
        return PhaseStats(
            poa=float(np.mean(poas)) if poas else float("nan"),
            poa_std=float(np.std(poas)) if poas else float("nan"),
            ttft_p99=p99(ttfts), itl_p99=p99(itls),
            rps=len(reqs) / max(dur, 1e-9), n=len(reqs))

    def overall(self) -> PhaseStats:
        saved = [r.phase for r in self.completed]
        for r in self.completed:
            r.phase = 0
        out = self.phase_stats(0)
        for r, p in zip(self.completed, saved):
            r.phase = p
        return out
