"""Distributed checkpointing: per-host pytree shards, atomic, async-capable.

Layout:  <dir>/step_<n>/shard_<host>.npz  + manifest.json
Save is crash-safe (write to ``.tmp`` then ``os.replace``); ``restore``
returns the latest complete step.  ``AsyncCheckpointer`` overlaps
serialization with training (one background thread, depth-1 queue —
the standard preemption-tolerance pattern for large jobs).
"""
from __future__ import annotations

import json
import os
import pathlib
import queue
import shutil
import threading
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): np.asarray(leaf)
            for path, leaf in flat}


def save(ckpt_dir: str, step: int, tree: Any, host_id: int = 0,
         num_hosts: int = 1, keep: int = 3):
    d = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    d.mkdir(parents=True, exist_ok=True)
    tmp = d / f"shard_{host_id}.npz.tmp"
    flat = _flatten(tree)
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, d / f"shard_{host_id}.npz")
    if host_id == 0:
        manifest = {"step": step, "num_hosts": num_hosts,
                    "keys": sorted(flat.keys())}
        mtmp = d / "manifest.json.tmp"
        mtmp.write_text(json.dumps(manifest))
        os.replace(mtmp, d / "manifest.json")
        _gc(ckpt_dir, keep)


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(pathlib.Path(ckpt_dir).glob("step_*"))
    for old in steps[:-keep]:
        shutil.rmtree(old, ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    best = None
    for d in sorted(pathlib.Path(ckpt_dir).glob("step_*")):
        if (d / "manifest.json").exists():
            best = int(d.name.split("_")[1])
    return best


def restore(ckpt_dir: str, like: Any, step: Optional[int] = None,
            host_id: int = 0) -> Tuple[Any, int]:
    """Restore into the structure of ``like``; returns (tree, step)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint in {ckpt_dir}")
    d = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    data = np.load(d / f"shard_{host_id}.npz")
    flat_like = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in flat_like[0]:
        key = jax.tree_util.keystr(path)
        arr = data[key]
        leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(flat_like[1], leaves), step


class AsyncCheckpointer:
    """Depth-1 background saver: training never blocks on serialization
    (the previous save is awaited before a new one is queued)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue(maxsize=1)
        self._worker = threading.Thread(target=self._loop, daemon=True)
        self._worker.start()
        self._error: Optional[BaseException] = None

    def _loop(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, tree = item
            try:
                save(self.ckpt_dir, step, tree, keep=self.keep)
            except BaseException as e:  # surfaced on next save/wait
                self._error = e
            finally:
                self._q.task_done()

    def save(self, step: int, tree: Any):
        if self._error:
            raise self._error
        # snapshot to host memory before queueing (donated buffers may die)
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self._q.join()
        self._q.put((step, host_tree))

    def wait(self):
        self._q.join()
        if self._error:
            raise self._error

    def close(self):
        self._q.join()
        self._q.put(None)
        self._worker.join()
