"""Checkpoint layer: atomicity, latest discovery, GC, async saver."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.training import checkpoint as ck


def _tree(x=0.0):
    return {"a": jnp.full((3, 2), x), "b": {"c": jnp.full((4,), x + 1)}}


def test_save_restore_roundtrip(tmp_path):
    d = str(tmp_path)
    ck.save(d, 7, _tree(2.5))
    restored, step = ck.restore(d, _tree())
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(_tree(2.5)["a"]))


def test_latest_step_and_gc(tmp_path):
    d = str(tmp_path)
    for s in (1, 2, 3, 4, 5):
        ck.save(d, s, _tree(float(s)), keep=3)
    assert ck.latest_step(d) == 5
    restored, _ = ck.restore(d, _tree())
    assert float(np.asarray(restored["a"])[0, 0]) == 5.0
    import pathlib
    assert len(list(pathlib.Path(d).glob("step_*"))) == 3  # GC kept 3


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ck.restore(str(tmp_path), _tree())


def test_incomplete_checkpoint_ignored(tmp_path):
    import pathlib
    d = str(tmp_path)
    ck.save(d, 1, _tree(1.0))
    # simulate a crash mid-save at step 2: shard written, no manifest
    p = pathlib.Path(d) / "step_00000002"
    p.mkdir()
    (p / "shard_0.npz").write_bytes(b"corrupt")
    assert ck.latest_step(d) == 1


def test_async_checkpointer(tmp_path):
    d = str(tmp_path)
    saver = ck.AsyncCheckpointer(d)
    for s in (10, 20):
        saver.save(s, _tree(float(s)))
    saver.wait()
    saver.close()
    assert ck.latest_step(d) == 20
