"""Unified model: one scan-over-layers decoder covering all assigned families.

Layers are grouped into *periods* (the smallest repeating block pattern —
1 for dense/MoE, 8 for Jamba's 1:7 attn:mamba interleave, 4 for xLSTM's
mLSTM/sLSTM mix) and the stack is a ``lax.scan`` over ``num_layers //
period`` periods with stacked parameters, keeping HLO size independent of
depth.

Three entry points per model:
  * ``train_loss(params, batch)``      — next-token loss (teacher forcing)
  * ``prefill(params, batch, max_len)``— fills KV/state caches, last logits
  * ``decode(params, caches, tokens, cur_index)`` — one token w/ cache

``input_specs``/``cache_specs`` provide ShapeDtypeStruct stand-ins for the
multi-pod dry-run (no allocation).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import runtime_flags as flags
from repro.models import ssm as ssm_lib
from repro.sharding import shard

# Deterministic synthetic-shape conventions for enc-dec / VLM cells
ENC_CTX_DECODE = 4_096   # encoder context length used by decode shapes
DEC_PREFIX = 64          # decoder prefix length for enc-dec prefill cells


@dataclass(frozen=True)
class BlockDesc:
    mixer: str                 # attn | mamba | mlstm | slstm
    mlp: Optional[str]         # dense | moe | None
    cross: bool = False


ENC_DESC = BlockDesc("attn", "dense")


def layer_layout(cfg: ModelConfig):
    """Return (period, [BlockDesc per position within the period])."""
    if cfg.family == "ssm":
        x = cfg.xlstm
        period = x.slstm_every
        descs = [BlockDesc("slstm" if i % x.slstm_every == x.slstm_offset
                           else "mlstm", None) for i in range(period)]
        return period, descs
    period = cfg.attn_layer_period
    if cfg.moe is not None:
        period = int(np.lcm(period, cfg.moe.every_k_layers))
    descs = []
    for i in range(period):
        mixer = "attn"
        if cfg.family == "hybrid" and i % cfg.attn_layer_period != cfg.attn_layer_offset:
            mixer = "mamba"
        if cfg.moe is not None and i % cfg.moe.every_k_layers == cfg.moe.moe_layer_offset:
            mlp = "moe"
        elif cfg.d_ff > 0:
            mlp = "dense"
        else:
            mlp = None
        descs.append(BlockDesc(mixer, mlp, cross=cfg.cross_attention))
    assert cfg.num_layers % period == 0, (cfg.name, cfg.num_layers, period)
    return period, descs


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.period, self.descs = layer_layout(cfg)
        self.n_periods = cfg.num_layers // self.period
        self.use_flash = False  # engines may switch on Pallas attention

    # ------------------------------------------------------------- init ----

    def _block_init(self, rng, desc: BlockDesc, dtype):
        cfg = self.cfg
        r = jax.random.split(rng, 4)
        p = {}
        if desc.mixer == "attn":
            p["attn"] = L.attention_init(r[0], cfg, dtype)
        elif desc.mixer == "mamba":
            p["mamba"] = ssm_lib.mamba_init(r[0], cfg, dtype)
        elif desc.mixer == "mlstm":
            p["mlstm"] = ssm_lib.mlstm_init(r[0], cfg, dtype)
        elif desc.mixer == "slstm":
            p["slstm"] = ssm_lib.slstm_init(r[0], cfg, dtype)
        if desc.cross:
            p["xattn"] = L.attention_init(r[1], cfg, dtype)
        if desc.mlp == "dense":
            p["mlp"] = L.mlp_init(r[2], cfg, dtype)
        elif desc.mlp == "moe":
            p["moe"] = moe_lib.moe_init(r[2], cfg, dtype)
        return p

    def _period_init(self, rng, dtype, descs=None):
        descs = descs if descs is not None else self.descs
        rs = jax.random.split(rng, len(descs))
        return {f"p{i}": self._block_init(rs[i], d, dtype)
                for i, d in enumerate(descs)}

    def init(self, rng, dtype=jnp.float32):
        cfg = self.cfg
        r = jax.random.split(rng, 6)
        params = {
            "embed": (jax.random.normal(r[0], (cfg.vocab_size, cfg.d_model),
                                        jnp.float32) * 0.02).astype(dtype),
            "unembed": (jax.random.normal(r[1], (cfg.d_model, cfg.vocab_size),
                                          jnp.float32)
                        * cfg.d_model ** -0.5).astype(dtype),
            "final_norm": L.rmsnorm_init(cfg.d_model, dtype),
            "stack": jax.vmap(lambda k: self._period_init(k, dtype))(
                jax.random.split(r[2], self.n_periods)),
        }
        if cfg.frontend:
            params["frontend_proj"] = (
                jax.random.normal(r[3], (cfg.frontend_dim, cfg.d_model),
                                  jnp.float32) * cfg.frontend_dim ** -0.5
            ).astype(dtype)
        if cfg.num_encoder_layers:
            params["enc_stack"] = jax.vmap(
                lambda k: self._period_init(k, dtype, [ENC_DESC]))(
                jax.random.split(r[4], cfg.num_encoder_layers))
            params["enc_final_norm"] = L.rmsnorm_init(cfg.d_model, dtype)
        return params

    def init_abstract(self, dtype=jnp.float32):
        return jax.eval_shape(lambda k: self.init(k, dtype),
                              jax.random.PRNGKey(0))

    # ------------------------------------------------------------ blocks ----

    def _block_apply(self, desc, bp, x, bc, *, positions, write_index,
                     enc_out, causal=True, decode_impl="sdpa",
                     page_table=None):
        """Apply one block. bc (the block cache) is None in train mode.
        Returns (x, new_block_cache, moe_aux or None)."""
        cfg = self.cfg
        is_step = x.shape[1] == 1 and bc is not None
        nc = {}
        if desc.mixer == "attn":
            h, kv = L.attention(bp["attn"], x, cfg, positions=positions,
                                kv_cache=bc.get("kv") if bc else None,
                                write_index=write_index, causal=causal,
                                use_flash=self.use_flash,
                                decode_impl=decode_impl,
                                page_table=page_table)
            if bc is not None:
                nc["kv"] = kv
            x = x + h
        elif desc.mixer == "mamba":
            h, st = ssm_lib.mamba_block(
                bp["mamba"], x, cfg, cache=bc.get("state") if is_step else None)
            if bc is not None:
                nc["state"] = st
            x = x + h
        elif desc.mixer == "mlstm":
            h, st = ssm_lib.mlstm_block(
                bp["mlstm"], x, cfg, cache=bc.get("state") if is_step else None)
            if bc is not None:
                nc["state"] = st
            x = x + h
        elif desc.mixer == "slstm":
            h, st = ssm_lib.slstm_block(
                bp["slstm"], x, cfg, cache=bc.get("state") if is_step else None)
            if bc is not None:
                nc["state"] = st
            x = x + h
        if desc.cross:
            if bc is not None:
                xk, xv = bc["xk"], bc["xv"]
                h = self._cross_cached(bp["xattn"], x, xk, xv)
                nc["xk"], nc["xv"] = xk, xv
            else:
                h, _ = L.attention(bp["xattn"], x, cfg, kv_source=enc_out,
                                   causal=False, use_rope=False)
            x = x + h
        aux = None
        if desc.mlp == "dense":
            x = x + L.mlp(bp["mlp"], x, cfg)
        elif desc.mlp == "moe":
            h, aux = moe_lib.moe(bp["moe"], x, cfg)
            x = x + h
        return x, nc, aux

    def _cross_cached(self, params, x, xk, xv):
        """Cross-attention against precomputed (cached) encoder K/V."""
        cfg = self.cfg
        xn = L.rmsnorm(params["norm"], x, cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", xn, params["wq"].astype(L.COMPUTE_DTYPE))
        out = L._sdpa(q, xk.astype(L.COMPUTE_DTYPE), xv.astype(L.COMPUTE_DTYPE),
                      None, cfg.q_heads_per_kv)
        return jnp.einsum("bshk,hkd->bsd", out,
                          params["wo"].astype(L.COMPUTE_DTYPE))

    # ------------------------------------------------------------ stacks ----

    def _run_stack(self, stack, x, *, caches=None, positions=None,
                   write_index=None, enc_out=None, causal=True, remat=False,
                   decode_impl="sdpa", page_table=None):
        """lax.scan over periods. Returns (x, new_caches_or_None, aux_sum)."""
        collect = caches is not None

        def body(carry, per):
            xx = carry
            pp, pc = per if collect else (per, None)
            new_c = {}
            aux_sum = jnp.zeros((), jnp.float32)
            for i, desc in enumerate(self.descs):
                bc = pc[f"p{i}"] if pc is not None else None
                xx, ncb, aux = self._block_apply(
                    desc, pp[f"p{i}"], xx, bc, positions=positions,
                    write_index=write_index, enc_out=enc_out, causal=causal,
                    decode_impl=decode_impl, page_table=page_table)
                new_c[f"p{i}"] = ncb
                if aux is not None:
                    aux_sum = aux_sum + aux["moe_aux_loss"]
            return xx, ((new_c, aux_sum) if collect else aux_sum)

        if remat:
            body = jax.checkpoint(body)
        unroll = flags.scan_unroll(self.n_periods)
        if collect:
            x, (new_caches, aux) = jax.lax.scan(body, x, (stack, caches),
                                                unroll=unroll)
        else:
            x, aux = jax.lax.scan(body, x, stack, unroll=unroll)
            new_caches = None
        return x, new_caches, jnp.sum(aux)

    def _run_encoder(self, params, frames):
        cfg = self.cfg
        x = jnp.einsum("bsf,fd->bsd", frames.astype(L.COMPUTE_DTYPE),
                       params["frontend_proj"].astype(L.COMPUTE_DTYPE))
        x = shard(x, "batch", "seq", "act_embed")

        def body(xx, pp):
            xx, _, _ = self._block_apply(ENC_DESC, pp["p0"], xx, None,
                                         positions=None, write_index=None,
                                         enc_out=None, causal=False)
            return xx, None

        x, _ = jax.lax.scan(body, x, params["enc_stack"],
                            unroll=flags.scan_unroll(cfg.num_encoder_layers))
        return L.rmsnorm(params["enc_final_norm"], x, cfg.norm_eps)

    # ------------------------------------------------------------- embed ----

    def _embed_inputs(self, params, batch):
        """Returns (x, enc_out, label_offset)."""
        cfg = self.cfg
        enc_out = None
        if cfg.family == "encdec":
            enc_out = self._run_encoder(params, batch["frames"])
        x = params["embed"].astype(L.COMPUTE_DTYPE)[batch["tokens"]]
        offset = 0
        if cfg.family == "vlm" and "patches" in batch:
            pe = jnp.einsum("bpf,fd->bpd",
                            batch["patches"].astype(L.COMPUTE_DTYPE),
                            params["frontend_proj"].astype(L.COMPUTE_DTYPE))
            x = jnp.concatenate([pe, x], axis=1)
            offset = pe.shape[1]
        return shard(x, "batch", "seq", "act_embed"), enc_out, offset

    # ------------------------------------------------------------- train ----

    def train_loss(self, params, batch, *, remat=True):
        """Next-token cross-entropy (+ MoE load-balance aux loss)."""
        cfg = self.cfg
        x, enc_out, offset = self._embed_inputs(params, batch)
        x, _, aux = self._run_stack(params["stack"], x, enc_out=enc_out,
                                    remat=remat)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        if offset:
            x = x[:, offset:, :]
        tokens = batch["tokens"]
        loss = _chunked_ce(x[:, :-1], tokens[:, 1:], params["unembed"])
        if cfg.moe is not None:
            loss = loss + 0.01 * aux / max(self.n_periods, 1)
        return loss

    # ----------------------------------------------------------- serving ----

    def cache_init(self, batch, max_len, abstract=False):
        """Stacked caches pytree for a decode session (zeros/-inf or SDS)."""
        def build():
            per = {}
            for i, desc in enumerate(self.descs):
                c = {}
                if desc.mixer == "attn":
                    c["kv"] = L.attention_cache_init(self.cfg, batch, max_len)
                elif desc.mixer == "mamba":
                    c["state"] = ssm_lib.mamba_cache_init(self.cfg, batch)
                elif desc.mixer == "mlstm":
                    c["state"] = ssm_lib.mlstm_cache_init(self.cfg, batch)
                elif desc.mixer == "slstm":
                    c["state"] = ssm_lib.slstm_cache_init(self.cfg, batch)
                if desc.cross:
                    k, hd = self.cfg.num_kv_heads, self.cfg.resolved_head_dim
                    c["xk"] = jnp.zeros((batch, ENC_CTX_DECODE, k, hd),
                                        L.COMPUTE_DTYPE)
                    c["xv"] = jnp.zeros((batch, ENC_CTX_DECODE, k, hd),
                                        L.COMPUTE_DTYPE)
                per[f"p{i}"] = c
            return jax.tree.map(
                lambda a: jnp.broadcast_to(a, (self.n_periods,) + a.shape)
                          + jnp.zeros((), a.dtype), per)
        if abstract:
            return jax.eval_shape(build)
        return build()

    def paged_cache_init(self, num_pages, block, abstract=False):
        """Global KV page-pool pytree for paged decode: same per-period
        structure as :meth:`cache_init`, but every "kv" leaf is a page pool
        ``(num_pages + 1, block, K, hd)`` shared by all slots — the +1 is
        the reserved trash page 0 (inactive slots write there; never
        allocated).  Attention-only stacks, see
        :attr:`supports_paged_decode`."""
        assert self.supports_paged_decode, self.cfg.name
        def build():
            per = {f"p{i}": {"kv": L.paged_attention_cache_init(
                        self.cfg, num_pages + 1, block)}
                   for i in range(len(self.descs))}
            return jax.tree.map(
                lambda a: jnp.broadcast_to(a, (self.n_periods,) + a.shape)
                          + jnp.zeros((), a.dtype), per)
        if abstract:
            return jax.eval_shape(build)
        return build()

    @property
    def supports_paged_decode(self) -> bool:
        """The paged KV layout holds every sequence mixer's decode state in
        the shared page pool, so (like padded prefill) it requires a pure
        causal-attention stack: recurrent mixers carry dense per-slot state
        that has no block-granular form."""
        return (all(d.mixer == "attn" and not d.cross for d in self.descs)
                and self.cfg.family not in ("encdec", "vlm"))

    def prefill(self, params, batch, max_len=None):
        """Process the prompt; returns (last_logits (B,V), caches)."""
        cfg = self.cfg
        x, enc_out, _ = self._embed_inputs(params, batch)
        b, s = x.shape[0], x.shape[1]
        max_len = max_len or s
        caches = self.cache_init(b, max_len)
        if cfg.family == "encdec" and enc_out is not None:
            caches = self._fill_cross_cache(params, caches, enc_out)
        positions = jnp.arange(s, dtype=jnp.int32)
        x, new_caches, _ = self._run_stack(
            params["stack"], x, caches=caches, positions=positions,
            write_index=0, enc_out=enc_out)
        x = L.rmsnorm(params["final_norm"], x[:, -1:, :], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x,
                            params["unembed"].astype(L.COMPUTE_DTYPE))
        return logits[:, 0].astype(jnp.float32), new_caches

    def prefill_batched(self, params, tokens, lengths, max_len=None):
        """Ragged prompt batch: one jitted pass over right-padded prompts.

        ``tokens``: (B, S) int32, each row right-padded to S; ``lengths``:
        (B,) valid prompt length per row.  Returns (last_logits (B, V) —
        row ``i``'s logits taken at position ``lengths[i] - 1`` — and the
        batch cache bundle; row ``i`` of the caches is a valid decode/donor
        cache for positions < ``lengths[i]``).

        Exactness under right-padding needs every sequence mixer to be
        causal attention (:attr:`supports_padded_prefill`): a padding token
        at position j ≥ length is never attended by a query at position
        < j, and the garbage K/V it writes is masked (and later overwritten
        by decode) before any real query can reach it.  Recurrent mixers
        (mamba/xLSTM) would absorb padding tokens into their terminal
        state, so padded batches are gated off for them — equal-length
        groups (no padding) remain exact for every family."""
        cfg = self.cfg
        x = params["embed"].astype(L.COMPUTE_DTYPE)[tokens]
        x = shard(x, "batch", "seq", "act_embed")
        b, s = tokens.shape
        max_len = max_len or s
        caches = self.cache_init(b, max_len)
        positions = jnp.arange(s, dtype=jnp.int32)
        x, new_caches, _ = self._run_stack(
            params["stack"], x, caches=caches, positions=positions,
            write_index=0)
        idx = jnp.clip(lengths.astype(jnp.int32) - 1, 0, s - 1)
        x = jnp.take_along_axis(x, idx[:, None, None], axis=1)   # (B,1,D)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x,
                            params["unembed"].astype(L.COMPUTE_DTYPE))
        return logits[:, 0].astype(jnp.float32), new_caches

    @property
    def supports_padded_prefill(self) -> bool:
        """Right-padded ragged prompt batches are exact only for pure
        causal-attention stacks (see :meth:`prefill_batched`); recurrent
        mixers fold padding tokens into their terminal decode state.
        Equal-length (padding-free) batches are always allowed."""
        return (all(d.mixer == "attn" and not d.cross for d in self.descs)
                and self.cfg.family not in ("encdec", "vlm"))

    @property
    def supports_prefill_resume(self) -> bool:
        """Prefix-resumable prompt passes need every mixer's sequence state
        to live in the KV cache: attention attends over the cache with a
        positional causal mask, so writing the suffix at ``start`` and
        masking does the right thing; SSM/recurrent mixers (mamba/xLSTM)
        recompute their state from the visible window during a multi-token
        pass, so a resumed window would silently drop the prefix state."""
        return (all(d.mixer == "attn" and not d.cross for d in self.descs)
                and self.cfg.family not in ("encdec", "vlm"))

    def prefill_resume(self, params, caches, tokens, start):
        """Continue a prompt pass from position ``start``.

        ``caches`` must hold valid K/V for positions < ``start`` (from an
        earlier :meth:`prefill` of a prompt sharing that prefix); ``tokens``
        is the (B, S_suffix) suffix starting at ``start``.  The suffix K/V
        is written at ``start``..``start+S_suffix-1``, overwriting whatever
        the donor prompt had there; stale donor positions at or beyond the
        new total length stay masked (kv_pos ≤ q_pos never reaches them),
        so the pass is exact — attention-only models, see
        :attr:`supports_prefill_resume`.  Returns (last_logits (B,V),
        caches), like :meth:`prefill`."""
        assert self.supports_prefill_resume, self.cfg.name
        cfg = self.cfg
        x = params["embed"].astype(L.COMPUTE_DTYPE)[tokens]
        x = shard(x, "batch", "seq", "act_embed")
        s = x.shape[1]
        start = jnp.asarray(start, jnp.int32)
        positions = jnp.arange(s, dtype=jnp.int32) + start
        x, new_caches, _ = self._run_stack(
            params["stack"], x, caches=caches, positions=positions,
            write_index=start, enc_out=None)
        x = L.rmsnorm(params["final_norm"], x[:, -1:, :], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x,
                            params["unembed"].astype(L.COMPUTE_DTYPE))
        return logits[:, 0].astype(jnp.float32), new_caches

    def _fill_cross_cache(self, params, caches, enc_out):
        def fill(pp, pc):
            out = dict(pc)
            for i, desc in enumerate(self.descs):
                if desc.cross:
                    xp = pp[f"p{i}"]["xattn"]
                    src = enc_out.astype(L.COMPUTE_DTYPE)
                    xk = jnp.einsum("bsd,dhk->bshk", src,
                                    xp["wk"].astype(L.COMPUTE_DTYPE))
                    xv = jnp.einsum("bsd,dhk->bshk", src,
                                    xp["wv"].astype(L.COMPUTE_DTYPE))
                    c = dict(out[f"p{i}"])
                    t = c["xk"].shape[1]
                    c["xk"] = _fit_len(xk, t)
                    c["xv"] = _fit_len(xv, t)
                    out[f"p{i}"] = c
            return out
        return jax.vmap(fill, in_axes=(0, 0))(params["stack"], caches)

    def decode(self, params, caches, tokens, cur_index, decode_impl="sdpa",
               page_table=None):
        """One decode step. tokens: (B,1) int32; cur_index: scalar int32, or
        an int32 (B,) vector for ragged continuous batching.

        ``decode_impl="pallas"`` routes the cached-attention step through
        the Pallas ragged decode kernel (per-row length masking from the
        position vector); ``"sdpa"`` keeps the XLA einsum path.  The paged
        impls ("paged" — Pallas paged kernel — and "paged_sdpa" — gathered
        dense XLA path) expect ``caches`` from :meth:`paged_cache_init` and
        a ``page_table`` (B, W) int32 mapping each slot's KV blocks into
        the shared page pool."""
        cfg = self.cfg
        x = params["embed"].astype(L.COMPUTE_DTYPE)[tokens]
        x = shard(x, "decode_batch", None, "act_embed")
        cur = jnp.asarray(cur_index, jnp.int32)
        if cur.ndim == 0:
            positions = jnp.full((tokens.shape[0], 1), cur, jnp.int32)
        else:
            positions = cur[:, None]
        x, new_caches, _ = self._run_stack(
            params["stack"], x, caches=caches, positions=positions,
            write_index=cur, decode_impl=decode_impl, page_table=page_table)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x,
                            params["unembed"].astype(L.COMPUTE_DTYPE))
        return logits[:, 0].astype(jnp.float32), new_caches

    # ----------------------------------------------------------- dry-run ----

    def input_specs(self, shape: ShapeConfig):
        """ShapeDtypeStruct stand-ins for the step inputs (no allocation)."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32, bf16 = jnp.int32, jnp.bfloat16
        if shape.kind in ("train", "prefill"):
            if cfg.family == "encdec":
                dec = s if shape.kind == "train" else DEC_PREFIX
                return {"frames": jax.ShapeDtypeStruct((b, s, cfg.frontend_dim), bf16),
                        "tokens": jax.ShapeDtypeStruct((b, dec), i32)}
            if cfg.family == "vlm":
                return {"patches": jax.ShapeDtypeStruct(
                            (b, cfg.num_patches, cfg.frontend_dim), bf16),
                        "tokens": jax.ShapeDtypeStruct((b, s - cfg.num_patches), i32)}
            return {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        return {"tokens": jax.ShapeDtypeStruct((b, 1), i32),
                "cur_index": jax.ShapeDtypeStruct((), i32)}

    def cache_specs(self, shape: ShapeConfig):
        assert shape.kind == "decode"
        return self.cache_init(shape.global_batch, shape.seq_len, abstract=True)

    # ------------------------------------------------------------- flops ----

    def model_flops(self, shape: ShapeConfig) -> float:
        """MODEL_FLOPS = 6·N·D (train) or 2·N·D (inference), N = active params."""
        n = self.cfg.active_param_count()
        if shape.kind == "train":
            return 6.0 * n * shape.global_batch * shape.seq_len
        if shape.kind == "prefill":
            return 2.0 * n * shape.global_batch * shape.seq_len
        return 2.0 * n * shape.global_batch  # decode: one token per sequence


LOSS_CHUNK = 512


def _chunked_ce(x, tgt, unembed, chunk=LOSS_CHUNK):
    """Cross-entropy without materializing the full (B,S,V) logits: the
    sequence is processed in blocks of ``chunk`` via lax.map (checkpointed so
    the backward pass also stays block-sized)."""
    b, s, d = x.shape

    @jax.checkpoint
    def block(args):
        xb, tb, wb = args
        logits = jnp.einsum("bsd,dv->bsv", xb,
                            unembed.astype(L.COMPUTE_DTYPE))
        logits = shard(logits, "batch", "seq", "vocab").astype(jnp.float32)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, tb[..., None], axis=-1)[..., 0]
        return jnp.sum((logz - ll) * wb), jnp.sum(wb)

    if s <= chunk:
        tot, cnt = block((x, tgt, jnp.ones((b, s), jnp.float32)))
        return tot / cnt
    pad = (-s) % chunk
    w = jnp.ones((b, s), jnp.float32)
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        tgt = jnp.pad(tgt, ((0, 0), (0, pad)))
        w = jnp.pad(w, ((0, 0), (0, pad)))
    nc = x.shape[1] // chunk
    xs = jnp.moveaxis(x.reshape(b, nc, chunk, d), 1, 0)
    ts = jnp.moveaxis(tgt.reshape(b, nc, chunk), 1, 0)
    ws = jnp.moveaxis(w.reshape(b, nc, chunk), 1, 0)
    _, (tots, cnts) = jax.lax.scan(
        lambda c, args: (c, block(args)), None, (xs, ts, ws),
        unroll=flags.scan_unroll(nc))
    return jnp.sum(tots) / jnp.sum(cnts)


def _fit_len(x, t):
    if x.shape[1] == t:
        return x
    if x.shape[1] > t:
        return x[:, :t]
    pad = [(0, 0)] * x.ndim
    pad[1] = (0, t - x.shape[1])
    return jnp.pad(x, pad)


_MODEL_CACHE = {}


def build_model(cfg: ModelConfig) -> Model:
    if cfg not in _MODEL_CACHE:
        _MODEL_CACHE[cfg] = Model(cfg)
    return _MODEL_CACHE[cfg]
