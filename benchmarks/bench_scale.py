"""Large-pool hot-path benchmark — the perf trajectory's first point.

Measures, at pool sizes 64/128/256:

* **routing decisions/sec** on a steady-state router (claims + loads from
  a real scale-scenario run): the pre-PR hot path (per-worker radix walk,
  scalar cost loop, hashing inside the call) against the aggregated
  single-walk + vectorized argmin + per-request hash memo, plus the
  simhash-bucketed approximate scorer (``affinity="simhash"``) that
  replaces the walk with a bucket lookup;
* **request hot path**: the full per-request router/indexer sequence —
  pre-PR hashed the same prompt four times (route, memo, matched-blocks,
  insert), the memoized path hashes once;
* **frozen-OPT window cost**: dense capacity-replicated Hungarian matrix
  vs. identical-column dedup;
* **end-to-end wall time** of the ``scale-*`` scenarios;
* **replica staleness sweep**: the ``scale-replica-*`` scenarios over
  staleness × replica-count grids — PoA-hat, TTFT P99 and the
  routing-agreement-vs-fresh probe quantify the price of routing on
  bounded-staleness state views (the paper's decentralization axis).

Output: CSV rows on stdout + ``reports/benchmarks/BENCH_scale.json``.
``--check BASELINE`` compares against a checked-in baseline and exits
non-zero on a >2x regression (wall times 2x slower, rates/speedups 2x
lower) — the CI guard for this file's own future.

    PYTHONPATH=src python -m benchmarks.bench_scale [--smoke] [--check FILE]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from benchmarks.common import emit, save_json
from repro.core.poa import CompletedRequest, PoATracker
from repro.core.radix import block_hashes
from repro.core.router import KvRouterConfig
from repro.serving.scenarios import build_simulator, list_scenarios
from repro.serving.workload import template_tokens

SCALE_SCENARIOS = ("scale-64", "scale-128", "scale-256")
REPLICA_SCENARIOS = ("scale-replica-64", "scale-replica-128",
                     "scale-replica-256")
assert set(SCALE_SCENARIOS + REPLICA_SCENARIOS) <= set(list_scenarios()), \
    "registry out of sync"

# the replica sweep grid (full mode); smoke keeps the two corner points
STALENESS_GRID = (0.0, 1.0, 4.0, 16.0)
REPLICA_GRID = (1, 2, 4, 8)


def _steady_state(name: str, **overrides):
    """A router carrying the claims/loads of a real scenario run, plus a
    timestamp inside the run's freshness horizon (after the drain every
    claim is TTL-stale and both walks degenerate)."""
    sim = build_simulator(name, seed=0, fast=True, **overrides)
    sim.run()
    now = max(r.decode_start for r in sim.completed)
    return sim, sim.router, now


def _request_stream(sim, n: int):
    toks_hs = []
    for t in range(16):
        toks = template_tokens(t, sim.workload.input_tokens)
        toks_hs.append((toks, tuple(block_hashes(toks))))
    return [toks_hs[i % len(toks_hs)] for i in range(n)]


def bench_routing(name: str, n: int = 2000) -> dict:
    res: dict = {}
    for mode in ("legacy", "new"):
        # identical starting state per mode: the request-path phase inserts
        # claims (and the aggregated walk sweeps stale ones), so timing
        # both modes on one shared router would bias the comparison
        sim, router, now = _steady_state(name)
        reqs = _request_stream(sim, n)
        res["workers"] = sim.cluster.num_decode
        new = mode == "new"
        router.indexer.aggregated = new
        router.vectorized = new

        def timed_best_of(loop, repeats=3):
            """Best-of-N timing: decisions are read-only and the request
            phase is idempotent at fixed ``now``, so repeats measure the
            same work and the min discards scheduler noise spikes."""
            best = float("inf")
            for _ in range(repeats):
                t0 = time.perf_counter()
                loop()
                best = min(best, time.perf_counter() - t0)
            return best / n * 1e6

        for toks, hs in reqs[:50]:                       # warm-up
            router.best_worker(toks, now=now,
                               hashes=hs if new else None)

        def decisions_new():
            for toks, hs in reqs:
                router.best_worker(toks, now=now, hashes=hs)

        def decisions_legacy():
            for toks, _hs in reqs:                       # pre-PR: hashes
                router.best_worker(toks, now=now)        # inside the call

        res[f"decision_us_{mode}"] = timed_best_of(
            decisions_new if new else decisions_legacy)

        # full per-request router/indexer sequence
        def requests_new():
            for toks, _ in reqs:
                hs = tuple(block_hashes(toks))           # memo: hash once
                _, ov, _ = router.best_worker(toks, now=now, hashes=hs)
                int(round(ov * len(hs)))                 # fresh from score
                router.on_schedule(0, toks, decode_blocks=0.0, now=now,
                                   hashes=hs)

        def requests_legacy():
            for toks, _ in reqs:                         # pre-PR: 4 hashes
                router.best_worker(toks, now=now)
                tuple(block_hashes(toks))
                router.indexer.matched_blocks(0, toks, now=now)
                router.on_schedule(0, toks, decode_blocks=0.0, now=now)

        res[f"request_us_{mode}"] = timed_best_of(
            requests_new if new else requests_legacy)

    res["decisions_per_s"] = 1e6 / res["decision_us_new"]
    res["decision_speedup"] = res["decision_us_legacy"] / res["decision_us_new"]
    res["request_speedup"] = res["request_us_legacy"] / res["request_us_new"]

    # simhash-bucketed approximate scorer: same steady-state protocol, the
    # radix walk replaced by a bucket lookup (exact-agreement on template
    # workloads is pinned in tests/test_router.py; this row prices it)
    sim, router, now = _steady_state(
        name, router_config=KvRouterConfig(affinity="simhash"))
    reqs = _request_stream(sim, n)
    for toks, hs in reqs[:50]:
        router.best_worker(toks, now=now, hashes=hs)
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        for toks, hs in reqs:
            router.best_worker(toks, now=now, hashes=hs)
        best = min(best, time.perf_counter() - t0)
    res["decision_us_simhash"] = best / n * 1e6
    res["decisions_per_s_simhash"] = 1e6 / res["decision_us_simhash"]

    emit(f"bench_scale_routing_{name}", res["decision_us_new"],
         f"workers={res['workers']};"
         f"decisions_per_s={res['decisions_per_s']:,.0f};"
         f"decisions_per_s_simhash={res['decisions_per_s_simhash']:,.0f};"
         f"decision_speedup={res['decision_speedup']:.1f}x;"
         f"request_speedup={res['request_speedup']:.1f}x")
    return res


def bench_opt(workers: int = 256, n: int = 128, warm_per_req: int = 4,
              hot_workers: int = 24) -> dict:
    """Frozen-OPT solve on a PoA window over a large pool: dense
    capacity-replicated matrix vs identical-column dedup.  Cache-affinity
    routing concentrates fresh prefixes on a hot subset of the pool, so
    most worker columns are identical (cold) — exactly what the dedup
    collapses."""
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(n):
        ov = np.zeros(workers)
        idx = rng.integers(0, hot_workers, size=warm_per_req)
        ov[idx] = rng.integers(1, 9, size=warm_per_req) / 8.0
        reqs.append(CompletedRequest(str(i), int(i % workers),
                                     1.0 + float(rng.random()),
                                     ov.tolist(), float(i) * 0.01))
    out = {"workers": workers, "window": n}
    for mode, dedup, iters in (("dense", False, 2), ("dedup", True, 5)):
        tr = PoATracker(num_workers=workers, dedup=dedup)
        tr.opt_cost(reqs)                                # warm-up
        t0 = time.perf_counter()
        for _ in range(iters):
            tr.opt_cost(reqs)
        out[f"opt_ms_{mode}"] = (time.perf_counter() - t0) / iters * 1e3
    out["opt_speedup"] = out["opt_ms_dense"] / out["opt_ms_dedup"]
    emit("bench_scale_opt", out["opt_ms_dedup"] * 1e3,
         f"workers={workers};dense_ms={out['opt_ms_dense']:.1f};"
         f"dedup_ms={out['opt_ms_dedup']:.2f};"
         f"speedup={out['opt_speedup']:.0f}x")
    return out


def bench_scenarios(smoke: bool) -> dict:
    out = {}
    for name in SCALE_SCENARIOS:
        t0 = time.perf_counter()
        sim = build_simulator(name, seed=0, fast=smoke)
        res = sim.run()
        wall = time.perf_counter() - t0
        s = res.overall()
        out[name] = {"wall_s": wall, "completed": len(res.completed),
                     "rps": s.rps, "ttft_p99": s.ttft_p99, "poa": s.poa}
        emit(f"bench_scale_{name}", wall / max(len(res.completed), 1) * 1e6,
             f"completed={len(res.completed)};wall_s={wall:.1f};"
             f"rps={s.rps:.0f};ttft_p99={s.ttft_p99:.3f}s")
    return out


def bench_replica(smoke: bool) -> dict:
    """The staleness sweep: PoA-hat, TTFT P99, agreement-vs-fresh and
    admission conflicts over the staleness × replica grid.  Only wall_s
    is regression-gated; the game metrics are the measurement."""
    if smoke:
        grid = {"scale-replica-64": [(1, 0.0), (4, 4.0)]}
        sizes = {"scale-replica-64": {}}
    else:
        full = [(r, s) for s in STALENESS_GRID for r in REPLICA_GRID]
        grid = {"scale-replica-64": full,
                "scale-replica-128": [(r, s) for s in (0.0, 4.0, 16.0)
                                      for r in (1, 4)],
                "scale-replica-256": [(r, s) for s in (0.0, 4.0, 16.0)
                                      for r in (1, 4)]}
        sizes = {"scale-replica-64": {"num_requests": 20_000},
                 "scale-replica-128": {"num_requests": 10_000},
                 "scale-replica-256": {"num_requests": 10_000}}
    out: dict = {}
    for name, points in grid.items():
        for replicas, staleness in points:
            t0 = time.perf_counter()
            sim = build_simulator(name, seed=0, fast=smoke,
                                  replicas=replicas, staleness=staleness,
                                  **sizes[name])
            res = sim.run()
            wall = time.perf_counter() - t0
            s = res.overall()
            cp = sim.control
            key = f"{name}.R{replicas}.S{staleness:g}"
            out[key] = {"wall_s": wall, "completed": len(res.completed),
                        "rps": s.rps, "ttft_p99": s.ttft_p99, "poa": s.poa,
                        "agreement": cp.agreement_rate,
                        "conflicts": cp.conflicts}
            emit(f"bench_replica_{key}",
                 wall / max(len(res.completed), 1) * 1e6,
                 f"poa={s.poa:.3f};ttft_p99={s.ttft_p99:.3f}s;"
                 f"agreement={cp.agreement_rate:.3f};"
                 f"conflicts={cp.conflicts}")
    return out


def _flatten(payload: dict, prefix: str = "") -> dict:
    flat = {}
    for k, v in payload.items():
        key = f"{prefix}{k}"
        if isinstance(v, dict):
            flat.update(_flatten(v, f"{key}."))
        elif isinstance(v, (int, float)):
            flat[key] = float(v)
    return flat


def check_regression(payload: dict, baseline_path: str,
                     factor: float = 2.0) -> list:
    """Compare against the checked-in baseline: wall/latency metrics may
    not be ``factor``× slower, rate/speedup metrics not ``factor``× lower.
    Counts and calibration outputs are informational only."""
    with open(baseline_path) as f:
        base = _flatten(json.load(f))
    cur = _flatten(payload)
    failures = []
    for key, ref in base.items():
        if key not in cur or ref <= 0:
            continue
        leaf = key.rsplit(".", 1)[-1]
        if leaf.startswith(("wall_s", "decision_us", "request_us", "opt_ms")):
            if cur[key] > factor * ref:
                failures.append(f"{key}: {cur[key]:.2f} > {factor}x "
                                f"baseline {ref:.2f}")
        elif leaf.startswith(("decisions_per_s", "decision_speedup",
                              "request_speedup", "opt_speedup")):
            if cur[key] < ref / factor:
                failures.append(f"{key}: {cur[key]:.2f} < baseline "
                                f"{ref:.2f} / {factor}")
    return failures


def run(smoke: bool = False) -> dict:
    payload = {"mode": "smoke" if smoke else "full",
               "routing": {name: bench_routing(name)
                           for name in SCALE_SCENARIOS},
               "opt": bench_opt(),
               "scenarios": bench_scenarios(smoke),
               "replica": bench_replica(smoke)}
    save_json("BENCH_scale", payload)
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast scenario variants (CI guard, not a "
                         "measurement)")
    ap.add_argument("--check", default=None, metavar="BASELINE",
                    help="fail on >2x regression vs this baseline JSON")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    payload = run(smoke=args.smoke)
    if args.check:
        failures = check_regression(payload, args.check)
        if failures:
            print("REGRESSION vs baseline:", file=sys.stderr)
            for f in failures:
                print(f"  {f}", file=sys.stderr)
            sys.exit(1)
        print(f"# regression check vs {args.check}: ok", file=sys.stderr)


if __name__ == "__main__":
    main()
