"""Named, reusable serving scenarios — the registry behind tests,
examples and benchmarks.

A *scenario* bundles the three inputs a simulator run needs:

* a :class:`~repro.serving.simulator.ClusterConfig` (possibly with a
  heterogeneous ``decode_workers`` pool and/or multiple prefill workers),
* a :class:`~repro.serving.workload.WorkloadConfig` (closed-loop ramp,
  open-loop Poisson/burst/diurnal, or JSONL trace replay),
* simulator keyword arguments (router config, routing policy, adaptive
  controller flag).

Usage::

    from repro.serving.scenarios import build_simulator, list_scenarios

    sim = build_simulator("hetero-decode-mixed", seed=0, fast=True)
    result = sim.run()

``get_scenario(name, **overrides)`` returns the :class:`Scenario` without
building; every factory accepts ``fast=True`` for a short-horizon variant
(used by the smoke tests) plus factory-specific knobs (``concurrency``,
``hold_s``, ``rate``, ``duration_s``, …).  Benchmarks parameterize the
``ramp``/``spike`` factories directly; examples and tests look scenarios
up by name.  Registered names span both cluster axes (homogeneous /
heterogeneous decode pools, single / pooled prefill) and all workload
modes — the paper's claim is that the three-regime PoA structure is a
property of the *mechanics*, so it should survive every one of these.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

from repro.core.planner import PlannerConfig
from repro.serving.fabric import FabricConfig
from repro.serving.simulator import (ClusterConfig, DecodeWorkerSpec,
                                     Simulator)
from repro.serving.workload import WorkloadConfig


@dataclass(frozen=True)
class Scenario:
    """A named (cluster, workload, simulator-kwargs) bundle."""
    name: str
    description: str
    cluster: ClusterConfig
    workload: WorkloadConfig
    sim_kwargs: Mapping[str, Any] = field(default_factory=dict)

    def build(self, seed: int = 0, **overrides) -> Simulator:
        """Instantiate the simulator; ``overrides`` win over the
        scenario's own ``sim_kwargs`` (e.g. ``adaptive=True``)."""
        kw = {**self.sim_kwargs, **overrides}
        return Simulator(self.cluster, self.workload, seed=seed, **kw)


# ------------------------------------------------------------ factories ----

def ramp(model: str, topo: str, concurrency: int, hold_s: float = 120.0,
         ramp_s: float = 30.0, **sim_kwargs) -> Scenario:
    """Closed-loop single-level ramp — the paper's Experiment 1/2 shape."""
    return Scenario(
        name=f"{model}-{topo}-ramp-C{concurrency}",
        description=f"closed-loop ramp to C={concurrency} on {model} {topo}",
        cluster=ClusterConfig.for_model(model, topo),
        workload=WorkloadConfig.single_level(concurrency, hold_s=hold_s,
                                             ramp_s=ramp_s),
        sim_kwargs=sim_kwargs)


def spike(model: str, topo: str, low: int = 32, high: int = 128,
          durations=(120.0, 180.0, 120.0), **sim_kwargs) -> Scenario:
    """Closed-loop three-phase load spike — Experiment 3's shape."""
    return Scenario(
        name=f"{model}-{topo}-spike",
        description=f"C={low}→{high}→{low} spike on {model} {topo}",
        cluster=ClusterConfig.for_model(model, topo),
        workload=WorkloadConfig.load_spike(low=low, high=high,
                                           durations=durations),
        sim_kwargs=sim_kwargs)


def _mixed_pool(big_cap: int = 56, small_cap: int = 24) -> Tuple[DecodeWorkerSpec, ...]:
    """A mixed-generation decode pool: one current-gen card plus two
    previous-gen cards with fewer slots, less HBM, slower decode and a
    slower interconnect."""
    big = DecodeWorkerSpec(decode_cap=big_cap, g1_blocks=100_000,
                           itl_base=0.0090, kv_transfer=0.012)
    small = DecodeWorkerSpec(decode_cap=small_cap, g1_blocks=40_000,
                             itl_base=0.0135, itl_slope=0.00001,
                             kv_transfer=0.020)
    return (big, small, small)


# ------------------------------------------------------------- registry ----

SCENARIOS: Dict[str, Callable[..., Scenario]] = {}


def register(name: str, factory: Callable[..., Scenario]) -> None:
    SCENARIOS[name] = factory


def list_scenarios() -> List[str]:
    return sorted(SCENARIOS)


def parity_scenarios() -> List[str]:
    """The backend-parity family — single source of truth for the parity
    test suite and ``benchmarks/bench_backend_parity.py`` (a scenario added
    to one must be covered by the other)."""
    return [n for n in list_scenarios() if n.startswith("parity-")]


def get_scenario(name: str, **overrides) -> Scenario:
    try:
        factory = SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"available: {', '.join(list_scenarios())}") from None
    return factory(**overrides)


def build_simulator(name: str, seed: int = 0, **overrides) -> Simulator:
    """Look up ``name`` and instantiate its simulator.  Factory knobs
    (``fast``, ``concurrency``, …) and simulator kwargs (``adaptive``,
    ``routing_policy``, …) are split automatically: anything the factory
    does not consume is forwarded to ``Scenario.build``."""
    sim_keys = {"router_config", "adaptive", "detector_config",
                "routing_policy", "regime_params", "planner_config",
                "lean_completed", "sanitize", "replicas", "staleness",
                "fabric", "network_aware"}
    sim_kw = {k: overrides.pop(k) for k in list(overrides)
              if k in sim_keys}
    return get_scenario(name, **overrides).build(seed=seed, **sim_kw)


# Engine-runner knobs build_backend() routes to EngineScenarioRunner
# (everything else is a factory knob or a DisaggregatedCluster kwarg).
_ENGINE_KEYS = {"model_name", "num_requests", "input_tokens",
                "output_tokens", "slots_per_worker", "serialize", "warmup",
                "model", "params", "adaptive", "router_config",
                "detector_config", "routing_policy", "cache_ttl",
                "prefill_cache_entries", "kv_transfer_per_block",
                "batch_prefill", "max_prefill_batch", "decode_impl",
                "num_pages", "sanitize", "replicas", "staleness_ticks",
                "fabric", "network_aware"}


def build_backend(name: str, backend: str = "analytic", seed: int = 0,
                  **overrides):
    """Instantiate a named scenario on either backend.

    ``backend="analytic"`` returns the event-driven :class:`Simulator`
    (identical to :func:`build_simulator`); ``backend="engine"`` returns an
    :class:`~repro.serving.engine_backend.EngineScenarioRunner` that drives
    the scenario's request stream through real jitted-JAX engines on a
    reduced CPU-testable model.  Both route through the shared
    :class:`~repro.serving.control_plane.ControlPlane`."""
    if backend == "analytic":
        return build_simulator(name, seed=seed, **overrides)
    if backend == "engine":
        from repro.serving.engine_backend import EngineScenarioRunner
        engine_kw = {k: overrides.pop(k) for k in list(overrides)
                     if k in _ENGINE_KEYS}
        return EngineScenarioRunner(get_scenario(name, **overrides),
                                    seed=seed, **engine_kw)
    raise ValueError(f"unknown backend {backend!r}; "
                     f"expected 'analytic' or 'engine'")


def _reg(name: str, doc: str):
    """Decorator: register ``factory`` under ``name`` with ``doc``."""
    def wrap(factory):
        def named(**kw) -> Scenario:
            sc = factory(**kw)
            return replace(sc, name=name, description=doc)
        register(name, named)
        return factory
    return wrap


# Closed-loop ramps (the paper's calibrated topologies) -----------------------

@_reg("70b-1p2d-ramp", "70B 1P/2D closed-loop ramp (paper Exp. 1 shape)")
def _70b_ramp(concurrency: int = 64, hold_s: float = 120.0,
              fast: bool = False, **kw) -> Scenario:
    if fast:
        kw.setdefault("ramp_s", 5.0)
        hold_s = 20.0
    return ramp("llama-3.1-70b", "1P/2D", concurrency, hold_s=hold_s, **kw)


@_reg("340b-1p2d-ramp", "340B 1P/2D closed-loop ramp (paper Exp. 1 shape)")
def _340b_ramp(concurrency: int = 64, hold_s: float = 120.0,
               fast: bool = False, **kw) -> Scenario:
    if fast:
        kw.setdefault("ramp_s", 5.0)
        hold_s = 20.0
    return ramp("nemotron-4-340b", "1P/2D", concurrency, hold_s=hold_s, **kw)


# Closed-loop spikes (Experiment 3) ------------------------------------------

def _register_spike(name: str, doc: str, model: str, topo: str) -> None:
    @_reg(name, doc)
    def _spike(low: int = 32, high: int = 128, fast: bool = False,
               **kw) -> Scenario:
        durations = (15.0, 20.0, 15.0) if fast else (120.0, 180.0, 120.0)
        return spike(model, topo, low=low, high=high,
                     durations=kw.pop("durations", durations), **kw)


_register_spike("70b-1p2d-spike", "70B 1P/2D C=32→128→32 spike",
                "llama-3.1-70b", "1P/2D")
_register_spike("70b-1p5d-spike", "70B 1P/5D C=32→128→32 spike",
                "llama-3.1-70b", "1P/5D")
_register_spike("340b-1p2d-spike", "340B 1P/2D C=32→128→32 spike",
                "nemotron-4-340b", "1P/2D")


# Open-loop arrival processes ------------------------------------------------

@_reg("70b-2p4d-poisson",
      "70B with a 2-worker prefill pool and 4 decode workers under "
      "open-loop Poisson arrivals")
def _70b_poisson(rate: float = 12.0, duration_s: float = 120.0,
                 fast: bool = False, **kw) -> Scenario:
    if fast:
        duration_s = 25.0
    return Scenario(
        name="", description="",
        cluster=ClusterConfig.for_model("llama-3.1-70b", "2P/4D"),
        workload=WorkloadConfig.poisson(rate=rate, duration_s=duration_s),
        sim_kwargs=kw)


@_reg("340b-1p5d-burst",
      "340B 1P/5D under bursty on/off arrivals (quiet 4 rps, bursts 24 rps)")
def _340b_burst(rate: float = 4.0, burst_rate: float = 24.0,
                duration_s: float = 180.0, fast: bool = False, **kw) -> Scenario:
    if fast:
        duration_s = 25.0
    return Scenario(
        name="", description="",
        cluster=ClusterConfig.for_model("nemotron-4-340b", "1P/5D"),
        workload=WorkloadConfig.bursty(rate=rate, burst_rate=burst_rate,
                                       duration_s=duration_s,
                                       on_s=8.0, off_s=20.0),
        sim_kwargs=kw)


@_reg("70b-1p2d-diurnal",
      "70B 1P/2D under a diurnal sinusoid arrival rate (period 120 s)")
def _70b_diurnal(rate: float = 10.0, duration_s: float = 240.0,
                 period_s: float = 120.0, fast: bool = False, **kw) -> Scenario:
    if fast:
        duration_s, period_s = 24.0, 12.0
    return Scenario(
        name="", description="",
        cluster=ClusterConfig.for_model("llama-3.1-70b", "1P/2D"),
        workload=WorkloadConfig.diurnal(rate=rate, duration_s=duration_s,
                                        period_s=period_s, amplitude=0.8),
        sim_kwargs=kw)


# Heterogeneous decode pools -------------------------------------------------

@_reg("hetero-decode-mixed",
      "70B with a mixed-generation decode pool (1 big + 2 small cards), "
      "closed-loop ramp")
def _hetero_mixed(concurrency: int = 64, hold_s: float = 120.0,
                  fast: bool = False, **kw) -> Scenario:
    if fast:
        hold_s = 20.0
    base = ClusterConfig.for_model("llama-3.1-70b", "1P/3D")
    return Scenario(
        name="", description="",
        cluster=replace(base, decode_workers=_mixed_pool()),
        workload=WorkloadConfig.single_level(concurrency, hold_s=hold_s,
                                             ramp_s=5.0 if fast else 30.0),
        sim_kwargs=kw)


@_reg("hetero-decode-burst",
      "mixed-generation decode pool under bursty open-loop arrivals — "
      "capacity-normalized routing is what keeps the small cards sane")
def _hetero_burst(rate: float = 6.0, burst_rate: float = 30.0,
                  duration_s: float = 180.0, fast: bool = False,
                  **kw) -> Scenario:
    if fast:
        duration_s = 25.0
    base = ClusterConfig.for_model("llama-3.1-70b", "1P/3D")
    return Scenario(
        name="", description="",
        cluster=replace(base, decode_workers=_mixed_pool()),
        workload=WorkloadConfig.bursty(rate=rate, burst_rate=burst_rate,
                                       duration_s=duration_s,
                                       on_s=6.0, off_s=18.0),
        sim_kwargs=kw)


# Cache pressure (Game 2 / Prop. 5) ------------------------------------------
#
# Tiny per-worker G1 HBM against the skewed template mix: resident blocks
# outgrow G1 mid-run, ρ crosses 1, and the KVBM starts demoting into
# G2/G3 — the contested regime where router overlap must stay coherent
# with actual HBM residency and G2/G3 hits pay Eq. 6 onboarding latency
# instead of full recompute.

def _pressure_cluster(g1_blocks: int, g2_blocks: Optional[int] = None,
                      g3_blocks: Optional[int] = None,
                      topo: str = "1P/2D") -> ClusterConfig:
    base = ClusterConfig.for_model("llama-3.1-70b", topo)
    return replace(base, g1_blocks=g1_blocks,
                   g2_blocks=g2_blocks if g2_blocks is not None else 2 * g1_blocks,
                   g3_blocks=g3_blocks if g3_blocks is not None else 4 * g1_blocks)


def _pressure_workload(workload: WorkloadConfig, input_tokens: int,
                       num_templates: int = 12) -> WorkloadConfig:
    # longer prompts (more blocks per template) and a wider Zipf-skewed
    # template universe, so the resident working set outgrows the
    # shrunken G1 within the run and keeps churning
    return replace(workload, input_tokens=input_tokens,
                   num_templates=num_templates)


@_reg("cache-pressure-70b",
      "70B 1P/2D ramp with tiny G1 HBM (Prop. 5: ρ crosses 1 mid-run, "
      "demotions + G2/G3 onboarding on the TTFT path)")
def _cache_pressure_ramp(concurrency: int = 48, hold_s: float = 90.0,
                         g1_blocks: int = 48, input_tokens: int = 256,
                         fast: bool = False, **kw) -> Scenario:
    if fast:
        hold_s = 20.0
    return Scenario(
        name="", description="",
        cluster=_pressure_cluster(g1_blocks),
        workload=_pressure_workload(
            WorkloadConfig.single_level(concurrency, hold_s=hold_s,
                                        ramp_s=5.0 if fast else 30.0),
            input_tokens),
        sim_kwargs=kw)


@_reg("cache-pressure-burst",
      "tiny-G1 cluster under bursty open-loop arrivals — tier churn plus "
      "the overload drain tail")
def _cache_pressure_burst(rate: float = 5.0, burst_rate: float = 25.0,
                          duration_s: float = 120.0, g1_blocks: int = 48,
                          input_tokens: int = 256, fast: bool = False,
                          **kw) -> Scenario:
    if fast:
        duration_s = 25.0
    return Scenario(
        name="", description="",
        cluster=_pressure_cluster(g1_blocks),
        workload=_pressure_workload(
            WorkloadConfig.bursty(rate=rate, burst_rate=burst_rate,
                                  duration_s=duration_s, on_s=6.0,
                                  off_s=14.0),
            input_tokens),
        sim_kwargs=kw)


@_reg("cache-pressure-hetero",
      "mixed-generation pool where only the small cards are G1-starved — "
      "per-worker ρ diverges and cache-affinity must follow residency")
def _cache_pressure_hetero(concurrency: int = 64, hold_s: float = 90.0,
                           input_tokens: int = 256, fast: bool = False,
                           **kw) -> Scenario:
    if fast:
        hold_s = 20.0
    big = DecodeWorkerSpec(decode_cap=56, g1_blocks=100_000,
                           itl_base=0.0090, kv_transfer=0.012)
    small = DecodeWorkerSpec(decode_cap=24, g1_blocks=32, g2_blocks=64,
                             g3_blocks=128, itl_base=0.0135,
                             itl_slope=0.00001, kv_transfer=0.020)
    base = ClusterConfig.for_model("llama-3.1-70b", "1P/3D")
    return Scenario(
        name="", description="",
        cluster=replace(base, decode_workers=(big, small, small)),
        workload=_pressure_workload(
            WorkloadConfig.single_level(concurrency, hold_s=hold_s,
                                        ramp_s=5.0 if fast else 30.0),
            input_tokens),
        sim_kwargs=kw)


# Elastic worker-role pools (Game 1 / Prop. 1) -------------------------------
#
# One unified pool of workers whose P/D split the Planner repartitions at
# runtime (drain protocol: stop admitting, drain decodes, flush KVBM +
# indexer claims).  The elastic calibration makes *both* pool objectives
# load-sensitive — prefill is slowed (long-prompt regime) so the prefill
# pool can saturate, and decode ITL gets a real load slope so shrinking
# the decode pool raises ITL violations.  Knobs documented in
# EXPERIMENTS.md ("Game 1 repartitioning calibration").

def _elastic_cluster(model: str, topo: str, *, prefill_rate: float,
                     itl_slope: float, decode_cap: int) -> ClusterConfig:
    base = ClusterConfig.for_model(model, topo)
    return replace(base, prefill_rate=prefill_rate, itl_slope=itl_slope,
                   decode_cap=decode_cap)


def _elastic_planner(fast: bool, *, itl_slo: float, ttft_slo: float,
                     adjust_interval: Optional[float] = None,
                     grace_intervals: Optional[int] = None) -> PlannerConfig:
    if adjust_interval is None:
        adjust_interval = 6.0 if fast else 20.0
    if grace_intervals is None:
        grace_intervals = 1 if fast else 2
    return PlannerConfig(adjust_interval=adjust_interval,
                         grace_intervals=grace_intervals,
                         ttft_slo=ttft_slo, itl_slo=itl_slo,
                         hysteresis=0.3)


@_reg("elastic-70b",
      "70B unified 6-worker pool starting decode-heavy (1P/5D); the "
      "Planner repartitions toward the Prop. 1 variational equilibrium "
      "under stationary closed-loop load")
def _elastic_70b(concurrency: int = 64, hold_s: float = 150.0,
                 topo: str = "1P/5D", fast: bool = False,
                 planner: bool = True, **kw) -> Scenario:
    if fast:
        hold_s = 60.0
    if planner:
        kw.setdefault("planner_config",
                      _elastic_planner(fast, itl_slo=0.016, ttft_slo=0.30))
    return Scenario(
        name="", description="",
        cluster=_elastic_cluster("llama-3.1-70b", topo,
                                 prefill_rate=16.0, itl_slope=4e-4,
                                 decode_cap=64),
        workload=WorkloadConfig.single_level(concurrency, hold_s=hold_s,
                                             ramp_s=5.0),
        sim_kwargs=kw)


@_reg("elastic-340b",
      "340B unified 6-worker pool (1P/5D start) under stationary "
      "closed-loop load with runtime P/D repartitioning")
def _elastic_340b(concurrency: int = 48, hold_s: float = 150.0,
                  topo: str = "1P/5D", fast: bool = False,
                  planner: bool = True, **kw) -> Scenario:
    if fast:
        hold_s = 60.0
    if planner:
        kw.setdefault("planner_config",
                      _elastic_planner(fast, itl_slo=0.035, ttft_slo=0.60))
    return Scenario(
        name="", description="",
        cluster=_elastic_cluster("nemotron-4-340b", topo,
                                 prefill_rate=8.0, itl_slope=8e-4,
                                 decode_cap=64),
        workload=WorkloadConfig.single_level(concurrency, hold_s=hold_s,
                                             ramp_s=5.0),
        sim_kwargs=kw)


@_reg("elastic-burst",
      "elastic 70B pool under a diurnal open-loop wave: the equilibrium "
      "split shifts with the arrival rate and the Planner re-splits "
      "across the cycle")
def _elastic_burst(rate: float = 10.0, duration_s: float = 240.0,
                   period_s: float = 120.0, topo: str = "1P/5D",
                   fast: bool = False, planner: bool = True,
                   **kw) -> Scenario:
    if fast:
        duration_s, period_s = 60.0, 30.0
    if planner:
        kw.setdefault("planner_config",
                      _elastic_planner(fast, itl_slo=0.016, ttft_slo=0.30,
                                       adjust_interval=5.0 if fast else 10.0))
    return Scenario(
        name="", description="",
        cluster=_elastic_cluster("llama-3.1-70b", topo,
                                 prefill_rate=16.0, itl_slope=4e-4,
                                 decode_cap=64),
        workload=WorkloadConfig.diurnal(rate=rate, duration_s=duration_s,
                                        period_s=period_s, amplitude=0.8),
        sim_kwargs=kw)


# Production-scale pools (large-pool hot path) -------------------------------
#
# Pools the size production disaggregated deployments run (tens to hundreds
# of decode workers) under open-loop Poisson traffic with a wide Zipf
# template mix — the regime where the per-worker radix walk, repeated
# request hashing and the dense frozen-OPT matrix used to melt the control
# plane.  The full variants push ~100k requests through the event loop
# (``benchmarks/bench_scale.py`` tracks their wall time); ``fast=True``
# keeps the pool size but shortens the horizon for smoke tests.

def _scale_pool(num_decode: int, hetero: bool) -> ClusterConfig:
    topo = f"{max(2, num_decode // 16)}P/{num_decode}D"
    base = ClusterConfig.for_model("llama-3.1-70b", topo)
    if not hetero:
        return base
    # mixed-generation pool: every fourth card is current-gen, the rest
    # are previous-gen with fewer slots, less HBM and slower decode
    big = DecodeWorkerSpec(decode_cap=56, g1_blocks=100_000,
                           itl_base=0.0090, kv_transfer=0.012)
    small = DecodeWorkerSpec(decode_cap=24, g1_blocks=40_000,
                             itl_base=0.0135, itl_slope=0.00001,
                             kv_transfer=0.020)
    pool = tuple(big if w % 4 == 0 else small for w in range(num_decode))
    return replace(base, decode_workers=pool)


def _scale_scenario(num_decode: int, hetero: bool, num_requests: int,
                    num_templates: int, fast: bool, **kw) -> Scenario:
    if fast:
        num_requests = min(num_requests, 1500)
    rate = 2.0 * num_decode          # load scales with the pool
    kw.setdefault("lean_completed", True)
    return Scenario(
        name="", description="",
        cluster=_scale_pool(num_decode, hetero),
        workload=replace(
            WorkloadConfig.poisson(rate=rate,
                                   duration_s=num_requests / rate),
            num_templates=num_templates, output_tokens=32),
        sim_kwargs=kw)


@_reg("scale-64",
      "64 homogeneous decode workers (4P/64D), 100k open-loop Poisson "
      "requests over a 64-template Zipf mix")
def _scale_64(num_requests: int = 100_000, num_templates: int = 64,
              fast: bool = False, **kw) -> Scenario:
    return _scale_scenario(64, False, num_requests, num_templates, fast, **kw)


@_reg("scale-128",
      "128-worker mixed-generation decode pool (8P/128D), 100k open-loop "
      "Poisson requests over a 96-template Zipf mix")
def _scale_128(num_requests: int = 100_000, num_templates: int = 96,
               fast: bool = False, **kw) -> Scenario:
    return _scale_scenario(128, True, num_requests, num_templates, fast, **kw)


@_reg("scale-256",
      "256 homogeneous decode workers (16P/256D), 100k open-loop Poisson "
      "requests over a 128-template Zipf mix")
def _scale_256(num_requests: int = 100_000, num_templates: int = 128,
               fast: bool = False, **kw) -> Scenario:
    return _scale_scenario(256, False, num_requests, num_templates, fast, **kw)


# Replicated control plane at scale ------------------------------------------
#
# The scale pools routed by R router replicas on bounded-staleness state
# views (ReplicatedControlPlane): each replica refreshes its snapshot
# every ``staleness`` metrics intervals and sees only its own placements
# in between.  ``replicas``/``staleness`` are first-class knobs so the
# staleness sweep in benchmarks/bench_scale.py (and the deterministic
# replay tests) can parameterize the grid through the registry.

def _scale_replica(num_decode: int, hetero: bool, num_requests: int,
                   num_templates: int, fast: bool, replicas: int,
                   staleness: float, **kw) -> Scenario:
    kw["replicas"] = replicas
    kw["staleness"] = staleness
    return _scale_scenario(num_decode, hetero, num_requests, num_templates,
                           fast, **kw)


@_reg("scale-replica-64",
      "scale-64 pool routed by R router replicas on bounded-staleness "
      "views (default R=4, staleness=4 sync intervals)")
def _scale_replica_64(num_requests: int = 100_000, num_templates: int = 64,
                      fast: bool = False, replicas: int = 4,
                      staleness: float = 4.0, **kw) -> Scenario:
    return _scale_replica(64, False, num_requests, num_templates, fast,
                          replicas, staleness, **kw)


@_reg("scale-replica-128",
      "scale-128 mixed-generation pool routed by R router replicas on "
      "bounded-staleness views (default R=4, staleness=4 sync intervals)")
def _scale_replica_128(num_requests: int = 100_000, num_templates: int = 96,
                       fast: bool = False, replicas: int = 4,
                       staleness: float = 4.0, **kw) -> Scenario:
    return _scale_replica(128, True, num_requests, num_templates, fast,
                          replicas, staleness, **kw)


@_reg("scale-replica-256",
      "scale-256 pool routed by R router replicas on bounded-staleness "
      "views (default R=4, staleness=4 sync intervals)")
def _scale_replica_256(num_requests: int = 100_000, num_templates: int = 128,
                       fast: bool = False, replicas: int = 4,
                       staleness: float = 4.0, **kw) -> Scenario:
    return _scale_replica(256, False, num_requests, num_templates, fast,
                          replicas, staleness, **kw)


# Trace replay ---------------------------------------------------------------

def example_trace_records(n: int = 120, horizon_s: float = 30.0) -> List[dict]:
    """A deterministic synthetic trace following the JSONL schema: arrival
    times thicken toward the middle of the horizon (a mini load wave),
    templates cycle with the popularity skew, output lengths alternate."""
    records = []
    for i in range(n):
        u = i / max(n - 1, 1)
        # quadratic time warp: denser arrivals mid-horizon
        t = horizon_s * (u - 0.35 * u * (1.0 - u) * 2.0)
        records.append({
            "t": round(max(t, 0.0), 4),
            "template": (i * 7) % 5,
            "input_tokens": 96 if i % 3 else 160,
            "output_tokens": 128 if i % 2 else 256,
        })
    return records


@_reg("trace-replay",
      "deterministic synthetic JSONL-schema trace replayed on 70B 1P/2D")
def _trace_replay(n: int = 120, horizon_s: float = 30.0,
                  fast: bool = False, **kw) -> Scenario:
    if fast:
        n, horizon_s = 60, 20.0
    return Scenario(
        name="", description="",
        cluster=ClusterConfig.for_model("llama-3.1-70b", "1P/2D"),
        workload=WorkloadConfig.from_records(
            example_trace_records(n, horizon_s)),
        sim_kwargs=kw)


# Backend parity (analytic vs engine) ----------------------------------------
#
# Tiny trace scenarios crafted so a τ=0 routing decision is a pure function
# of the indexer's insert history on BOTH backends: explicit template
# sequences (no sampling), zero service jitter, a metrics interval longer
# than the run (the analytic router's load view stays frozen at zero, like
# the engine's between serialized requests) and a cache TTL longer than the
# horizon.  Under that protocol the two backends must agree decision-for-
# decision (tests/test_backend_parity.py) — any drift is a control-plane
# coherence bug, not timing noise.

def _parity_cluster(topo: str, decode_workers: Tuple[DecodeWorkerSpec, ...] = ()
                    ) -> ClusterConfig:
    base = ClusterConfig.for_model("llama-3.1-70b", topo)
    return replace(base, service_sigma=0.0, metrics_interval=1000.0,
                   cache_ttl=1000.0,
                   decode_workers=decode_workers)


def _parity_trace(templates, n: int, spacing: float = 0.45,
                  input_tokens: int = 48, output_tokens: int = 16
                  ) -> WorkloadConfig:
    records = [{"t": round(i * spacing, 4),
                "template": templates[i % len(templates)],
                "input_tokens": input_tokens,
                "output_tokens": output_tokens}
               for i in range(n)]
    return replace(WorkloadConfig.from_records(records), num_templates=12)


@_reg("parity-2d-warm",
      "1P/2D backend-parity trace, warm-heavy template cycle (0,1,0,2): "
      "cache-affinity decisions must agree across backends")
def _parity_2d_warm(n: int = 16, fast: bool = False,
                    templates: Tuple[int, ...] = (0, 1, 0, 2),
                    **kw) -> Scenario:
    if fast:
        n = 8
    return Scenario(
        name="", description="",
        cluster=_parity_cluster("1P/2D"),
        workload=_parity_trace(templates, n),
        sim_kwargs=kw)


@_reg("parity-3d-hetero",
      "1P/3D mixed-generation backend-parity trace (cycle 0,1,2,0,1) — "
      "capacity-normalized routing must agree across backends")
def _parity_3d_hetero(n: int = 15, fast: bool = False, **kw) -> Scenario:
    if fast:
        n = 10
    return Scenario(
        name="", description="",
        cluster=_parity_cluster("1P/3D", _mixed_pool()),
        workload=_parity_trace((0, 1, 2, 0, 1), n),
        sim_kwargs=kw)


@_reg("parity-3d-rr",
      "1P/3D backend-parity trace under round-robin routing: templates "
      "spread across the pool, so per-worker overlap VECTORS (not just "
      "the chosen worker) must agree across backends")
def _parity_3d_rr(n: int = 15, fast: bool = False, **kw) -> Scenario:
    if fast:
        n = 9
    kw.setdefault("routing_policy", "round_robin")
    return Scenario(
        name="", description="",
        cluster=_parity_cluster("1P/3D"),
        workload=_parity_trace((0, 1, 2, 0, 1), n),
        sim_kwargs=kw)


@_reg("parity-2d-cold",
      "1P/2D backend-parity trace of all-distinct templates — the full-"
      "miss path (zero overlap everywhere) must agree across backends")
def _parity_2d_cold(n: int = 10, fast: bool = False, **kw) -> Scenario:
    if fast:
        n = 6
    return Scenario(
        name="", description="",
        cluster=_parity_cluster("1P/2D"),
        workload=_parity_trace(tuple(range(10)), n),
        sim_kwargs=kw)


# Routing-policy baseline ----------------------------------------------------

@_reg("70b-1p2d-rr-baseline",
      "70B 1P/2D ramp under static round-robin routing (§9.2 baseline)")
def _70b_rr(concurrency: int = 64, hold_s: float = 120.0,
            fast: bool = False, **kw) -> Scenario:
    if fast:
        kw.setdefault("ramp_s", 5.0)
        hold_s = 20.0
    kw.setdefault("routing_policy", "round_robin")
    return ramp("llama-3.1-70b", "1P/2D", concurrency, hold_s=hold_s, **kw)


# Fabric-aware KV transfer (Game 4) ------------------------------------------
#
# Variants that attach the explicit datacenter-fabric model
# (repro.serving.fabric): every P→D KV transfer becomes a sized
# transmission serializing store-and-forward across NIC / rack-switch /
# spine links, and ``network_aware=True`` adds the congestion-aware quote
# to decode selection.  The congested variant pins a deliberately thin
# NIC so sync-window herding visibly queues transfers — the regime where
# network-aware selection beats cache-affinity-only routing
# (benchmarks/bench_fabric.py gates the win in CI).

def default_fabric() -> FabricConfig:
    """The calibrated default fabric: 25 Gbps NICs price one full 8-block
    transfer at ≈ the legacy flat kv_transfer charge (~13 ms), so
    attaching the fabric preserves the uncongested timing scale."""
    return FabricConfig()


def congested_fabric() -> FabricConfig:
    """A deliberately thin fabric (8 Gbps NICs, halved switching tiers)
    for the congestion experiments: herded transfers queue visibly on
    the victim decode NIC."""
    return FabricConfig(nic_gbps=8.0, rack_gbps=50.0, spine_gbps=50.0)


@_reg("fabric-ramp",
      "70B 1P/4D closed-loop ramp with the explicit fabric attached "
      "(store-and-forward KV transmissions over NIC/rack/spine links)")
def _fabric_ramp(concurrency: int = 64, hold_s: float = 120.0,
                 fast: bool = False, **kw) -> Scenario:
    if fast:
        kw.setdefault("ramp_s", 5.0)
        hold_s = 20.0
    kw.setdefault("fabric", default_fabric())
    return ramp("llama-3.1-70b", "1P/4D", concurrency, hold_s=hold_s, **kw)


@_reg("fabric-drain",
      "elastic 70B pool with fabric attached: Planner flips re-path "
      "future transfers and the drain protocol cancels in-flight "
      "transmissions, refunding their reserved link time")
def _fabric_drain(concurrency: int = 64, hold_s: float = 150.0,
                  fast: bool = False, **kw) -> Scenario:
    kw.setdefault("fabric", default_fabric())
    return _elastic_70b(concurrency=concurrency, hold_s=hold_s, fast=fast,
                        **kw)


@_reg("fabric-scale-64",
      "scale-64 pool on a deliberately thin fabric (8 Gbps NICs): "
      "sync-window herding queues KV transfers on shared decode NICs — "
      "the congested regime where network_aware=True should win")
def _fabric_scale_64(num_requests: int = 100_000, num_templates: int = 64,
                     fast: bool = False, **kw) -> Scenario:
    kw.setdefault("fabric", congested_fabric())
    return _scale_scenario(64, False, num_requests, num_templates, fast,
                           **kw)
