"""Dry-run machinery on a small multi-device mesh (subprocess: the device
count must be set before JAX initializes, and the main test process runs on
one device)."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
from repro.launch.dryrun_lib import run_cell

mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
rec = run_cell("xlstm-125m", "decode_32k", mesh, verbose=False)
print("JSON:" + json.dumps({
    "devices": rec["devices"],
    "flops": rec["cost"]["flops"],
    "coll": rec["collectives"]["total_bytes"],
    "bottleneck": rec["roofline"]["bottleneck"],
    "mem_args": rec["memory"]["argument_size_in_bytes"],
}))
"""


@pytest.mark.slow
def test_dryrun_cell_on_8_devices():
    import jax
    if not hasattr(jax.sharding, "AxisType"):
        pytest.skip("jax too old for explicit mesh axis_types (needs >=0.5)")
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("JSON:")][0]
    rec = json.loads(line[5:])
    assert rec["devices"] == 8
    assert rec["flops"] > 0
    assert rec["mem_args"] > 0
    assert rec["bottleneck"] in ("compute", "memory", "collective")
