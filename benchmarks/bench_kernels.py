"""Kernel micro-benchmarks: flash / decode / paged attention vs their jnp
oracles.

Default mode times the Pallas kernels in interpret mode (CPU wall-time —
a correctness-adjacent smoke number, not a speed claim).  ``--compiled``
adds real compiled-kernel rows (``interpret=False``); it requires a TPU
backend and auto-skips with a message anywhere else, so the same command
line is safe in CPU CI and on hardware.

Schema (``reports/benchmarks/bench_kernels.json``): per kernel,
``ref_us`` (jitted jnp oracle), ``pallas_interpret_us``, and with
``--compiled`` also ``pallas_compiled_us`` — plus a work descriptor
(``flops`` / ``kv_bytes``).

    PYTHONPATH=src python -m benchmarks.bench_kernels [--compiled]
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, save_json
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref


def _time(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run(compiled: bool = False):
    if compiled and jax.default_backend() != "tpu":
        print(f"# --compiled skipped: backend is "
              f"{jax.default_backend()!r}, compiled Pallas kernels need "
              f"a TPU", file=sys.stderr)
        compiled = False

    results = {}
    b, s, h, kh, hd = 1, 512, 8, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kh, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kh, hd), jnp.float32)

    t_ref = _time(jax.jit(lambda *a: flash_attention_ref(*a)), q, k, v)
    t_pal = _time(lambda *a: flash_attention(*a, interpret=True), q, k, v)
    flops = 4 * b * s * s * h * hd / 2  # causal
    results["flash_attention"] = dict(ref_us=t_ref, pallas_interpret_us=t_pal,
                                      flops=flops)
    if compiled:
        results["flash_attention"]["pallas_compiled_us"] = _time(
            lambda *a: flash_attention(*a, interpret=False), q, k, v)
    emit("bench_flash_attention", t_pal,
         f"ref_us={t_ref:.0f};causal_gqa_{s}x{s}x{h}h")

    t = 2048
    q1 = jax.random.normal(ks[0], (8, h, hd), jnp.float32)
    k1 = jax.random.normal(ks[1], (8, t, kh, hd), jnp.float32)
    v1 = jax.random.normal(ks[2], (8, t, kh, hd), jnp.float32)
    lengths = jnp.full((8,), t, jnp.int32)
    t_ref = _time(jax.jit(lambda *a: decode_attention_ref(*a)), q1, k1, v1,
                  lengths)
    t_pal = _time(lambda *a: decode_attention(*a, interpret=True), q1, k1, v1,
                  lengths)
    kv_bytes = 2 * 8 * t * kh * hd * 4
    results["decode_attention"] = dict(ref_us=t_ref,
                                       pallas_interpret_us=t_pal,
                                       kv_bytes=kv_bytes)
    if compiled:
        results["decode_attention"]["pallas_compiled_us"] = _time(
            lambda *a: decode_attention(*a, interpret=False), q1, k1, v1,
            lengths)
    emit("bench_decode_attention", t_pal,
         f"ref_us={t_ref:.0f};kv_bytes={kv_bytes}")

    # paged decode: 8 sequences reading scattered 16-token pages from a
    # shared pool (the serving path's KV layout)
    bp, block, pages, per_seq = 8, 16, 128, 8
    q2 = jax.random.normal(ks[0], (bp, h, hd), jnp.float32)
    k2 = jax.random.normal(ks[1], (pages, block, kh, hd), jnp.float32)
    v2 = jax.random.normal(ks[2], (pages, block, kh, hd), jnp.float32)
    table = jax.random.permutation(
        jax.random.PRNGKey(7), pages)[: bp * per_seq].reshape(bp, per_seq)
    table = table.astype(jnp.int32)
    plen = jnp.full((bp,), block * per_seq, jnp.int32)
    t_ref = _time(jax.jit(lambda *a: paged_attention_ref(*a)), q2, k2, v2,
                  table, plen)
    t_pal = _time(lambda *a: paged_attention(*a, interpret=True), q2, k2, v2,
                  table, plen)
    paged_bytes = 2 * bp * per_seq * block * kh * hd * 4
    results["paged_attention"] = dict(ref_us=t_ref,
                                      pallas_interpret_us=t_pal,
                                      kv_bytes=paged_bytes)
    if compiled:
        results["paged_attention"]["pallas_compiled_us"] = _time(
            lambda *a: paged_attention(*a, interpret=False), q2, k2, v2,
            table, plen)
    emit("bench_paged_attention", t_pal,
         f"ref_us={t_ref:.0f};kv_bytes={paged_bytes}")

    results["compiled"] = compiled
    save_json("bench_kernels", results)
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--compiled", action="store_true",
                    help="also time interpret=False Pallas kernels "
                         "(TPU only; auto-skips elsewhere)")
    args = ap.parse_args()
    run(compiled=args.compiled)


if __name__ == "__main__":
    main()
