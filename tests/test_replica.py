"""Replicated control plane: R router replicas on bounded-staleness views.

The refactor contract (the pin the rest of the suite trusts): with R=1
and staleness=0 the replicated plane IS the single-router plane —
request-level and poll-log bit-exact across the whole scenario registry,
on both backends.  With staleness > 0 the runs stay deterministic (same
seed → identical per-replica decision logs), the write path reconciles
replica conflicts at admission, and the agreement-vs-fresh probe
quantifies how often a stale view disagrees with fresh state.
"""
import json

import pytest

from repro.serving.control_plane import (ControlPlane,
                                         ReplicatedControlPlane,
                                         StateView)
from repro.serving.scenarios import build_simulator, list_scenarios
from repro.serving.simulator import ClusterConfig, Simulator
from repro.serving.workload import WorkloadConfig

ALL_SCENARIOS = list_scenarios()

TOKENS = list(range(64))


def _request_view(res):
    return [(r.rid, r.decode_worker, r.submit_t, r.prefill_end, r.finish_t,
             r.overlap, r.overlaps_all, r.onboard_frac, r.onboard_latency)
            for r in res.completed]


def _poll_view(res):
    # json round-trip: NaN PoA values compare equal as the literal "NaN"
    return json.dumps(res.poll_log)


# ------------------------------------------- R=1 / staleness=0 pin ----------


@pytest.mark.parametrize("name", ALL_SCENARIOS)
def test_identity_replica_bit_exact_full_registry(name):
    """R=1/staleness=0 replicated plane vs the single-router plane, over
    EVERY registered scenario (replicas=None forces the plain plane even
    on the scale-replica-* entries, whose factory defaults are stale)."""
    base = build_simulator(name, seed=0, fast=True,
                           replicas=None, staleness=0.0)
    repl = build_simulator(name, seed=0, fast=True,
                           replicas=1, staleness=0.0)
    assert not isinstance(base.control, ReplicatedControlPlane)
    assert isinstance(repl.control, ReplicatedControlPlane)
    rb, rr = base.run(), repl.run()
    assert _request_view(rb) == _request_view(rr)
    assert _poll_view(rb) == _poll_view(rr)
    # identity path: no snapshots exist and every decision "agrees"
    assert repl.control.replica_views == []
    assert repl.control.agreement_rate == 1.0
    assert repl.control.conflicts == 0


@pytest.mark.parametrize("name", ["scale-64", "70b-1p2d-ramp"])
def test_staleness_zero_bit_exact_for_any_replica_count(name):
    """Fresh pass-through views make R itself invisible: R=4 at
    staleness=0 still reproduces the single-router run bit-exactly."""
    base = build_simulator(name, seed=0, fast=True)
    repl = build_simulator(name, seed=0, fast=True,
                           replicas=4, staleness=0.0)
    rb, rr = base.run(), repl.run()
    assert _request_view(rb) == _request_view(rr)
    assert _poll_view(rb) == _poll_view(rr)
    # decisions still round-robin across the R logs
    logs = repl.control.replica_logs
    assert len(logs) == 4
    assert sum(len(l) for l in logs) == repl.control.decisions_total
    assert max(len(l) for l in logs) - min(len(l) for l in logs) <= 1


# ------------------------------------------------ stale determinism ---------


def _replica_log_view(sim):
    return [[(d.rid, d.worker, d.overlap, d.now) for d in log]
            for log in sim.control.replica_logs]


def test_stale_replay_same_seed_identical_logs():
    """staleness > 0 runs are deterministic: the same seed reproduces the
    per-replica decision logs (and the run itself) exactly."""
    a = build_simulator("scale-replica-64", seed=3, fast=True)
    b = build_simulator("scale-replica-64", seed=3, fast=True)
    ra, rb = a.run(), b.run()
    assert _replica_log_view(a) == _replica_log_view(b)
    assert _request_view(ra) == _request_view(rb)
    assert _poll_view(ra) == _poll_view(rb)
    assert a.control.agreement_rate == b.control.agreement_rate
    assert a.control.conflicts == b.control.conflicts
    c = build_simulator("scale-replica-64", seed=4, fast=True)
    c.run()
    assert _replica_log_view(a) != _replica_log_view(c)


def test_stale_run_disagrees_and_reconciles():
    """At the default grid point (R=4, staleness=4) stale views must
    actually disagree with fresh state sometimes — otherwise the sweep
    measures nothing — and every conflict resolves at admission."""
    sim = build_simulator("scale-replica-64", seed=0, fast=True)
    res = sim.run()
    cp = sim.control
    assert 0.0 < cp.agreement_rate < 1.0
    assert cp.conflicts > 0
    assert sim.in_flight == 0 and len(res.completed) > 1000
    # round-robin assignment keeps the replica logs balanced
    logs = cp.replica_logs
    assert max(len(l) for l in logs) - min(len(l) for l in logs) <= 1
    assert sum(len(l) for l in logs) == cp.decisions_total
    # every view's age respects its staleness bound at run end
    for v in cp.replica_views:
        assert v.age(sim.now) <= v.bound + 1e-9


def test_view_snapshot_is_isolated_from_live_state():
    """Between syncs a replica's snapshot must not move when the
    authoritative store does — that isolation IS the staleness model."""
    cp = ReplicatedControlPlane(4, replicas=2, staleness_s=5.0,
                                capacities={i: 8.0 for i in range(4)})
    v = cp.replica_views[0]
    frozen = v.frozen_state()
    # authoritative writes: load bump, claim insert, health flip
    cp.router.on_schedule(2, TOKENS, decode_blocks=3.0, now=1.0)
    cp.router.set_health(3, False)
    assert v.frozen_state() == frozen
    assert 3 in v.healthy_ids()              # stale view still trusts w3
    cp.sync_views(2.0)
    assert v.frozen_state() != frozen
    assert 3 not in v.healthy_ids()


def test_conflict_unhealthy_worker_redirects_at_admission():
    """A stale view routing onto a worker that left the pool after the
    last sync: the serialized write takes the fresh choice instead."""
    cp = ReplicatedControlPlane(2, replicas=1, staleness_s=10.0,
                                capacities={0: 8.0, 1: 8.0})
    cp.sync_views(0.0)
    # make worker 0 the stale view's favorite, then kill it
    cp.router.on_schedule(0, TOKENS, now=0.0)
    cp.sync_views(0.5)
    cp.router.set_health(0, False)
    w, _, _, ids = cp.select_worker(TOKENS, now=1.0, rid=0)
    assert w == 1 and 0 not in ids
    assert cp.conflicts == 1
    # the replica log still records what the replica *decided* (worker 0)
    assert cp.replica_logs[0][-1].worker == 0


def test_admission_ledger_bounds_contested_pileup():
    """Contested placements (stale view and fresh state disagree) land —
    and queue — until occupancy plus in-window contested writes exhaust
    the bounded admission queue (ADMIT_QUEUE_FACTOR × capacity); only the
    overflow reconciles to the fresh choice."""
    cp = ReplicatedControlPlane(2, replicas=1, staleness_s=100.0,
                                capacities={0: 4.0, 1: 4.0})
    cp.sync_views(0.0)                       # view snapshots loads (0, 0)
    cp.router.workers[0].active_blocks = 7   # authoritative: w0 near-full
    # stale tie-break herds onto w0; fresh prefers the idle w1
    first, _, _, _ = cp.select_worker(TOKENS, now=1.0, rid=0)
    assert first == 0 and cp.conflicts == 0  # lands: 7 + 0 < 2 x 4
    assert cp._window_writes == {0: 1}
    second, _, _, _ = cp.select_worker(TOKENS, now=1.1, rid=1)
    assert second == 1 and cp.conflicts == 1  # overflow: 7 + 1 >= 8
    cp.sync_views(2.0)                       # sync opens a new window
    assert cp._window_writes == {}


def test_stale_views_require_kv_policy():
    with pytest.raises(ValueError, match="routing_policy='kv'"):
        ReplicatedControlPlane(2, replicas=2, staleness_s=1.0,
                               routing_policy="round-robin")
    with pytest.raises(ValueError, match="replicas"):
        ReplicatedControlPlane(2, replicas=0)
    # staleness 0 works with any policy (identity path)
    cp = ReplicatedControlPlane(2, replicas=2, staleness_s=0.0,
                                routing_policy="round-robin")
    assert cp.replica_views == []


def test_fresh_view_is_default_read_path():
    """The single-router plane reads through a StateView too — the
    snapshot layer is the ONLY read path, not a replicated-only bolt-on."""
    cp = ControlPlane(3)
    assert isinstance(cp.view, StateView)
    assert cp.view.age(123.4) == 0.0
    assert cp.view.healthy_ids() == [0, 1, 2]
    w, ov, overlaps, ids = cp.select_worker(TOKENS, now=0.0, rid=0)
    assert w in ids and len(overlaps) == len(ids)


# ------------------------------------------------- bounded decision log -----


def test_decision_log_bounded_deque():
    cp = ControlPlane(2, log_decisions=True, decision_log_maxlen=8)
    for i in range(20):
        cp.select_worker(TOKENS, now=float(i), rid=i)
    assert cp.decision_log.maxlen == 8
    assert len(cp.decision_log) == 8
    assert [d.rid for d in cp.decision_log] == list(range(12, 20))


def test_decision_log_unbounded_by_default():
    """Parity scenarios rely on the default: the harness replays EVERY
    placement, so nothing may fall off the front."""
    cp = ControlPlane(2, log_decisions=True)
    for i in range(20):
        cp.select_worker(TOKENS, now=float(i), rid=i)
    assert cp.decision_log.maxlen is None
    assert [d.rid for d in cp.decision_log] == list(range(20))


def test_bounded_log_does_not_change_routing():
    """The cap is pure memory bounding: decisions are identical with and
    without it."""
    a = ControlPlane(4, log_decisions=True, seed=1)
    b = ControlPlane(4, log_decisions=True, decision_log_maxlen=4, seed=1)
    picks_a, picks_b = [], []
    for i in range(32):
        picks_a.append(a.select_worker(TOKENS, now=float(i), rid=i)[0])
        picks_b.append(b.select_worker(TOKENS, now=float(i), rid=i)[0])
    assert picks_a == picks_b
    assert len(b.decision_log) == 4


# ------------------------------------------------------------- engine -------


def test_engine_cluster_syncs_on_tick_cadence():
    """Engine backend: views refresh every ``staleness_ticks`` step()
    calls — checked by counting actual sync timestamps."""
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    from repro.configs import get_reduced
    from repro.models import build_model
    from repro.serving.disagg import DisaggregatedCluster

    cfg = get_reduced("phi4-mini-3.8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), jnp.bfloat16)
    cl = DisaggregatedCluster(model, params, num_decode=2,
                              slots_per_worker=2, replicas=2,
                              staleness_ticks=3)
    assert isinstance(cl.control, ReplicatedControlPlane)
    synced = []
    orig = cl.control.sync_views
    cl.control.sync_views = lambda now: (synced.append(now), orig(now))[1]
    for _ in range(9):
        cl.step()
    assert len(synced) == 3                  # ticks 0, 3, 6


@pytest.mark.slow
def test_engine_identity_replica_bit_exact():
    """R=1/staleness_ticks=0 on the real-JAX engine backend reproduces
    the single-router run: identical decisions, tokens and regime
    transitions on a parity scenario."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_reduced
    from repro.models import build_model
    from repro.serving.scenarios import build_backend, parity_scenarios

    cfg = get_reduced("phi4-mini-3.8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), jnp.bfloat16)
    name = parity_scenarios()[0]

    runs = {}
    for replicas in (None, 1):
        eng = build_backend(name, backend="engine", seed=0,
                            model=model, params=params,
                            replicas=replicas, staleness_ticks=0)
        res = eng.run()
        runs[replicas] = (
            [(i, w, round(ov, 12)) for i, w, ov in res.decisions],
            [(r.request_id, tuple(r.output)) for r in
             sorted(res.requests, key=lambda r: r.request_id)],
            [(a, b) for _, a, b in res.regime_transitions],
        )
    assert runs[None] == runs[1]
