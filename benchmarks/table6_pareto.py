"""Table 6 / Figure 6 / Experiments 4a+4b: 4×4 (τ, ω) Pareto sweeps.

(a) 340B 1P/2D at C=64 (below saturation) — PoA invariance;
(b) 340B 1P/2D at C=128 (saturation) — moderate unstructured spread;
(c) 70B 1P/2D at C=128 — clearer structure;
(+) 70B 1P/5D at C=128 — the sweep the controller's TRANSITION row is
    calibrated from (paper §6.3).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, run_sim, save_json
from repro.core.router import KvRouterConfig

TAUS = [0.0, 0.3, 0.7, 1.0]
OMEGAS = [0.0, 0.3, 0.7, 1.0]


def sweep(model, topo, concurrency, hold_s):
    grid = {}
    for tau in TAUS:
        for om in OMEGAS:
            res = run_sim(model, topo, concurrency, hold_s,
                          router_config=KvRouterConfig(temperature=tau,
                                                       overlap_weight=om))
            s = res.overall()
            grid[(tau, om)] = dict(poa=s.poa, ttft_p99=s.ttft_p99, rps=s.rps)
    return grid


def _print_grid(title, grid, key="poa"):
    print(f"\n# {title} ({key})")
    print("tau\\omega " + "".join(f"{o:>8}" for o in OMEGAS))
    for tau in TAUS:
        row = "".join(f"{grid[(tau, o)][key]:>8.2f}" for o in OMEGAS)
        print(f"{tau:>8} {row}")
    vals = np.asarray([grid[(t, o)][key] for t in TAUS for o in OMEGAS])
    print(f"mean={vals.mean():.2f} std={vals.std():.2f} "
          f"spread={vals.max()/max(vals.min(),1e-9):.2f}x")
    return vals


def run(hold_s: float = 90.0):
    t0 = time.perf_counter()
    panels = {
        "a_340b_C64": ("nemotron-4-340b", "1P/2D", 64),
        "b_340b_C128": ("nemotron-4-340b", "1P/2D", 128),
        "c_70b2d_C128": ("llama-3.1-70b", "1P/2D", 128),
        "d_70b5d_C128": ("llama-3.1-70b", "1P/5D", 128),
    }
    out = {}
    stats = {}
    for key, (model, topo, c) in panels.items():
        grid = sweep(model, topo, c, hold_s)
        vals = _print_grid(f"Table 6{key}: {model} {topo} C={c}", grid)
        out[key] = {f"{t}/{o}": v for (t, o), v in grid.items()}
        stats[key] = dict(mean=float(vals.mean()), std=float(vals.std()),
                          spread=float(vals.max() / max(vals.min(), 1e-9)))
    save_json("table6_pareto", dict(grids=out, stats=stats))
    dt = (time.perf_counter() - t0) * 1e6
    emit("table6_pareto", dt / (len(panels) * 16),
         f"below_sat_spread={stats['a_340b_C64']['spread']:.2f}x;"
         f"sat_spread_70b={stats['c_70b2d_C128']['spread']:.2f}x")
    return out, stats


if __name__ == "__main__":
    run()
