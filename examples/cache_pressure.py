"""Game 2 in the serving loop: watch ρ cross 1 and the tier hierarchy churn.

Runs the ``cache-pressure-70b`` scenario (tiny per-worker G1 HBM against a
Zipf-skewed 12-template mix) next to the same workload with unbounded G1,
and prints the Prop. 5 observables the simulator now logs every poll:
per-worker capacity ratio ρ, tier residency, demotion/promotion counters,
and the Eq. 6 onboarding latency requests paid on the TTFT path.

    PYTHONPATH=src python examples/cache_pressure.py
"""
from repro.serving.scenarios import build_simulator


def describe(tag: str, g1_blocks: int) -> None:
    sim = build_simulator("cache-pressure-70b", seed=0, fast=True,
                          g1_blocks=g1_blocks)
    res = sim.run()
    s = res.overall()
    print(f"\n=== {tag} (g1_blocks={g1_blocks}) ===")
    print(f"completed={len(res.completed)}  ttft_p99={s.ttft_p99:.3f}s  "
          f"rps={s.rps:.1f}")
    print("t      rho(per worker)        demotions  promotions")
    for p in res.poll_log:
        rho = " ".join(f"{r:5.2f}" for r in p["rho"])
        print(f"{p['t']:5.1f}  {rho:22s} {p['demotions']!s:10s} "
              f"{p['promotions']!s}")
    for w, kv in enumerate(sim.kvbm):
        tiers = {t: n for t, n in kv.tier_distribution().items() if n}
        print(f"worker {w}: tiers={tiers}  evictions={kv.evictions}")
    onboarded = [r for r in res.completed if r.onboard_frac > 0]
    if onboarded:
        total = sum(r.onboard_latency for r in onboarded)
        print(f"{len(onboarded)} requests onboarded G2/G3 blocks "
              f"({total * 1e3:.1f} ms total TTFT added — cheaper than "
              f"miss-penalty recompute)")
    else:
        print("no onboarding: every hit was already G1-resident")


def main() -> None:
    describe("contested (rho crosses 1)", g1_blocks=48)
    describe("uncontested baseline", g1_blocks=100_000)


if __name__ == "__main__":
    main()
