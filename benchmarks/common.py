"""Shared benchmark helpers: CSV emission, JSON reports, sim sweeps."""
from __future__ import annotations

import json
import pathlib
import time
from typing import Callable

REPORT_DIR = pathlib.Path("reports/benchmarks")

_rows = []


def emit(name: str, us_per_call: float, derived: str):
    """One CSV row per paper table: name,us_per_call,derived."""
    line = f"{name},{us_per_call:.1f},{derived}"
    _rows.append(line)
    print(line, flush=True)


def save_json(name: str, payload):
    REPORT_DIR.mkdir(parents=True, exist_ok=True)
    (REPORT_DIR / f"{name}.json").write_text(json.dumps(payload, indent=1))


def timed(fn: Callable, *args, **kwargs):
    t0 = time.perf_counter()
    out = fn(*args, **kwargs)
    return out, (time.perf_counter() - t0) * 1e6


def run_sim(name: str, topo: str, concurrency: int, hold_s: float = 120.0,
            seed: int = 0, **kw):
    """Closed-loop ramp sweep point via the scenario registry's ``ramp``
    factory (benchmarks never inline cluster/workload configs)."""
    from repro.serving.scenarios import ramp
    return ramp(name, topo, concurrency, hold_s=hold_s, **kw) \
        .build(seed=seed).run()
