"""RA001 good: mutations go through the property setters; the owner's
own ``self._x`` writes (inside WorkerState) are exempt."""


def update_through_setters(router):
    st = router.workers[0]
    st.active_blocks = 5.0        # property setter invalidates the cache
    st.healthy = False
    st.capacity = 2.0


class WorkerStateLike:
    def __init__(self, worker_id):
        self.worker_id = worker_id
        self._active_blocks = 0.0  # the owning class initializes its slots
        self._healthy = True
        self._capacity = 1.0

    def reset(self):
        self._active_blocks = 0.0  # self-writes are the setter's own body
