"""Seeded-violation corpus: corrupt live state deliberately and assert the
sanitizer fires with a useful message.

Each test runs a real (small) scenario to a green, fully-populated state,
then breaks exactly one invariant the way a plausible bug would — a
setter-bypassing write, a dropped unpin, a stale lookup-table entry — and
asserts :class:`SanitizeError` names the invariant and carries the event
trace.  This is the proof that every check can actually fail (a sanitizer
that never fires is indistinguishable from one that checks nothing).
"""
import pytest

from repro.analysis.sanitize import SanitizeError, attach_engine_sanitizer
from repro.core.radix import _Node
from repro.core.router import KvRouterConfig
from repro.serving.control_plane import ControlPlane, ReplicatedControlPlane
from repro.serving.engine import Slot
from repro.serving.fabric import FabricConfig
from repro.serving.paging import PageAllocator
from repro.serving.simulator import ClusterConfig, SimRequest, Simulator
from repro.serving.workload import WorkloadConfig

BOGUS_HASH = 0xDEAD_BEEF_F00D


@pytest.fixture()
def sim():
    """A small completed run with instrumented, populated state."""
    s = Simulator(ClusterConfig.for_model("llama-3.1-70b", "1P/2D"),
                  WorkloadConfig.single_level(16, hold_s=4.0),
                  seed=0, sanitize=True)
    s.run()
    s.sanitizer.check_all("post-run")        # baseline must be green
    return s


def _decode_worker(sim):
    for wid in sim.decode_ids:
        w = sim.workers[wid]
        if not w.draining and w.kvbm is not None and w.kvbm.blocks:
            return w
    pytest.fail("no populated live decode worker")


# ----------------------------------------------------------- I3 pins --------


def test_demote_of_pinned_block_fires(sim):
    kv = _decode_worker(sim).kvbm
    bid = next(iter(kv.blocks))
    kv.pin(bid)
    with pytest.raises(SanitizeError, match="I3 pinned-block eviction"):
        kv._demote(kv.blocks[bid])


def test_free_of_pinned_block_fires(sim):
    kv = _decode_worker(sim).kvbm
    bid = next(iter(kv.blocks))
    kv.pin(bid)
    with pytest.raises(SanitizeError, match="I3 pinned-block free"):
        kv.free(bid)


def test_unpin_past_zero_fires(sim):
    kv = _decode_worker(sim).kvbm
    bid = next(iter(kv.blocks))
    assert kv.blocks[bid].pin_count == 0     # run completed: all released
    with pytest.raises(SanitizeError, match="I2 unbalanced unpin"):
        kv.unpin(bid)


# ------------------------------------------------------ I2 pin balance ------


def test_pin_leak_fires(sim):
    w = _decode_worker(sim)
    w.kvbm.pin(next(iter(w.kvbm.blocks)))    # pinned, no in-flight decode
    with pytest.raises(SanitizeError, match="I2 pin leak"):
        sim.sanitizer.check_all()


def test_inflight_decode_with_evicted_block_fires(sim):
    w = _decode_worker(sim)
    sim.sanitizer.admitted[10**9] = (w.wid, (BOGUS_HASH,))
    w.running += 1                           # keep I7 quiet: isolate I2
    with pytest.raises(SanitizeError,
                       match="I2 pin balance.*gone from the KVBM"):
        sim.sanitizer.check_all()


def test_pin_count_mismatch_fires(sim):
    w = _decode_worker(sim)
    bid = next(iter(w.kvbm.blocks))
    sim.sanitizer.admitted[10**9] = (w.wid, (bid,))   # decode without pin
    w.running += 1
    with pytest.raises(SanitizeError, match="I2 pin balance"):
        sim.sanitizer.check_all()


def test_kvbm_tier_usage_drift_fires(sim):
    kv = _decode_worker(sim).kvbm
    kv.tier_usage["G1"] += 1                 # accounting drift
    with pytest.raises(SanitizeError, match="I2 KVBM accounting"):
        sim.sanitizer.check_all()


# ---------------------------------------------------------- I7 slots --------


def test_running_count_drift_fires(sim):
    _decode_worker(sim).running += 1
    with pytest.raises(SanitizeError, match="I7 slot accounting"):
        sim.sanitizer.check_all()


# ---------------------------------------------------------- I6 drain --------


def test_draining_worker_with_queued_transfers_fires(sim):
    w = _decode_worker(sim)
    w.draining = True
    w.transfer_queue.append(
        SimRequest(rid=10**9, template=0, tokens=[], output_tokens=1))
    with pytest.raises(SanitizeError, match="I6 drain protocol"):
        sim.sanitizer.check_all()


def test_admit_onto_draining_worker_fires(sim):
    w = _decode_worker(sim)
    w.draining = True
    req = SimRequest(rid=10**9, template=0, tokens=list(range(32)),
                     output_tokens=1, decode_worker=w.wid)
    with pytest.raises(SanitizeError, match="I6 drain protocol"):
        sim._admit_decode(req)


def test_route_with_every_worker_draining_fires(sim):
    for wid in sim.decode_ids:
        sim.workers[wid].draining = True
    req = SimRequest(rid=10**9, template=0, tokens=list(range(64)),
                     output_tokens=1)
    with pytest.raises(SanitizeError, match="I6 drain protocol"):
        sim._route(req)


# --------------------------------------------------------- I1 closure -------


def test_claim_without_resident_block_fires(sim):
    w = _decode_worker(sim)
    sim.router.indexer.insert(w.wid, [], now=sim.now, hashes=[BOGUS_HASH])
    with pytest.raises(SanitizeError, match="I1 claim/residency closure"):
        sim.sanitizer.check_all()


# ------------------------------------------------------------ I4 radix ------


def test_broken_parent_link_fires(sim):
    idx = sim.router.indexer
    node = next(iter(idx._node_by_hash.values()))
    node.parent = None
    with pytest.raises(SanitizeError, match="I4 radix tree consistency"):
        sim.sanitizer.check_all()


def test_claim_counter_drift_fires(sim):
    idx = sim.router.indexer
    wid = next(iter(idx._worker_blocks))
    idx._worker_blocks[wid] += 1
    with pytest.raises(SanitizeError, match="I4 radix tree consistency"):
        sim.sanitizer.check_all()


def test_stale_lookup_table_entry_fires(sim):
    idx = sim.router.indexer
    idx._node_by_hash[BOGUS_HASH] = _Node(key=BOGUS_HASH)
    with pytest.raises(SanitizeError, match="I4 radix tree consistency"):
        sim.sanitizer.check_all()


def test_unpruned_empty_node_fires(sim):
    idx = sim.router.indexer
    parent = next(iter(idx._node_by_hash.values()))
    ghost = _Node(key=BOGUS_HASH, parent=parent)     # no claims, no kids
    parent.children[BOGUS_HASH] = ghost
    idx._node_by_hash[BOGUS_HASH] = ghost
    with pytest.raises(SanitizeError, match="I4 radix tree consistency"):
        sim.sanitizer.check_all()


def test_prefix_closure_break_fires(sim):
    idx = sim.router.indexer
    deep = next((n for n in idx._node_by_hash.values()
                 if n.parent is not None and n.parent.parent is not None),
                None)
    assert deep is not None, "no depth-2 chain in the tree"
    deep.workers[9999] = sim.now             # claim child, never parent
    idx._worker_blocks[9999] = 1             # counters consistent: isolate
    with pytest.raises(SanitizeError, match="I4 radix tree consistency"):
        sim.sanitizer.check_all()


# ------------------------------------------------------- I5 router cache ----


def test_stale_router_load_cache_fires():
    """A setter-bypassing load write (exactly what lint rule RA001 exists
    to catch statically) leaves the cached dense load vector stale; the
    next routing decision trips the sanitizer."""
    cp = ControlPlane(16, router_config=KvRouterConfig(temperature=0.0),
                      sanitize=True)
    tokens = list(range(64))
    cp.select_worker(tokens, now=0.0, rid=0)          # builds the cache
    cp.router.workers[3]._active_blocks = 40.0        # ra: allow[RA001]
    with pytest.raises(SanitizeError, match="I5 router load-cache"):
        cp.select_worker(tokens, now=0.0, rid=1)


def test_setter_write_keeps_cache_coherent():
    cp = ControlPlane(16, router_config=KvRouterConfig(temperature=0.0),
                      sanitize=True)
    tokens = list(range(64))
    cp.select_worker(tokens, now=0.0, rid=0)
    cp.router.workers[3].active_blocks = 40.0         # through the setter
    cp.select_worker(tokens, now=0.0, rid=1)          # no error


# ------------------------------------------------- R1/R2 replica views ------


@pytest.fixture()
def rsim():
    """A small completed *replicated* run (R=2, staleness=2 intervals)."""
    s = Simulator(ClusterConfig.for_model("llama-3.1-70b", "1P/2D"),
                  WorkloadConfig.single_level(16, hold_s=4.0),
                  seed=0, sanitize=True, replicas=2, staleness=2.0)
    s.run()
    s.sanitizer.check_all("post-run")        # baseline must be green
    assert len(s.control.replica_views) == 2
    return s


def test_replica_view_age_past_bound_fires(rsim):
    """A view whose refresh was silently skipped (sync scheduling bug)
    ages past its staleness bound."""
    rsim.control.replica_views[0].synced_at -= 100.0
    with pytest.raises(SanitizeError, match="R1 replica staleness bound"):
        rsim.sanitizer.check_all()


def test_replica_snapshot_load_mutation_fires(rsim):
    """Base-snapshot loads drifting between syncs means a replica saw a
    fresh (authoritative) write — exactly what RA011 forbids statically."""
    v = rsim.control.replica_views[0]
    v._loads = tuple(l + 1.0 for l in v._loads)
    with pytest.raises(SanitizeError,
                       match="R2 replica snapshot integrity.*loads"):
        rsim.sanitizer.check_all()


def test_replica_snapshot_claim_mutation_fires(rsim):
    v = rsim.control.replica_views[1]
    v._hash_claims[BOGUS_HASH] = (0,)
    with pytest.raises(SanitizeError,
                       match="R2 replica snapshot integrity.*hash claims"):
        rsim.sanitizer.check_all()


def test_local_delta_does_not_trip_snapshot_check(rsim):
    """A replica noting its *own* placements between syncs is the designed
    optimistic delta, not a snapshot violation."""
    v = rsim.control.replica_views[0]
    v.note_placement(0, [BOGUS_HASH, BOGUS_HASH + 1])
    rsim.sanitizer.check_all()               # still green


# --------------------------------------------------------- error quality ----


def test_error_carries_invariant_and_trace(sim):
    _decode_worker(sim).running += 1
    with pytest.raises(SanitizeError) as exc:
        sim.sanitizer.check_all()
    err = exc.value
    assert err.invariant == "I7 slot accounting"
    assert "running=" in err.detail
    msg = str(err)
    assert "recent events (oldest first):" in msg
    assert "t=" in msg                       # real event history attached


# ------------------------------------------------------------- engine -------


class _FakeDecoder:
    """Slot-lifecycle shape of :class:`DecodeEngine`, no JAX compute."""

    def __init__(self, wid, num_slots=2):
        self.worker_id = wid
        self.slots = [Slot() for _ in range(num_slots)]

    def reserve(self, slot, request_id):
        s = self.slots[slot]
        s.active = True
        s.request_id = request_id

    def admit(self, slot, request_id, prefill_caches, first_token,
              prompt_len, max_new, hashes=(), src_row=0):
        s = self.slots[slot]
        s.active = True
        s.request_id = request_id
        s.length = prompt_len
        return 0

    def release(self, slot):
        self.slots[slot] = Slot()


class _FakeCluster:
    def __init__(self):
        self.decoders = [_FakeDecoder(0), _FakeDecoder(1)]
        self.control = ControlPlane(2)
        self.running = {}
        self.now = 0.0

    def _now(self):
        return self.now

    def step(self):
        return []


@pytest.fixture()
def cluster():
    cl = _FakeCluster()
    attach_engine_sanitizer(cl)
    return cl


def test_reserve_into_held_slot_fires(cluster):
    dec = cluster.decoders[0]
    dec.reserve(0, "a")
    with pytest.raises(SanitizeError, match="E1 slot reuse"):
        dec.reserve(0, "b")


def test_admit_over_other_requests_reservation_fires(cluster):
    dec = cluster.decoders[0]
    dec.reserve(1, "a")
    with pytest.raises(SanitizeError, match="E1 slot reuse"):
        dec.admit(1, "b", None, 0, 4, 8)


def test_leaked_active_slot_fires(cluster):
    dec = cluster.decoders[1]
    dec.reserve(0, "a")
    dec.admit(0, "a", None, 0, 4, 8)         # never entered cluster.running
    with pytest.raises(SanitizeError, match="E2 slot accounting"):
        cluster.step()


def test_running_request_with_empty_slot_fires(cluster):
    cluster.running["r1"] = (None, 0, 1)     # slot 1 was never admitted
    with pytest.raises(SanitizeError, match="E2 slot accounting"):
        cluster.step()


def test_clean_lifecycle_is_green(cluster):
    dec = cluster.decoders[0]
    dec.reserve(0, "a")
    dec.admit(0, "a", None, 0, 4, 8)
    cluster.running["a"] = (None, 0, 0)
    cluster.step()
    del cluster.running["a"]
    dec.release(0)
    cluster.step()


# ------------------------------------------------------- paged KV pages -----


class _FakePagedDecoder(_FakeDecoder):
    """Adds a real :class:`PageAllocator` under the fake slot lifecycle,
    so the P-invariants run against genuine pool accounting while the
    seeded corruption stays surgical."""

    def __init__(self, wid, num_slots=2, num_pages=8):
        super().__init__(wid, num_slots)
        self.paged = True
        self.allocator = PageAllocator(num_pages, block=16)

    def admit(self, slot, request_id, prefill_caches, first_token,
              prompt_len, max_new, hashes=(), src_row=0):
        self.allocator.admit(slot, self.allocator.pages_for(prompt_len + 1))
        return super().admit(slot, request_id, prefill_caches, first_token,
                             prompt_len, max_new, hashes, src_row)

    def release(self, slot):
        self.allocator.release(slot)
        super().release(slot)


@pytest.fixture()
def paged_cluster():
    cl = _FakeCluster()
    cl.decoders = [_FakePagedDecoder(0), _FakePagedDecoder(1)]
    attach_engine_sanitizer(cl)
    return cl


def _paged_admit(cl, dec, slot, rid, prompt_len=20):
    dec.reserve(slot, rid)
    dec.admit(slot, rid, None, 0, prompt_len, 4)
    cl.running[rid] = (None, dec.worker_id, slot)


def test_paged_clean_lifecycle_is_green(paged_cluster):
    dec = paged_cluster.decoders[0]
    _paged_admit(paged_cluster, dec, 0, "a")      # 20+1 tokens → 2 pages
    _paged_admit(paged_cluster, dec, 1, "b", prompt_len=40)
    paged_cluster.step()
    del paged_cluster.running["a"]
    dec.release(0)
    paged_cluster.step()
    del paged_cluster.running["b"]
    dec.release(1)
    paged_cluster.step()
    assert dec.allocator.free_pages == dec.allocator.num_pages


def test_leaked_page_fires_partition(paged_cluster):
    """A page that falls out of both the free list and every live table
    (a lost-update on the free list) breaks the pool partition."""
    dec = paged_cluster.decoders[0]
    _paged_admit(paged_cluster, dec, 0, "a")
    dec.allocator._free.remove(dec.allocator._free[0])
    with pytest.raises(SanitizeError, match="P1 page-pool partition"):
        paged_cluster.step()


def test_double_owned_page_fires(paged_cluster):
    """The same physical page mapped into two live slots' tables — one
    request would decode over another's KV."""
    dec = paged_cluster.decoders[1]
    _paged_admit(paged_cluster, dec, 0, "a")
    _paged_admit(paged_cluster, dec, 1, "b")
    dec.allocator.owned[1].append(dec.allocator.owned[0][0])
    with pytest.raises(SanitizeError, match="P2 page double-own"):
        paged_cluster.step()


def test_released_slot_holding_pages_fires(paged_cluster):
    """A slot torn down without returning its pages (release bypassed the
    allocator) leaks pool capacity until restart."""
    dec = paged_cluster.decoders[0]
    _paged_admit(paged_cluster, dec, 0, "a")
    del paged_cluster.running["a"]
    dec.slots[0] = Slot()                    # bypasses release()
    with pytest.raises(SanitizeError, match="P3 released-slot pages"):
        paged_cluster.step()


# ----------------------------------------------- engine replica views -------


@pytest.fixture()
def replica_cluster():
    """Fake cluster fronted by a real ReplicatedControlPlane (R=2,
    staleness=2 scheduler ticks)."""
    cl = _FakeCluster()
    cl.control = ReplicatedControlPlane(
        2, replicas=2, staleness_s=2.0, capacities={0: 8.0, 1: 8.0})
    cl.staleness_ticks = 2
    attach_engine_sanitizer(cl)
    return cl


def test_engine_missed_sync_cadence_fires(replica_cluster):
    """The scheduler loop forgetting to call sync_views on its tick
    cadence is the engine-clock form of an R1 violation."""
    replica_cluster.step()
    replica_cluster.step()                   # at the bound: still green
    with pytest.raises(SanitizeError, match="R1 replica staleness bound"):
        replica_cluster.step()


def test_engine_resync_resets_cadence(replica_cluster):
    replica_cluster.step()
    replica_cluster.control.sync_views(1.0)  # resets the tick counter
    replica_cluster.step()
    replica_cluster.step()                   # green again


def test_engine_snapshot_mutation_fires(replica_cluster):
    replica_cluster.control.sync_views(0.5)  # fresh frozen copy, ticks=0
    v = replica_cluster.control.replica_views[1]
    v._hash_claims[BOGUS_HASH] = (0,)
    with pytest.raises(SanitizeError,
                       match="R2 replica snapshot integrity"):
        replica_cluster.step()


# ------------------------------------------------------- N1/N2 fabric -------


@pytest.fixture()
def fsim():
    """A completed fabric-attached run with instrumented link state."""
    s = Simulator(ClusterConfig.for_model("llama-3.1-70b", "1P/2D"),
                  WorkloadConfig.single_level(16, hold_s=4.0),
                  seed=0, sanitize=True, fabric=FabricConfig())
    s.run()
    s.sanitizer.check_all("post-run")        # baseline must be green
    assert s.fabric.enqueued > 0             # the fabric actually carried KV
    return s


def test_link_byte_drift_fires(fsim):
    fab = fsim.fabric
    fab.links["nic:0"].bytes_inflight += fab.config.bytes_per_block
    with pytest.raises(SanitizeError, match="N1 fabric byte conservation"):
        fsim.sanitizer.check_all()


def test_live_transfer_to_drained_worker_fires(fsim):
    fab = fsim.fabric
    dst = fsim.decode_ids[0]
    # a drain that forgot to cancel: live unadmitted transfer, dst drained
    fab.enqueue(10**9, fab.prefill_ids[0], dst, 2, fsim.now)
    fsim.workers[dst].draining = True
    with pytest.raises(SanitizeError,
                       match=r"N1 fabric byte conservation \(drain\)"):
        fsim.sanitizer.check_all()


def test_cancel_refund_stays_green(fsim):
    fab = fsim.fabric
    txm = fab.enqueue(10**9, fab.prefill_ids[0], fsim.decode_ids[0], 4,
                      fsim.now)
    fab.cancel(txm, fsim.now)                # the drain protocol's refund
    assert fab.cancelled == 1
    fsim.sanitizer.check_all()               # byte accounting balances


def test_quote_charge_drift_fires(fsim):
    fab = fsim.fabric
    fab.quote = lambda src, dst, n_blocks, now: 0.0   # stale pricing model
    with pytest.raises(SanitizeError,
                       match="N2 fabric quote/charge parity"):
        fab.enqueue(10**9, fab.prefill_ids[0], fsim.decode_ids[0], 2,
                    fsim.now)
