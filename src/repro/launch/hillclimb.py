import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (must precede any jax import — see dryrun.py)

"""§Perf hillclimbing driver: re-lower + re-analyse a cell under named
sharding/config variants and print before/after roofline terms.

    PYTHONPATH=src python -m repro.launch.hillclimb \
        --arch nemotron-4-340b --shape decode_32k --variant serve_tp_only
"""

import argparse      # noqa: E402
import json          # noqa: E402
import pathlib       # noqa: E402

from repro.launch.dryrun_lib import run_cell          # noqa: E402
from repro.launch.mesh import make_production_mesh    # noqa: E402

# Named variants: sharding-rule overrides handed to ShardingPolicy.
VARIANTS = {
    "baseline": {},
    # serving: TP-only params — no per-step FSDP all-gathers
    "serve_tp_only": {"_no_fsdp": True},
    # training: sequence-shard the residual stream (ring-attention style)
    "seq_shard": {"seq": ("model",)},
    # decode: shard KV cache batch over model too (more chips per cache)
    "decode_batch_2d": {"decode_batch": ("pod", "data", "model")},
    # MoE: expert-parallel over data axis instead of model
    "experts_on_data": {"experts": ("data",), "expert_batch": ("model",)},
    # disable activation TP (diagnose collective sources)
    "no_act_tp": {"act_mlp": None, "heads": None},
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default="baseline",
                    help="|".join(VARIANTS))
    ap.add_argument("--rules", default=None, help="extra JSON rule overrides")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--out", default="reports/hillclimb.jsonl")
    args = ap.parse_args()

    rules = dict(VARIANTS[args.variant])
    if args.rules:
        extra = json.loads(args.rules)
        rules.update({k: (tuple(v) if isinstance(v, list) else v)
                      for k, v in extra.items()})
    mesh = make_production_mesh(multi_pod=False)
    rec = run_cell(args.arch, args.shape, mesh, rules=rules or None,
                   remat=not args.no_remat)
    rec["variant"] = args.variant
    rec["extra_rules"] = args.rules
    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    with out.open("a") as f:
        f.write(json.dumps(rec) + "\n")
    r = rec["roofline"]
    print(json.dumps({k: rec["collectives"]["bytes_by_kind"].get(k, 0.0)
                      for k in ("all-reduce", "all-gather", "reduce-scatter",
                                "all-to-all", "collective-permute")},
                     indent=1))
    print(f"variant={args.variant}: compute={r['compute_s']:.3e}s "
          f"memory={r['memory_s']:.3e}s collective={r['collective_s']:.3e}s "
          f"bottleneck={r['bottleneck']} frac={r['roofline_fraction']:.3f}")


if __name__ == "__main__":
    main()
