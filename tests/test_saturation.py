"""Saturation detector: EWMA (Eq. 10), regimes (Eq. 11), hysteresis."""
import pytest

from repro.core.saturation import DetectorConfig, Regime, SaturationDetector


def test_ewma_exact():
    d = SaturationDetector(DetectorConfig(alpha=0.3))
    d.observe(1.0, 0.0)
    assert d.ewma == pytest.approx(1.0)
    d.observe(2.0, 5.0)
    assert d.ewma == pytest.approx(0.3 * 2.0 + 0.7 * 1.0)


def test_regime_thresholds_with_hysteresis():
    cfg = DetectorConfig(theta1=0.3, theta2=2.0, alpha=1.0, hysteresis_k=2)
    d = SaturationDetector(cfg)
    assert d.observe(0.1, 0) == Regime.BELOW
    assert d.observe(0.5, 5) == Regime.BELOW        # 1st sample above θ1
    assert d.observe(0.5, 10) == Regime.TRANSITION  # k=2 confirmed
    assert d.observe(3.0, 15) == Regime.TRANSITION
    assert d.observe(3.0, 20) == Regime.SATURATED


def test_downward_hysteresis_epsilon():
    cfg = DetectorConfig(theta1=0.3, theta2=2.0, alpha=1.0,
                         hysteresis_k=1, epsilon=0.05)
    d = SaturationDetector(cfg)
    d.observe(0.5, 0)
    assert d.regime == Regime.TRANSITION
    d.observe(0.28, 5)       # above θ1 − ε: stays TRANSITION
    assert d.regime == Regime.TRANSITION
    d.observe(0.2, 10)       # below θ1 − ε
    assert d.regime == Regime.BELOW


def test_oscillation_suppressed():
    cfg = DetectorConfig(theta1=0.3, theta2=2.0, alpha=1.0, hysteresis_k=3)
    d = SaturationDetector(cfg)
    vals = [0.5, 0.1, 0.5, 0.1, 0.5, 0.1]  # never 3 consecutive
    for i, v in enumerate(vals):
        d.observe(v, 5.0 * i)
    assert d.regime == Regime.BELOW
    assert d.transitions == []


def test_model_specific_thresholds():
    c70 = DetectorConfig.for_model("llama-3.1-70b")
    c340 = DetectorConfig.for_model("nemotron-4-340b")
    assert (c70.theta1, c70.theta2) == (0.3, 2.0)
    assert (c340.theta1, c340.theta2) == (1.0, 10.0)


def test_threshold_from_baseline():
    c = DetectorConfig.from_baseline_ttft(0.055)  # 70B baseline ≈ 55 ms
    assert 0.15 <= c.theta1 <= 0.3                # paper: 3–5× baseline
    assert c.theta2 == pytest.approx(10 * c.theta1)


def test_history_and_transitions_logged():
    cfg = DetectorConfig(theta1=0.3, theta2=2.0, alpha=1.0, hysteresis_k=1)
    d = SaturationDetector(cfg)
    d.observe(0.1, 0)
    d.observe(5.0, 5)
    assert len(d.history) == 2
    assert d.transitions == [(5, 0, 2)]
