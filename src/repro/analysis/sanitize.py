"""Runtime coherence sanitizer — opt-in cross-structure invariant checks.

The serving hot path is fast because it trusts a handful of coherence
invariants instead of recomputing state: the router's cached dense load
vector, the indexer's claim counters, KVBM pin refcounts, the drain
protocol, the engine's slot lifecycle.  The sanitizer re-derives each of
those from first principles at event/tick boundaries and raises
:class:`SanitizeError` — with the recent event trace attached — the moment
the cheap view and the recomputed truth diverge.

Enablement (default OFF, zero-cost when off — attachment happens once at
construction, never per event):

* ``REPRO_SANITIZE=1`` in the environment, or
* ``sanitize=True`` on :class:`~repro.serving.simulator.Simulator`,
  :class:`~repro.serving.control_plane.ControlPlane`, or
  :class:`~repro.serving.disagg.DisaggregatedCluster`.

Every check is a pure read: no RNG draws, no event pushes, no lazy tree
sweeps (the radix audit walks read-only, unlike ``overlap_depths``), so a
sanitized run is bit-exact with an un-instrumented one
(``tests/test_sanitizer.py`` pins this over the whole scenario registry).

Invariants checked on the analytic backend (:class:`SimSanitizer`):

I1  indexer claims ⊆ G1-resident KVBM blocks, modulo requests routed but
    not yet admitted (and draining workers, whose inert claims flush at
    the role flip);
I2  pin refcounts ≥ 0, and every block's pin count equals the number of
    admitted in-flight requests whose hash chain contains it (pin/unpin
    balanced at completion; no pin leaks);
I3  pinned blocks are never demoted, freed, or over-unpinned;
I4  radix tree structure: parent links, ``_node_by_hash`` ≡ live nodes,
    empty-node pruning, claim counters, prefix closure;
I5  router's cached dense load vector ≡ a fresh recompute from the table;
I6  the drain protocol never routes to or admits onto a draining/
    non-decode worker, and draining workers hold no queued transfers;
I7  per-worker ``running`` equals the recomputed admitted-request count.

On the engine backend (:class:`EngineSanitizer`): I4/I5 plus the
``DecodeEngine`` slot lifecycle — reserve only into a free slot, admit
only into the slot reserved for that request (no stale-KV slot reuse),
slot table ≡ the cluster's running/placed view at every tick boundary —
and, for paged decoders, the page-pool invariants: free list ∪ live page
tables exactly partitions the pool (P1), no page owned by two live slots
(P2), released slots hold zero pages (P3).

Fabric (both backends, when a :class:`~repro.serving.fabric.Fabric` is
attached):

N1  per-link byte conservation: every link's ``bytes_inflight`` equals
    the recomputed sum over live transmissions whose path crosses it —
    enqueue/complete/cancel (the drain-protocol refund) must balance;
    and on the analytic backend no live transmission targets a draining
    or non-decode worker unless its request was already admitted there
    before the drain began;
N2  quote/charge parity: the network-aware router's pure quote replays
    exactly as the committed transmission's finish time — pricing and
    charging share one link-scheduling routine.

Replicated control plane (both backends, when ``replica_views`` exist):

R1  bounded staleness: no replica view's age ever exceeds its configured
    bound — event-clock seconds on the analytic backend, scheduler ticks
    since the last ``sync_views`` on the engine backend;
R2  snapshot integrity: each view's base snapshot (healthy set, load
    vector, regime, hash claims) is identical to the frozen copy recorded
    when ``sync_views`` ran — nothing but ``sync()`` may rewrite it (the
    runtime complement of lint rule RA011).
"""
from __future__ import annotations

import os
from collections import deque
from typing import Deque, Dict, List, Optional, Set, Tuple

TRACE_LEN = 32


def sanitize_enabled(default: Optional[bool] = None) -> bool:
    """Resolve the sanitizer switch: an explicit ``sanitize=`` argument
    wins; otherwise the ``REPRO_SANITIZE`` environment variable."""
    if default is not None:
        return bool(default)
    return os.environ.get("REPRO_SANITIZE", "").strip().lower() in (
        "1", "true", "yes", "on")


class SanitizeError(AssertionError):
    """A coherence invariant failed.  The message carries the invariant,
    the divergence, and the recent event trace for context."""

    def __init__(self, invariant: str, detail: str,
                 trace: Optional[Deque[str]] = None):
        self.invariant = invariant
        self.detail = detail
        lines = [f"sanitizer: {invariant}: {detail}"]
        if trace:
            lines.append("recent events (oldest first):")
            lines.extend(f"  {e}" for e in trace)
        super().__init__("\n".join(lines))


class _Trace:
    """Bounded ring buffer of recent event descriptions."""

    def __init__(self, maxlen: int = TRACE_LEN):
        self.events: Deque[str] = deque(maxlen=maxlen)

    def add(self, desc: str) -> None:
        self.events.append(desc)

    def fail(self, invariant: str, detail: str) -> None:
        raise SanitizeError(invariant, detail, self.events)


def _check_frozen_views(control, frozen, trace: _Trace, where: str) -> None:
    """R2: every replica view's base snapshot must equal the frozen copy
    recorded at the last ``sync_views`` — a mismatch means replica-side
    code rewrote snapshot state between syncs."""
    views = getattr(control, "replica_views", ())
    for v, want in zip(views, frozen):
        got = v.frozen_state()
        if got != want:
            labels = ("synced_at", "healthy ids", "loads", "regime",
                      "hash claims", "fabric links")
            diffs = [labels[i] if i < len(labels) else f"field {i}"
                     for i in range(max(len(got), len(want)))
                     if (got[i:i + 1] or None) != (want[i:i + 1] or None)]
            trace.fail(
                "R2 replica snapshot integrity",
                f"at {where}: replica {v.index} base snapshot diverged "
                f"from its sync-time frozen copy in: {', '.join(diffs)} — "
                f"only sync() may rewrite snapshot state")


def _check_fabric(fabric, trace: _Trace, where: str,
                  live_dsts: Optional[Set[int]] = None,
                  admitted_rids: Optional[Set] = None) -> None:
    """N1: recompute every link's ``bytes_inflight`` from the live
    transmission set and compare to the incrementally-maintained counter
    — an imbalance means an enqueue/complete/cancel edge (most likely the
    drain-protocol refund) leaked or double-released bytes.  With
    ``live_dsts`` (analytic backend), also check that no live
    transmission still targets a drained destination unless its request
    was admitted there before the drain began."""
    expect: Dict[str, int] = {}
    for txm in fabric.active.values():
        for name in txm.path:
            expect[name] = expect.get(name, 0) + txm.size
        if live_dsts is not None and txm.dst not in live_dsts:
            if admitted_rids is None or txm.rid not in admitted_rids:
                trace.fail(
                    "N1 fabric byte conservation (drain)",
                    f"at {where}: transmission tid={txm.tid} "
                    f"(rid={txm.rid}) still in flight toward drained "
                    f"worker {txm.dst} — the drain protocol must cancel "
                    f"before re-routing")
    for name in sorted(fabric.links):
        link = fabric.links[name]
        want = expect.get(name, 0)
        if link.bytes_inflight != want:
            trace.fail(
                "N1 fabric byte conservation",
                f"at {where}: link {name} accounts "
                f"bytes_inflight={link.bytes_inflight} but live "
                f"transmissions crossing it sum to {want}")


def _wrap_fabric_enqueue(fabric, trace: _Trace):
    """N2: wrap ``fabric.enqueue`` so every committed transfer is checked
    against the pure quote taken an instant before — pricing (what the
    network-aware router sees) and charging (what the request pays) must
    replay identically."""
    orig = fabric.enqueue

    def enqueue(rid, src, dst, n_blocks, now):
        quoted = fabric.quote(src, dst, n_blocks, now)
        txm = orig(rid, src, dst, n_blocks, now)
        if txm is not None:
            trace.add(f"t={now:.4f} xfer rid={rid} {src}->{dst} "
                      f"{n_blocks}blk finish={txm.finish_t:.4f}")
            charged = txm.finish_t - now
            if abs(charged - quoted) > 1e-9:
                trace.fail(
                    "N2 fabric quote/charge parity",
                    f"tid={txm.tid} (rid={rid}) {src}->{dst}: quoted "
                    f"{quoted:.9f}s but charged {charged:.9f}s — the "
                    f"router priced a different fabric than the one "
                    f"that carried the transfer")
        return txm

    fabric.enqueue = enqueue


# -------------------------------------------------------------- analytic ----


class SimSanitizer:
    """Coherence checks over a :class:`~repro.serving.simulator.Simulator`.

    Attached by wrapping the simulator's bound event handlers as instance
    attributes (the class stays untouched — an unsanitized simulator pays
    nothing).  Light per-event checks run inline; the full cross-structure
    sweep runs at the ``sync``/``poll`` boundaries, where the event plane
    itself re-derives state.
    """

    def __init__(self, sim):
        self.sim = sim
        self.trace = _Trace()
        # rid -> (worker, hash set): routed (claims inserted) but not yet
        # admitted (blocks not yet in the KVBM) — the I1 exemption window
        self.pending: Dict[int, Tuple[int, Set[int]]] = {}
        # rid -> (worker, hash chain): admitted, in-flight decodes — the
        # ground truth I2/I7 recompute from
        self.admitted: Dict[int, Tuple[int, Tuple[int, ...]]] = {}
        # R2: frozen snapshot copies, recorded per sync_views (replicated
        # control plane only; ReplicatedControlPlane syncs once during
        # construction, before attachment, so seed the record here)
        self.view_frozen: List[tuple] = []
        self._instrument()
        if getattr(sim.control, "replica_views", None):
            self._sync_views = sim.control.sync_views
            sim.control.sync_views = self._wrap_sync_views
            self.view_frozen = [v.frozen_state()
                                for v in sim.control.replica_views]

    # ------------------------------------------------------------- wiring ---

    def _instrument(self) -> None:
        sim = self.sim
        self._route = sim._route
        self._admit = sim._admit_decode
        self._done = sim._on_decode_done
        self._sync = sim._on_sync
        self._poll = sim._on_poll
        self._new_kvbm = sim._new_kvbm
        sim._route = self._wrap_route
        sim._admit_decode = self._wrap_admit
        sim._on_decode_done = self._wrap_done
        sim._on_sync = self._wrap_sync
        sim._on_poll = self._wrap_poll
        sim._new_kvbm = self._wrap_new_kvbm
        for wid in sim.decode_ids:
            self._instrument_kvbm(sim.workers[wid].kvbm)
        if getattr(sim, "fabric", None) is not None:
            _wrap_fabric_enqueue(sim.fabric, self.trace)

    def _instrument_kvbm(self, kv) -> None:
        """Guard the eviction/refcount edges of one KVBM: demoting or
        freeing a pinned block, or unpinning past zero, fails immediately
        (the state it corrupts may be unreachable by the next sweep)."""
        if kv is None or getattr(kv, "_sanitized", False):
            return
        kv._sanitized = True
        orig_demote, orig_free, orig_unpin = kv._demote, kv.free, kv.unpin

        def demote(blk):
            if blk.pin_count > 0:
                self.trace.fail(
                    "I3 pinned-block eviction",
                    f"worker {kv.worker_id}: demoting block "
                    f"{blk.block_id:#x} out of {blk.tier} with "
                    f"pin_count={blk.pin_count}")
            return orig_demote(blk)

        def free(block_id):
            blk = kv.blocks.get(block_id)
            if blk is not None and blk.pin_count > 0:
                self.trace.fail(
                    "I3 pinned-block free",
                    f"worker {kv.worker_id}: freeing block {block_id:#x} "
                    f"with pin_count={blk.pin_count}")
            return orig_free(block_id)

        def unpin(block_id):
            blk = kv.blocks.get(block_id)
            if blk is not None and blk.pin_count == 0:
                self.trace.fail(
                    "I2 unbalanced unpin",
                    f"worker {kv.worker_id}: unpin of block {block_id:#x} "
                    f"already at pin_count=0")
            return orig_unpin(block_id)

        kv._demote = demote
        kv.free = free
        kv.unpin = unpin

    # ----------------------------------------------------------- wrappers ---

    def _wrap_new_kvbm(self, worker):
        kv = self._new_kvbm(worker)
        self._instrument_kvbm(kv)
        return kv

    def _wrap_route(self, req):
        self._route(req)
        sim = self.sim
        w = sim.workers[req.decode_worker]
        self.trace.add(f"t={sim.now:.4f} route rid={req.rid} -> "
                       f"worker {req.decode_worker} overlap={req.overlap:.3f}")
        if w.role != "decode" or w.draining:
            self.trace.fail(
                "I6 drain protocol (routing)",
                f"rid {req.rid} routed to "
                f"{'draining' if w.draining else w.role} worker {w.wid}")
        self.pending[req.rid] = (req.decode_worker, set(req.hashes))

    def _wrap_admit(self, req):
        sim = self.sim
        w = sim.workers[req.decode_worker]
        if w.role != "decode" or w.draining:
            # the simulator's own RuntimeError would also fire inside
            # _admit_decode; failing here attaches the event trace
            self.trace.fail(
                "I6 drain protocol (admission)",
                f"rid {req.rid} admitted to "
                f"{'draining' if w.draining else w.role} worker {w.wid}")
        self._admit(req)
        self.trace.add(f"t={sim.now:.4f} admit rid={req.rid} on "
                       f"worker {req.decode_worker}")
        self.pending.pop(req.rid, None)
        self.admitted[req.rid] = (req.decode_worker, tuple(req.hashes))

    def _wrap_done(self, req):
        self.trace.add(f"t={self.sim.now:.4f} decode_done rid={req.rid} on "
                       f"worker {req.decode_worker}")
        self._done(req)
        self.admitted.pop(req.rid, None)

    def _wrap_sync(self):
        self._sync()
        self.trace.add(f"t={self.sim.now:.4f} sync")
        self.check_all("sync")

    def _wrap_sync_views(self, now):
        self._sync_views(now)
        self.trace.add(f"t={now:.4f} sync_views")
        self.view_frozen = [v.frozen_state()
                            for v in self.sim.control.replica_views]

    def _wrap_poll(self):
        self._poll()
        self.trace.add(f"t={self.sim.now:.4f} poll")
        self.check_all("poll")

    # ------------------------------------------------------------- checks ---

    def check_all(self, where: str = "sweep") -> None:
        """The full cross-structure sweep (pure reads only)."""
        sim = self.sim
        fail = self.trace.fail

        # I5: router load-vector cache vs fresh recompute
        divergence = sim.router.cache_coherent()
        if divergence is not None:
            fail("I5 router load-cache coherence", f"at {where}: {divergence}")

        # I4: radix tree structural audit (read-only walk)
        for problem in sim.router.indexer.audit():
            fail("I4 radix tree consistency", f"at {where}: {problem}")

        # R1/R2: replicated control plane — view age within the staleness
        # bound, base snapshots bit-identical to their sync-time copies
        views = getattr(sim.control, "replica_views", ())
        for v in views:
            age = v.age(sim.now)
            if age > v.bound + 1e-9:
                fail("R1 replica staleness bound",
                     f"at {where}: replica {v.index} view age {age:.6f}s "
                     f"exceeds its configured bound {v.bound:.6f}s "
                     f"(synced_at={v.synced_at})")
        if views:
            _check_frozen_views(sim.control, self.view_frozen, self.trace,
                                where)

        # N1: fabric byte conservation + drain closure over live transfers
        if getattr(sim, "fabric", None) is not None:
            live = {wid for wid in sim.decode_ids
                    if not sim.workers[wid].draining}
            _check_fabric(sim.fabric, self.trace, where, live_dsts=live,
                          admitted_rids=set(self.admitted))

        # recompute the admitted view once: per-worker running counts and
        # per-(worker, hash) expected pin counts
        running: Dict[int, int] = {}
        expected_pins: Dict[int, Dict[int, int]] = {}
        for _rid, (wid, hashes) in self.admitted.items():
            running[wid] = running.get(wid, 0) + 1
            pins = expected_pins.setdefault(wid, {})
            for h in hashes:
                pins[h] = pins.get(h, 0) + 1
        pending_by_worker: Dict[int, Set[int]] = {}
        for _rid, (wid, hset) in self.pending.items():
            pending_by_worker.setdefault(wid, set()).update(hset)

        for wid in sim.decode_ids:
            w = sim.workers[wid]
            kv = w.kvbm

            # I6: draining workers admit nothing and queue nothing
            if w.draining and w.transfer_queue:
                fail("I6 drain protocol (queued transfers)",
                     f"at {where}: draining worker {wid} still holds "
                     f"{len(w.transfer_queue)} queued transfer(s)")

            # I7: admission-slot accounting
            if w.running != running.get(wid, 0):
                fail("I7 slot accounting",
                     f"at {where}: worker {wid} reports running={w.running} "
                     f"but {running.get(wid, 0)} admitted request(s) are "
                     f"in flight")

            if kv is None:
                continue

            # KVBM internal accounting (tier recounts, pin sign)
            for problem in kv.audit():
                fail("I2 KVBM accounting",
                     f"at {where}: worker {wid}: {problem}")

            # I2: pin refcounts ≡ admitted in-flight coverage
            pins = expected_pins.get(wid, {})
            for h, n in pins.items():
                blk = kv.blocks.get(h)
                if blk is None:
                    fail("I2 pin balance",
                         f"at {where}: worker {wid}: block {h:#x} backs "
                         f"{n} in-flight decode(s) but is gone from the "
                         f"KVBM")
                elif blk.pin_count != n:
                    fail("I2 pin balance",
                         f"at {where}: worker {wid}: block {h:#x} has "
                         f"pin_count={blk.pin_count}, expected {n} from "
                         f"in-flight decodes")
            for h, blk in kv.blocks.items():
                if blk.pin_count > 0 and h not in pins:
                    fail("I2 pin leak",
                         f"at {where}: worker {wid}: block {h:#x} has "
                         f"pin_count={blk.pin_count} but no in-flight "
                         f"decode covers it")

            # I1: claims ⊆ G1-resident ∪ pending-routed (draining workers'
            # claims are inert — router health is off — and flush at flip)
            if not w.draining:
                pend = pending_by_worker.get(wid, ())
                for h in sim.router.indexer.claimed_hashes(wid):
                    blk = kv.blocks.get(h)
                    if blk is not None and blk.tier == "G1":
                        continue
                    if h in pend:
                        continue
                    fail("I1 claim/residency closure",
                         f"at {where}: worker {wid} claims block {h:#x} "
                         f"which is "
                         + (f"resident in {blk.tier}, not G1" if blk
                            else "not in its KVBM")
                         + " and not pending admission")


def attach_sim_sanitizer(sim) -> SimSanitizer:
    """Instrument a Simulator in place; returns the sanitizer (exposed as
    ``sim.sanitizer``)."""
    san = SimSanitizer(sim)
    sim.sanitizer = san
    return san


# --------------------------------------------------------------- engines ----


class EngineSanitizer:
    """Coherence checks over a
    :class:`~repro.serving.disagg.DisaggregatedCluster` (engine backend).

    Per-call slot-lifecycle guards on every :class:`DecodeEngine` plus a
    control-plane sweep (I4/I5) and a slot-table ≡ running-view recompute
    at each tick boundary."""

    def __init__(self, cluster):
        self.cluster = cluster
        self.trace = _Trace()
        # (worker, slot) -> request_id reserved but not yet admitted
        self.reserved: Dict[Tuple[int, int], str] = {}
        # R1 (engine clock = scheduler ticks) / R2 state
        self.view_frozen: List[tuple] = []
        self.ticks_since_sync = 0
        self._instrument()

    def _instrument(self) -> None:
        cl = self.cluster
        for dec in cl.decoders:
            self._instrument_decoder(dec)
        self._step = cl.step
        cl.step = self._wrap_step
        if getattr(cl, "fabric", None) is not None:
            _wrap_fabric_enqueue(cl.fabric, self.trace)
        if getattr(cl.control, "replica_views", None):
            self._sync_views = cl.control.sync_views
            cl.control.sync_views = self._wrap_sync_views
            self.view_frozen = [v.frozen_state()
                                for v in cl.control.replica_views]

    def _wrap_sync_views(self, now):
        self._sync_views(now)
        self.trace.add(f"t={now:.4f} sync_views")
        self.ticks_since_sync = 0
        self.view_frozen = [v.frozen_state()
                            for v in self.cluster.control.replica_views]

    def _instrument_decoder(self, dec) -> None:
        wid = dec.worker_id
        orig_reserve, orig_admit, orig_release = (
            dec.reserve, dec.admit, dec.release)

        def reserve(slot, request_id, prompt_len=None, max_new=0):
            s = dec.slots[slot]
            if s.active:
                self.trace.fail(
                    "E1 slot reuse (reserve)",
                    f"worker {wid}: reserving slot {slot} for "
                    f"{request_id!r} while it is held by {s.request_id!r}")
            self.trace.add(f"reserve w{wid}/s{slot} <- {request_id!r}")
            if prompt_len is None:
                out = orig_reserve(slot, request_id)
            else:
                out = orig_reserve(slot, request_id, prompt_len=prompt_len,
                                   max_new=max_new)
            self.reserved[(wid, slot)] = request_id
            return out

        def admit(slot, request_id, prefill_caches, first_token,
                  prompt_len, max_new, hashes=(), src_row=0):
            s = dec.slots[slot]
            holder = self.reserved.get((wid, slot))
            if s.active and s.request_id != request_id:
                self.trace.fail(
                    "E1 slot reuse (admit)",
                    f"worker {wid}: admitting {request_id!r} into slot "
                    f"{slot} held by {s.request_id!r} — stale KV would be "
                    f"served")
            if holder is not None and holder != request_id:
                self.trace.fail(
                    "E1 slot reuse (admit)",
                    f"worker {wid}: slot {slot} reserved for {holder!r} "
                    f"but admitted {request_id!r}")
            self.trace.add(f"admit w{wid}/s{slot} <- {request_id!r} "
                           f"(prompt_len={prompt_len})")
            out = orig_admit(slot, request_id, prefill_caches, first_token,
                             prompt_len, max_new, hashes=hashes,
                             src_row=src_row)
            self.reserved.pop((wid, slot), None)
            return out

        def release(slot):
            self.trace.add(f"release w{wid}/s{slot}")
            self.reserved.pop((wid, slot), None)
            return orig_release(slot)

        dec.reserve = reserve
        dec.admit = admit
        dec.release = release

    def _wrap_step(self):
        out = self._step()
        self.trace.add(f"tick t={self.cluster._now():.4f} "
                       f"completed={out}")
        self.check_all("tick")
        return out

    def check_all(self, where: str = "tick") -> None:
        cl = self.cluster
        fail = self.trace.fail

        divergence = cl.control.router.cache_coherent()
        if divergence is not None:
            fail("I5 router load-cache coherence", f"at {where}: {divergence}")
        for problem in cl.control.router.indexer.audit():
            fail("I4 radix tree consistency", f"at {where}: {problem}")

        # R1/R2: the engine's event clock is the scheduler tick — views
        # must refresh within ``staleness_ticks`` ticks, and base
        # snapshots must match their sync-time frozen copies
        if getattr(cl.control, "replica_views", None):
            self.ticks_since_sync += 1
            bound = max(cl.staleness_ticks, 1)
            if self.ticks_since_sync > bound:
                fail("R1 replica staleness bound",
                     f"at {where}: {self.ticks_since_sync} tick(s) since "
                     f"the last sync_views exceeds the configured cadence "
                     f"of {bound} tick(s)")
            _check_frozen_views(cl.control, self.view_frozen, self.trace,
                                where)

        # N1: fabric byte conservation (no drain protocol on this backend)
        if getattr(cl, "fabric", None) is not None:
            _check_fabric(cl.fabric, self.trace, where)

        # E2: slot table ≡ cluster running view.  Every running request
        # owns exactly its recorded slot; every active slot is owned by a
        # running request or a live reservation.
        owned: Dict[Tuple[int, int], str] = dict(self.reserved)
        for rid, (_req, worker, slot) in cl.running.items():
            s = cl.decoders[worker].slots[slot]
            if not s.active or s.request_id != rid:
                fail("E2 slot accounting",
                     f"at {where}: running request {rid!r} maps to "
                     f"worker {worker} slot {slot}, which holds "
                     f"{'nothing' if not s.active else repr(s.request_id)}")
            owned[(worker, slot)] = rid
        for dec in cl.decoders:
            for i, s in enumerate(dec.slots):
                if s.active and (dec.worker_id, i) not in owned:
                    fail("E2 slot accounting",
                         f"at {where}: worker {dec.worker_id} slot {i} "
                         f"active for {s.request_id!r} but neither running "
                         f"nor reserved — leaked slot")
            self._check_pages(dec, where)

    def _check_pages(self, dec, where: str) -> None:
        """Paged-KV invariants over one decoder's allocator (dense
        decoders have no allocator and skip):

        P1  free list ∪ live page tables exactly partitions the pool
            (every allocatable page is free or owned, never both, and the
            trash page 0 never circulates);
        P2  no page is owned by two live slots;
        P3  released (inactive) slots hold zero pages.
        """
        alloc = getattr(dec, "allocator", None)
        if alloc is None:
            return
        fail = self.trace.fail
        wid = dec.worker_id

        held: List[int] = []
        for slot, pages in alloc.owned.items():
            held.extend(pages)
            dups = {p for p in pages if pages.count(p) > 1}
            if dups:
                fail("P2 page double-own",
                     f"at {where}: worker {wid} slot {slot} maps page(s) "
                     f"{sorted(dups)} more than once")
        seen: Dict[int, int] = {}
        for slot, pages in alloc.owned.items():
            for p in pages:
                if p in seen and seen[p] != slot:
                    fail("P2 page double-own",
                         f"at {where}: worker {wid} page {p} owned by both "
                         f"slot {seen[p]} and slot {slot} — one request "
                         f"would decode over another's KV")
                seen[p] = slot

        for slot, pages in alloc.owned.items():
            s = dec.slots[slot] if slot < len(dec.slots) else None
            if s is None or not s.active:
                fail("P3 released-slot pages",
                     f"at {where}: worker {wid} slot {slot} is released "
                     f"but still holds {len(pages)} page(s) "
                     f"{sorted(pages)} — leaked pool capacity")

        free = alloc.free_list()
        if len(set(free)) != len(free):
            fail("P1 page-pool partition",
                 f"at {where}: worker {wid} free list holds duplicates")
        free_set, held_set = set(free), set(held)
        if 0 in free_set or 0 in held_set:
            fail("P1 page-pool partition",
                 f"at {where}: worker {wid} trash page 0 entered "
                 f"circulation")
        both = free_set & held_set
        if both:
            fail("P1 page-pool partition",
                 f"at {where}: worker {wid} page(s) {sorted(both)} are "
                 f"simultaneously free and owned")
        covered = free_set | held_set
        missing = alloc.all_pages() - covered
        extra = covered - alloc.all_pages()
        if missing or extra:
            fail("P1 page-pool partition",
                 f"at {where}: worker {wid} free ∪ owned ≠ pool "
                 f"(missing={sorted(missing)}, foreign={sorted(extra)})")
        if alloc.reserved_pages > len(free):
            fail("P1 page-pool partition",
                 f"at {where}: worker {wid} reservations "
                 f"({alloc.reserved_pages}) exceed the free list "
                 f"({len(free)})")


def attach_engine_sanitizer(cluster) -> EngineSanitizer:
    """Instrument a DisaggregatedCluster in place; returns the sanitizer
    (exposed as ``cluster.sanitizer``)."""
    san = EngineSanitizer(cluster)
    cluster.sanitizer = san
    return san


# ----------------------------------------------------------- control plane --


class ControlPlaneSanitizer:
    """Standalone control-plane checks (I4/I5) after every routing
    decision — for users driving a bare :class:`ControlPlane` without
    either backend's richer sanitizer."""

    def __init__(self, control):
        self.control = control
        self.trace = _Trace()
        self._select = control.select_worker
        control.select_worker = self._wrap_select

    def _wrap_select(self, tokens, **kw):
        out = self._select(tokens, **kw)
        self.trace.add(f"select rid={kw.get('rid')!r} -> worker {out[0]} "
                       f"at now={kw.get('now', 0.0)}")
        divergence = self.control.router.cache_coherent()
        if divergence is not None:
            self.trace.fail("I5 router load-cache coherence", divergence)
        for problem in self.control.router.indexer.audit():
            self.trace.fail("I4 radix tree consistency", problem)
        return out


def attach_control_sanitizer(control) -> ControlPlaneSanitizer:
    san = ControlPlaneSanitizer(control)
    control.sanitizer = san
    return san
