from repro.models.model import Model, build_model, layer_layout  # noqa: F401
