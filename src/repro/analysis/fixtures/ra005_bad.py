"""RA005 bad: unseeded / process-global RNG feeding decisions."""
import random

import numpy as np


def pick_worker(ids):
    rng = np.random.default_rng()        # OS entropy: unreproducible
    return ids[rng.integers(len(ids))]


def shuffle_queue(queue):
    random.shuffle(queue)                # process-global state


def sample_load():
    return np.random.poisson(4.0)        # numpy's global stream


def make_stream():
    return random.Random()               # unseeded instance
