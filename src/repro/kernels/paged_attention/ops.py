"""jit'd wrapper for the paged-attention Pallas kernel (interpret on CPU)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.paged_attention import (
    paged_attention_pallas)
from repro.kernels.paged_attention.ref import gather_pages  # noqa: F401


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pool, v_pool, page_table, lengths, *,
                    interpret=None):
    """q: (B,H,hd); k_pool, v_pool: (N, block, K, hd); page_table: (B, W)
    int32; lengths: (B,).  Returns (B,H,hd).

    Table entries are clamped into the pool so every grid step loads a real
    page (unmapped entries point at the trash page 0 and are masked by
    ``length``); lengths are clamped to the table's addressable window.
    Rows with ``length == 0`` return zeros — inactive serving slots must
    come back finite, never NaN."""
    if interpret is None:
        interpret = not _on_tpu()
    b, h, hd = q.shape
    kh = k_pool.shape[2]
    g = h // kh
    qg = q.reshape(b, kh, g, hd)
    table = jnp.clip(page_table.astype(jnp.int32), 0, k_pool.shape[0] - 1)
    lengths = jnp.minimum(lengths.astype(jnp.int32),
                          table.shape[1] * k_pool.shape[1])
    out = paged_attention_pallas(qg, k_pool, v_pool, table, lengths,
                                 interpret=interpret)
    return out.reshape(b, h, hd)
