"""Config dataclasses for models, shapes, serving and training.

Every assigned architecture gets a module in this package exporting
``CONFIG`` (the exact published numbers) and ``reduced()`` (a tiny
same-family config for CPU smoke tests).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    every_k_layers: int = 1        # MoE on layers with idx % every_k == offset
    moe_layer_offset: int = 0
    dense_residual: bool = False   # arctic: dense MLP in parallel with MoE
    d_ff_dense: int = 0
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2-style SSD block hyperparameters (TPU adaptation, see DESIGN.md)."""
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 128


@dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 4          # sLSTM at layer idx % every == offset
    slstm_offset: int = 3
    chunk: int = 64
    proj_factor: int = 2          # mLSTM up-projection factor


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    activation: str = "swiglu"     # swiglu | squared_relu | gelu
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # hybrid layout: attention on layers with idx % period == offset; SSM otherwise
    attn_layer_period: int = 1
    attn_layer_offset: int = 0
    # encoder-decoder
    num_encoder_layers: int = 0
    cross_attention: bool = False
    # modality frontend stubs
    frontend: Optional[str] = None  # 'audio' | 'vision'
    num_patches: int = 0            # vision/audio prefix length folded into seq
    frontend_dim: int = 0           # raw embedding dim from the (stubbed) frontend
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    subquadratic: bool = False      # True => long_500k shape is runnable
    source: str = ""                # provenance string from the assignment table

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // max(self.num_heads, 1)

    @property
    def q_heads_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    def param_count(self) -> int:
        """Approximate parameter count N (for MODEL_FLOPS = 6·N·D)."""
        d, hd = self.d_model, self.resolved_head_dim
        h, k = self.num_heads, self.num_kv_heads
        n = self.vocab_size * d  # embedding
        if self.family == "ssm":
            x = self.xlstm or XLSTMConfig()
            di = x.proj_factor * d
            per_m = 2 * d * di + 3 * di * di // max(self.num_heads, 1) + di * d
            per_s = 4 * d * d + 4 * d * d // max(self.num_heads, 1)
            n_m = sum(1 for i in range(self.num_layers)
                      if i % x.slstm_every != x.slstm_offset)
            n += n_m * per_m + (self.num_layers - n_m) * per_s
            n += self.vocab_size * d  # untied output head
            return n
        attn = d * h * hd + 2 * d * k * hd + h * hd * d
        if self.activation == "swiglu":
            mlp_dense = 3 * d * self.d_ff
        else:
            mlp_dense = 2 * d * self.d_ff
        n_layers_total = self.num_layers + self.num_encoder_layers
        for i in range(self.num_layers):
            is_attn = (i % self.attn_layer_period) == self.attn_layer_offset
            if is_attn or self.family != "hybrid":
                n += attn
            else:
                s = self.ssm or SSMConfig()
                di = s.expand * d
                n += d * (2 * di + 2 * s.d_state + di // s.head_dim) + di * d
            if self.moe and (i % self.moe.every_k_layers) == self.moe.moe_layer_offset:
                mult = 3 if self.activation == "swiglu" else 2
                n += self.moe.num_experts * mult * d * self.moe.d_ff_expert
                n += d * self.moe.num_experts
                if self.moe.dense_residual:
                    n += mult * d * self.moe.d_ff_dense
            elif self.d_ff > 0:
                n += mlp_dense
        for _ in range(self.num_encoder_layers):
            n += attn + mlp_dense
            if self.cross_attention:
                n += attn  # decoder cross-attention blocks
        n += self.vocab_size * d  # untied LM head
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if not self.moe:
            return self.param_count()
        full = self.param_count()
        mult = 3 if self.activation == "swiglu" else 2
        n_moe_layers = sum(1 for i in range(self.num_layers)
                           if (i % self.moe.every_k_layers) == self.moe.moe_layer_offset)
        all_e = n_moe_layers * self.moe.num_experts * mult * self.d_model * self.moe.d_ff_expert
        act_e = n_moe_layers * self.moe.top_k * mult * self.d_model * self.moe.d_ff_expert
        return full - all_e + act_e


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """long_500k needs sub-quadratic attention; skip for full-attention archs."""
    if shape.name == "long_500k":
        return cfg.subquadratic
    return True


def reduce_config(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    small = dict(
        num_layers=min(cfg.num_layers, 4 if cfg.family != "hybrid" else cfg.attn_layer_period),
        d_model=128,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=32,
        d_ff=256 if cfg.d_ff > 0 else 0,
        vocab_size=512,
        num_encoder_layers=2 if cfg.num_encoder_layers else 0,
        num_patches=16 if cfg.num_patches else 0,
        frontend_dim=64 if cfg.frontend_dim else 0,
    )
    if cfg.family == "hybrid":
        small["num_layers"] = cfg.attn_layer_period  # one full period
    if cfg.moe:
        small["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=min(cfg.moe.top_k, 2), d_ff_expert=64,
            d_ff_dense=64 if cfg.moe.dense_residual else 0)
    if cfg.ssm:
        small["ssm"] = dataclasses.replace(cfg.ssm, d_state=8, head_dim=16, chunk=16)
    if cfg.xlstm:
        small["xlstm"] = dataclasses.replace(cfg.xlstm, chunk=8)
        small["num_layers"] = 4
        small["num_kv_heads"] = 4
    small["name"] = cfg.name + "-reduced"
    small.update(overrides)
    return dataclasses.replace(cfg, **small)


SMOKE_SHAPE = ShapeConfig("smoke", 64, 2, "train")
