"""The Planner — Game 1 (prefill/decode GNEP resource allocation).

Implements the three layers the paper describes:

* ``variational_equilibrium`` — the analytical solution of Prop. 1: on the
  constraint manifold G_P + G_D = G, find the split equalizing marginal SLO
  violation improvements (Eq. 5), and the *social optimum* of Remark 1 which
  additionally credits prefill's positive externality on decode.

* ``Planner`` — the runtime best-response dynamic with inertia: ±1 worker per
  adjustment interval (30 s), 3-interval grace period for newly assigned
  decode workers, driven by polled TTFT/ITL violation metrics.  Converges to
  the variational equilibrium under stationary load (validated in tests).

* ``ResponseModel`` — the profiled response curves v_TTFT(G_P) / v_ITL(G_D)
  the paper's pre-deployment profiling step produces, anchored at a runtime
  operating point (measured arrival rate, prefill service time, decode
  residency).  TTFT violations follow an M/M/c Erlang-C wait tail over the
  prefill pool; ITL violations follow a Poisson tail over per-worker decode
  occupancy against the load-dependent ITL curve.  The simulator's Planner
  loop feeds ``marginals()`` to ``Planner.step`` as best-response signals,
  and the PoA tracker evaluates the same curves for the resource-game
  counterfactual — so convergence to ``variational_equilibrium`` of these
  curves is the closed-loop claim Game 1 benchmarks verify.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Tuple



def variational_equilibrium(v_ttft: Callable[[float], float],
                            v_itl: Callable[[float], float],
                            total: int) -> int:
    """Integer split G_P* with |marginal| balance of Eq. 5 (exhaustive scan —
    G is small; convexity makes the crossing unique)."""
    best, best_gap = 1, float("inf")
    for gp in range(1, total):
        gd = total - gp
        m_p = v_ttft(gp + 1) - v_ttft(gp)      # ≤ 0, marginal improvement
        m_d = v_itl(gd + 1) - v_itl(gd)
        gap = abs(m_p - m_d)
        if gap < best_gap:
            best, best_gap = gp, gap
    return best


def social_optimum(v_ttft: Callable[[float], float],
                   v_itl_joint: Callable[[float, float], float],
                   total: int) -> int:
    """argmin_{G_P} V_TTFT(G_P) + V_ITL(G−G_P, G_P) (Remark 1)."""
    costs = [(v_ttft(gp) + v_itl_joint(total - gp, gp), gp)
             for gp in range(1, total)]
    return min(costs)[1]


def erlang_c(c: int, a: float) -> float:
    """P(wait > 0) in an M/M/c queue with offered load ``a`` erlangs
    (iterative Erlang-B recurrence, then the standard C conversion)."""
    if c <= 0 or a >= c:
        return 1.0
    if a <= 0.0:
        return 0.0
    b = 1.0
    for k in range(1, c + 1):
        b = a * b / (k + a * b)
    rho = a / c
    return b / (1.0 - rho + rho * b)


def poisson_sf(k: float, mean: float) -> float:
    """P(X > k) for X ~ Poisson(mean), clamped to [0, 1]."""
    if mean <= 0.0:
        return 0.0
    kk = int(math.floor(k))
    if kk < 0:
        return 1.0
    term = math.exp(-mean)
    if term == 0.0:          # mean so large the pmf underflows: tail ≈ 1
        return 1.0
    cdf = term
    for i in range(1, kk + 1):
        term *= mean / i
        cdf += term
    return max(0.0, min(1.0, 1.0 - cdf))


@dataclass(frozen=True)
class ResponseModel:
    """Game 1 response curves anchored at an observed operating point.

    ``v_ttft(G_P)`` — probability a request's prefill wait exceeds the TTFT
    SLO slack, from the Erlang-C wait tail of an M/M/c queue with c = G_P
    servers at the measured arrival rate and mean prefill service time.

    ``v_itl(G_D)`` — probability a decode worker's occupancy N (Poisson
    around the Little's-law mean λ·T_dec/G_D) pushes the load-dependent ITL
    ``itl_base + itl_slope·N`` past the ITL SLO, plus a linear
    excess-occupancy congestion term once the mean runs past the violation
    knee (admission stalls).

    Both curves are strictly decreasing in their pool size, so the
    best-response dynamic over ``marginals()`` descends to the Prop. 1
    equilibrium.
    """
    arrival_rate: float          # λ measured over the planner window (req/s)
    prefill_service: float       # mean prefill service time per request (s)
    decode_residency: float      # mean decode duration per request (s)
    itl_base: float
    itl_slope: float
    decode_cap: float            # admission slots per decode worker
    ttft_slack: float            # TTFT SLO minus pipelined base latency (s)
    itl_slo: float

    # In the overloaded region the violation *probability* clamps at 1,
    # which would zero the marginals and hand the equilibrium scan spurious
    # flat-region fixed points (adding one worker to a destroyed pool
    # "doesn't help").  Both curves therefore extend past 1 with the excess
    # offered load — a strictly decreasing violation *cost* whose marginals
    # keep pointing the best-response dynamic at the starved pool.

    def v_ttft(self, gp: float) -> float:
        c = int(gp)
        a = self.arrival_rate * self.prefill_service
        if c <= 0:
            return 2.0 + a
        if a >= c:
            return 1.0 + (a - c) / c
        p_wait = erlang_c(c, a)
        mu = 1.0 / max(self.prefill_service, 1e-9)
        return min(1.0, p_wait * math.exp(-(c - a) * mu * self.ttft_slack))

    def v_itl(self, gd: float) -> float:
        g = int(gd)
        n_total = self.arrival_rate * self.decode_residency
        cap = max(self.decode_cap, 1.0)
        if g <= 0:
            return 2.0 + n_total / cap
        n_bar = n_total / g
        n_star = (self.itl_slo - self.itl_base) / max(self.itl_slope, 1e-12)
        knee = min(n_star, cap)
        # Poisson occupancy tail, plus the excess-occupancy congestion term
        # (linear in n̄, so strictly convex decreasing in gd): deep inside
        # saturation the tail alone is flat at 1 for every pool size.
        return poisson_sf(knee, n_bar) + max(0.0, (n_bar - knee) / cap)

    def marginals(self, gp: int, gd: int) -> Tuple[float, float]:
        """Estimated violation-rate reduction from +1 worker per pool —
        the best-response signals the Planner consumes (Eq. 5)."""
        m_p = max(self.v_ttft(gp) - self.v_ttft(gp + 1), 0.0)
        m_d = max(self.v_itl(gd) - self.v_itl(gd + 1), 0.0)
        return m_p, m_d


@dataclass
class PlannerConfig:
    total_workers: int = 3
    adjust_interval: float = 30.0     # seconds
    grace_intervals: int = 3          # grace for newly assigned decode workers
    ttft_slo: float = 1.0             # seconds
    itl_slo: float = 0.050
    min_signal: float = 1e-4          # marginal dead-band: park when healthy
    measure_window: float = 30.0      # window for the ResponseModel inputs
                                      # (λ, prefill service, decode
                                      # residency); SLO violation *rates*
                                      # read the shared 30 s ttft/itl
                                      # telemetry windows
    hysteresis: float = 0.0           # move only if the starved pool's
                                      # signal beats the other by this factor


@dataclass
class Planner:
    """±1-worker best-response dynamic over polled violation rates."""
    config: PlannerConfig = field(default_factory=PlannerConfig)
    prefill_workers: int = 1
    decode_workers: int = 2
    _last_adjust: float = 0.0
    _grace_until: float = 0.0
    history: List[Tuple[float, int, int]] = field(default_factory=list)

    def step(self, now: float, ttft_violation: float, itl_violation: float
             ) -> Optional[str]:
        """Called per telemetry poll; may move one worker between pools.
        Returns 'to_prefill' / 'to_decode' / None."""
        c = self.config
        if now - self._last_adjust < c.adjust_interval or now < self._grace_until:
            return None
        move = None
        hyst = 1.0 + c.hysteresis
        if ttft_violation > itl_violation * hyst and self.decode_workers > 1:
            self.prefill_workers += 1
            self.decode_workers -= 1
            move = "to_prefill"
        elif itl_violation > ttft_violation * hyst and self.prefill_workers > 1:
            self.prefill_workers -= 1
            self.decode_workers += 1
            move = "to_decode"
            self._grace_until = now + c.grace_intervals * c.adjust_interval
        if move:
            self._last_adjust = now
            self.history.append((now, self.prefill_workers, self.decode_workers))
        return move
