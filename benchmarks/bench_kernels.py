"""Kernel micro-benchmarks: flash / decode attention vs their jnp oracles
(CPU wall-time; on TPU the same harness reports compiled-kernel timings)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, save_json
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref


def _time(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    results = {}
    b, s, h, kh, hd = 1, 512, 8, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kh, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kh, hd), jnp.float32)

    t_ref = _time(jax.jit(lambda *a: flash_attention_ref(*a)), q, k, v)
    t_pal = _time(lambda *a: flash_attention(*a, interpret=True), q, k, v)
    flops = 4 * b * s * s * h * hd / 2  # causal
    results["flash_attention"] = dict(ref_us=t_ref, pallas_interpret_us=t_pal,
                                      flops=flops)
    emit("bench_flash_attention", t_pal,
         f"ref_us={t_ref:.0f};causal_gqa_{s}x{s}x{h}h")

    t = 2048
    q1 = jax.random.normal(ks[0], (8, h, hd), jnp.float32)
    k1 = jax.random.normal(ks[1], (8, t, kh, hd), jnp.float32)
    v1 = jax.random.normal(ks[2], (8, t, kh, hd), jnp.float32)
    lengths = jnp.full((8,), t, jnp.int32)
    t_ref = _time(jax.jit(lambda *a: decode_attention_ref(*a)), q1, k1, v1,
                  lengths)
    t_pal = _time(lambda *a: decode_attention(*a, interpret=True), q1, k1, v1,
                  lengths)
    kv_bytes = 2 * 8 * t * kh * hd * 4
    results["decode_attention"] = dict(ref_us=t_ref,
                                       pallas_interpret_us=t_pal,
                                       kv_bytes=kv_bytes)
    emit("bench_decode_attention", t_pal,
         f"ref_us={t_ref:.0f};kv_bytes={kv_bytes}")
    save_json("bench_kernels", results)
    return results


if __name__ == "__main__":
    run()
