"""Pallas flash-attention kernel vs pure-jnp oracle: shape/dtype sweep
(interpret mode on CPU; the identical kernel body compiles for TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref

SHAPES = [
    # (b, s, h, kh, hd)
    (1, 64, 2, 2, 32),     # MHA
    (2, 128, 4, 2, 64),    # GQA g=2
    (1, 256, 8, 1, 64),    # MQA
    (2, 96, 4, 4, 128),    # non-block-multiple seq (padding path)
    (1, 128, 8, 2, 96),    # hd not a lane multiple
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_ref(shape, dtype, causal):
    b, s, h, kh, hd = shape
    ks = jax.random.split(jax.random.PRNGKey(hash(shape) % 2**31), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, kh, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, kh, hd), jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, blk_q=64, blk_k=64,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal)
    atol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=atol, rtol=atol)


def test_flash_block_size_invariance():
    b, s, h, kh, hd = 1, 128, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, kh, hd))
    v = jax.random.normal(ks[2], (b, s, kh, hd))
    o1 = flash_attention(q, k, v, blk_q=32, blk_k=32, interpret=True)
    o2 = flash_attention(q, k, v, blk_q=128, blk_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


def test_flash_first_token_attends_only_itself():
    """Causal: row 0 must equal v[0] exactly (softmax over one key)."""
    b, s, h, hd = 1, 64, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    out = flash_attention(q, k, v, causal=True, interpret=True)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(v[:, 0]),
                               atol=1e-5)
