# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness entry point.

Each module reproduces one paper table/figure on the calibrated cluster
simulator (Experiments 1-4) or micro-benchmarks a system layer.  Output:
human-readable tables on stdout + one ``name,us_per_call,derived`` CSV row
per artifact + JSON payloads under reports/benchmarks/.

    PYTHONPATH=src python -m benchmarks.run [--fast]
"""
from __future__ import annotations

import argparse
import sys
import time


def _scenario_sweep() -> None:
    """Run every registry scenario (fast variant) and emit one CSV row per
    scenario: wall time per simulated request + headline stats."""
    from benchmarks.common import emit, save_json
    from repro.serving.scenarios import build_simulator, list_scenarios
    rows = {}
    for name in list_scenarios():
        t0 = time.perf_counter()
        sim = build_simulator(name, seed=0, fast=True)
        res = sim.run()
        dt = (time.perf_counter() - t0) * 1e6
        s = res.overall()
        rows[name] = dict(completed=len(res.completed), poa=s.poa,
                          ttft_p99=s.ttft_p99, rps=s.rps)
        emit(f"scenario_{name}", dt / max(len(res.completed), 1),
             f"n={len(res.completed)};ttft_p99={s.ttft_p99:.3f}s;"
             f"rps={s.rps:.1f}")
    save_json("scenario_sweep", rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="shorter holds / fewer iterations")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny horizons + single seed: CI bit-rot guard "
                         "for the benchmark scripts, not a measurement")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    ap.add_argument("--list-scenarios", action="store_true",
                    help="print the scenario registry and exit")
    args = ap.parse_args()
    if args.list_scenarios:
        from repro.serving.scenarios import get_scenario, list_scenarios
        for name in list_scenarios():
            print(f"{name:24s} {get_scenario(name, fast=True).description}")
        return
    hold = 60.0 if args.fast else 120.0
    iters = 2 if args.fast else 3
    if args.smoke:
        hold, iters = 12.0, 1

    from benchmarks import (baselines_static_routing, bench_backend_parity,
                            bench_kernels, bench_router, bench_scale,
                            exp2_saturation_detection,
                            fig5_poa_curves, game1_repartition,
                            prop5_g1_sweep, table4_equilibrium,
                            table5_crossmodel, table6_pareto,
                            table78_adaptive)

    smoke = args.smoke
    registry = {
        "table4": lambda: table4_equilibrium.run(hold),
        "table5": lambda: table5_crossmodel.run(hold),
        "exp2": lambda: exp2_saturation_detection.run(hold),
        "table6": lambda: table6_pareto.run(min(hold, 90.0)),
        "table78": lambda: table78_adaptive.run(iters),
        "fig5": lambda: fig5_poa_curves.run(min(hold, 90.0)),
        "prop5": lambda: (prop5_g1_sweep.run(8.0, seeds=(0,), concurrency=48)
                          if smoke else prop5_g1_sweep.run(min(hold, 60.0))),
        "game1": lambda: game1_repartition.run(hold=min(hold, 150.0),
                                               smoke=smoke),
        "baselines": lambda: baselines_static_routing.run(min(hold, 90.0)),
        "kernels": bench_kernels.run,
        "router": bench_router.run,
        "scale": lambda: bench_scale.run(smoke=smoke or args.fast),
        "backend_parity": lambda: bench_backend_parity.run(
            smoke=smoke or args.fast),
        "scenarios": _scenario_sweep,
    }
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    t0 = time.time()
    for name, fn in registry.items():
        if only and name not in only:
            continue
        fn()
    print(f"# total benchmark time: {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
