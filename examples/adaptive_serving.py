"""Adaptive vs static routing under a load spike (mini Experiment 3).

Runs the ``70b-1p5d-spike`` registry scenario (the paper's C = 32 → 128 → 32
spike on the calibrated 70B 1P/5D cluster) with both strategies and prints
the per-phase comparison — the controller detects the TRANSITION regime and
switches router parameters per Table 2.

    PYTHONPATH=src python examples/adaptive_serving.py
"""
from repro.serving.scenarios import get_scenario

SCENARIO = "70b-1p5d-spike"


def main():
    scenario = get_scenario(SCENARIO)
    cluster = scenario.cluster
    print(f"scenario: {SCENARIO} — {scenario.description}")
    print("cluster:", cluster.name, f"1P/{cluster.num_decode}D",
          f"(prefill ceiling {cluster.prefill_rate} rps)")
    for adaptive in (False, True):
        sim = scenario.build(seed=1, adaptive=adaptive)
        res = sim.run()
        tag = "ADAPTIVE" if adaptive else "STATIC  "
        print(f"\n{tag} — per-phase results")
        for ph, name in [(0, "below"), (1, "saturated"), (2, "recovery")]:
            s = res.phase_stats(ph)
            print(f"  {name:10s} PoA={s.poa:6.2f}  TTFT P99={s.ttft_p99:7.3f}s"
                  f"  ITL P99={s.itl_p99*1000:6.2f}ms  rps={s.rps:5.1f}")
        if res.switch_time is not None:
            print(f"  zero-downtime switch fired at t={res.switch_time:.1f}s")
        # regime timeline
        line = "".join(str(p["regime"]) for p in res.poll_log)
        print(f"  regime timeline (5s polls): {line}")


if __name__ == "__main__":
    main()
