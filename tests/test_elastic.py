"""Elastic scaling, heartbeats, straggler mitigation."""
import pytest

from repro.training.elastic import (ElasticMesh, HeartbeatMonitor,
                                    StragglerMitigator)


def test_heartbeat_failure_detection():
    hb = HeartbeatMonitor(timeout=10.0)
    hb.beat(0, now=0.0)
    hb.beat(1, now=0.0)
    hb.beat(0, now=8.0)
    assert hb.failed_hosts(now=12.0) == [1]
    assert hb.alive_hosts(now=12.0) == [0]


def test_elastic_mesh_shrinks_data_axis():
    em = ElasticMesh(model_parallel=4)
    assert em.best_shape(32) == (8, 4)
    assert em.best_shape(28) == (7, 4)   # lost a host: data axis shrinks
    assert em.best_shape(5) == (1, 4)
    with pytest.raises(RuntimeError):
        em.best_shape(3)                 # cannot satisfy model parallelism


def test_straggler_detection_and_reassignment():
    sm = StragglerMitigator(factor=1.5)
    for _step in range(8):
        sm.record(0, 1.0)
        sm.record(1, 1.1)
        sm.record(2, 3.0)  # straggler
    assert sm.stragglers() == [2]
    shares = sm.reassignment(16)
    assert sum(shares.values()) == 16
    assert shares[2] < shares[0]         # slow host gets fewer microbatches


def test_reassignment_handles_empty():
    assert StragglerMitigator().reassignment(8) == {}
