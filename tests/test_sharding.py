"""Sharding policy: divisibility fallbacks and FSDP+TP parameter heuristics.

The policy only reads ``mesh.axis_names`` and ``mesh.devices.shape``, so a
lightweight stub mesh lets these tests run on one CPU device.
"""
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding.policy import ShardingPolicy
from repro.sharding.specs import param_spec


class StubMesh:
    def __init__(self, shape, axes):
        self.devices = np.empty(shape, dtype=object)
        self.axis_names = axes


@pytest.fixture
def policy():
    return ShardingPolicy(StubMesh((16, 16), ("data", "model")))


@pytest.fixture
def policy3d():
    return ShardingPolicy(StubMesh((2, 16, 16), ("pod", "data", "model")))


def test_batch_sharded_over_data(policy):
    spec = policy.spec(("batch", "seq", "act_embed"), (256, 4096, 1024))
    assert spec == P("data", None, None)


def test_pod_axis_joins_batch(policy3d):
    spec = policy3d.spec(("batch", "seq", "act_embed"), (256, 4096, 1024))
    assert spec == P(("pod", "data"), None, None)


def test_divisibility_fallback_drops_axis(policy):
    # 24 heads not divisible by model=16 → replicated
    spec = policy.spec(("batch", "seq", "heads", "head_dim"),
                       (32, 128, 24, 128))
    assert spec == P("data", None, None, None)
    # 96 heads divisible → sharded
    spec = policy.spec(("batch", "seq", "heads", "head_dim"),
                       (32, 128, 96, 128))
    assert spec == P("data", None, "model", None)


def test_axis_used_once(policy):
    # both dims want "model": only the first gets it
    spec = policy.spec(("heads", "act_mlp"), (32, 1024))
    assert spec == P("model", None)


def test_long_seq_rule(policy):
    spec = policy.spec(("stack", "long_seq", "kv_heads"), (8, 524288, 8))
    assert spec[1] == "data"


def test_param_spec_fsdp_tp(policy):
    # biggest dim → model, second → data
    spec = param_spec("['stack']['p0']['mlp']['wu']", (96, 18432, 73728), policy)
    assert spec == P(None, "data", "model")
    # embedding special case: vocab → model, d → data
    spec = param_spec("['embed']", (256000, 18432), policy)
    assert spec == P("model", "data")
    # 1-D: replicated
    spec = param_spec("['final_norm']['scale']", (18432,), policy)
    assert spec == P(None)


def test_param_spec_indivisible_replicates(policy):
    spec = param_spec("['x']", (7, 13), policy)
    assert spec == P(None, None)


def test_rule_override():
    pol = ShardingPolicy(StubMesh((4, 2), ("data", "model")),
                         rules={"act_mlp": ("data",)})
    spec = pol.spec(("batch", "act_mlp"), (1, 8))  # batch falls back (1%4)
    assert spec == P(None, "data")
