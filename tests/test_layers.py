"""Layer-level unit tests: RoPE, GQA, chunked attention, MLP variants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import layers as L


@pytest.fixture
def cfg():
    return get_reduced("phi4-mini-3.8b")


def test_rmsnorm_unit_scale():
    p = L.rmsnorm_init(8, jnp.float32)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 3, 8)) * 10,
                    jnp.float32)
    y = L.rmsnorm(p, x)
    rms = jnp.sqrt(jnp.mean(y * y, axis=-1))
    assert jnp.allclose(rms, 1.0, atol=1e-3)


def test_rope_relative_position_invariance():
    """RoPE dot products depend only on relative position."""
    hd = 32
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 1, 1, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, hd)), jnp.float32)

    def score(pq, pk):
        cq, sq = L.rope_table(jnp.asarray([pq], jnp.int32), hd, 1e4)
        ck, sk = L.rope_table(jnp.asarray([pk], jnp.int32), hd, 1e4)
        qr = L.apply_rope(q, cq, sq)
        kr = L.apply_rope(k, ck, sk)
        return float(jnp.sum(qr * kr))

    assert abs(score(3, 7) - score(13, 17)) < 1e-3
    assert abs(score(0, 5) - score(10, 15)) < 1e-3
    assert abs(score(3, 7) - score(3, 8)) > 1e-5  # but absolute shift matters


def test_chunked_sdpa_equals_plain():
    rng = jax.random.PRNGKey(0)
    b, s, h, hd = 2, 40, 4, 16
    q = jax.random.normal(rng, (b, s, h, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, 2, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, 2, hd))
    qp = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    full = L._sdpa_chunked(q, k, v, qp, 2, kind="causal", q_chunk=1024)
    chunked = L._sdpa_chunked(q, k, v, qp, 2, kind="causal", q_chunk=16)
    assert jnp.allclose(full, chunked, atol=1e-5)


def test_gqa_equals_mha_with_replicated_kv(cfg):
    """GQA with K<H must equal MHA whose K/V heads are replicated."""
    cfg_gqa = dataclasses.replace(cfg, num_heads=4, num_kv_heads=2, head_dim=16)
    cfg_mha = dataclasses.replace(cfg, num_heads=4, num_kv_heads=4, head_dim=16)
    p = L.attention_init(jax.random.PRNGKey(0), cfg_gqa, jnp.float32)
    p_mha = dict(p)
    p_mha["wk"] = jnp.repeat(p["wk"], 2, axis=1)
    p_mha["wv"] = jnp.repeat(p["wv"], 2, axis=1)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 8, cfg.d_model),
                          jnp.float32)
    o1, _ = L.attention(p, x, cfg_gqa)
    o2, _ = L.attention(p_mha, x, cfg_mha)
    assert jnp.allclose(o1, o2, atol=1e-2, rtol=1e-2)


def test_causal_mask_blocks_future(cfg):
    c = dataclasses.replace(cfg, num_heads=2, num_kv_heads=2, head_dim=16)
    p = L.attention_init(jax.random.PRNGKey(0), c, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, c.d_model), jnp.float32)
    o1, _ = L.attention(p, x, c)
    x2 = x.at[:, -1].set(0.0)  # change only the last token
    o2, _ = L.attention(p, x2, c)
    assert jnp.allclose(o1[:, :-1], o2[:, :-1], atol=1e-5)  # prefix unaffected


@pytest.mark.parametrize("act", ["swiglu", "squared_relu", "gelu"])
def test_mlp_variants(cfg, act):
    c = dataclasses.replace(cfg, activation=act, d_ff=32)
    p = L.mlp_init(jax.random.PRNGKey(0), c, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, c.d_model), jnp.float32)
    y = L.mlp(p, x, c)
    assert y.shape == x.shape and jnp.all(jnp.isfinite(y))
    if act == "swiglu":
        assert "wg" in p
    else:
        assert "wg" not in p


def test_squared_relu_nonnegative_preactivation(cfg):
    c = dataclasses.replace(cfg, activation="squared_relu", d_ff=32)
    p = L.mlp_init(jax.random.PRNGKey(0), c, jnp.float32)
    p2 = dict(p, wd=jnp.abs(p["wd"]))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, c.d_model), jnp.float32)
    y = L.mlp(p2, x, c)  # relu² ≥ 0, positive wd ⇒ y ≥ 0
    assert float(jnp.min(y)) >= 0.0
