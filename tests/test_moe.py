"""MoE dispatch: exactness at high capacity, dropping at low capacity,
router-load observability (the §10.1 inner congestion game)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import moe as moe_lib
from repro.models.layers import rmsnorm


@pytest.fixture
def cfg():
    base = get_reduced("qwen3-moe-30b-a3b")
    return dataclasses.replace(
        base, moe=dataclasses.replace(base.moe, capacity_factor=64.0))


def _dense_reference(params, x, cfg):
    """Per-token loop over its top-k experts (no capacity), fp32."""
    m = cfg.moe
    b, s, d = x.shape
    xn = rmsnorm(params["norm"], x, cfg.norm_eps).reshape(-1, d)
    logits = (xn.astype(jnp.float32) @ params["wr"].astype(jnp.float32))
    w, idx = jax.lax.top_k(logits, m.top_k)
    w = jax.nn.softmax(w, axis=-1)
    out = np.zeros((xn.shape[0], d), np.float32)
    xn32 = np.asarray(xn, np.float32)
    for t in range(xn.shape[0]):
        for j in range(m.top_k):
            e = int(idx[t, j])
            g = np.asarray(params["wg"][e], np.float32)
            u = np.asarray(params["wu"][e], np.float32)
            dn = np.asarray(params["wd"][e], np.float32)
            gate = xn32[t] @ g
            up = xn32[t] @ u
            h = (gate / (1 + np.exp(-gate))) * up  # silu(gate) * up
            out[t] += float(w[t, j]) * (h @ dn)
    return out.reshape(b, s, d)


def test_moe_matches_dense_reference(cfg):
    model_params = moe_lib.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, cfg.d_model),
                          jnp.float32)
    y, aux = moe_lib.moe(model_params, x, cfg)
    ref = _dense_reference(model_params, x, cfg)
    assert np.allclose(np.asarray(y, np.float32), ref, atol=0.05, rtol=0.05)


def test_expert_load_sums_to_tk(cfg):
    params = moe_lib.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          jnp.float32)
    _, aux = moe_lib.moe(params, x, cfg)
    total = float(jnp.sum(aux["expert_load"]))
    assert total == pytest.approx(2 * 8 * cfg.moe.top_k)


def test_capacity_drops_tokens(cfg):
    tight = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.05))
    params = moe_lib.moe_init(jax.random.PRNGKey(0), tight, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, tight.d_model),
                          jnp.float32)
    y_tight, _ = moe_lib.moe(params, x, tight)
    y_loose, _ = moe_lib.moe(params, x, cfg)
    # under-capacity must change (drop) some outputs
    assert not jnp.allclose(y_tight, y_loose, atol=1e-4)


def test_aux_loss_prefers_balance(cfg):
    """Uniform router logits ⇒ aux loss ≈ 1 (its minimum for top-1 share)."""
    params = moe_lib.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    params = dict(params, wr=jnp.zeros_like(params["wr"]))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model),
                          jnp.float32)
    _, aux = moe_lib.moe(params, x, cfg)
    assert float(aux["moe_aux_loss"]) == pytest.approx(1.0, abs=0.05)


def test_dense_residual_arctic():
    cfg = get_reduced("arctic-480b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    params = moe_lib.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    assert "du" in params and "dd" in params  # dense residual branch exists
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, cfg.d_model),
                          jnp.float32)
    y, _ = moe_lib.moe(params, x, cfg)
    # zeroing the dense residual changes the output
    params2 = dict(params, dd=jnp.zeros_like(params["dd"]))
    y2, _ = moe_lib.moe(params2, x, cfg)
    assert not jnp.allclose(y, y2, atol=1e-5)
