"""RA005 good: every stream is explicitly seeded."""
import random

import numpy as np


def pick_worker(ids, seed):
    rng = np.random.default_rng(seed)
    return ids[rng.integers(len(ids))]


def shuffle_queue(queue, seed):
    random.Random(seed).shuffle(queue)


def sample_load(rng):
    return rng.poisson(4.0)              # caller-provided seeded stream


def make_stream(seed=0):
    return random.Random(seed)
