"""Differential batching harness: the engine fast path (bucketed batched
prefill + Pallas ragged decode) is pinned against the slow path it replaced
(sequential batch-1 prefill + XLA `_sdpa` decode), one axis at a time.

**Scheduling/batching axis — exact.**  For seeded request streams across
admit/release interleavings — flood, staggered submission, and mid-stream
admission into a slot freed the same tick — the batched engine must emit
*identical token streams per request* to a sequential batch-1 engine
running the same decode implementation.  Batched padded prefill is
bitwise-equal to the batch-1 pass on CPU, so any stream difference on
this axis is a real scheduling/slot/caching bug, never numerics.

**Decode-impl axis — logits tolerance.**  Pallas online softmax and the
XLA `_sdpa` einsum reassociate floating-point sums differently (~1e-7
relative), so greedy argmax over a near-uniform reduced-model vocabulary
legitimately flips on near-ties; cross-impl *stream* equality is not a
well-defined contract.  The impl axis is pinned where it is exact: the
two impls' step logits must agree within dtype tolerance at every decode
position (`test_decode_impl_logits_parity`), and the kernel itself is
pinned against a dense masked-softmax reference in the kernel suites.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import build_model
from repro.serving.disagg import DisaggregatedCluster, ServeRequest
from repro.serving.engine import DecodeEngine, PrefillEngine
from repro.serving.workload import template_tokens

# real-model runs (jit compiles per prompt shape): tier-2 only
pytestmark = pytest.mark.slow

FAST = dict(batch_prefill=True, decode_impl="pallas")
# sequential batch-1 prefill, same decode impl: isolates the scheduling /
# batching machinery so stream equality is exact (see module docstring)
REFERENCE = dict(batch_prefill=False, decode_impl="pallas")


@pytest.fixture(scope="module")
def reduced_model():
    cfg = get_reduced("phi4-mini-3.8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), jnp.bfloat16)
    return cfg, model, params


def _toks(cfg, template, n=48):
    return [t % cfg.vocab_size for t in template_tokens(template, n)]


def _cluster(reduced_model, mode, **kw):
    cfg, model, params = reduced_model
    kw.setdefault("num_decode", 2)
    kw.setdefault("slots_per_worker", 2)
    kw.setdefault("max_len", 96)
    kw.setdefault("adaptive", False)
    return DisaggregatedCluster(model, params, **mode, **kw)


def _stream(cfg, seed, n):
    """Seeded request specs: (template, prompt_len, max_new)."""
    rng = np.random.default_rng(seed)
    return [(int(rng.integers(0, 4)), int(rng.integers(33, 49)),
             int(rng.integers(2, 6))) for _ in range(n)]


def _outputs(cluster):
    return {r.request_id: list(r.output) for r in cluster.done}


# ----------------------------------------------------------- prompt pass ----


def test_batched_prefill_logits_match_sequential(reduced_model):
    """Cold buckets (ragged padding), duplicate collapse and stacked-donor
    resume groups all reproduce the sequential batch-1 logits."""
    cfg, model, params = reduced_model
    eng = PrefillEngine(model, params, max_len=96)
    ref = PrefillEngine(model, params, max_len=96, cache_entries=0)
    cold = [(_toks(cfg, 0, 45), None, None), (_toks(cfg, 1, 48), None, None),
            (_toks(cfg, 2, 40), None, None), (_toks(cfg, 0, 45), None, None)]
    outs = eng.prefill_many(cold)
    for (tokens, _, _), (logits, _, _) in zip(cold, outs):
        seq_logits, _ = ref.prefill(tokens)
        np.testing.assert_allclose(logits, seq_logits, rtol=2e-3, atol=2e-3)
        assert int(np.argmax(logits)) == int(np.argmax(seq_logits))
    # duplicate prompts collapse onto one batch row of one shared bundle
    assert outs[0][2] == outs[3][2] and outs[0][1] is outs[3][1]
    assert eng.stats.batched_requests >= 3
    # warm second wave: resume groups (distinct (start, length) keys)
    warm = [(_toks(cfg, 0, 48), None, None), (_toks(cfg, 1, 45), None, None)]
    outs2 = eng.prefill_many(warm)
    assert eng.stats.reused_blocks > 0
    for (tokens, _, _), (logits, _, _) in zip(warm, outs2):
        seq_logits, _ = ref.prefill(tokens)
        np.testing.assert_allclose(logits, seq_logits, rtol=2e-3, atol=2e-3)
        assert int(np.argmax(logits)) == int(np.argmax(seq_logits))


def test_batched_prefill_isolates_rows(reduced_model):
    """A row's logits must not depend on its batch mates: the same prompt
    batched against different companions yields identical logits."""
    cfg, model, params = reduced_model
    probe = _toks(cfg, 0, 45)
    a = PrefillEngine(model, params, max_len=96)
    outs_a = a.prefill_many([(probe, None, None),
                             (_toks(cfg, 1, 40), None, None)])
    b = PrefillEngine(model, params, max_len=96)
    outs_b = b.prefill_many([(_toks(cfg, 2, 48), None, None),
                             (probe, None, None),
                             (_toks(cfg, 3, 37), None, None)])
    np.testing.assert_array_equal(outs_a[0][0], outs_b[1][0])


# ------------------------------------------------------- token streams ------


def test_differential_flood(reduced_model):
    """All requests submitted at once: bucketed multi-request prefill
    batches + backpressure retries, fast vs reference streams identical."""
    streams = {}
    for mode in (FAST, REFERENCE):
        cluster = _cluster(reduced_model, mode)
        for i, (t, n, m) in enumerate(_stream(reduced_model[0], seed=1, n=8)):
            cluster.submit(ServeRequest(
                f"r{i}", _toks(reduced_model[0], t, n), max_new_tokens=m))
        cluster.run_until_done()
        streams[id(mode)] = _outputs(cluster)
        if mode is FAST:   # the fast path must actually have batched
            assert cluster.prefill.stats.batched_requests > 0
            assert all(d.decode_impl == "pallas" for d in cluster.decoders)
    assert streams[id(FAST)] == streams[id(REFERENCE)]


def test_differential_staggered(reduced_model):
    """Submissions interleaved with ticks: admissions land mid-decode, into
    slots freed by earlier completions — including same-tick reuse."""
    streams = {}
    for mode in (FAST, REFERENCE):
        cluster = _cluster(reduced_model, mode, num_decode=1,
                           slots_per_worker=2)
        specs = _stream(reduced_model[0], seed=2, n=7)
        for i, (t, n, m) in enumerate(specs):
            cluster.submit(ServeRequest(
                f"s{i}", _toks(reduced_model[0], t, n), max_new_tokens=m))
            cluster.step()
            if i % 3 == 0:
                cluster.step()
        cluster.run_until_done()
        streams[id(mode)] = _outputs(cluster)
    assert len(streams[id(FAST)]) == 7
    assert streams[id(FAST)] == streams[id(REFERENCE)]


def test_differential_same_tick_slot_reuse(reduced_model):
    """Mid-stream admission into a slot freed the same tick: one slot,
    queued requests — every completion frees the slot inside step() and the
    next pending request is admitted on the very next scheduler pass."""
    streams = {}
    for mode in (FAST, REFERENCE):
        cluster = _cluster(reduced_model, mode, num_decode=1,
                           slots_per_worker=1)
        for i, (t, n, m) in enumerate(_stream(reduced_model[0], seed=3, n=5)):
            cluster.submit(ServeRequest(
                f"q{i}", _toks(reduced_model[0], t, n), max_new_tokens=m))
        cluster.run_until_done()
        assert len(cluster.done) == 5
        streams[id(mode)] = _outputs(cluster)
    assert streams[id(FAST)] == streams[id(REFERENCE)]


def test_batching_exact_under_sdpa(reduced_model):
    """Batched prefill is exact under the other decode impl too: batched vs
    sequential streams identical with `_sdpa` decode on both sides."""
    streams = {}
    for mode in (dict(batch_prefill=True, decode_impl="sdpa"),
                 dict(batch_prefill=False, decode_impl="sdpa")):
        cluster = _cluster(reduced_model, mode)
        for i, (t, n, m) in enumerate(_stream(reduced_model[0], seed=4, n=6)):
            cluster.submit(ServeRequest(
                f"f{i}", _toks(reduced_model[0], t, n), max_new_tokens=m))
        cluster.run_until_done()
        streams[mode["batch_prefill"]] = _outputs(cluster)
    assert streams[True] == streams[False]


# ------------------------------------------------------------ paged KV ------
# The paged-KV layout axis is pinned the same two ways as the batching
# axis: `paged_sdpa` (page gather + the exact `_sdpa` math on the dense
# view) must reproduce dense `sdpa` *streams* exactly — any divergence is
# a page-table/adopt/growth bug, never numerics — while the Pallas paged
# kernel is pinned at logits tolerance (its online softmax reassociates
# sums, same as the pallas-vs-sdpa contract above).

PAGED = dict(batch_prefill=True, decode_impl="paged_sdpa")
DENSE = dict(batch_prefill=True, decode_impl="sdpa")


def _paged_accounting_clean(cluster):
    for dec in cluster.decoders:
        assert dec.allocator.audit() == []
        # drained run: every page back on the free list, nothing reserved
        assert dec.allocator.free_pages == dec.allocator.num_pages
        assert dec.allocator.reserved_pages == 0


def test_differential_paged_flood(reduced_model):
    """Flooded stream through page-table-indirected KV vs the dense
    max_len layout: identical token streams per request, and the page
    pool drains back to empty with clean accounting."""
    streams = {}
    for mode in (PAGED, DENSE):
        cluster = _cluster(reduced_model, mode)
        for i, (t, n, m) in enumerate(_stream(reduced_model[0], seed=1, n=8)):
            cluster.submit(ServeRequest(
                f"r{i}", _toks(reduced_model[0], t, n), max_new_tokens=m))
        cluster.run_until_done()
        streams[id(mode)] = _outputs(cluster)
        if mode is PAGED:
            assert all(d.paged for d in cluster.decoders)
            _paged_accounting_clean(cluster)
            assert cluster.pool_utilization        # observable was recorded
    assert len(streams[id(PAGED)]) == 8
    assert streams[id(PAGED)] == streams[id(DENSE)]


def test_differential_paged_staggered(reduced_model):
    """Staggered admissions land mid-decode while earlier slots grow their
    page tables across block boundaries: streams still exact."""
    streams = {}
    for mode in (PAGED, DENSE):
        cluster = _cluster(reduced_model, mode, num_decode=1,
                           slots_per_worker=2)
        specs = _stream(reduced_model[0], seed=2, n=7)
        for i, (t, n, m) in enumerate(specs):
            cluster.submit(ServeRequest(
                f"s{i}", _toks(reduced_model[0], t, n), max_new_tokens=m))
            cluster.step()
            if i % 3 == 0:
                cluster.step()
        cluster.run_until_done()
        streams[id(mode)] = _outputs(cluster)
        if mode is PAGED:
            _paged_accounting_clean(cluster)
    assert len(streams[id(PAGED)]) == 7
    assert streams[id(PAGED)] == streams[id(DENSE)]


def test_differential_paged_tight_pool(reduced_model):
    """A pool smaller than the dense worst case forces page backpressure
    (admissions deferred until releases return pages).  Admission *timing*
    shifts, but per-request streams must not: rows are isolated, so a
    request's tokens depend only on its own prompt."""
    streams = {}
    for mode, pages in ((PAGED, 5), (DENSE, None)):
        cluster = _cluster(reduced_model, mode, num_decode=1,
                           slots_per_worker=2, num_pages=pages)
        for i, (t, n, m) in enumerate(_stream(reduced_model[0], seed=5, n=6)):
            cluster.submit(ServeRequest(
                f"t{i}", _toks(reduced_model[0], t, n), max_new_tokens=m))
        cluster.run_until_done()
        assert len(cluster.done) == 6
        streams[id(mode)] = _outputs(cluster)
        if mode is PAGED:
            dec = cluster.decoders[0]
            # the gate actually bound: 5 pages cannot cover two worst-case
            # requests (each needs ceil(54/16) = 4), so at most one slot
            # was ever page-admitted concurrently
            assert dec.allocator.num_pages == 5
            _paged_accounting_clean(cluster)
    assert streams[id(PAGED)] == streams[id(DENSE)]


def test_paged_kernel_logits_parity(reduced_model):
    """The Pallas paged kernel and dense `_sdpa` agree on step logits at
    every position of a forced decode walk over the same KV state — the
    paged analogue of `test_decode_impl_logits_parity`, at the same
    bf16-propagation bound.  The prompt is sized so the admitted page
    mapping already covers the walk (growth is the engine loop's job and
    is exercised by the stream tests above)."""
    cfg, model, params = reduced_model
    assert model.supports_paged_decode
    pre = PrefillEngine(model, params, max_len=96, cache_entries=0)
    toks = _toks(cfg, 1, 33)              # ceil(34/16)=3 pages ≥ walk end
    logits, caches = pre.prefill(toks)
    tok = int(np.argmax(logits))
    cache_s = caches
    dec = DecodeEngine(model, params, num_slots=1, max_len=96,
                       decode_impl="paged")
    dec.admit(0, "r", caches, tok, prompt_len=len(toks), max_new=10,
              hashes=())
    cache_p = dec.caches
    table = jnp.asarray(dec.page_table)
    for step in range(10):
        cur = jnp.int32(len(toks) + step)
        arr = jnp.full((1, 1), tok, jnp.int32)
        ls, cache_s = model.decode(params, cache_s, arr, cur,
                                   decode_impl="sdpa")
        lp, cache_p = model.decode(params, cache_p, arr, cur,
                                   decode_impl="paged", page_table=table)
        ls, lp = np.asarray(ls), np.asarray(lp)
        spread = float(ls.max() - ls.min())
        assert float(np.abs(lp - ls).max()) < 0.02 * spread, step
        tok = int(np.argmax(ls))


def test_decode_impl_logits_parity(reduced_model):
    """The Pallas ragged decode branch and the XLA `_sdpa` branch agree on
    step logits at every position of a forced decode walk (same cache
    state, same token fed to both).  The bound is bf16-propagation scale:
    the two impls reassociate the softmax sum differently (~1e-7 in f32),
    which rounds to ≤1 bf16 ulp at the attention output and compounds
    through the residual stack — a masking/length bug moves logits by the
    scale of the logit range instead."""
    cfg, model, params = reduced_model
    pre = PrefillEngine(model, params, max_len=96, cache_entries=0)
    toks = _toks(cfg, 1, 41)
    logits, caches = pre.prefill(toks)
    tok = int(np.argmax(logits))
    cache_s = caches
    cache_p = jax.tree.map(jnp.copy, caches)
    for step in range(10):
        cur = jnp.int32(len(toks) + step)
        arr = jnp.full((1, 1), tok, jnp.int32)
        ls, cache_s = model.decode(params, cache_s, arr, cur,
                                   decode_impl="sdpa")
        lp, cache_p = model.decode(params, cache_p, arr, cur,
                                   decode_impl="pallas")
        ls, lp = np.asarray(ls), np.asarray(lp)
        spread = float(ls.max() - ls.min())
        assert float(np.abs(lp - ls).max()) < 0.02 * spread, step
        tok = int(np.argmax(ls))
