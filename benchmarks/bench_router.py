"""Control-plane latency: router decisions/s and PoA-estimator cost — the
paper's constraint is sub-millisecond routing (SGLang/vLLM scheduling
budgets, §1)."""
from __future__ import annotations

import time


from benchmarks.common import emit, save_json
from repro.core.poa import CompletedRequest, PoATracker
from repro.core.router import KvPushRouter, KvRouterConfig
from repro.serving.workload import template_tokens


def run():
    r = KvPushRouter(5, KvRouterConfig(temperature=0.7, overlap_weight=1.0))
    for t in range(5):
        r.on_schedule(t, template_tokens(t), now=0.0)
    toks = [template_tokens(i % 5) for i in range(1000)]
    t0 = time.perf_counter()
    for tk in toks:
        r.best_worker(tk, now=1.0)
    route_us = (time.perf_counter() - t0) / len(toks) * 1e6

    tr = PoATracker(num_workers=5)
    for i in range(128):
        tr.record(CompletedRequest(str(i), i % 5, 1.0, [0.0] * 5,
                                   float(i) * 0.01))
    t0 = time.perf_counter()
    for _ in range(50):
        tr.current_poa()
    poa_us = (time.perf_counter() - t0) / 50 * 1e6

    print(f"\n# Router micro-bench: route={route_us:.1f}us/decision "
          f"({1e6/route_us:,.0f}/s), PoA estimate={poa_us:.0f}us/window")
    emit("bench_router", route_us,
         f"decisions_per_s={1e6/route_us:,.0f};poa_window_us={poa_us:.0f};"
         f"sub_ms={'yes' if route_us < 1000 else 'NO'}")
    save_json("bench_router", dict(route_us=route_us, poa_us=poa_us))
    return route_us, poa_us


if __name__ == "__main__":
    run()
