"""Adaptive vs static routing under a load spike (mini Experiment 3).

Runs the calibrated 70B 1P/5D cluster simulator through the paper's
C = 32 → 128 → 32 spike with both strategies and prints the per-phase
comparison — the controller detects the TRANSITION regime and switches
router parameters per Table 2.

    PYTHONPATH=src python examples/adaptive_serving.py
"""
from repro.serving.simulator import ClusterConfig, Simulator
from repro.serving.workload import WorkloadConfig


def main():
    cluster = ClusterConfig.for_model("llama-3.1-70b", "1P/5D")
    print("cluster:", cluster.name, f"1P/{cluster.num_decode}D",
          f"(prefill ceiling {cluster.prefill_rate} rps)")
    for adaptive in (False, True):
        sim = Simulator(cluster, WorkloadConfig.load_spike(),
                        adaptive=adaptive, seed=1)
        res = sim.run()
        tag = "ADAPTIVE" if adaptive else "STATIC  "
        print(f"\n{tag} — per-phase results")
        for ph, name in [(0, "below"), (1, "saturated"), (2, "recovery")]:
            s = res.phase_stats(ph)
            print(f"  {name:10s} PoA={s.poa:6.2f}  TTFT P99={s.ttft_p99:7.3f}s"
                  f"  ITL P99={s.itl_p99*1000:6.2f}ms  rps={s.rps:5.1f}")
        if res.switch_time is not None:
            print(f"  zero-downtime switch fired at t={res.switch_time:.1f}s")
        # regime timeline
        line = "".join(str(p["regime"]) for p in res.poll_log)
        print(f"  regime timeline (5s polls): {line}")


if __name__ == "__main__":
    main()
