"""RA011 bad: replica-side code reading authoritative control-plane
state outside ``sync()`` — fresh reads smuggled into a supposedly
bounded-staleness view."""


class ReplicaLoadView:
    def __init__(self, plane):
        self._plane = plane
        self.router = plane.router       # stashed live reference

    def healthy_ids(self):
        return self._plane.router.healthy_ids()   # fresh read, not snapshot

    def load_of(self, wid):
        return self.router.workers[wid].active_blocks


class ReplicaRegimeView:
    def __init__(self, plane):
        self._plane = plane

    def regime(self):
        return self._plane.detector.regime        # live detector read

    def overlap(self, plane, tokens, ids, now):
        return plane.indexer.overlap_scores(tokens, ids, now)
