"""RA002 bad: a hashes memo is in scope but hot-path calls drop it."""


def route_request(router, req):
    hashes = tuple(req.hashes)                       # memo bound here
    worker, overlap, _ = router.best_worker(req.tokens, now=0.0)
    router.on_schedule(worker, req.tokens, now=0.0)  # re-hashes again
    return worker, overlap, hashes


def score_overlaps(indexer, req, ids, now):
    hs = req.hashes                                  # memo bound here
    return hs, indexer.overlap_scores(req.tokens, ids, now)
