"""Workload generation (paper Section 7.4) — closed-loop, open-loop, trace.

Three workload modes, selected by ``WorkloadConfig.mode``:

``closed``
    The paper's short-chat profile: 5 prompt templates × 128 input tokens,
    256 max output tokens, deterministic generation.  Closed-loop clients
    hold a target concurrency via a semaphore; each phase has a linear ramp
    then a hold.

``open``
    Open-loop arrival processes decoupled from service completions: Poisson
    (stationary rate), bursty on/off (MMPP-style two-rate switching), and a
    diurnal sinusoid (nonhomogeneous Poisson via thinning).  These are the
    non-stationary traffic shapes the scenario registry exercises — under
    open-loop arrivals saturation is an input property, not an emergent one.

``trace``
    Replay of a recorded request trace.  The JSONL schema is one object per
    line with fields::

        {"t": <arrival time, s>,            # required, non-decreasing
         "template": <int>,                 # optional, default 0
         "input_tokens": <int>,             # optional, default workload's
         "output_tokens": <int>}            # optional, default workload's

    Load a file with :meth:`WorkloadConfig.from_trace_file` or build one
    in-memory with :meth:`WorkloadConfig.from_records`.

All modes are deterministic given the simulator seed: open-loop arrival
times are drawn from a dedicated generator so closed-loop runs are
byte-identical to the pre-scenario-subsystem simulator.
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

NUM_TEMPLATES = 5
INPUT_TOKENS = 128
OUTPUT_TOKENS = 256

# The paper's short-chat template popularity (mildly skewed — what lets
# cache-affinity herding concentrate load).  Shared by both backends so the
# analytic simulator and the engine cluster sample identical template
# streams from identical seeds.
TEMPLATE_POPULARITY = (0.35, 0.25, 0.20, 0.12, 0.08)


def template_mix(num_templates: int) -> Tuple[float, ...]:
    """Template popularity distribution for a ``num_templates``-wide mix.

    The legacy 5-template mix verbatim (identity path, keeps pre-scenario
    runs bit-exact), or a Zipf(0.9) skew when the workload asks for a wider
    template universe (cache-pressure scenarios grow the working set past
    G1 this way)."""
    if num_templates == len(TEMPLATE_POPULARITY):
        return TEMPLATE_POPULARITY
    w = [1.0 / (i + 1) ** 0.9 for i in range(num_templates)]
    tot = sum(w)
    return tuple(x / tot for x in w)


def template_tokens(template_id: int, n_tokens: int = INPUT_TOKENS) -> List[int]:
    """Deterministic token ids per template (shared prefixes per template)."""
    base = template_id * 100_000
    return [base + i for i in range(n_tokens)]


@dataclass(frozen=True)
class Phase:
    target_concurrency: int
    ramp_s: float
    hold_s: float


@dataclass(frozen=True)
class ArrivalProcess:
    """Open-loop arrival process spec.

    ``poisson``  — homogeneous Poisson at ``rate`` req/s.
    ``burst``    — on/off switching: ``burst_rate`` during ``on_s``-long
                   bursts, ``rate`` during ``off_s``-long quiet periods.
    ``diurnal``  — nonhomogeneous Poisson with intensity
                   rate·(1 + amplitude·sin(2πt/period_s)), sampled by
                   thinning against the peak rate.
    """
    kind: str = "poisson"          # poisson | burst | diurnal
    rate: float = 10.0             # baseline arrival rate (req/s)
    burst_rate: float = 40.0       # on-phase rate for kind="burst"
    on_s: float = 10.0             # burst duration
    off_s: float = 30.0            # quiet duration
    period_s: float = 120.0        # diurnal period
    amplitude: float = 0.8         # diurnal modulation depth, in [0, 1)

    def times(self, duration_s: float, rng: np.random.Generator) -> List[float]:
        """Arrival times in [0, duration_s), deterministic given ``rng``."""
        if self.kind == "poisson":
            return self._homogeneous(self.rate, 0.0, duration_s, rng)
        if self.kind == "burst":
            out: List[float] = []
            t = 0.0
            while t < duration_s:
                end_on = min(t + self.on_s, duration_s)
                out.extend(self._homogeneous(self.burst_rate, t, end_on, rng))
                t = end_on
                end_off = min(t + self.off_s, duration_s)
                out.extend(self._homogeneous(self.rate, t, end_off, rng))
                t = end_off
            return out
        if self.kind == "diurnal":
            peak = self.rate * (1.0 + self.amplitude)
            cand = self._homogeneous(peak, 0.0, duration_s, rng)
            out = []
            for t in cand:
                lam = self.rate * (1.0 + self.amplitude
                                   * math.sin(2.0 * math.pi * t / self.period_s))
                if rng.random() * peak <= lam:
                    out.append(t)
            return out
        raise ValueError(f"unknown arrival kind {self.kind!r}")

    @staticmethod
    def _homogeneous(rate: float, t0: float, t1: float,
                     rng: np.random.Generator) -> List[float]:
        if rate <= 0.0 or t1 <= t0:
            return []
        out = []
        t = t0
        while True:
            t += float(rng.exponential(1.0 / rate))
            if t >= t1:
                return out
            out.append(t)


@dataclass(frozen=True)
class TraceEntry:
    """One replayed request. ``template < 0`` means sample from popularity."""
    t: float
    template: int = 0
    input_tokens: int = INPUT_TOKENS
    output_tokens: int = OUTPUT_TOKENS


@dataclass(frozen=True)
class WorkloadConfig:
    phases: Tuple[Phase, ...] = ()
    input_tokens: int = INPUT_TOKENS
    output_tokens: int = OUTPUT_TOKENS
    num_templates: int = NUM_TEMPLATES
    mode: str = "closed"                       # closed | open | trace
    arrival: Optional[ArrivalProcess] = None   # mode="open"
    duration_s: float = 0.0                    # mode="open"
    trace: Tuple[TraceEntry, ...] = ()         # mode="trace"

    # ------------------------------------------------------ constructors ----

    @classmethod
    def single_level(cls, concurrency: int, hold_s: float = 120.0,
                     ramp_s: float = 30.0) -> "WorkloadConfig":
        return cls(phases=(Phase(concurrency, ramp_s, hold_s),))

    @classmethod
    def load_spike(cls, low: int = 32, high: int = 128,
                   durations=(120.0, 180.0, 120.0)) -> "WorkloadConfig":
        """Experiment 3: C = low → high → low."""
        return cls(phases=(Phase(low, 10.0, durations[0]),
                           Phase(high, 10.0, durations[1]),
                           Phase(low, 0.0, durations[2])))

    @classmethod
    def open_loop(cls, arrival: ArrivalProcess, duration_s: float,
                  **kw) -> "WorkloadConfig":
        return cls(mode="open", arrival=arrival, duration_s=duration_s, **kw)

    @classmethod
    def poisson(cls, rate: float, duration_s: float, **kw) -> "WorkloadConfig":
        return cls.open_loop(ArrivalProcess("poisson", rate=rate),
                             duration_s, **kw)

    @classmethod
    def bursty(cls, rate: float, burst_rate: float, duration_s: float,
               on_s: float = 10.0, off_s: float = 30.0, **kw) -> "WorkloadConfig":
        return cls.open_loop(
            ArrivalProcess("burst", rate=rate, burst_rate=burst_rate,
                           on_s=on_s, off_s=off_s), duration_s, **kw)

    @classmethod
    def diurnal(cls, rate: float, duration_s: float, period_s: float = 120.0,
                amplitude: float = 0.8, **kw) -> "WorkloadConfig":
        return cls.open_loop(
            ArrivalProcess("diurnal", rate=rate, period_s=period_s,
                           amplitude=amplitude), duration_s, **kw)

    @staticmethod
    def _validate_record(r, where: str) -> None:
        """One trace record against the JSONL schema; raises ValueError
        naming the offending record (index, or file:line when loaded from
        disk) and the exact field that is malformed."""
        if not isinstance(r, dict):
            raise ValueError(f"trace {where}: expected an object with an "
                             f"arrival time 't', got {type(r).__name__}: "
                             f"{r!r}")
        if "t" not in r:
            raise ValueError(f"trace {where}: missing required field 't' "
                             f"(arrival time in seconds); got fields "
                             f"{sorted(r)}")
        t = r["t"]
        if isinstance(t, bool) or not isinstance(t, (int, float)):
            raise ValueError(f"trace {where}: 't' must be a number "
                             f"(seconds), got {t!r}")
        if not math.isfinite(float(t)) or float(t) < 0.0:
            raise ValueError(f"trace {where}: 't' must be finite and "
                             f">= 0, got {t!r}")
        tpl = r.get("template", 0)
        if isinstance(tpl, bool) or not isinstance(tpl, int):
            raise ValueError(f"trace {where}: 'template' must be an "
                             f"integer id (< 0 samples from popularity), "
                             f"got {tpl!r}")
        for key in ("input_tokens", "output_tokens"):
            if key not in r:
                continue
            v = r[key]
            ok = (not isinstance(v, bool)
                  and isinstance(v, (int, float))
                  and float(v).is_integer() and v > 0)
            if not ok:
                raise ValueError(f"trace {where}: '{key}' must be a "
                                 f"positive integer token count, got "
                                 f"{v!r}")

    @classmethod
    def from_records(cls, records: Sequence[dict],
                     _context: Optional[Sequence[str]] = None,
                     **kw) -> "WorkloadConfig":
        """Build a trace workload from dicts following the JSONL schema.
        Every record is validated first — a malformed entry raises
        :class:`ValueError` naming the record and field, instead of a
        KeyError/TypeError from deep inside the simulator."""
        defaults = dict(input_tokens=kw.get("input_tokens", INPUT_TOKENS),
                        output_tokens=kw.get("output_tokens", OUTPUT_TOKENS))
        entries = []
        for i, r in enumerate(records):
            where = _context[i] if _context is not None else f"record {i}"
            cls._validate_record(r, where)
            entries.append(
                TraceEntry(t=float(r["t"]),
                           template=int(r.get("template", 0)),
                           input_tokens=int(r.get("input_tokens",
                                                  defaults["input_tokens"])),
                           output_tokens=int(r.get("output_tokens",
                                                   defaults["output_tokens"]))))
        return cls(mode="trace",
                   trace=tuple(sorted(entries, key=lambda e: e.t)), **kw)

    @classmethod
    def from_trace_file(cls, path, **kw) -> "WorkloadConfig":
        """Load a JSONL trace (see module docstring for the schema).
        Parse and schema errors carry ``path:line`` context."""
        records: List[dict] = []
        context: List[str] = []
        with open(path) as f:
            for ln, line in enumerate(f, 1):
                line = line.strip()
                if not line or line.startswith("#"):
                    continue
                try:
                    records.append(json.loads(line))
                except json.JSONDecodeError as e:
                    raise ValueError(f"trace {path}:{ln}: invalid JSON "
                                     f"({e.msg} at column {e.colno})") from e
                context.append(f"{path}:{ln}")
        return cls.from_records(records, _context=context, **kw)

    # ----------------------------------------------------------- queries ----

    def total_duration(self) -> float:
        if self.mode == "open":
            return self.duration_s
        if self.mode == "trace":
            return self.trace[-1].t if self.trace else 0.0
        return sum(p.ramp_s + p.hold_s for p in self.phases)

    def concurrency_at(self, t: float) -> int:
        """Target concurrency at absolute time t (linear ramps).

        Open-loop and trace modes have no concurrency target (arrivals do
        not wait for completions) — returns 0 so the closed-loop client
        never submits.
        """
        if self.mode != "closed":
            return 0
        t0 = 0.0
        prev = 0
        for p in self.phases:
            if t < t0 + p.ramp_s:
                frac = (t - t0) / max(p.ramp_s, 1e-9)
                return max(1, int(round(prev + frac * (p.target_concurrency - prev))))
            t0 += p.ramp_s
            if t < t0 + p.hold_s:
                return p.target_concurrency
            t0 += p.hold_s
            prev = p.target_concurrency
        return 0

    def phase_of(self, t: float):
        """Index of the phase active at time t (ramp attributed to its phase).
        Open-loop/trace workloads are single-phase (index 0)."""
        if self.mode != "closed" or not self.phases:
            return 0
        t0 = 0.0
        for i, p in enumerate(self.phases):
            t0 += p.ramp_s + p.hold_s
            if t < t0:
                return i
        return len(self.phases) - 1

    def arrivals(self, rng: np.random.Generator) -> List[TraceEntry]:
        """Materialized arrival list for open/trace modes ([] for closed).

        Open-loop entries carry ``template=-1`` — the simulator samples the
        template from its popularity distribution at arrival time, matching
        closed-loop template statistics.
        """
        if self.mode == "trace":
            return list(self.trace)
        if self.mode == "open":
            assert self.arrival is not None, "open mode needs an arrival spec"
            return [TraceEntry(t=t, template=-1,
                               input_tokens=self.input_tokens,
                               output_tokens=self.output_tokens)
                    for t in self.arrival.times(self.duration_s, rng)]
        return []
