"""Radix (prefix) tree over token-block hashes — the KvIndexer.

Tracks which KV cache blocks reside on which workers so the Smart Router can
compute per-worker overlap scores (the positive externality of Game 3).
Blocks are fixed-size token runs; a sequence maps to the list of hashes of
its prefixes, so shared prompt prefixes share leading blocks exactly like
Dynamo's global radix tree.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

BLOCK_SIZE = 16  # tokens per KV block (vLLM/Dynamo default granularity)


def block_hashes(tokens: Sequence[int], block_size: int = BLOCK_SIZE) -> List[int]:
    """Prefix-chained block hashes: hash_i = H(hash_{i-1}, block_i_tokens)."""
    out: List[int] = []
    h = 0
    n_full = len(tokens) // block_size
    for i in range(n_full):
        blk = tuple(tokens[i * block_size:(i + 1) * block_size])
        h = hash((h,) + blk)
        out.append(h)
    return out


@dataclass
class _Node:
    children: Dict[int, "_Node"] = field(default_factory=dict)
    workers: Dict[int, float] = field(default_factory=dict)  # worker → last touch


class KvIndexer:
    """Prefix tree: path = chained block hashes; each node records which
    workers hold that block and when they last touched it.

    ``ttl`` models cache churn: a worker's claim on a block expires if not
    refreshed within ttl seconds (vLLM-style LRU recycling of KV blocks).
    ``ttl=None`` disables expiry (blocks live forever)."""

    def __init__(self, block_size: int = BLOCK_SIZE,
                 ttl: Optional[float] = None):
        self.block_size = block_size
        self.ttl = ttl
        self.root = _Node()
        self._worker_blocks: Dict[int, Set[Tuple[int, ...]]] = {}
        # Chained hashes are prefix-unique (hash_i commits to the whole
        # prefix), so each hash identifies exactly one tree node/path —
        # the lookup tables single-block invalidation needs.
        self._node_by_hash: Dict[int, _Node] = {}
        self._path_by_hash: Dict[int, Tuple[int, ...]] = {}

    def _fresh(self, node: _Node, worker: int, now: float) -> bool:
        t = node.workers.get(worker)
        if t is None:
            return False
        return self.ttl is None or (now - t) <= self.ttl

    # ------------------------------------------------------------ update ----

    def insert(self, worker: int, tokens: Sequence[int], now: float = 0.0):
        hs = block_hashes(tokens, self.block_size)
        node = self.root
        path: List[int] = []
        for h in hs:
            node = node.children.setdefault(h, _Node())
            node.workers[worker] = now
            path.append(h)
            self._worker_blocks.setdefault(worker, set()).add(tuple(path))
            self._node_by_hash[h] = node
            self._path_by_hash[h] = tuple(path)

    def remove_worker_block(self, worker: int, block_hash: int):
        """Tier-coherence invalidation: drop ``worker``'s claim on one
        block (identified by its chained hash, e.g. on a KVBM demotion
        out of G1).  Because overlap scoring walks from the root and stops
        at the first unclaimed node, removing a mid-chain claim truncates
        the credited prefix right before this block."""
        node = self._node_by_hash.get(block_hash)
        if node is None:
            return
        node.workers.pop(worker, None)
        wb = self._worker_blocks.get(worker)
        if wb is not None:
            # Drop this block's path and every deeper path running through
            # it — those claims are no longer reachable from the root, so
            # num_blocks() must not count them.
            prefix = self._path_by_hash.get(block_hash, ())
            k = len(prefix)
            wb.difference_update(
                {p for p in wb if p[:k] == prefix})

    def remove_worker_blocks(self, worker: int, tokens: Sequence[int]):
        """Eviction event: drop this worker from every block of the sequence."""
        hs = block_hashes(tokens, self.block_size)
        node = self.root
        path: List[int] = []
        for h in hs:
            node = node.children.get(h)
            if node is None:
                return
            node.workers.pop(worker, None)
            path.append(h)
            wb = self._worker_blocks.get(worker)
            if wb is not None:
                wb.discard(tuple(path))

    def clear_worker(self, worker: int):
        def walk(node):
            node.workers.pop(worker, None)
            for ch in node.children.values():
                walk(ch)
        walk(self.root)
        self._worker_blocks.pop(worker, None)

    # ------------------------------------------------------------- query ----

    def matched_blocks(self, worker: int, tokens: Sequence[int],
                       now: float = 0.0) -> int:
        """Longest fresh prefix (in blocks) of `tokens` cached on `worker`."""
        hs = block_hashes(tokens, self.block_size)
        node = self.root
        n = 0
        for h in hs:
            node = node.children.get(h)
            if node is None or not self._fresh(node, worker, now):
                break
            n += 1
        return n

    def overlap_scores(self, tokens: Sequence[int], workers: Sequence[int],
                       now: float = 0.0):
        """o_ij ∈ [0,1]: fresh matched-prefix fraction per worker (Eq. 7)."""
        hs = block_hashes(tokens, self.block_size)
        total = max(len(hs), 1)
        out = []
        for w in workers:
            node = self.root
            n = 0
            for h in hs:
                node = node.children.get(h)
                if node is None or not self._fresh(node, w, now):
                    break
                n += 1
            out.append(n / total)
        return out

    def num_blocks(self, worker: int) -> int:
        return len(self._worker_blocks.get(worker, ()))
