"""Engine fast-path throughput — batched prefill and ragged decode.

Measures, on the reduced CPU-testable models the engine backend runs:

* **prefill tokens/s** at queue depth ≥ 4: one bucketed ragged
  ``prefill_many`` pass over the queue vs the sequential batch-1 loop it
  replaced.  Two queue shapes: the *gated* point is a deep queue of
  one-block prompts — the regime batching exists for, where the ~ms
  fixed dispatch cost of a batch-1 XLA pass rivals its compute and the
  batched pass amortizes it across the queue (CI gate: ≥ 2x) — plus an
  informational point at the parity-scenario scale (48-token prompts),
  where per-token compute dominates on CPU and the win is smaller.
* **decode tokens/s/slot** for both cached-attention implementations
  (``pallas`` ragged kernel — interpret mode on CPU, compiled on TPU —
  and the XLA ``_sdpa`` path), at full slot occupancy.
* **batch-occupancy histogram** of a flood run: per-tick active-slot
  totals from ``DisaggregatedCluster.occupancy`` — how full the
  continuous-batching slots actually run under backpressure.

Output: CSV rows on stdout + ``reports/benchmarks/BENCH_engine.json``.
``--check BASELINE`` enforces the ≥ 2x batched-prefill gate and fails on
>2x regressions of the ratio/rate metrics vs the committed baseline
(machine-robust: the primary gates are same-machine ratios, not absolute
rates).

    PYTHONPATH=src python -m benchmarks.bench_engine_throughput \
        [--smoke] [--check FILE]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, save_json
from repro.serving.disagg import DisaggregatedCluster, ServeRequest
from repro.serving.engine import DecodeEngine, PrefillEngine
from repro.serving.workload import template_tokens

MODEL_NAME = "phi4-mini-3.8b"
MAX_LEN = 96
MIN_PREFILL_SPEEDUP = 2.0      # ISSUE gate: batched ≥ 2x at depth ≥ 4


def _build_model():
    from repro.configs import get_reduced
    from repro.models import build_model
    cfg = get_reduced(MODEL_NAME)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), jnp.bfloat16)
    return cfg, model, params


def _queue(cfg, depth: int, lo: int, hi: int):
    """depth distinct prompts with lengths ramping lo..hi inside one
    padded bucket, so the batched pass exercises real ragged padding."""
    out = []
    for i in range(depth):
        n = lo + ((hi - lo) * i) // max(depth - 1, 1)
        toks = [t % cfg.vocab_size for t in template_tokens(i, n)]
        out.append(toks)
    return out


def _best_of(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _prefill_point(model, params, cfg, label: str, depth: int,
                   lo: int, hi: int, repeats: int) -> dict:
    """Batched vs sequential prompt passes over one queue of ``depth``
    requests.  Prefix cache off: every repeat measures cold compute."""
    prompts = _queue(cfg, depth, lo, hi)
    tokens = sum(len(p) for p in prompts)
    eng = PrefillEngine(model, params, max_len=MAX_LEN, cache_entries=0,
                        max_batch=depth)
    lengths = sorted(set(len(p) for p in prompts))
    eng.warmup(lengths, batch_sizes=[1, depth])

    def batched():
        eng.prefill_many([(p, None, None) for p in prompts])

    def sequential():
        for p in prompts:
            eng.prefill(p)

    batched()                      # shake out any remaining first-call cost
    sequential()
    wall_b = _best_of(batched, repeats)
    wall_s = _best_of(sequential, repeats)
    out = {
        "depth": depth,
        "prompt_lengths": [lo, hi],
        "prompt_tokens": tokens,
        "batched_tokens_per_s": tokens / wall_b,
        "sequential_tokens_per_s": tokens / wall_s,
        "batched_speedup": wall_s / wall_b,
        "batches": eng.stats.batches,
        "padded_tokens": eng.stats.padded_tokens,
    }
    emit(f"bench_engine_prefill_{label}", wall_b / depth * 1e6,
         f"depth={depth};lens={lo}..{hi};"
         f"tok_per_s_batched={out['batched_tokens_per_s']:,.0f};"
         f"tok_per_s_seq={out['sequential_tokens_per_s']:,.0f};"
         f"speedup={out['batched_speedup']:.2f}x")
    return out


def bench_prefill(model, params, cfg, smoke: bool) -> dict:
    """The gated point batches one-block prompts (the dispatch-bound
    regime) at depth 16; full runs add the parity-scenario scale
    (48-token, compute-bound on CPU) as an ungated reference."""
    repeats = 3 if smoke else 5
    out = {"gated": _prefill_point(model, params, cfg, "short_d16",
                                   depth=16, lo=12, hi=16,
                                   repeats=repeats)}
    out["batched_speedup"] = out["gated"]["batched_speedup"]
    if not smoke:
        out["parity_scale"] = _prefill_point(model, params, cfg,
                                             "parity_d8", depth=8,
                                             lo=33, hi=48, repeats=repeats)
    return out


def bench_decode(model, params, cfg, steps: int) -> dict:
    """Decode tokens/s/slot at full occupancy, per attention impl.  The
    Pallas kernel runs in interpret mode on CPU — its absolute rate here
    is an interpreter artifact (compiled path is TPU); the `_sdpa` row is
    the CPU-meaningful rate."""
    slots = 4
    prompts = _queue(cfg, slots, 33, 48)
    pre = PrefillEngine(model, params, max_len=MAX_LEN, cache_entries=0)
    bundles = []
    for p in prompts:
        logits, caches = pre.prefill(p)
        bundles.append((p, int(logits.argmax()), caches))
    out = {}
    for impl in ("sdpa", "pallas"):
        dec = DecodeEngine(model, params, num_slots=slots, max_len=MAX_LEN,
                           decode_impl=impl)
        dec.warmup()
        for i, (p, first, caches) in enumerate(bundles):
            dec.admit(i, f"d{i}", caches, first, prompt_len=len(p),
                      max_new=MAX_LEN, hashes=())
        dec.step()                 # first stepped shape compiles here
        t0 = time.perf_counter()
        for _ in range(steps):
            n = len(dec.step())
            assert n == slots      # nobody finishes inside the window
        wall = time.perf_counter() - t0
        out[impl] = {"tokens_per_s_per_slot": steps / wall,
                     "tokens_per_s": steps * slots / wall}
        emit(f"bench_engine_decode_{impl}", wall / steps / slots * 1e6,
             f"slots={slots};tok_per_s_per_slot="
             f"{out[impl]['tokens_per_s_per_slot']:,.1f}")
    return out


def bench_occupancy(model, params, cfg, n_requests: int) -> dict:
    """Flood a 2-worker × 2-slot cluster and histogram the per-tick total
    active slots: how full continuous batching runs under backpressure."""
    cluster = DisaggregatedCluster(model, params, num_decode=2,
                                   slots_per_worker=2, max_len=MAX_LEN,
                                   adaptive=False)
    for i in range(n_requests):
        n = 33 + (15 * i) // max(n_requests - 1, 1)
        toks = [t % cfg.vocab_size for t in template_tokens(i % 8, n)]
        cluster.submit(ServeRequest(f"o{i}", toks, max_new_tokens=4))
    t0 = time.perf_counter()
    cluster.run_until_done()
    wall = time.perf_counter() - t0
    totals = [sum(occ) for occ in cluster.occupancy]
    hist = {}
    for t in totals:
        hist[str(t)] = hist.get(str(t), 0) + 1
    capacity = 4
    busy = [t for t in totals if t > 0]
    out = {
        "requests": n_requests,
        "wall_s": wall,
        "ticks": len(totals),
        "histogram": dict(sorted(hist.items())),
        "mean_active_slots": sum(totals) / max(len(totals), 1),
        "mean_busy_fill": (sum(busy) / len(busy) / capacity) if busy else 0.0,
        "prefill_batches": cluster.prefill.stats.batches,
        "prefill_batched_requests": cluster.prefill.stats.batched_requests,
    }
    emit("bench_engine_occupancy", wall / max(n_requests, 1) * 1e6,
         f"requests={n_requests};mean_active={out['mean_active_slots']:.2f};"
         f"busy_fill={out['mean_busy_fill']:.2f};"
         f"batched_requests={out['prefill_batched_requests']}")
    return out


def _flatten(payload: dict, prefix: str = "") -> dict:
    flat = {}
    for k, v in payload.items():
        key = f"{prefix}{k}"
        if isinstance(v, dict):
            flat.update(_flatten(v, f"{key}."))
        elif isinstance(v, (int, float)):
            flat[key] = float(v)
    return flat


def check_regression(payload: dict, baseline_path: str,
                     factor: float = 2.0) -> list:
    """Hard gate: batched prefill ≥ MIN_PREFILL_SPEEDUP (same-machine
    ratio, robust to runner speed).  Baseline gates: ratio and rate
    metrics may not be ``factor``× lower than the committed baseline;
    occupancy/counters are informational."""
    failures = []
    speedup = payload["prefill"]["batched_speedup"]
    if speedup < MIN_PREFILL_SPEEDUP:
        failures.append(f"prefill.batched_speedup: {speedup:.2f} < "
                        f"required {MIN_PREFILL_SPEEDUP}x")
    with open(baseline_path) as f:
        base = _flatten(json.load(f))
    cur = _flatten(payload)
    for key, ref in base.items():
        if key not in cur or ref <= 0:
            continue
        leaf = key.rsplit(".", 1)[-1]
        if leaf.startswith(("batched_speedup", "tokens_per_s",
                            "tokens_per_s_per_slot",
                            "batched_tokens_per_s",
                            "sequential_tokens_per_s", "mean_busy_fill")):
            if cur[key] < ref / factor:
                failures.append(f"{key}: {cur[key]:.2f} < baseline "
                                f"{ref:.2f} / {factor}")
    return failures


def run(smoke: bool = False) -> dict:
    cfg, model, params = _build_model()
    payload = {
        "mode": "smoke" if smoke else "full",
        "model": MODEL_NAME,
        "prefill": bench_prefill(model, params, cfg, smoke=smoke),
        "decode": bench_decode(model, params, cfg,
                               steps=8 if smoke else 32),
        "occupancy": bench_occupancy(model, params, cfg,
                                     n_requests=8 if smoke else 16),
    }
    save_json("BENCH_engine", payload)
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced depths/steps (CI guard, not a "
                         "measurement)")
    ap.add_argument("--check", default=None, metavar="BASELINE",
                    help="enforce the 2x prefill gate and fail on >2x "
                         "regression vs this baseline JSON")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    payload = run(smoke=args.smoke)
    if args.check:
        failures = check_regression(payload, args.check)
        if failures:
            print("REGRESSION vs baseline:", file=sys.stderr)
            for f in failures:
                print(f"  {f}", file=sys.stderr)
            sys.exit(1)
        print(f"# regression check vs {args.check}: ok", file=sys.stderr)


if __name__ == "__main__":
    main()
