"""Training driver: jitted (optionally sharded) train step with gradient
accumulation, checkpoint/restart, and fault-tolerance hooks.
"""
from __future__ import annotations

import contextlib
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.model import Model, build_model
from repro.training import checkpoint as ckpt_lib
from repro.training import optimizer as opt_lib
from repro.training.data import DataConfig, make_batch


@dataclass
class TrainConfig:
    opt: opt_lib.OptimizerConfig = field(default_factory=opt_lib.OptimizerConfig)
    grad_accum: int = 1
    remat: bool = True
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10


def make_train_step(model: Model, cfg: TrainConfig):
    def train_step(state, batch):
        def loss_fn(p):
            return model.train_loss(p, batch, remat=cfg.remat)

        if cfg.grad_accum <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(state["params"])
        else:
            # microbatch accumulation along the batch axis
            def mb(i, carry):
                acc_loss, acc_grads = carry
                mb_batch = jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * (x.shape[0] // cfg.grad_accum),
                        x.shape[0] // cfg.grad_accum, 0), batch)
                l, g = jax.value_and_grad(
                    lambda p: model.train_loss(p, mb_batch, remat=cfg.remat)
                )(state["params"])
                return (acc_loss + l,
                        jax.tree.map(jnp.add, acc_grads, g))
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 state["params"])
            loss, grads = jax.lax.fori_loop(
                0, cfg.grad_accum, mb, (jnp.zeros((), jnp.float32), zeros))
            loss = loss / cfg.grad_accum
            grads = jax.tree.map(lambda g: g / cfg.grad_accum, grads)
        new_p, new_opt, stats = opt_lib.update(
            cfg.opt, state["params"], grads, state["opt"])
        stats = dict(stats, loss=loss)
        return {"params": new_p, "opt": new_opt}, stats

    return jax.jit(train_step, donate_argnums=0)


class Trainer:
    def __init__(self, model_cfg: ModelConfig, shape: ShapeConfig,
                 cfg: Optional[TrainConfig] = None, seed: int = 0):
        self.model_cfg = model_cfg
        self.shape = shape
        self.cfg = cfg or TrainConfig()
        self.model = build_model(model_cfg)
        params = self.model.init(jax.random.PRNGKey(seed), jnp.float32)
        self.state = {"params": params, "opt": opt_lib.init(params)}
        self.step_fn = make_train_step(self.model, self.cfg)
        self.step = 0
        self.history: list = []
        self.data_cfg = DataConfig(vocab_size=model_cfg.vocab_size,
                                   seq_len=shape.seq_len,
                                   global_batch=shape.global_batch, seed=seed)
        if self.cfg.ckpt_dir:
            with contextlib.suppress(FileNotFoundError):
                self.state, self.step = ckpt_lib.restore(
                    self.cfg.ckpt_dir, self.state)
                print(f"restored checkpoint at step {self.step}")

    def run(self, num_steps: int, log: Optional[Callable[[dict], None]] = None):
        for _ in range(num_steps):
            batch = make_batch(self.data_cfg, self.step)
            t0 = time.time()
            self.state, stats = self.step_fn(self.state, batch)
            stats = {k: float(v) for k, v in stats.items()}
            stats.update(step=self.step, step_time=time.time() - t0)
            self.history.append(stats)
            if log and self.step % self.cfg.log_every == 0:
                log(stats)
            self.step += 1
            if (self.cfg.ckpt_dir and self.step % self.cfg.ckpt_every == 0):
                ckpt_lib.save(self.cfg.ckpt_dir, self.step, self.state)
        return self.history
