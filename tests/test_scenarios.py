"""Scenario subsystem: registry smoke tests, invariants, determinism.

Every registered scenario must run green for a short horizon with the
core invariants intact (all requests complete, per-worker occupancy never
exceeds that worker's admission cap, PoA-hat finite once the Eq. 12
window fills).  Determinism is a regression guard for the event-loop
refactor: the same seed must reproduce SimResult.overall() exactly, for
both homogeneous and heterogeneous clusters.
"""
import dataclasses
import json
import math

import numpy as np
import pytest

from repro.serving.scenarios import (build_simulator, example_trace_records,
                                     get_scenario, list_scenarios)
from repro.serving.simulator import ClusterConfig, DecodeWorkerSpec, Simulator
from repro.serving.workload import (ArrivalProcess, TraceEntry,
                                    WorkloadConfig)

ALL_SCENARIOS = list_scenarios()


def test_registry_covers_required_axes():
    assert len(ALL_SCENARIOS) >= 8
    scenarios = {n: get_scenario(n, fast=True) for n in ALL_SCENARIOS}
    modes = {s.workload.mode for s in scenarios.values()}
    assert modes == {"closed", "open", "trace"}
    kinds = {s.workload.arrival.kind for s in scenarios.values()
             if s.workload.arrival is not None}
    assert {"poisson", "burst", "diurnal"} <= kinds
    hetero = [s for s in scenarios.values() if s.cluster.decode_workers]
    assert hetero, "registry must include a heterogeneous decode pool"
    pooled_prefill = [s for s in scenarios.values()
                      if s.cluster.num_prefill > 1]
    assert pooled_prefill, "registry must include a multi-prefill cluster"


@pytest.mark.parametrize("name", ALL_SCENARIOS)
def test_scenario_smoke_invariants(name):
    sim = build_simulator(name, seed=0, fast=True)
    res = sim.run()

    # all submitted requests completed (the drain margin covers the tail)
    assert sim.in_flight == 0
    assert len(res.completed) > 0

    # per-worker decode occupancy never exceeded that worker's cap
    for w, spec in enumerate(sim.specs):
        assert sim.peak_decode_running[w] <= spec.decode_cap, (
            f"worker {w} peaked at {sim.peak_decode_running[w]} "
            f"> cap {spec.decode_cap}")

    # PoA-hat is finite on every poll whose Eq. 12 window has filled
    filled = [p for p in res.poll_log
              if p["poa_n"] >= 0.8 * sim.poa.window_count]
    for p in filled:
        assert math.isfinite(p["poa"]) and p["poa"] > 0.0

    # basic latency sanity
    for r in res.completed:
        assert r.finish_t >= r.decode_start >= r.submit_t
        assert r.ttft >= 0.0


@pytest.mark.parametrize("name", ["70b-1p2d-ramp", "hetero-decode-burst"])
def test_determinism_same_seed_identical_results(name):
    """Same seed → bit-identical overall() tuple (homogeneous closed-loop
    and heterogeneous open-loop), guarding the event-loop refactor."""
    a = build_simulator(name, seed=7, fast=True).run()
    b = build_simulator(name, seed=7, fast=True).run()
    assert dataclasses.astuple(a.overall()) == dataclasses.astuple(b.overall())
    assert [r.rid for r in a.completed] == [r.rid for r in b.completed]
    assert [r.decode_worker for r in a.completed] == \
        [r.decode_worker for r in b.completed]
    c = build_simulator(name, seed=8, fast=True).run()
    assert dataclasses.astuple(a.overall()) != dataclasses.astuple(c.overall())


def test_hetero_cluster_resolves_specs():
    pool = (DecodeWorkerSpec(decode_cap=40), DecodeWorkerSpec(decode_cap=10))
    cfg = ClusterConfig(name="x", num_decode=5, decode_workers=pool)
    assert cfg.num_decode == 2                 # pinned to the pool length
    assert cfg.worker_specs == pool
    homo = ClusterConfig.for_model("llama-3.1-70b", "1P/2D")
    assert len(homo.worker_specs) == 2
    assert homo.worker_specs[0].decode_cap == homo.decode_cap


def test_hetero_routing_respects_capacity_shares():
    """Under sustained load, a worker with 2× the capacity should absorb
    clearly more requests than each small worker (capacity-normalized
    load in Eq. 1), and small workers must still get traffic."""
    sim = build_simulator("hetero-decode-mixed", seed=0, fast=True,
                          concurrency=96)
    res = sim.run()
    per_worker = np.bincount([r.decode_worker for r in res.completed],
                             minlength=sim.cluster.num_decode)
    assert per_worker.min() > 0
    big, small = per_worker[0], per_worker[1:].max()
    assert big > small


def test_topology_parses_prefill_pool():
    cfg = ClusterConfig.for_model("llama-3.1-70b", "2P/4D")
    assert cfg.num_prefill == 2 and cfg.num_decode == 4
    lower = ClusterConfig.for_model("llama-3.1-70b", "1p/2d")
    assert lower.num_prefill == 1 and lower.num_decode == 2


@pytest.mark.parametrize("bad", ["1P5D", "1p/", "P/D", "2D/1P", "1P/2D/3D",
                                 "", "0P/2D", "1P/0D", "x1P/2D"])
def test_topology_rejects_malformed_strings(bad):
    """`for_model` used to silently mis-parse these (e.g. "1P5D" →
    int("1P5D".rstrip("Pp")) crash with an unrelated message)."""
    with pytest.raises(ValueError, match="topology"):
        ClusterConfig.for_model("llama-3.1-70b", bad)


def test_registry_includes_elastic_pools():
    """Game 1 axis: the elastic family carries a planner_config and spans
    closed-loop and open-loop workloads."""
    elastic = {n: get_scenario(n, fast=True) for n in ALL_SCENARIOS
               if n.startswith("elastic-")}
    assert len(elastic) >= 3
    assert all("planner_config" in s.sim_kwargs for s in elastic.values())
    assert {s.workload.mode for s in elastic.values()} == {"closed", "open"}


# ----------------------------------------------------------- workloads ------

def test_arrival_processes_deterministic_and_shaped():
    for kind in ("poisson", "burst", "diurnal"):
        proc = ArrivalProcess(kind, rate=6.0, burst_rate=30.0)
        t1 = proc.times(50.0, np.random.default_rng(3))
        t2 = proc.times(50.0, np.random.default_rng(3))
        assert t1 == t2
        assert all(0.0 <= t < 50.0 for t in t1)
        assert t1 == sorted(t1)
    # burst mode produces a higher rate than its quiet baseline
    quiet = ArrivalProcess("poisson", rate=6.0).times(
        200.0, np.random.default_rng(0))
    burst = ArrivalProcess("burst", rate=6.0, burst_rate=60.0,
                           on_s=10.0, off_s=10.0).times(
        200.0, np.random.default_rng(0))
    assert len(burst) > 1.5 * len(quiet)


def test_open_loop_workload_has_no_concurrency_target():
    w = WorkloadConfig.poisson(rate=5.0, duration_s=30.0)
    assert w.mode == "open"
    assert w.total_duration() == 30.0
    assert w.concurrency_at(10.0) == 0
    assert w.phase_of(10.0) == 0


def test_trace_jsonl_roundtrip(tmp_path):
    records = example_trace_records(n=30, horizon_s=10.0)
    path = tmp_path / "trace.jsonl"
    path.write_text("# comment line\n" +
                    "\n".join(json.dumps(r) for r in records) + "\n")
    w = WorkloadConfig.from_trace_file(path)
    assert w.mode == "trace" and len(w.trace) == 30
    assert w.trace == WorkloadConfig.from_records(records).trace
    assert [e.t for e in w.trace] == sorted(e.t for e in w.trace)
    # defaults fill in for omitted fields
    w2 = WorkloadConfig.from_records([{"t": 1.0}])
    assert w2.trace[0] == TraceEntry(t=1.0)


def test_trace_replay_honors_trace_lengths():
    records = [{"t": 0.2 * i, "template": 1, "input_tokens": 64,
                "output_tokens": 32} for i in range(20)]
    sim = Simulator(ClusterConfig.for_model("llama-3.1-70b", "1P/2D"),
                    WorkloadConfig.from_records(records), seed=0)
    res = sim.run()
    assert len(res.completed) == 20
    assert all(len(r.tokens) == 64 and r.output_tokens == 32
               for r in res.completed)


def test_closed_loop_unaffected_by_refactor():
    """The closed-loop path predates the scenario subsystem; its arrivals
    must not consume the open-loop RNG stream (regression pin)."""
    cfg = ClusterConfig.for_model("llama-3.1-70b", "1P/2D")
    w = WorkloadConfig.single_level(16, hold_s=10.0, ramp_s=5.0)
    r1 = Simulator(cfg, w, seed=0).run()
    r2 = Simulator(cfg, w, seed=0).run()
    assert dataclasses.astuple(r1.overall()) == dataclasses.astuple(r2.overall())
    assert len(r1.completed) > 0
