"""Shared serving control plane — one runtime driving both backends.

The paper's mechanisms (Smart Router Eq. 1/2 + KvIndexer radix tree,
saturation detector Eq. 10/11, Table 2 adaptive regime params +
dual-frontend switch, Planner, PoA tracker Eq. 12, metrics registry) are
backend-agnostic: they consume routing-time token/hash streams and
telemetry, not simulated or real compute.  :class:`ControlPlane` owns that
wiring once, and two *backends* drive it:

* the **analytic backend** — :class:`repro.serving.simulator.Simulator`,
  the event-driven latency-model cluster (all calibrated experiments);
* the **engine backend** — :class:`repro.serving.disagg.DisaggregatedCluster`
  over real jitted-JAX :class:`~repro.serving.engine.PrefillEngine` /
  :class:`~repro.serving.engine.DecodeEngine` workers, where a cache-warm
  routing decision actually skips prefill recomputation.

Both backends route through :meth:`select_worker`, so a routing decision is
computed by the *same* code path given the same (tokens, hashes, indexer
state, load view) — that is what makes backend parity a testable property
(``tests/test_backend_parity.py``, ``benchmarks/bench_backend_parity.py``).

``decision_log`` (opt-in) records every routing decision for parity
comparison; it is off by default so large analytic runs carry no extra
per-request state, and it is a bounded deque when a backend passes
``decision_log_maxlen`` (parity scenarios keep ``None`` — they must see
every placement).

Replicated control plane (production scale-out): every read a routing
decision consumes — load vector, overlap scores, healthy set, detector
regime — goes through an explicit :class:`StateView`.  The single-router
path uses the fresh pass-through view (zero-copy, bit-exact with direct
access); :class:`ReplicatedControlPlane` runs R router replicas, each
against its own :class:`ReplicaStateView` — a frozen snapshot of the
authoritative state refreshed on the backend's event-clock sync cadence,
plus the replica's *own* placements since the last sync (a replica sees
its own writes immediately, everyone else's only at sync — the
eventual-consistency model of multi-replica router deployments).  Writes
(claims, load bumps, drains, plan flips) still serialize through the one
authoritative router/indexer store, and replica conflicts — a stale view
placing onto a worker that is gone or already at capacity — reconcile at
the admission write, not at routing.
"""
from __future__ import annotations

import math
import random
import time
from collections import deque
from dataclasses import replace
from typing import (Deque, Dict, List, Mapping, NamedTuple, Optional,
                    Sequence, Tuple)

from repro.core.radix import block_hashes

from repro.core.controller import (REGIME_PARAMS, DualFrontend,
                                   export_game_metrics)
from repro.core.metrics import MetricsRegistry
from repro.core.planner import Planner, PlannerConfig
from repro.core.poa import PoATracker
from repro.core.router import (KvPushRouter, KvRouterConfig, PowerOfTwoRouter,
                               RandomRouter, RoundRobinRouter)
from repro.core.saturation import DetectorConfig, Regime, SaturationDetector
from repro.serving.fabric import transfer_block_count


def _net_argmin(fabric, cfg, ids, overlaps, loads, total_blocks, now, rng):
    """Network-aware Eq. 1: the cache-affinity cost plus each candidate's
    *effective* transfer time quoted from current link queue depths —
    decode selection as congestion avoidance (the NetKV term).

    ``fabric`` may be the live :class:`~repro.serving.fabric.Fabric` (fresh
    view) or a frozen :class:`~repro.serving.fabric.FabricSnapshot`
    (replica view) — both expose ``route_src``/``quote``/``config``."""
    scale = KvPushRouter.PREFILL_BLOCK_SCALE
    weight = fabric.config.net_weight
    src = fabric.route_src(now)
    costs = []
    for ov, ld, w in zip(overlaps, loads, ids):
        blocks = transfer_block_count(total_blocks, ov)
        costs.append(cfg.overlap_weight * (scale * (1.0 - ov)) + ld
                     + weight * fabric.quote(src, w, blocks, now))
    if cfg.temperature <= 0.0 or len(ids) == 1:
        j = min(range(len(ids)), key=lambda i: (costs[i], ids[i]))
    else:
        mn = min(costs)
        spread = max(max(costs) - mn, 1e-9)
        z = [(c - mn) / spread for c in costs]
        ws = [math.exp(-zi / cfg.temperature) for zi in z]
        tot = sum(ws)
        r = rng.random() * tot
        acc = 0.0
        j = len(ids) - 1
        for i, w in enumerate(ws):
            acc += w
            if r <= acc:
                j = i
                break
    return ids[j], overlaps[j], overlaps


class RoutingDecision(NamedTuple):
    """One logged routing decision (parity comparisons key on these)."""
    rid: object            # backend request id (int rid / str request_id)
    worker: int
    overlap: float
    now: float


class StateView:
    """Fresh pass-through view of the control plane's routing state.

    Every read :meth:`ControlPlane.select_worker` performs goes through a
    view — this one delegates verbatim to the live authoritative objects,
    so the single-router path stays bit-exact with direct access while
    sharing one read interface with the bounded-staleness
    :class:`ReplicaStateView`."""

    def __init__(self, plane: "ControlPlane"):
        self._plane = plane

    @property
    def regime(self):
        return self._plane.detector.regime

    def age(self, now: float) -> float:
        return 0.0

    def healthy_ids(self) -> List[int]:
        return self._plane.router.healthy_ids()

    def overlap_scores(self, tokens: Sequence[int], ids: Sequence[int],
                       now: float,
                       hashes: Optional[Sequence[int]] = None) -> List[float]:
        return self._plane.router.indexer.overlap_scores(
            tokens, ids, now, hashes=hashes)

    def best_worker(self, tokens: Sequence[int], cfg, now: float,
                    hashes: Optional[Sequence[int]]
                    ) -> Tuple[int, float, List[float]]:
        return self._plane.policy.best_worker(
            tokens, router_config_override=cfg, now=now, hashes=hashes)

    def net_best_worker(self, tokens: Sequence[int], cfg, now: float,
                        hashes: Optional[Sequence[int]]
                        ) -> Tuple[int, float, List[float]]:
        """Network-aware selection against live link state (fresh view)."""
        plane = self._plane
        router = plane.router
        ids = router.healthy_ids()
        overlaps = self.overlap_scores(tokens, ids, now, hashes=hashes)
        caps = [router.workers[w].capacity for w in ids]
        if len(set(caps)) <= 1:
            loads = [float(router.workers[w].active_blocks) for w in ids]
        else:       # capacity-normalized, mirroring _normalized_load
            ref = sum(caps) / len(caps)
            loads = [router.workers[w].active_blocks * (ref / cap)
                     for w, cap in zip(ids, caps)]
        total = len(hashes) if hashes is not None else len(
            block_hashes(tokens))
        return _net_argmin(plane.fabric, cfg, ids, overlaps, loads, total,
                           now, plane._net_rng)


class ReplicaStateView(StateView):
    """Bounded-staleness replica view: a frozen snapshot of the
    authoritative routing state (healthy set, load vector, regime,
    fresh indexer claims) taken at :meth:`sync`, plus a local delta of
    the placements *this replica* routed since — KV events stream to the
    replica that issued them immediately, while everyone else's claims
    and all load telemetry arrive only at the next sync.

    Scoring mirrors the router's Eq. 1 arithmetic against the snapshot:
    ``cost = ω · PREFILL_BLOCK_SCALE · (1 − overlap) + load`` with the
    (cost, worker-id) tie-break at τ=0 and the spread-normalized softmax
    sample (per-replica seeded RNG) at τ>0.

    Every read method here works only off ``self`` snapshot fields —
    authoritative reads are confined to :meth:`sync` (lint rule RA011
    enforces this repo-wide for ``Replica*View`` classes)."""

    def __init__(self, plane: "ControlPlane", index: int, bound: float,
                 seed: int = 0):
        super().__init__(plane)
        self.index = index
        self.bound = bound                 # max allowed age (backend clock)
        self.synced_at: Optional[float] = None
        self._rng = random.Random((seed + 1) * 7919 + index)
        self._ids: List[int] = []
        self._loads: List[float] = []
        self._regime = None
        # base snapshot: block hash → workers with a fresh claim at sync
        self._hash_claims: Dict[int, Tuple[int, ...]] = {}
        # local delta: block hash → workers this replica placed since sync
        self._local_claims: Dict[int, List[int]] = {}
        # frozen fabric link state (None when the plane has no fabric)
        self._fabric = None

    # ------------------------------------------------------------- sync ----

    def sync(self, now: float) -> None:
        """Refresh the snapshot from the authoritative store.  The ONLY
        method allowed to read the plane's mutable state."""
        plane = self._plane
        router = plane.router
        ids = router.healthy_ids()
        caps = [router.workers[w].capacity for w in ids]
        if len(set(caps)) <= 1:
            loads = [float(router.workers[w].active_blocks) for w in ids]
        else:      # capacity-normalized, mirroring _normalized_load
            ref = sum(caps) / len(caps)
            loads = [router.workers[w].active_blocks * (ref / cap)
                     for w, cap in zip(ids, caps)]
        self._ids = ids
        self._loads = loads
        self._regime = plane.detector.regime
        self._hash_claims = router.indexer.snapshot_claims(now)
        self._local_claims = {}
        fabric = plane.fabric
        self._fabric = fabric.freeze() if fabric is not None else None
        self.synced_at = now

    def frozen_state(self):
        """Deep-frozen copy of the base snapshot (NOT the local delta) —
        the sanitizer records one per sync and asserts nothing but
        :meth:`sync` ever rewrites it."""
        base = (self.synced_at, tuple(self._ids), tuple(self._loads),
                self._regime,
                tuple(sorted((h, ws) for h, ws in self._hash_claims.items())))
        if self._fabric is not None:
            return base + (self._fabric.state_key(),)
        return base

    # ------------------------------------------------------------- reads ----

    @property
    def regime(self):
        return self._regime

    def age(self, now: float) -> float:
        if self.synced_at is None:
            return math.inf
        return now - self.synced_at

    def healthy_ids(self) -> List[int]:
        return list(self._ids)

    def overlap_depths(self, hashes: Sequence[int]) -> Dict[int, int]:
        """Fresh contiguous prefix depth per worker against the snapshot
        claims ∪ this replica's local placements — same walk semantics as
        ``KvIndexer.overlap_depths``, no tree access, no TTL sweep."""
        depth: Dict[int, int] = {}
        get = depth.get
        i = 0
        for h in hashes:
            base = self._hash_claims.get(h, ())
            local = self._local_claims.get(h, ())
            advanced = 0
            for w in base:
                if get(w, 0) == i:
                    depth[w] = i + 1
                    advanced += 1
            for w in local:
                if get(w, 0) == i:
                    depth[w] = i + 1
                    advanced += 1
            if not advanced:
                break
            i += 1
        return depth

    def overlap_scores(self, tokens: Sequence[int], ids: Sequence[int],
                       now: float,
                       hashes: Optional[Sequence[int]] = None) -> List[float]:
        hs = list(hashes) if hashes is not None else block_hashes(tokens)
        total = max(len(hs), 1)
        depth = self.overlap_depths(hs)
        return [depth.get(w, 0) / total for w in ids]

    def best_worker(self, tokens: Sequence[int], cfg, now: float,
                    hashes: Optional[Sequence[int]]
                    ) -> Tuple[int, float, List[float]]:
        ids = self._ids
        if not ids:
            raise RuntimeError(f"replica {self.index}: no healthy workers "
                               f"in view")
        scale = KvPushRouter.PREFILL_BLOCK_SCALE   # class constant, not state
        overlaps = self.overlap_scores(tokens, ids, now, hashes=hashes)
        costs = [cfg.overlap_weight * (scale * (1.0 - ov)) + ld
                 for ov, ld in zip(overlaps, self._loads)]
        if cfg.temperature <= 0.0 or len(ids) == 1:
            j = min(range(len(ids)), key=lambda i: (costs[i], ids[i]))
        else:
            mn = min(costs)
            spread = max(max(costs) - mn, 1e-9)
            z = [(c - mn) / spread for c in costs]
            ws = [math.exp(-zi / cfg.temperature) for zi in z]
            tot = sum(ws)
            r = self._rng.random() * tot
            acc = 0.0
            j = len(ids) - 1
            for i, w in enumerate(ws):
                acc += w
                if r <= acc:
                    j = i
                    break
        return ids[j], overlaps[j], overlaps

    def net_best_worker(self, tokens: Sequence[int], cfg, now: float,
                        hashes: Optional[Sequence[int]]
                        ) -> Tuple[int, float, List[float]]:
        """Network-aware selection against the *frozen* fabric snapshot
        taken at the last sync — a replica quotes link queues exactly as
        stale as the rest of its world (no authoritative reads here)."""
        ids = self._ids
        if not ids:
            raise RuntimeError(f"replica {self.index}: no healthy workers "
                               f"in view")
        overlaps = self.overlap_scores(tokens, ids, now, hashes=hashes)
        total = len(hashes) if hashes is not None else len(
            block_hashes(tokens))
        return _net_argmin(self._fabric, cfg, ids, overlaps, self._loads,
                           total, now, self._rng)

    # ------------------------------------------------------------ writes ----

    def note_placement(self, worker: int, hashes: Optional[Sequence[int]]
                       ) -> None:
        """Record this replica's own placement in the local delta (its KV
        events are visible to itself immediately, to peers at sync)."""
        for h in hashes or ():
            ws = self._local_claims.get(h)
            if ws is None:
                self._local_claims[h] = [worker]
            elif worker not in ws:
                ws.append(worker)


class ControlPlane:
    """Router + indexer + detector + adaptive params + Planner + PoA +
    metrics, wired once and shared by the analytic and engine backends."""

    def __init__(self, num_workers: int, *,
                 router_config: Optional[KvRouterConfig] = None,
                 routing_policy: str = "kv",    # kv|round_robin|random|p2c
                 seed: int = 0,
                 adaptive: bool = False,
                 detector_config: Optional[DetectorConfig] = None,
                 regime_params: Optional[Dict] = None,
                 cache_ttl: Optional[float] = None,
                 capacities: Optional[Mapping[int, float]] = None,
                 poa_num_workers: Optional[int] = None,
                 poa_window_s: float = 30.0,
                 poa_window_count: Optional[int] = None,
                 poa_capacities: Sequence[float] = (),
                 planner_config: Optional[PlannerConfig] = None,
                 num_prefill: int = 0,
                 log_decisions: bool = False,
                 decision_log_maxlen: Optional[int] = None,
                 fabric=None,                   # repro.serving.fabric.Fabric
                 network_aware: bool = False,
                 sanitize: Optional[bool] = None):
        # Fourth game: an attached Fabric prices P→D transfers on shared
        # links; network_aware additionally folds each candidate's quoted
        # transfer time into the routing cost (requires the kv policy —
        # baselines carry no per-candidate cost vector to extend).
        self.fabric = fabric
        self.network_aware = bool(network_aware and fabric is not None)
        if self.network_aware and routing_policy != "kv":
            raise ValueError(
                "network_aware selection requires routing_policy='kv' "
                f"(got {routing_policy!r})")
        self._net_rng = random.Random((seed + 1) * 104729)
        self.router = KvPushRouter(num_workers,
                                   router_config or KvRouterConfig(),
                                   seed=seed)
        if cache_ttl is not None:
            self.router.indexer.ttl = cache_ttl
            if self.router.affinity is not None:
                self.router.affinity.ttl = cache_ttl
        if capacities:
            for wid, cap in capacities.items():
                self.router.set_capacity(wid, cap)
        # Baselines share the router's worker table so health changes
        # propagate to every policy.
        self.routing_policy = routing_policy
        if routing_policy == "round_robin":
            self.policy = RoundRobinRouter(self.router)
        elif routing_policy == "random":
            self.policy = RandomRouter(self.router, seed)
        elif routing_policy == "p2c":
            self.policy = PowerOfTwoRouter(self.router, seed)
        else:
            self.policy = self.router

        self.adaptive = adaptive
        self.detector = SaturationDetector(detector_config or DetectorConfig())
        self.dual = DualFrontend()
        self.regime_params = dict(regime_params or REGIME_PARAMS)
        self.metrics = MetricsRegistry()
        self.switch_time: Optional[float] = None

        # Game 1: the Planner joins the control plane when configured.
        self.planner: Optional[Planner] = None
        self.planner_config: Optional[PlannerConfig] = None
        if planner_config is not None:
            self.planner_config = replace(
                planner_config, total_workers=num_workers + num_prefill)
            self.planner = Planner(config=self.planner_config,
                                   prefill_workers=num_prefill,
                                   decode_workers=num_workers)

        poa_kw = dict(num_workers=poa_num_workers or num_workers,
                      window_s=poa_window_s, capacities=tuple(poa_capacities))
        if poa_window_count is not None:
            poa_kw["window_count"] = poa_window_count
        self.poa = PoATracker(**poa_kw)

        self.log_decisions = log_decisions
        # Bounded by default-None: parity harnesses need every placement,
        # but 100k-request scale runs that turn logging on would otherwise
        # grow this without bound.
        self.decision_log: Deque[RoutingDecision] = \
            deque(maxlen=decision_log_maxlen)
        self._last_config: KvRouterConfig = self.router.config
        # every routing-time read goes through a StateView (the fresh
        # pass-through one here; ReplicatedControlPlane routes replicas
        # against bounded-staleness snapshots instead)
        self.view = StateView(self)

        # Opt-in coherence sanitizer for bare control-plane users; the
        # backends pass sanitize=False here and attach their own richer
        # sanitizers over this plane's structures.
        self.sanitizer = None
        if sanitize is not False:
            from repro.analysis.sanitize import (attach_control_sanitizer,
                                                 sanitize_enabled)
            if sanitize_enabled(sanitize):
                attach_control_sanitizer(self)

    # ------------------------------------------------------------ params ----

    def active_router_config(self, now: float) -> KvRouterConfig:
        """Table 2 regime-gated (τ, ω) override (plus the §6.4 dual-frontend
        switch bookkeeping); static config when not adaptive."""
        if not self.adaptive:
            return self.router.config
        regime = self.view.regime
        self.dual.on_regime(regime, now)
        if self.dual.active_port == 8001 and self.switch_time is None:
            self.switch_time = self.dual.switch_time
        return self.regime_params.get(regime) or self.router.config

    # ----------------------------------------------------------- routing ----

    def select_worker(self, tokens: Sequence[int], *,
                      hashes: Optional[Sequence[int]] = None,
                      now: float = 0.0,
                      live_ids: Optional[Sequence[int]] = None,
                      rid: object = None, record: bool = True
                      ) -> Tuple[int, float, List[float], List[int]]:
        """One routing decision through the active policy.

        Returns ``(worker, overlap, overlaps, ids)`` where ``overlaps`` is
        positionally aligned with ``ids``.  Baseline policies (round-robin /
        random / p2c) report no overlap themselves, so their overlap vector
        is re-scored from the indexer over ``live_ids`` (the backend's live
        decode set) — the counterfactual the PoA tracker prices.

        ``record=False`` keeps the decision out of ``decision_log`` — for
        callers that may abandon the route (engine backpressure retries)
        and log only the placement that actually happened via
        :meth:`log_decision`.
        """
        cfg = self._last_config = self.active_router_config(now)
        view = self.view
        if self.network_aware:
            worker, overlap, overlaps = view.net_best_worker(
                tokens, cfg, now, hashes=hashes)
        else:
            worker, overlap, overlaps = view.best_worker(tokens, cfg, now,
                                                         hashes=hashes)
        if self.policy is not self.router:
            ids = (list(live_ids) if live_ids is not None
                   else view.healthy_ids())
            overlaps = view.overlap_scores(tokens, ids, now, hashes=hashes)
            overlap = overlaps[ids.index(worker)]
        else:
            ids = view.healthy_ids()
        if record:
            self.log_decision(rid, worker, overlap, now)
        return worker, overlap, overlaps, ids

    def log_decision(self, rid: object, worker: int, overlap: float,
                     now: float) -> None:
        if self.log_decisions:
            self.decision_log.append(
                RoutingDecision(rid, worker, overlap, now))

    def route(self, tokens: Sequence[int], *,
              hashes: Optional[Sequence[int]] = None,
              now: float = 0.0,
              live_ids: Optional[Sequence[int]] = None,
              rid: object = None, record: bool = True
              ) -> Tuple[int, float, List[float], List[int]]:
        """Engine-path routing: :meth:`select_worker` plus the Algorithm 1
        Prometheus exports (game_poa, game_saturation_state,
        game_router_temperature, game_overlap_weight, game_routing_cost)."""
        t0 = time.perf_counter()
        worker, overlap, overlaps, ids = self.select_worker(
            tokens, hashes=hashes, now=now, live_ids=live_ids, rid=rid,
            record=record)
        dt = time.perf_counter() - t0
        export_game_metrics(self.metrics, regime=self.detector.regime,
                            config=self._last_config, decision_s=dt,
                            now=now, poa_tracker=self.poa)
        return worker, overlap, overlaps, ids

    # --------------------------------------------------------- telemetry ----

    def observe(self, ttft_p99: float, now: float) -> Regime:
        """Feed one polled TTFT P99 sample to the saturation detector."""
        return self.detector.observe(ttft_p99, now)

    def regime_transitions(self) -> List[Tuple[float, int, int]]:
        """(t, from, to) regime transitions — the parity observable."""
        return list(self.detector.transitions)


class ReplicatedControlPlane(ControlPlane):
    """R router replicas over bounded-staleness :class:`ReplicaStateView`s.

    Requests are assigned to replicas deterministically (round-robin on
    the decision counter); each replica routes against its own view,
    refreshed when the backend calls :meth:`sync_views` on its event-clock
    sync cadence.  Writes still serialize through the single authoritative
    store (``self.router``/``self.poa``/…), and the write path resolves
    replica conflicts at admission — routing itself never blocks on fresh
    state.  Two cases reconcile:

    * the stale view placed onto a worker that has since left the healthy
      set (drain/flip): the write cannot land, the fresh choice is taken;
    * replicas piled onto the same near-full worker within one sync
      window: the admission ledger (:attr:`_window_writes`, reset at each
      sync) accepts serialized placements until running occupancy plus
      in-window writes exceed ``ADMIT_QUEUE_FACTOR ×`` the worker's
      declared capacity — a bounded admission queue — and redirects the
      overflow to the fresh choice.

    The ledger threshold matters for what the staleness sweep measures:
    stale herding onto a visibly busy worker is *legal* (it queues — that
    queueing delay IS the staleness externality PoA-hat prices); only the
    unbounded pile-up a real admission controller would refuse gets
    reconciled.

    ``staleness_s = 0`` keeps every replica on the fresh pass-through
    view: routing is bit-exact with the single-router :class:`ControlPlane`
    for any R (the refactor pin), at zero extra scoring cost.

    With ``staleness_s > 0`` every decision also runs the authoritative
    fresh-state scorer — that is what the returned ``(overlap, overlaps)``
    report, so backend physics (prefill discount, tier split, transfer
    charge) and the PoA tracker's counterfactual columns price the *real*
    cache/load state and PoA-hat isolates the staleness externality
    instead of compounding it with phantom-overlap accounting.  The
    fresh pass doubles as the routing-agreement probe
    (``agreement_rate``) and the conflict-resolution fallback."""

    # Admission-ledger queue bound: a worker accepts serialized placements
    # until running occupancy + in-window writes reach this multiple of
    # its declared capacity (one extra capacity-worth of queued work);
    # beyond that, placements reconcile to the fresh choice.
    ADMIT_QUEUE_FACTOR = 2.0

    def __init__(self, num_workers: int, *, replicas: int = 1,
                 staleness_s: float = 0.0, seed: int = 0, **kw):
        super().__init__(num_workers, seed=seed, **kw)
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        if staleness_s > 0 and self.routing_policy != "kv":
            raise ValueError(
                "stale replica views require routing_policy='kv' "
                f"(got {self.routing_policy!r}): baseline policies carry "
                "per-policy mutable state a frozen snapshot cannot replay")
        self.num_replicas = replicas
        self.staleness_s = staleness_s
        self.replica_logs: List[List[RoutingDecision]] = \
            [[] for _ in range(replicas)]
        self.decisions_total = 0
        self.agree_fresh = 0
        self.conflicts = 0
        # serialized admission ledger: worker → placements since the last
        # sync (the write-write conflict window)
        self._window_writes: Dict[int, int] = {}
        # staleness 0 → no snapshots at all: every replica routes on the
        # fresh pass-through view (identity path, nothing to sync)
        self.replica_views: List[ReplicaStateView] = []
        if staleness_s > 0:
            self.replica_views = [
                ReplicaStateView(self, i, staleness_s, seed=seed)
                for i in range(replicas)]
            self.sync_views(0.0)

    # ------------------------------------------------------------- views ----

    def sync_views(self, now: float) -> None:
        """Event-clock sync point: refresh every replica's snapshot from
        the authoritative store (no-op at staleness 0)."""
        for v in self.replica_views:
            v.sync(now)
        self._window_writes = {}

    @property
    def agreement_rate(self) -> float:
        """Fraction of decisions where the replica's stale-view choice
        matched the fresh-state choice."""
        return self.agree_fresh / max(self.decisions_total, 1)

    # ----------------------------------------------------------- routing ----

    def select_worker(self, tokens: Sequence[int], *,
                      hashes: Optional[Sequence[int]] = None,
                      now: float = 0.0,
                      live_ids: Optional[Sequence[int]] = None,
                      rid: object = None, record: bool = True
                      ) -> Tuple[int, float, List[float], List[int]]:
        r = self.decisions_total % self.num_replicas
        self.decisions_total += 1
        if not self.replica_views:
            # staleness 0: fresh views — the single-router path verbatim
            out = super().select_worker(tokens, hashes=hashes, now=now,
                                        live_ids=live_ids, rid=rid,
                                        record=record)
            self.agree_fresh += 1
            self.replica_logs[r].append(
                RoutingDecision(rid, out[0], out[1], now))
            return out

        view = self.replica_views[r]
        cfg = self._last_config = self.active_router_config(now)
        # adaptive regimes are read through the view too: a replica plays
        # the (τ, ω) of the regime it *believes* the cluster is in
        vcfg = cfg if not self.adaptive else (
            self.regime_params.get(view.regime) or self.router.config)
        if self.network_aware:
            stale_w, stale_ov, _ = view.net_best_worker(tokens, vcfg, now,
                                                        hashes=hashes)
        else:
            stale_w, stale_ov, _ = view.best_worker(tokens, vcfg, now,
                                                    hashes=hashes)
        view.note_placement(stale_w, hashes)
        self.replica_logs[r].append(
            RoutingDecision(rid, stale_w, stale_ov, now))

        # authoritative fresh pass: agreement probe + PoA counterfactual
        # vector + the state the serialized admission write checks
        if self.network_aware:
            fresh_w, _fresh_ov, overlaps = self.view.net_best_worker(
                tokens, cfg, now, hashes=hashes)
        else:
            fresh_w, _fresh_ov, overlaps = self.policy.best_worker(
                tokens, router_config_override=cfg, now=now, hashes=hashes)
        ids = self.router.healthy_ids()
        if fresh_w == stale_w:
            self.agree_fresh += 1
        worker = stale_w
        st = self.router.workers.get(stale_w)
        if st is None or not st.healthy:
            # the worker left the pool (drain/flip) after the last sync:
            # the write cannot land — take the fresh choice
            self.conflicts += 1
            worker = fresh_w
        elif fresh_w != stale_w:
            # contested placement: the stale view herded somewhere fresh
            # state would not.  The admission ledger lets contested writes
            # land (and queue — that delay IS the staleness externality)
            # until occupancy + contested-in-window writes exhaust the
            # bounded admission queue; only the pile-up beyond that
            # reconciles to the fresh choice, at admission, not at routing.
            if (st.capacity > 1.0
                    and st.active_blocks
                    + self._window_writes.get(stale_w, 0)
                    >= self.ADMIT_QUEUE_FACTOR * st.capacity):
                self.conflicts += 1
                worker = fresh_w
            else:
                self._window_writes[stale_w] = \
                    self._window_writes.get(stale_w, 0) + 1
        overlap = overlaps[ids.index(worker)]
        if record:
            self.log_decision(rid, worker, overlap, now)
        return worker, overlap, overlaps, ids
