"""xLSTM-125M — sLSTM + mLSTM blocks (7:1-style mix). [arXiv:2405.04517; unverified]

d_ff=0: xLSTM blocks carry their own up/down projections instead of a
separate FFN. mLSTM uses a chunked linear-attention formulation (TPU
adaptation); sLSTM keeps its sequential recurrence via lax.scan.
"""
from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    head_dim=192,
    activation="gelu",
    xlstm=XLSTMConfig(slstm_every=4, slstm_offset=3, chunk=64, proj_factor=2),
    subquadratic=True,
    source="arXiv:2405.04517; unverified",
)
