"""Property tests for both Pallas kernels against their pure-jnp oracles.

Hypothesis drives shapes and ragged lengths through the regions where
blocked attention kernels historically break: lengths of 0/1, lengths
straddling a key-block boundary (``blk_k ± 1``), sequence lengths that are
not a multiple of the block (right-padding path), and every GQA group
ratio from MQA to MHA.  Block size must be a pure performance knob —
``blk_k`` invariance is asserted as part of every decode example rather
than at a single hand-picked shape.

``hypothesis`` is an optional dependency (the CI engine lane installs it;
the base container may not have it) — the module skips cleanly when
missing.  Examples are capped small: each example jit-compiles a kernel
variant in interpret mode, so the budget goes to boundary coverage
(explicit ``@example`` pins) rather than bulk random sampling.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, example, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.kernels.decode_attention.ops import decode_attention  # noqa: E402
from repro.kernels.decode_attention.ref import decode_attention_ref  # noqa: E402
from repro.kernels.flash_attention.ops import flash_attention  # noqa: E402
from repro.kernels.flash_attention.ref import flash_attention_ref  # noqa: E402
from repro.kernels.paged_attention.ops import (  # noqa: E402
    gather_pages, paged_attention)
from repro.kernels.paged_attention.ref import paged_attention_ref  # noqa: E402

# interpret-mode kernels are slow and compile per shape: few, surgical
# examples with no deadline (first example pays the jit wall)
COMMON = dict(deadline=None, max_examples=12, derandomize=True,
              suppress_health_check=[HealthCheck.too_slow])

BLK_K = 32


def _qkv(key, b, s, t, h, kh, hd):
    ks = jax.random.split(key, 3)
    q_shape = (b, h, hd) if s is None else (b, s, h, hd)
    q = jax.random.normal(ks[0], q_shape, jnp.float32)
    k = jax.random.normal(ks[1], (b, t, kh, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, t, kh, hd), jnp.float32)
    return q, k, v


@settings(**COMMON)
@given(seed=st.integers(0, 2**31 - 1),
       t=st.integers(2, 160),
       kh=st.sampled_from([1, 2, 4]),
       g=st.sampled_from([1, 2, 4]),          # q_per_kv: MQA → GQA → MHA
       raw_lengths=st.lists(st.integers(0, 200), min_size=1, max_size=4))
@example(seed=0, t=BLK_K, kh=2, g=2,
         raw_lengths=[0, 1, BLK_K - 1, BLK_K])         # block-edge lengths
@example(seed=1, t=BLK_K + 1, kh=1, g=4,
         raw_lengths=[BLK_K + 1])                      # t not block-multiple
@example(seed=2, t=3 * BLK_K, kh=4, g=1,
         raw_lengths=[2 * BLK_K - 1, 2 * BLK_K, 2 * BLK_K + 1])
def test_decode_matches_ref_property(seed, t, kh, g, raw_lengths):
    """Ragged decode == dense masked softmax for arbitrary (t, GQA ratio,
    lengths) — including length 0 (defined as zero output) — and the
    result is invariant to the key-block size."""
    b, hd = len(raw_lengths), 16
    q, k, v = _qkv(jax.random.PRNGKey(seed), b, None, t, kh * g, kh, hd)
    lengths = jnp.asarray([min(n, t) for n in raw_lengths], jnp.int32)
    out = decode_attention(q, k, v, lengths, blk_k=BLK_K, interpret=True)
    ref = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
    # blk_k is a tiling knob, never a semantic one
    alt = decode_attention(q, k, v, lengths, blk_k=2 * BLK_K, interpret=True)
    np.testing.assert_allclose(np.asarray(alt), np.asarray(out),
                               atol=2e-5, rtol=2e-5)
    # inactive rows (length 0) must be finite zeros, never NaN
    zero = np.asarray(out)[np.asarray(lengths) == 0]
    assert np.all(zero == 0.0)


# tokens per KV page in the paged suite — small so examples stay fast while
# partial-last-page and page-boundary lengths remain reachable
PAGE_BLK = 16


@settings(**COMMON)
@given(seed=st.integers(0, 2**31 - 1),
       w=st.integers(1, 5),                   # pages_per_slot (table width)
       extra_pages=st.integers(0, 6),         # pool slack beyond the tables
       kh=st.sampled_from([1, 2, 4]),
       g=st.sampled_from([1, 2, 4]),          # q_per_kv: MQA → GQA → MHA
       raw_lengths=st.lists(st.integers(0, 90), min_size=1, max_size=4))
@example(seed=0, w=2, extra_pages=1, kh=2, g=2,
         raw_lengths=[0, 1, PAGE_BLK - 1, PAGE_BLK])   # page-edge lengths
@example(seed=1, w=3, extra_pages=0, kh=1, g=4,
         raw_lengths=[PAGE_BLK + 1])                   # partial last page
@example(seed=2, w=4, extra_pages=2, kh=4, g=1,
         raw_lengths=[2 * PAGE_BLK - 1, 2 * PAGE_BLK, 2 * PAGE_BLK + 1])
def test_paged_matches_ref_property(seed, w, extra_pages, kh, g, raw_lengths):
    """Page-table-indirected decode == dense masked softmax over the
    gathered pages, for arbitrary (table width, pool assignment, GQA
    ratio, ragged lengths) — including length 0 (defined as zero output)
    and lengths ending inside a partial last page.  Tables deliberately
    include the trash page 0 and shared pages: reads are pure, so any
    valid page id is legal wherever the length mask hides or allows it."""
    b, hd = len(raw_lengths), 16
    n_pages = b * w + 1 + extra_pages            # +1: trash page 0
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (b, kh * g, hd), jnp.float32)
    k_pool = jax.random.normal(ks[1], (n_pages, PAGE_BLK, kh, hd),
                               jnp.float32)
    v_pool = jax.random.normal(ks[2], (n_pages, PAGE_BLK, kh, hd),
                               jnp.float32)
    table = jax.random.randint(ks[3], (b, w), 0, n_pages, jnp.int32)
    lengths = jnp.asarray([min(n, w * PAGE_BLK) for n in raw_lengths],
                          jnp.int32)
    out = paged_attention(q, k_pool, v_pool, table, lengths, interpret=True)
    ref = paged_attention_ref(q, k_pool, v_pool, table, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
    # the oracle itself must agree with the dense ragged oracle on the
    # gathered view — pages are pure indirection, not new semantics
    dense = decode_attention_ref(q, gather_pages(k_pool, table),
                                 gather_pages(v_pool, table), lengths)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(dense),
                               atol=2e-5, rtol=2e-5)
    # inactive rows (length 0) must be finite zeros, never NaN
    zero = np.asarray(out)[np.asarray(lengths) == 0]
    assert np.all(zero == 0.0)
    assert np.all(np.isfinite(np.asarray(out)))


@settings(**COMMON)
@given(seed=st.integers(0, 2**31 - 1),
       s=st.integers(1, 80),
       extra=st.integers(0, 48),              # t = s + extra (offset cache)
       kh=st.sampled_from([1, 2, 4]),
       g=st.sampled_from([1, 2, 4]),
       causal=st.booleans())
@example(seed=0, s=BLK_K - 1, extra=0, kh=2, g=2, causal=True)
@example(seed=1, s=BLK_K + 1, extra=1, kh=1, g=4, causal=True)
@example(seed=2, s=1, extra=BLK_K, kh=4, g=1, causal=True)
@example(seed=3, s=2 * BLK_K, extra=0, kh=2, g=1, causal=False)
def test_flash_matches_ref_property(seed, s, extra, kh, g, causal):
    """Blocked flash == dense softmax for non-multiple-of-block sequence
    lengths, offset KV caches (t > s) and all GQA ratios, causal and not —
    and invariant to both block sizes."""
    b, hd, t = 1, 16, s + extra
    q, k, v = _qkv(jax.random.PRNGKey(seed), b, s, t, kh * g, kh, hd)
    out = flash_attention(q, k, v, causal=causal, blk_q=BLK_K, blk_k=BLK_K,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
    alt = flash_attention(q, k, v, causal=causal, blk_q=2 * BLK_K,
                          blk_k=2 * BLK_K, interpret=True)
    np.testing.assert_allclose(np.asarray(alt), np.asarray(out),
                               atol=2e-5, rtol=2e-5)
