"""Exact FLOP / byte accounting by walking the jaxpr.

XLA's ``cost_analysis()`` counts a ``while`` body once, and fully unrolling
every loop makes tracing/compile time explode on big models.  The jaxpr,
however, carries every ``scan`` length explicitly — so walking it with
trip-count multiplication gives exact totals in seconds, independent of
model size.

Conventions:
  * FLOPs: dot_general = 2·M·N·K·batch; elementwise = 1/elem
    (transcendentals = 4/elem); reductions = 1/input-elem.
  * Bytes: per equation, sum of operand + result buffer sizes (an
    *unfused* upper bound — XLA fusion removes some intermediate traffic;
    matmul-dominated models are within ~2× of the fused number).
  * Shapes in jaxpr are GLOBAL (pre-SPMD): per-device numbers divide by the
    device count, i.e. they assume the sharding policy parallelizes all
    compute (slightly optimistic for replicated elementwise work).

Validated against XLA's fully-unrolled ``cost_analysis`` on the cells small
enough to compile both ways (see tests/test_jaxpr_cost.py).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

TRANSCENDENTAL = {
    "exp", "log", "log1p", "expm1", "tanh", "logistic", "sin", "cos",
    "erf", "rsqrt", "sqrt", "cbrt", "pow", "exp2",
}

ZERO_FLOP = {
    "broadcast_in_dim", "reshape", "transpose", "slice", "dynamic_slice",
    "dynamic_update_slice", "concatenate", "pad", "rev", "squeeze",
    "convert_element_type", "bitcast_convert_type", "copy", "stop_gradient",
    "gather", "scatter", "scatter-add", "iota", "eq", "ne", "lt", "le",
    "gt", "ge", "and", "or", "not", "xor", "select_n", "clamp", "sign",
    "is_finite", "shift_left", "shift_right_logical", "floor", "ceil",
    "round", "rem", "device_put", "copy_p", "split", "argmax", "argmin",
    "reduce_precision", "real", "imag",
}


def _nbytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0


def _nelems(aval) -> int:
    try:
        return int(np.prod(aval.shape))
    except Exception:
        return 0


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0

    def __add__(self, o):
        return Cost(self.flops + o.flops, self.bytes + o.bytes)

    def __mul__(self, k):
        return Cost(self.flops * k, self.bytes * k)


def _dot_general_flops(eqn) -> float:
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    ((lc, rc), (lb, rb)) = eqn.params["dimension_numbers"]
    batch = int(np.prod([lhs.shape[i] for i in lb])) if lb else 1
    k = int(np.prod([lhs.shape[i] for i in lc])) if lc else 1
    m = int(np.prod([d for i, d in enumerate(lhs.shape)
                     if i not in set(lc) | set(lb)]))
    n = int(np.prod([d for i, d in enumerate(rhs.shape)
                     if i not in set(rc) | set(rb)]))
    return 2.0 * batch * m * n * k


def _eqn_cost(eqn) -> Cost:
    name = eqn.primitive.name
    out_elems = sum(_nelems(v.aval) for v in eqn.outvars)
    io_bytes = (sum(_nbytes(v.aval) for v in eqn.invars
                    if hasattr(v, "aval"))
                + sum(_nbytes(v.aval) for v in eqn.outvars))
    if name == "dot_general":
        return Cost(_dot_general_flops(eqn), io_bytes)
    if name in ("conv_general_dilated",):
        lhs = eqn.invars[0].aval
        rhs = eqn.invars[1].aval
        out = eqn.outvars[0].aval
        k = int(np.prod(rhs.shape))
        return Cost(2.0 * _nelems(out) * k / max(out.shape[-1], 1), io_bytes)
    if name in ZERO_FLOP:
        return Cost(0.0, io_bytes)
    if name.startswith("reduce_") or name in ("reduce_sum", "reduce_max",
                                              "reduce_min", "reduce_prod",
                                              "reduce_and", "reduce_or"):
        in_elems = sum(_nelems(v.aval) for v in eqn.invars
                       if hasattr(v, "aval"))
        return Cost(float(in_elems), io_bytes)
    if name in ("cumsum", "cumlogsumexp", "cummax", "cummin", "cumprod"):
        return Cost(float(out_elems), io_bytes)
    if name in ("sort", "argsort", "top_k"):
        in_elems = sum(_nelems(v.aval) for v in eqn.invars
                       if hasattr(v, "aval"))
        return Cost(float(in_elems) * max(np.log2(max(in_elems, 2)), 1.0),
                    io_bytes)
    if name in TRANSCENDENTAL:
        return Cost(4.0 * out_elems, io_bytes)
    # default: elementwise unary/binary
    return Cost(float(out_elems), io_bytes)


_CALL_PRIMS = {"pjit", "closed_call", "core_call", "xla_call", "remat2",
               "checkpoint", "custom_jvp_call", "custom_vjp_call",
               "custom_vjp_call_jaxpr", "custom_jvp_call_jaxpr",
               "custom_lin"}


def _subjaxprs(eqn):
    for k in ("jaxpr", "call_jaxpr", "fun_jaxpr", "cond_jaxpr"):
        if k in eqn.params:
            j = eqn.params[k]
            yield j.jaxpr if hasattr(j, "jaxpr") else j
    if "branches" in eqn.params:
        for b in eqn.params["branches"]:
            yield b.jaxpr if hasattr(b, "jaxpr") else b


def jaxpr_cost(jaxpr) -> Cost:
    total = Cost()
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "scan":
            body = eqn.params["jaxpr"]
            body = body.jaxpr if hasattr(body, "jaxpr") else body
            total = total + jaxpr_cost(body) * int(eqn.params["length"])
        elif name == "while":
            body = eqn.params["body_jaxpr"]
            body = body.jaxpr if hasattr(body, "jaxpr") else body
            total = total + jaxpr_cost(body)  # trip count unknown: ×1
        elif name == "cond":
            subs = [jaxpr_cost(b.jaxpr if hasattr(b, "jaxpr") else b)
                    for b in eqn.params["branches"]]
            total = total + max(subs, key=lambda c: c.flops)
        elif name in _CALL_PRIMS or any(True for _ in _subjaxprs(eqn)):
            for sub in _subjaxprs(eqn):
                total = total + jaxpr_cost(sub)
        else:
            total = total + _eqn_cost(eqn)
    return total


def cost_of(fn, *args) -> Cost:
    """Trace fn abstractly and return its total Cost (global shapes)."""
    closed = jax.make_jaxpr(fn)(*args)
    return jaxpr_cost(closed.jaxpr)
