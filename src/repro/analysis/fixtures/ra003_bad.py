"""RA003 bad: impure captures inside jit/Pallas-traced functions."""
import random
import time

import jax

_log = []


@jax.jit
def wall_clock_bakes_in(x):
    t0 = time.time()              # runs once, at trace time
    return x + t0


@jax.jit
def global_rng_bakes_in(x):
    return x * random.random()    # one sample, frozen into the trace


@jax.jit
def mutates_capture(x):
    _log.append("step")           # trace-time side effect only
    return x + 1


def build():
    step = jax.jit(lambda x: x + time.perf_counter())  # via jit(fn) too
    return step
