"""RA004 good: every kernel-shaping kwarg is static."""
import functools

import jax
from jax.experimental import pallas as pl


@functools.partial(jax.jit,
                   static_argnames=("blk_q", "blk_k", "interpret"))
def attention(q, k, v, *, blk_q=128, blk_k=128, interpret=None):
    interpret = _on_cpu() if interpret is None else interpret
    return pl.pallas_call(_attn_kernel, grid=(q.shape[0] // blk_q,),
                          interpret=interpret)(q, k, v)
