"""RA009 bad: wall-clock reads in an event-clock module.

Linted via ``lint_source`` with a spoofed in-scope path such as
``src/repro/serving/simulator.py`` (see fixtures/README.md) — the rule is
scoped to event-clock modules by path.
"""
import time
from datetime import datetime


def on_poll(sim):
    stamp = time.time()                  # host wall clock, not `now`
    sim.poll_log.append(stamp)


def settle(sim):
    time.sleep(0.01)                     # host latency leaks into events
    return datetime.now()
