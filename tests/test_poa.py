"""PoA estimator (Eq. 12): Hungarian correctness, window semantics,
regime-indicator behavior."""
import itertools

import numpy as np
import pytest

from repro.core.poa import (CompletedRequest, PoATracker, hungarian,
                            hungarian_jv)


def _brute_force(cost):
    n, m = cost.shape
    best = np.inf
    for perm in itertools.permutations(range(m), n):
        best = min(best, cost[np.arange(n), list(perm)].sum())
    return best


@pytest.mark.parametrize("seed", range(10))
def test_hungarian_optimal_vs_bruteforce(seed):
    rng = np.random.default_rng(seed)
    n, m = rng.integers(1, 5), rng.integers(5, 7)
    cost = rng.random((n, m))
    idx = hungarian(cost)
    assert len(set(idx.tolist())) == n  # one-to-one
    assert cost[np.arange(n), idx].sum() == pytest.approx(_brute_force(cost))


@pytest.mark.parametrize("seed", range(5))
def test_pure_jv_matches_scipy(seed):
    rng = np.random.default_rng(100 + seed)
    n, m = rng.integers(2, 12), rng.integers(12, 20)
    cost = rng.random((n, m))
    a = hungarian(cost)
    b = hungarian_jv(cost)
    assert cost[np.arange(n), a].sum() == pytest.approx(
        cost[np.arange(n), b].sum())


def _req(i, latency, workers=2, t=0.0, overlap=None):
    return CompletedRequest(
        request_id=str(i), worker=i % workers, latency=latency,
        overlap=overlap if overlap is not None else [0.0] * workers,
        finish_time=t)


def test_poa_scales_with_observed_latency():
    tr = PoATracker(num_workers=2)
    for i in range(64):
        tr.record(_req(i, latency=1.0, t=float(i) * 0.1))
    poa1 = tr.current_poa()
    tr2 = PoATracker(num_workers=2)
    for i in range(64):
        tr2.record(_req(i, latency=3.0, t=float(i) * 0.1))
    assert tr2.current_poa() == pytest.approx(3 * poa1, rel=1e-6)


def test_window_count_cap():
    tr = PoATracker(num_workers=2, window_count=16)
    for i in range(100):
        tr.record(_req(i, 1.0, t=float(i) * 0.01))
    assert tr.window_size() == 16


def test_window_time_cap():
    tr = PoATracker(num_workers=2, window_s=5.0, window_count=1000)
    for i in range(50):
        tr.record(_req(i, 1.0, t=float(i)))
    assert tr.window_size(now=49.0) <= 6


def test_overlap_credit_reduces_opt():
    tr = PoATracker(num_workers=2)
    reqs_cold = [_req(i, 1.0, overlap=[0.0, 0.0]) for i in range(32)]
    reqs_warm = [_req(i, 1.0, overlap=[1.0, 1.0]) for i in range(32)]
    assert tr.opt_cost(reqs_warm) < tr.opt_cost(reqs_cold)


def test_more_workers_lower_opt():
    """The 1P/5D plateau sits above 1P/2D because OPT prices a lighter
    balanced load per worker (paper §8.1)."""
    reqs = [_req(i, 1.0, workers=2) for i in range(128)]
    opt2 = PoATracker(num_workers=2).opt_cost(reqs)
    reqs5 = [CompletedRequest(str(i), i % 5, 1.0, [0.0] * 5, 0.0)
             for i in range(128)]
    opt5 = PoATracker(num_workers=5).opt_cost(reqs5)
    assert opt5 < opt2


def test_empty_window_nan():
    tr = PoATracker(num_workers=2)
    assert np.isnan(tr.current_poa())


def test_truncation_branch_scaled_lower_bound_vs_bruteforce():
    """n > cols: OPT prices the first ``cols`` requests one-to-one and
    scales by n/cols.  Pin that against brute force on an instance small
    enough to enumerate (2 workers × capacity 2 = 4 columns, 6 requests),
    for the dedup and the dense path both."""
    overlaps = [[0.9, 0.0], [0.0, 0.4], [0.2, 0.2],
                [0.7, 0.1], [0.0, 0.0], [0.5, 0.5]]
    reqs = [_req(i, 1.0, overlap=o) for i, o in enumerate(overlaps)]
    n, cols = len(reqs), 4
    got = {}
    for dedup in (True, False):
        tr = PoATracker(num_workers=2, capacity=2, dedup=dedup)
        got[dedup] = tr.opt_cost(reqs)
    assert got[True] == pytest.approx(got[False], abs=0.0)   # identical
    # brute force the truncated square problem, then apply the same scale
    tr = PoATracker(num_workers=2, capacity=2, dedup=False)
    from repro.core.latency import latency
    base = float(latency(np.asarray(n / 2), tr.params))
    cost = np.array([[base - tr.cache_weight * o for o in ov]
                     for ov in overlaps])[:cols]
    cost = np.repeat(cost, [2, 2], axis=1)
    assert got[False] == pytest.approx(_brute_force(cost) * (n / cols))


@pytest.mark.parametrize("seed", range(6))
def test_column_dedup_matches_dense_matrix(seed):
    """Collapsing identical replicated columns into capacitated columns
    must return the same OPT as the dense matrix — homogeneous and
    heterogeneous capacity shares, sparse overlap vectors."""
    rng = np.random.default_rng(seed)
    w = int(rng.integers(3, 8))
    n = int(rng.integers(4, 40))
    reqs = []
    for i in range(n):
        ov = np.zeros(w)
        warm = rng.integers(0, w, size=rng.integers(0, 3))
        ov[warm] = rng.integers(1, 9, size=warm.shape) / 8.0
        reqs.append(_req(i, 1.0, workers=w, overlap=ov.tolist()))
    caps = () if seed % 2 == 0 else tuple(
        float(c) for c in rng.integers(0, 4, size=w) * 8.0)
    for capacity in (2, 64):
        kw = dict(num_workers=w, capacity=capacity, capacities=caps)
        dense = PoATracker(dedup=False, **kw).opt_cost(reqs)
        deduped = PoATracker(dedup=True, **kw).opt_cost(reqs)
        assert deduped == pytest.approx(dense, rel=1e-12)
