"""Fabric model (fourth game): topology, store-and-forward scheduling,
quote/charge parity, drain refunds, flat-path pricing parity, and the
congestion counterfactual the network-aware router is supposed to win.
"""
import json
import math

import pytest

from repro.serving.fabric import (Fabric, FabricConfig, kv_hop_seconds,
                                  transfer_block_count)
from repro.serving.scenarios import build_simulator


def _fabric(nd=4, npre=2, **kw):
    return Fabric(FabricConfig(**kw), num_decode=nd, num_prefill=npre)


# ------------------------------------------------------- shared pricing ----


def test_kv_hop_seconds_is_the_flat_charge():
    # both backends price the fabric-less hop through this one helper:
    # the engine passes (per-block seconds, moved blocks), the simulator
    # (per-block seconds, miss fraction) — same product either way
    assert kv_hop_seconds(0.012, 3) == 0.012 * 3
    assert kv_hop_seconds(0.020, 1.0 - 0.75) == 0.020 * 0.25
    assert kv_hop_seconds(0.012, 0) == 0.0


def test_transfer_block_count():
    assert transfer_block_count(8, 0.0) == 8          # full miss
    assert transfer_block_count(8, 1.0) == 0          # fully warm
    assert transfer_block_count(8, 0.5) == 4
    assert transfer_block_count(0, 0.0) == 0
    assert transfer_block_count(-3, 0.0) == 0
    assert transfer_block_count(8, 0.99) == 0         # rounds to zero
    for total in range(1, 20):
        for ov in (0.0, 0.1, 0.33, 0.5, 0.9, 1.0):
            n = transfer_block_count(total, ov)
            assert 0 <= n <= total


def test_sim_flat_path_prices_through_shared_helper(monkeypatch):
    """Satellite regression: the simulator's fabric-less transfer charge
    must route through kv_hop_seconds (one pricing helper, both
    backends) — a reintroduced inline formula breaks this spy."""
    import repro.serving.simulator as simmod
    calls = []
    real = kv_hop_seconds

    def spy(per_block_s, blocks):
        calls.append((per_block_s, blocks))
        return real(per_block_s, blocks)

    monkeypatch.setattr(simmod, "kv_hop_seconds", spy)
    sim = build_simulator("70b-1p2d-ramp", seed=0, fast=True)
    res = sim.run()
    assert res.completed and calls
    per_block = {c[0] for c in calls}
    specs = {sim.workers[w].spec.kv_transfer for w in sim.decode_ids}
    assert per_block <= specs                 # priced at the worker's rate
    assert all(0.0 <= blocks <= 1.0 for _s, blocks in calls)


def test_engine_flat_path_prices_through_shared_helper(monkeypatch):
    """Same spy on the engine backend: per-block rate × integral moved
    block count, through the same helper."""
    from repro.serving.scenarios import build_backend
    import repro.serving.disagg as dmod
    calls = []
    real = kv_hop_seconds

    def spy(per_block_s, blocks):
        calls.append((per_block_s, blocks))
        return real(per_block_s, blocks)

    monkeypatch.setattr(dmod, "kv_hop_seconds", spy)
    runner = build_backend("parity-2d-warm", backend="engine", seed=0,
                           fast=True, num_requests=4)
    out = runner.run()
    assert out.requests and calls
    assert all(s == runner.cluster.kv_transfer_per_block for s, _b in calls)
    assert all(float(b).is_integer() and b >= 0 for _s, b in calls)


# ------------------------------------------------------------ topology ----


def test_rack_layout_and_paths():
    fab = _fabric(nd=12, npre=4, rack_size=8)    # 16 workers, 2 racks
    assert fab.num_racks == 2
    assert "spine" in fab.links
    assert fab.rack_of(0) == 0 and fab.rack_of(7) == 0 and fab.rack_of(8) == 1
    assert fab.path(3, 3) == []
    assert fab.path(1, 5) == ["nic:1", "rack:0", "nic:5"]
    assert fab.path(1, 9) == ["nic:1", "rack:0", "spine", "rack:1", "nic:9"]


def test_single_rack_has_no_spine():
    fab = _fabric(nd=4, npre=2, rack_size=8)
    assert fab.num_racks == 1
    assert "spine" not in fab.links
    assert fab.path(5, 2) == ["nic:5", "rack:0", "nic:2"]


def test_default_pool_layout_matches_simulator_convention():
    fab = _fabric(nd=4, npre=2)
    assert fab.decode_ids == (0, 1, 2, 3)
    assert fab.prefill_ids == (4, 5)


# ------------------------------------------------ store-and-forward ----


def test_uncongested_transfer_is_path_serialization():
    fab = _fabric(nd=4, npre=2, nic_gbps=25.0, rack_gbps=100.0)
    n, size = 8, 8 * fab.config.bytes_per_block
    q = fab.quote(4, 0, n, now=0.0)
    nic = size / (25.0 * 1e9 / 8)
    rack = size / (100.0 * 1e9 / 8)
    assert q == pytest.approx(nic + rack + nic)
    assert fab.floor_seconds(4, n) == pytest.approx(q)


def test_shared_nic_serializes_transfers():
    fab = _fabric(nd=4, npre=3)
    t1 = fab.enqueue("a", 4, 0, 8, now=0.0)
    # second transfer into the SAME decode NIC queues behind the first
    t2 = fab.enqueue("b", 5, 0, 8, now=0.0)
    assert t2.finish_t > t1.finish_t
    # a transfer between DIFFERENT endpoints does not pay that queue
    t3 = fab.enqueue("c", 6, 1, 8, now=0.0)
    assert t3.finish_t < t2.finish_t


def test_quote_replays_as_charge():
    fab = _fabric(nd=8, npre=2)
    now = 0.0
    for i, (src, dst) in enumerate([(8, 0), (9, 0), (8, 3), (9, 0)]):
        q = fab.quote(src, dst, 4 + i, now)
        txm = fab.enqueue(i, src, dst, 4 + i, now)
        assert txm.finish_t - now == pytest.approx(q, abs=1e-12)
        now += 0.001


def test_byte_conservation_across_lifecycle():
    fab = _fabric(nd=4, npre=2)
    t1 = fab.enqueue("a", 4, 0, 8, now=0.0)
    t2 = fab.enqueue("b", 5, 1, 4, now=0.0)
    t3 = fab.enqueue("c", 4, 0, 2, now=0.0)
    for name, link in fab.links.items():
        want = sum(t.size for t in (t1, t2, t3) if name in t.path)
        assert link.bytes_inflight == want
    fab.complete(t1)
    fab.cancel(t3, now=0.0)
    for name, link in fab.links.items():
        want = t2.size if name in t2.path else 0
        assert link.bytes_inflight == want
    fab.complete_until(t2.finish_t)           # engine-style lazy settlement
    assert not fab.active
    assert all(l.bytes_inflight == 0 for l in fab.links.values())
    assert (fab.enqueued, fab.completed, fab.cancelled) == (3, 2, 1)


def test_cancel_refunds_reserved_link_time():
    fab = _fabric(nd=4, npre=2)
    q0 = fab.quote(4, 0, 8, 0.0)
    txm = fab.enqueue("a", 4, 0, 8, now=0.0)
    assert fab.quote(4, 0, 8, 0.0) > q0       # reservation visible
    fab.cancel(txm, now=0.0)                  # nothing transmitted yet
    assert fab.links["nic:4"].busy_until == pytest.approx(0.0)
    assert all(l.bytes_inflight == 0 for l in fab.links.values())
    assert all(abs(l.busy_s) < 1e-12 for l in fab.links.values())
    # a later arrival re-quotes as if the cancelled transfer never was
    # (each link refunds back to its segment start, so the staircase
    # reassembles exactly)
    assert fab.quote(4, 0, 8, 0.0) == pytest.approx(q0, abs=1e-12)


def test_cancel_midflight_keeps_transmitted_time():
    fab = _fabric(nd=4, npre=2)
    txm = fab.enqueue("a", 4, 0, 8, now=0.0)
    mid = txm.finish_t / 2
    fab.cancel(txm, now=mid)
    # only the untransmitted residual is refunded; spent time stays spent
    assert fab.links["nic:4"].busy_until <= txm.segments[0][2]
    assert all(l.bytes_inflight == 0 for l in fab.links.values())
    assert fab.links["nic:4"].busy_s >= 0.0


def test_route_src_picks_least_queued_prefill_nic():
    fab = _fabric(nd=4, npre=2)
    assert fab.route_src(0.0) == 4            # tie: lowest wid
    fab.enqueue("a", 4, 0, 8, now=0.0)
    assert fab.route_src(0.0) == 5            # 4's NIC now queued


def test_floor_seconds_cross_rack():
    fab = _fabric(nd=4, npre=8, rack_size=4)  # prefill 4..11, racks 1-2
    same = fab.floor_seconds(4, 8)            # rack 1... decode rack is 0
    fab2 = _fabric(nd=8, npre=4, rack_size=4)
    in_rack = fab2.floor_seconds(4, 8)        # src rack 1, decode racks 0-1
    cross_only = _fabric(nd=4, npre=4, rack_size=4)
    far = cross_only.floor_seconds(4, 8)      # src rack 1, decode rack 0
    assert far > in_rack
    assert same == far                        # 4 decode ids -> rack 0 only


def test_snapshot_quotes_match_frozen_state():
    fab = _fabric(nd=4, npre=2)
    fab.enqueue("a", 4, 0, 8, now=0.0)
    snap = fab.freeze()
    for dst in range(4):
        assert snap.quote(4, dst, 8, 0.0) == pytest.approx(
            fab.quote(4, dst, 8, 0.0))
    assert snap.route_src(0.0) == fab.route_src(0.0)
    key = snap.state_key()
    fab.enqueue("b", 5, 1, 8, now=0.0)        # live fabric moves on...
    assert snap.state_key() == key            # ...the snapshot must not


# ------------------------------------------------------- integration ----


def test_fabric_run_emits_link_telemetry_and_network_game():
    sim = build_simulator("fabric-ramp", seed=0, fast=True)
    res = sim.run()
    assert sim.fabric.enqueued > 0
    assert not sim.fabric.active              # everything settled
    entry = res.poll_log[-1]
    assert "links" in entry and "network_game" in entry
    assert any(v["bytes"] > 0 for v in entry["links"].values())
    ng = entry["network_game"]
    assert ng["poa_network"] >= 1.0 - 1e-9
    assert math.isfinite(ng["poa_network"])
    json.dumps(res.poll_log)                  # telemetry stays serializable


def test_flat_run_has_no_fabric_telemetry():
    res = build_simulator("70b-1p2d-ramp", seed=0, fast=True).run()
    for entry in res.poll_log:
        assert "links" not in entry and "network_game" not in entry
    assert all(r.transfer_wait == 0.0 and r.transfer_floor == 0.0
               for r in res.completed)


def test_completed_requests_carry_transfer_accounting():
    sim = build_simulator("fabric-ramp", seed=0, fast=True)
    res = sim.run()
    waits = [r.transfer_wait for r in res.completed]
    floors = [r.transfer_floor for r in res.completed]
    assert any(w > 0 for w in waits)
    # realized wait can never beat the uncongested floor
    assert all(w >= f - 1e-12 for w, f in zip(waits, floors))


def test_drain_protocol_cancels_inflight_transfer():
    """Drive the drain protocol against a live transmission: the stalled
    request's transfer is refunded, the request re-routes away from the
    draining worker, and the byte accounting stays green (N1)."""
    sim = build_simulator("fabric-ramp", seed=0, fast=True, sanitize=True)
    res = sim.run()
    fab = sim.fabric
    victim = sim.workers[sim.decode_ids[0]]
    req = res.completed[-1]
    req.decode_worker = victim.wid
    req.txm = fab.enqueue(req.rid, fab.route_src(sim.now), victim.wid, 4,
                          sim.now)
    victim.transfer_queue.append(req)
    before = fab.cancelled
    sim._start_drain_to_prefill(victim)
    assert fab.cancelled == before + 1
    assert req.decode_worker != victim.wid    # re-routed off the victim
    sim.sanitizer.check_all("post-drain")     # refund balanced the links


def test_network_aware_selection_wins_under_congestion():
    """The acceptance observable at smoke scale: on the congested fabric
    scenario, network-aware decode selection strictly reduces realized
    transfer waiting versus cache-affinity-only routing, and the network
    PoA-hat drops toward 1."""
    flat = build_simulator("fabric-scale-64", seed=0, fast=True).run()
    aware = build_simulator("fabric-scale-64", seed=0, fast=True,
                            network_aware=True).run()
    ng_flat = flat.poll_log[-1]["network_game"]
    ng_aware = aware.poll_log[-1]["network_game"]
    wait_flat = sum(r.transfer_wait for r in flat.completed)
    wait_aware = sum(r.transfer_wait for r in aware.completed)
    assert wait_aware < wait_flat
    assert ng_aware["poa_network"] <= ng_flat["poa_network"]
    assert len(aware.completed) == len(flat.completed)


def test_replicated_fabric_views_quote_frozen_state():
    """Replica views score candidates against the fabric snapshot taken
    at sync — the run completes, settles every transfer, and R2 covers
    the snapshot's link state (6-tuple frozen_state)."""
    sim = build_simulator("fabric-scale-64", seed=0, fast=True, replicas=2,
                          staleness=2.0, network_aware=True, sanitize=True)
    res = sim.run()
    sim.sanitizer.check_all("post-run")
    assert res.completed and not sim.fabric.active
    for v in sim.control.replica_views:
        assert len(v.frozen_state()) == 6
