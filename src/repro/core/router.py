"""KV-aware Smart Router — the mechanism of Game 3.

Per-worker cost (Dynamo Eq. 1):      c_j = ω·b_j^prefill + b_j^active
Worker selection (Eq. 2):            argmin (τ=0)  or  softmax(−c/τ) sample

``b_j^prefill`` — token blocks that would need prefilling on worker j
(total blocks − cached overlap, from the KvIndexer radix tree);
``b_j^active`` — active decode blocks on worker j (load proxy).

``best_worker`` accepts a per-request ``router_config_override`` — the hook
the paper's adaptive controller uses to switch (τ, ω) without restarts.
The sequential greedy assignment this implements is best-response dynamics
in the routing congestion game (paper §4.3).
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.radix import KvIndexer


@dataclass(frozen=True)
class KvRouterConfig:
    overlap_weight: float = 1.0        # ω (kv_overlap_score_weight)
    temperature: float = 0.0           # τ (router_temperature)


@dataclass
class WorkerState:
    worker_id: int
    active_blocks: int = 0             # b_j^active
    healthy: bool = True
    capacity: float = 1.0              # relative decode capacity (slots)


class KvPushRouter:
    """The router core; mirrors Dynamo's Python handler semantics."""

    def __init__(self, num_workers: int, config: Optional[KvRouterConfig] = None,
                 indexer: Optional[KvIndexer] = None, seed: int = 0):
        self.workers: Dict[int, WorkerState] = {
            i: WorkerState(i) for i in range(num_workers)}
        self.config = config or KvRouterConfig()
        self.indexer = indexer or KvIndexer()
        self._rng = random.Random(seed)

    # ------------------------------------------------------------- costs ----

    # Cache-affinity scale: how much active load (in request units) a full
    # prefix hit is worth in the Eq. 1 cost. Dynamo measures both terms in
    # blocks; we normalize b_active to request units and scale b_prefill so
    # ω=1 affinity competes with realistic load imbalances (calibration
    # liberty recorded in DESIGN.md).
    PREFILL_BLOCK_SCALE = 20.0

    def _normalized_load(self, ids: List[int]) -> List[float]:
        """b_j^active normalized by relative worker capacity.

        Heterogeneous pools (mixed-generation GPUs) expose different
        ``capacity`` values; the load proxy is rescaled so a worker at 50%
        of its slots competes equally regardless of absolute slot count.
        Homogeneous pools (all capacities equal) take the identity path —
        raw block counts — so legacy behavior is bit-exact.
        """
        caps = [self.workers[wid].capacity for wid in ids]
        if len(set(caps)) <= 1:
            return [float(self.workers[wid].active_blocks) for wid in ids]
        ref = sum(caps) / len(caps)
        return [self.workers[wid].active_blocks * (ref / cap)
                for wid, cap in zip(ids, caps)]

    def costs(self, tokens: Sequence[int],
              config: Optional[KvRouterConfig] = None, now: float = 0.0
              ) -> Tuple[List[int], List[float], List[float]]:
        """Returns (worker_ids, costs c_j, overlap fractions o_j)."""
        cfg = config or self.config
        ids = self.healthy_ids()
        overlaps = self.indexer.overlap_scores(tokens, ids, now)
        loads = self._normalized_load(ids)
        costs = []
        for ov, b_active in zip(overlaps, loads):
            b_prefill = self.PREFILL_BLOCK_SCALE * (1.0 - ov)
            costs.append(cfg.overlap_weight * b_prefill + b_active)
        return ids, costs, overlaps

    # ------------------------------------------------------------ select ----

    def best_worker(self, tokens: Sequence[int],
                    router_config_override: Optional[KvRouterConfig] = None,
                    now: float = 0.0) -> Tuple[int, float, List[float]]:
        """Returns (worker_id, overlap_score_of_chosen, overlap_per_worker).

        τ=0: deterministic argmin (Eq. 2 limit). τ>0: softmax over costs
        normalized by their spread (Dynamo's τ∈[0,1] operates on normalized
        costs; raw block counts would make any τ≤1 effectively greedy)."""
        cfg = router_config_override or self.config
        ids, costs, overlaps = self.costs(tokens, cfg, now)
        if not ids:
            raise RuntimeError("no healthy workers")
        if cfg.temperature <= 0.0 or len(ids) == 1:
            j = min(range(len(ids)), key=lambda i: (costs[i], ids[i]))
        else:
            mn = min(costs)
            spread = max(max(costs) - mn, 1e-9)
            z = [(c - mn) / spread for c in costs]          # ∈ [0, 1]
            ws = [math.exp(-zi / cfg.temperature) for zi in z]
            tot = sum(ws)
            r = self._rng.random() * tot
            acc = 0.0
            j = len(ids) - 1
            for i, w in enumerate(ws):
                acc += w
                if r <= acc:
                    j = i
                    break
        return ids[j], overlaps[j], overlaps

    # --------------------------------------------------------- bookkeeping --

    def healthy_ids(self) -> List[int]:
        """Worker ids eligible for routing, in the table's stable order —
        the positional universe of ``costs()``/``best_worker()`` overlaps."""
        return [w for w, st in self.workers.items() if st.healthy]

    def add_worker(self, worker_id: int, capacity: float = 1.0) -> WorkerState:
        """(Re-)enlist a worker in the routing table with a clean load view
        — the Game 1 repartitioning path when a prefill-role worker flips
        into the decode pool.  Re-enlisting an id that drained out earlier
        reuses its table slot (keeping positional order stable)."""
        st = self.workers.get(worker_id)
        if st is None:
            st = self.workers[worker_id] = WorkerState(worker_id)
        st.healthy = True
        st.active_blocks = 0
        st.capacity = max(capacity, 1e-9)
        return st

    def on_schedule(self, worker_id: int, tokens: Sequence[int],
                    decode_blocks: float = 1.0, now: float = 0.0):
        """Request placed: bump the load proxy and index its KV blocks."""
        st = self.workers[worker_id]
        st.active_blocks += decode_blocks
        self.indexer.insert(worker_id, tokens, now)

    def on_complete(self, worker_id: int, tokens: Sequence[int],
                    decode_blocks: float = 1.0):
        st = self.workers[worker_id]
        st.active_blocks = max(st.active_blocks - decode_blocks, 0.0)

    def set_health(self, worker_id: int, healthy: bool):
        self.workers[worker_id].healthy = healthy

    def set_capacity(self, worker_id: int, capacity: float):
        """Declare a worker's relative decode capacity (heterogeneity)."""
        self.workers[worker_id].capacity = max(capacity, 1e-9)


# ------------------------------------------------------ static baselines ----
#
# Every baseline implements the same ``best_worker(tokens,
# router_config_override=None, now=0.0)`` signature as KvPushRouter, so
# routing policies are drop-in interchangeable, and all of them skip
# unhealthy workers (routing to a dead worker is not a baseline, it's a
# bug).  Built from an int they keep a standalone all-healthy worker
# table; built from a KvPushRouter they share its table, so
# ``set_health`` on the router is visible to the baseline.


class _BaselineRouter:
    def __init__(self, workers):
        if isinstance(workers, KvPushRouter):
            self._table = workers.workers
        else:
            self._table = {i: WorkerState(i) for i in range(int(workers))}

    def _healthy_ids(self) -> List[int]:
        ids = [w for w, st in self._table.items() if st.healthy]
        if not ids:
            raise RuntimeError("no healthy workers")
        return ids

    def set_health(self, worker_id: int, healthy: bool):
        self._table[worker_id].healthy = healthy


class RoundRobinRouter(_BaselineRouter):
    """§9.2 counterfactual baseline: cycle over the healthy workers."""

    def __init__(self, workers):
        super().__init__(workers)
        self._i = 0

    def best_worker(self, tokens, router_config_override=None, now=0.0):
        ids = self._healthy_ids()
        w = ids[self._i % len(ids)]
        self._i += 1
        return w, 0.0, [0.0] * len(ids)


class RandomRouter(_BaselineRouter):
    def __init__(self, workers, seed: int = 0):
        super().__init__(workers)
        self._rng = random.Random(seed)

    def best_worker(self, tokens, router_config_override=None, now=0.0):
        ids = self._healthy_ids()
        return ids[self._rng.randrange(len(ids))], 0.0, [0.0] * len(ids)


class PowerOfTwoRouter(_BaselineRouter):
    """Pick two random workers, route to the less loaded (§9.2 baseline)."""

    def __init__(self, router: KvPushRouter, seed: int = 0):
        super().__init__(router)
        self.router = router
        self._rng = random.Random(seed)

    def best_worker(self, tokens, router_config_override=None, now=0.0):
        ids = self._healthy_ids()
        a, b = self._rng.sample(ids, 2) if len(ids) >= 2 else (ids[0], ids[0])
        # compare capacity-normalized utilization so heterogeneous pools
        # don't starve the small workers (ties break to the first pick)
        wa = (self.router.workers[a].active_blocks
              / self.router.workers[a].capacity)
        wb = (self.router.workers[b].active_blocks
              / self.router.workers[b].capacity)
        w = a if wa <= wb else b
        return w, 0.0, [0.0] * len(ids)
