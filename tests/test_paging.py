"""PageAllocator unit + property tests (pure Python, no JAX).

The deterministic half pins the arithmetic and ordering contracts the
engine relies on (ceil-div page counts, LIFO reuse determinism, trash
page exclusion, reserve→admit→grow accounting).  The hypothesis half
drives random reserve/admit/grow/release schedules and asserts the two
global invariants every schedule must preserve: no page is ever leaked
or double-owned (``audit()`` stays empty), and capacity accounting is
exact — an admission is granted iff the worst case fits in
``available_pages``, and a drained allocator restores the full pool.

``hypothesis`` is optional (the CI engine lane installs it; the base
container may not have it) — the property tests skip cleanly when
missing while the deterministic half always runs.
"""
import pytest

from repro.serving.paging import TRASH_PAGE, PageAllocator

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - exercised in base container
    HAVE_HYPOTHESIS = False

BLOCK = 16


# ---------------------------------------------------------- deterministic --

def test_pages_for_ceil_division():
    a = PageAllocator(8, BLOCK)
    assert a.pages_for(0) == 1          # a slot always holds ≥ 1 page
    assert a.pages_for(1) == 1
    assert a.pages_for(BLOCK) == 1
    assert a.pages_for(BLOCK + 1) == 2
    assert a.pages_for(3 * BLOCK) == 3
    assert a.pages_for(3 * BLOCK + 1) == 4


def test_trash_page_never_allocated():
    a = PageAllocator(4, BLOCK)
    pages = a.admit(0, 4)
    assert TRASH_PAGE not in pages
    assert sorted(pages) == [1, 2, 3, 4]
    a.release(0)
    assert TRASH_PAGE not in a.free_list()


def test_fresh_pool_hands_out_ascending_then_lifo_reuse():
    a = PageAllocator(6, BLOCK)
    assert a.admit(0, 2) == [1, 2]
    assert a.admit(1, 2) == [3, 4]
    a.release(0)                         # 1, 2 go to the free-list tail
    # LIFO: the most recently released page comes back first —
    # deterministic replay is what makes engine streams reproducible
    assert a.admit(2, 1) == [2]
    assert a.admit(3, 2) == [1, 5]


def test_reserve_then_admit_accounting():
    a = PageAllocator(6, BLOCK)
    assert a.reserve(0, 4)
    assert a.available_pages == 2        # 6 free − 4 promised
    # a second same-tick reservation cannot count slot 0's promise
    assert not a.reserve(1, 3)
    assert a.reserve(1, 2)
    assert a.available_pages == 0
    assert not a.can_admit(1)
    # admit maps the prompt pages now; the remainder stays reserved
    pages = a.admit(0, 2, 4)
    assert len(pages) == 2
    assert a.used_pages == 2 and a.reserved_pages == 2 + 2
    # growth draws on the reservation, never on other slots' promises
    a.grow(0)
    a.grow(0)
    assert a.reserved.get(0, 0) == 0 and len(a.owned[0]) == 4
    # slot 1's promise survived untouched
    assert a.reserved[1] == 2
    a.release(0)
    a.release(1)
    assert a.free_pages == 6 and a.reserved_pages == 0
    assert a.audit() == []


def test_unreserved_admit_gates_on_worst_case():
    a = PageAllocator(4, BLOCK)
    # worst case 5 > pool: refused even though n_map fits
    assert a.admit(0, 2, 5) is None
    assert a.free_pages == 4 and a.audit() == []
    pages = a.admit(0, 2, 4)
    assert len(pages) == 2 and a.reserved[0] == 2


def test_ungated_grow_raises():
    a = PageAllocator(2, BLOCK)
    a.admit(0, 2)                        # whole pool, no reservation left
    with pytest.raises(RuntimeError, match="page pool exhausted"):
        a.grow(0)


def test_release_of_reserve_only_slot():
    a = PageAllocator(4, BLOCK)
    a.reserve(0, 3)
    assert a.release(0) == []
    assert a.available_pages == 4 and a.audit() == []


# --------------------------------------------------------------- property --
# @given/@settings evaluate at import time, so the whole section lives
# behind the availability check rather than a per-test skipif

if HAVE_HYPOTHESIS:
    # each op: (kind, slot, n_map, n_total) — slots from a small id space
    # so schedules revisit slots across lifecycles
    _OPS = st.lists(
        st.tuples(st.sampled_from(["reserve", "admit", "grow", "release"]),
                  st.integers(0, 3),
                  st.integers(1, 4),
                  st.integers(1, 6)),
        min_size=1, max_size=40)

    @settings(deadline=None, max_examples=200, derandomize=True,
              suppress_health_check=[HealthCheck.too_slow])
    @given(num_pages=st.integers(2, 12), ops=_OPS)
    def test_random_schedule_never_leaks_or_double_owns(num_pages, ops):
        """Any interleaving of the lifecycle ops keeps the pool
        partitioned: audit() stays empty after every op, admissions are
        granted iff the worst case fits available_pages, grow succeeds
        whenever admission was gated, and draining all slots restores the
        exact full pool."""
        a = PageAllocator(num_pages, BLOCK)
        for kind, slot, n_map, n_total in ops:
            n_total = max(n_map, n_total)
            if kind == "reserve" and slot not in a.owned \
                    and slot not in a.reserved:
                pre_avail = a.available_pages
                ok = a.reserve(slot, n_total)
                assert ok == (n_total <= pre_avail)
            elif kind == "admit" and slot not in a.owned:
                # engine contract: an admitted prompt maps at most the
                # worst case promised at reserve time
                if slot in a.reserved:
                    n_map = min(n_map, a.reserved[slot])
                # a pre-reserved slot draws on its own promise, so its
                # own reservation counts as available to it
                pre_avail = a.available_pages + a.reserved.get(slot, 0)
                pages = a.admit(slot, n_map, n_total)
                if pages is None:
                    # refusal is exact: the worst case really didn't fit
                    assert n_total > pre_avail
                else:
                    assert len(pages) == n_map
                    assert len(set(pages)) == n_map
                    assert TRASH_PAGE not in pages
            elif kind == "grow" and slot in a.owned:
                if a.reserved.get(slot, 0) > 0 or a.available_pages > 0:
                    page = a.grow(slot)
                    assert page != TRASH_PAGE
                else:
                    with pytest.raises(RuntimeError):
                        a.grow(slot)
            elif kind == "release":
                a.release(slot)
            # the partition invariant holds after EVERY op
            assert a.audit() == []
            assert a.used_pages + a.free_pages == num_pages
            assert 0 <= a.available_pages <= a.free_pages
        for slot in list(a.owned) + list(a.reserved):
            a.release(slot)
        assert a.free_pages == num_pages and a.reserved_pages == 0
        assert sorted(a.free_list()) == sorted(a.all_pages())

    @settings(deadline=None, max_examples=100, derandomize=True,
              suppress_health_check=[HealthCheck.too_slow])
    @given(num_pages=st.integers(1, 10),
           requests=st.lists(st.integers(1, 5), min_size=1, max_size=8))
    def test_capacity_accounting_exact(num_pages, requests):
        """Sequential admissions succeed exactly while the summed worst
        cases fit the pool — no page stranded, none double-counted."""
        a = PageAllocator(num_pages, BLOCK)
        admitted = 0
        for slot, n in enumerate(requests):
            want = a.can_admit(n)
            assert want == (n <= num_pages - a.used_pages
                            - a.reserved_pages)
            pages = a.admit(slot, n, n)
            assert (pages is not None) == want
            if pages is not None:
                admitted += n
        assert a.used_pages == admitted
        assert a.audit() == []
