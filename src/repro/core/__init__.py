# The paper's primary contribution: the three coupled games of disaggregated
# inference, the empirical PoA estimator, and the adaptive routing controller.
from repro.core.controller import AdaptiveRouter, DualFrontend, REGIME_PARAMS  # noqa: F401
from repro.core.games import CacheGame, RoutingGame, singular_game  # noqa: F401
from repro.core.kvbm import KVBlockManager  # noqa: F401
from repro.core.latency import LatencyParams, latency, routing_cost  # noqa: F401
from repro.core.metrics import MetricsRegistry  # noqa: F401
from repro.core.planner import Planner, PlannerConfig, variational_equilibrium  # noqa: F401
from repro.core.poa import CompletedRequest, PoATracker, hungarian  # noqa: F401
from repro.core.radix import KvIndexer, block_hashes  # noqa: F401
from repro.core.router import (KvPushRouter, KvRouterConfig,  # noqa: F401
                               PowerOfTwoRouter, RandomRouter, RoundRobinRouter)
from repro.core.saturation import DetectorConfig, Regime, SaturationDetector  # noqa: F401
