"""Discrete-event simulator of a disaggregated serving cluster.

Faithfully wires together the paper's mechanisms — Smart Router (Eq. 1/2),
KvIndexer radix tree, KVBM frequency eviction, PoA tracker (Eq. 12),
saturation detector (Eq. 10/11), adaptive controller (Table 2), Planner —
around an event-driven cluster model with the paper's causal channels:

* requests are routed to a decode worker **at arrival** (Dynamo semantics);
* prefill is the compute-bound bottleneck; prefill work per request shrinks
  with the chosen decode worker's KV overlap (cache-warm routing skips
  recomputation — the §8.4 "redundant prefill recomputation" channel), so
  cache-oblivious spreading costs throughput;
* each decode worker has an admission cap (transfer/batch slots); requests
  bound for a saturated worker stall in its transfer queue — the herding
  pathology that blows up TTFT P99 under static greedy routing;
* template traffic is mildly skewed (realistic popularity), which is what
  lets cache-affinity herding concentrate load.

The cluster is a **unified worker-role pool**: one list of :class:`Worker`
objects, each carrying a role (``prefill``/``decode``), its spec, and its
role-specific state (busy flag vs. admission slots + transfer queue +
KVBM).  Static clusters fix the roles at construction; passing a
``planner_config`` closes the Game 1 loop — the Planner joins the event
loop as a third control-plane event (alongside ``poll``/``sync``) and may
flip one worker's role per adjust interval via the drain protocol: stop
admitting, drain running decodes, flush the worker's KVBM and invalidate
its KvIndexer claims, honor the grace period.  Repartitioning therefore
pays the paper's real switching costs (a flipped-in decode worker starts
cache-cold).

The cluster model generalizes along three scenario axes (see
``repro.serving.scenarios`` for the named registry): a prefill *pool*
(``num_prefill`` workers draining one shared queue), a possibly
heterogeneous decode pool (per-worker ``DecodeWorkerSpec`` — admission
cap, HBM blocks, ITL, KV-transfer latency — with capacity-normalized
router loads and capacity-weighted PoA counterfactuals), and three
workload modes (closed-loop ramps, open-loop Poisson/burst/diurnal
arrivals, JSONL trace replay).

Closed-loop clients maintain the workload's target concurrency. Calibrated
per model (340B / 70B; Section 7) so the paper's regime structure — PoA
plateau below the knee, first post-knee grid point at C=128, TTFT explosion
with flat ITL, throughput ceilings ≈18/47 rps — emerges from the same
mechanics the paper identifies (prefill-rate × request-residency ≈ C at the
knee). Calibration constants and deviations are logged in EXPERIMENTS.md.
"""
from __future__ import annotations

import heapq
import itertools
import math
import re
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.controller import violation_rates
from repro.core.kvbm import KVBlockManager
from repro.core.planner import PlannerConfig, ResponseModel
from repro.core.poa import CompletedRequest
from repro.core.radix import block_hashes
from repro.core.router import KvRouterConfig
from repro.core.saturation import DetectorConfig
from repro.serving.control_plane import ControlPlane
from repro.serving.fabric import (Fabric, FabricConfig, kv_hop_seconds,
                                  transfer_block_count)
from repro.serving.workload import (WorkloadConfig, template_mix,
                                    template_tokens)

PREFILL_ROLE = "prefill"
DECODE_ROLE = "decode"

_TOPOLOGY_RE = re.compile(r"(\d+)\s*[Pp]\s*/\s*(\d+)\s*[Dd]")


@dataclass(frozen=True)
class DecodeWorkerSpec:
    """Per-decode-worker capacity profile (heterogeneous pools).

    A mixed-generation GPU pool is expressed as a tuple of these: newer
    cards get a larger ``decode_cap``/``g1_blocks`` and smaller
    ``itl_base``; remote nodes get a larger ``kv_transfer``.  The
    ``g2_blocks``/``g3_blocks`` tiers back the hierarchical KVBM (Def. 2):
    blocks demoted out of G1 HBM land in CPU DRAM then local SSD, from
    which they can be onboarded instead of recomputed (§8.4).
    """
    decode_cap: int = 60              # admission slots (transfer/batch)
    g1_blocks: int = 100_000          # HBM KV-block capacity
    g2_blocks: int = 400_000          # CPU-DRAM KV-block capacity
    g3_blocks: int = 1_600_000        # local-SSD KV-block capacity
    itl_base: float = 0.0090          # inter-token latency at low load (s)
    itl_slope: float = 0.000005       # load dependence (bandwidth-bound)
    kv_transfer: float = 0.012        # prefill→decode KV transfer latency (s)


@dataclass(frozen=True)
class ClusterConfig:
    """Calibrated per model/topology (paper Section 7.3/8).

    Homogeneous clusters use the scalar per-worker fields below; a
    heterogeneous decode pool is declared by ``decode_workers`` (a tuple of
    :class:`DecodeWorkerSpec`), which overrides the scalars and pins
    ``num_decode`` to its length.  ``num_prefill > 1`` models a prefill
    pool draining one shared queue.
    """
    name: str = "llama-3.1-70b"
    num_prefill: int = 1
    num_decode: int = 2
    prefill_rate: float = 47.0        # cache-warm requests/s ceiling per worker
    prefill_base: float = 0.015       # pipelined prefill latency component (s)
    miss_penalty: float = 0.65        # extra prefill work on a full cache miss
    itl_base: float = 0.0090          # inter-token latency at low load (s)
    itl_slope: float = 0.000005       # mild load dependence (bandwidth-bound)
    kv_transfer: float = 0.012        # cross-node KV transfer latency (s)
    decode_cap: int = 60              # admission slots per decode worker
    g1_blocks: int = 100_000          # per-decode-worker HBM block capacity
    g2_blocks: int = 400_000          # per-decode-worker CPU-DRAM blocks
    g3_blocks: int = 1_600_000        # per-decode-worker local-SSD blocks
    # Eq. 6 per-block onboarding latencies, α_G1 < α_G2 < α_G3 < α_G4 < γ
    # (a G1 hit is free; γ ≈ miss_penalty/prefill_rate per input block —
    # ~1.7 ms for the 70B defaults — bounds the alphas from above so
    # onboarding is always preferable to redundant recompute).
    alpha_g2: float = 0.0003          # G2→G1 onboarding per block (s)
    alpha_g3: float = 0.0012          # G3→G1 onboarding per block (s)
    alpha_g4: float = 0.0016          # G4→G1 onboarding per block (s)
    service_sigma: float = 0.5        # lognormal service jitter (batching)
    cache_ttl: float = 3.0            # radix-claim freshness (LRU churn model)
    metrics_interval: float = 1.0     # event-plane load-metric staleness (s)
    decode_workers: Tuple[DecodeWorkerSpec, ...] = ()

    def __post_init__(self):
        if self.decode_workers and self.num_decode != len(self.decode_workers):
            object.__setattr__(self, "num_decode", len(self.decode_workers))

    def default_spec(self) -> DecodeWorkerSpec:
        """The homogeneous per-worker spec built from the scalar fields —
        also what a prefill-origin worker carries into the decode pool."""
        return DecodeWorkerSpec(
            decode_cap=self.decode_cap, g1_blocks=self.g1_blocks,
            g2_blocks=self.g2_blocks, g3_blocks=self.g3_blocks,
            itl_base=self.itl_base, itl_slope=self.itl_slope,
            kv_transfer=self.kv_transfer)

    @property
    def worker_specs(self) -> Tuple[DecodeWorkerSpec, ...]:
        """Resolved per-worker specs (homogeneous scalars expanded)."""
        if self.decode_workers:
            return self.decode_workers
        return tuple(self.default_spec() for _ in range(self.num_decode))

    @classmethod
    def parse_topology(cls, topology: str) -> Tuple[int, int]:
        """Parse ``"<n>P/<m>D"`` into (num_prefill, num_decode), rejecting
        malformed strings (``"1P5D"``, ``"1p/"``, ``"2D/1P"``, …) with a
        clear error instead of silently mis-parsing them."""
        m = _TOPOLOGY_RE.fullmatch(topology.strip())
        if m is None:
            raise ValueError(
                f"malformed topology {topology!r}: expected \"<n>P/<m>D\" "
                f"(prefill workers, a slash, decode workers — e.g. \"1P/2D\")")
        npf, nd = int(m.group(1)), int(m.group(2))
        if npf < 1 or nd < 1:
            raise ValueError(
                f"topology {topology!r} needs at least one prefill and one "
                f"decode worker")
        return npf, nd

    @classmethod
    def for_model(cls, name: str, topology: str = "1P/2D") -> "ClusterConfig":
        npf, nd = cls.parse_topology(topology)
        if "340b" in name.lower() or "nemotron" in name.lower():
            return cls(name="nemotron-4-340b", num_prefill=npf, num_decode=nd,
                       prefill_rate=19.0, prefill_base=0.030,
                       itl_base=0.0214, kv_transfer=0.030,
                       decode_cap=58 if nd <= 2 else 30)
        return cls(name="llama-3.1-70b", num_prefill=npf, num_decode=nd,
                   prefill_rate=47.0 if nd <= 2 else 49.0,
                   prefill_base=0.015, itl_base=0.0090,
                   kv_transfer=0.012,
                   decode_cap=56 if nd <= 2 else 30)


@dataclass
class SimRequest:
    rid: int
    template: int
    tokens: List[int]
    output_tokens: int
    submit_t: float = 0.0
    prefill_start: float = 0.0
    prefill_end: float = 0.0
    decode_start: float = 0.0
    finish_t: float = 0.0
    decode_worker: int = -1
    overlap: float = 0.0
    overlaps_all: Tuple[float, ...] = ()
    loads_at_schedule: Tuple[float, ...] = ()
    phase: int = 0
    # tier-coherent cache accounting (quoted at scheduling time)
    hashes: Tuple[int, ...] = ()          # chained KV block hashes
    onboard_frac: float = 0.0             # blocks onboarded from G2/G3/G4
    onboard_latency: float = 0.0          # Eq. 6 onboarding TTFT add (s)
    # fabric accounting (fourth game; all zero/None when fabric is off)
    prefill_worker: int = -1              # wid whose NIC sources the transfer
    txm: Optional[object] = None          # live Transmission, if any
    transfer_wait: float = 0.0            # fabric service incl. link queueing
    transfer_floor: float = 0.0           # uncongested (OPT) transfer time

    @property
    def ttft(self) -> float:
        return self.prefill_end - self.submit_t

    @property
    def itl(self) -> float:
        return (self.finish_t - self.decode_start) / max(self.output_tokens, 1)


@dataclass
class Worker:
    """One GPU slot in the unified pool; ``role`` decides which state is
    live.

    Prefill-role workers drain the shared prefill queue (``busy``);
    decode-role workers own admission slots (``running`` vs
    ``spec.decode_cap``), a ``transfer_queue`` of stalled KV transfers, and
    a hierarchical ``kvbm``.  The Planner flips roles at runtime through
    the drain protocol: ``draining`` decode workers stop admitting and
    finish their running decodes before the flip completes; a busy prefill
    worker flagged ``pending_role`` flips at its next idle moment."""
    wid: int
    role: str
    spec: DecodeWorkerSpec
    # prefill-role state
    busy: bool = False
    # decode-role state
    running: int = 0
    peak_running: int = 0
    transfer_queue: Deque[SimRequest] = field(default_factory=deque)
    kvbm: Optional[KVBlockManager] = None
    # drain protocol
    draining: bool = False
    pending_role: Optional[str] = None


class Simulator:
    """Event-driven cluster; see module docstring."""

    def __init__(self, cluster: ClusterConfig, workload: WorkloadConfig,
                 router_config: Optional[KvRouterConfig] = None,
                 adaptive: bool = False,
                 detector_config: Optional[DetectorConfig] = None,
                 routing_policy: str = "kv",       # kv|round_robin|random|p2c
                 seed: int = 0,
                 regime_params: Optional[dict] = None,
                 planner_config: Optional[PlannerConfig] = None,
                 lean_completed: bool = False,
                 replicas: Optional[int] = None,
                 staleness: float = 0.0,
                 fabric: Optional[FabricConfig] = None,
                 network_aware: bool = False,
                 sanitize: Optional[bool] = None):
        self.cluster = cluster
        self.workload = workload
        # Control-plane scale-out: ``replicas=None`` keeps the legacy
        # single-router ControlPlane; an int builds a
        # ReplicatedControlPlane whose replica views refresh every
        # ``staleness`` sync events (staleness 0 = fresh views, pinned
        # bit-exact with the single-router path for any replica count).
        self.replicas = replicas
        self.staleness = staleness
        self._replica_sync_every = (max(int(round(staleness)), 1)
                                    if replicas is not None and staleness > 0
                                    else 0)
        self._sync_i = 0
        # Large-pool scenarios keep 100k+ completed requests around; the
        # per-request O(workers) overlap/load vectors are only consumed by
        # the PoA tracker (which holds its own windowed reference), so lean
        # mode drops them from a request once it is fully accounted.
        self.lean_completed = lean_completed
        # (template, input_tokens) → (tokens, chained block hashes): every
        # request of a template shares the same prompt, so tokenization and
        # hashing happen once per template instead of once per request.
        self._template_cache: dict = {}
        self.now = 0.0
        self._events: List[Tuple[float, int, str, object]] = []
        self._eid = itertools.count()
        self.rng = np.random.default_rng(seed)
        # dedicated stream for open-loop arrival sampling so closed-loop
        # runs stay byte-identical to the pre-scenario simulator
        self.arrival_rng = np.random.default_rng([seed, 0xA221])
        # Template popularity: shared with the engine backend (see
        # repro.serving.workload.template_mix) so both backends sample
        # identical template streams from identical seeds.
        self.template_probs = template_mix(workload.num_templates)

        # ---- unified worker-role pool: decode wids first (0..nd-1, the
        # legacy router universe), then the prefill pool (nd..nd+np-1).
        nd, npre = cluster.num_decode, cluster.num_prefill
        decode_specs = cluster.worker_specs
        prefill_spec = cluster.default_spec()
        self.workers: List[Worker] = (
            [Worker(w, DECODE_ROLE, decode_specs[w]) for w in range(nd)]
            + [Worker(nd + i, PREFILL_ROLE, prefill_spec)
               for i in range(npre)])
        self.decode_ids: List[int] = list(range(nd))
        self.prefill_ids: List[int] = list(range(nd, nd + npre))

        # ---- shared control plane (router + indexer + detector + adaptive
        # params + Planner + PoA + metrics).  Game 1: when a Planner is
        # configured the PoA universe widens to the whole pool (prefill-role
        # slots carry zero capacity, contributing no counterfactual
        # columns); without one the legacy decode-only universe keeps every
        # pre-existing scenario bit-exact.
        if planner_config is not None:
            self._poa_universe = list(range(nd + npre))
        else:
            self._poa_universe = list(range(nd))
        # Fourth game: an explicit fabric replaces the flat KV-hop charge —
        # transfers serialize on shared NIC/rack/spine links and (opt-in)
        # routing quotes effective transfer times from link queue depths.
        self.fabric = (Fabric(fabric, num_decode=nd, num_prefill=npre)
                       if fabric is not None else None)
        plane_kw = dict(
            router_config=router_config,
            routing_policy=routing_policy,
            seed=seed,
            adaptive=adaptive,
            detector_config=(detector_config
                             or DetectorConfig.for_model(cluster.name)),
            regime_params=regime_params,
            cache_ttl=cluster.cache_ttl,
            capacities={wid: float(self.workers[wid].spec.decode_cap)
                        for wid in self.decode_ids},
            poa_num_workers=len(self._poa_universe),
            poa_window_s=30.0,
            planner_config=planner_config,
            num_prefill=npre,
            fabric=self.fabric,
            network_aware=network_aware,
            sanitize=False)   # the simulator attaches its own, richer one
        if replicas is None:
            self.control = ControlPlane(nd, **plane_kw)
        else:
            from repro.serving.control_plane import ReplicatedControlPlane
            self.control = ReplicatedControlPlane(
                nd, replicas=replicas,
                staleness_s=staleness * cluster.metrics_interval,
                **plane_kw)
        cp = self.control
        self.router = cp.router
        self.policy = cp.policy
        self.adaptive = cp.adaptive
        self.detector = cp.detector
        self.dual = cp.dual
        self.regime_params = cp.regime_params
        self.metrics = cp.metrics
        self.planner = cp.planner
        self.planner_config = cp.planner_config
        self.poa = cp.poa
        if self.planner is not None:
            # service-rate telemetry shares the Planner's measurement
            # window (histograms pin window_s at creation, so create them
            # here; without a Planner they default to the 30 s telemetry
            # window on first observation)
            win = self.planner_config.measure_window
            self.metrics.histogram("prefill_service", window_s=win)
            self.metrics.histogram("decode_residency", window_s=win)
        self.role_flips: List[Tuple[float, int, str]] = []
        self._arrivals: Deque[float] = deque()

        self.poa.capacities = self._poa_capacities()

        # Tier-coherent hierarchical cache: whenever KVBM demotes (or
        # frees) a block out of G1 HBM, the router's overlap claim for it
        # is invalidated, so cache-affinity routing only ever credits
        # G1-resident prefixes (the NetKV coherence channel).
        for wid in self.decode_ids:
            self.workers[wid].kvbm = self._new_kvbm(self.workers[wid])

        # shared prefill queue (deque: overload drains pop from the head
        # tens of thousands of times; list.pop(0) is O(n) per pop)
        self.prefill_queue: Deque[SimRequest] = deque()

        self.in_flight = 0
        self.completed: List[SimRequest] = []
        self._rid = itertools.count()
        self.poll_log: List[dict] = []

        # Opt-in runtime coherence sanitizer (repro.analysis.sanitize):
        # wraps the event handlers as instance attributes, so the default
        # (off) path carries no per-event branch at all.
        self.sanitizer = None
        if sanitize is not False:
            from repro.analysis.sanitize import (attach_sim_sanitizer,
                                                 sanitize_enabled)
            if sanitize_enabled(sanitize):
                attach_sim_sanitizer(self)

    # ------------------------------------------------- pool projections -----
    #
    # Legacy views of the worker pool, ordered by the current decode/prefill
    # membership — what tests, benchmarks and examples indexed before the
    # unified pool existed.

    @property
    def specs(self) -> Tuple[DecodeWorkerSpec, ...]:
        return tuple(self.workers[w].spec for w in self.decode_ids)

    @property
    def kvbm(self) -> List[KVBlockManager]:
        return [self.workers[w].kvbm for w in self.decode_ids]

    @property
    def prefill_busy(self) -> List[bool]:
        return [self.workers[w].busy for w in self.prefill_ids]

    @property
    def decode_running(self) -> List[int]:
        return [self.workers[w].running for w in self.decode_ids]

    @property
    def peak_decode_running(self) -> List[int]:
        return [self.workers[w].peak_running for w in self.decode_ids]

    @property
    def transfer_queue(self) -> List[Deque[SimRequest]]:
        return [self.workers[w].transfer_queue for w in self.decode_ids]

    def _new_kvbm(self, worker: Worker) -> KVBlockManager:
        spec = worker.spec
        return KVBlockManager(
            {"G1": spec.g1_blocks, "G2": spec.g2_blocks,
             "G3": spec.g3_blocks},
            worker.wid,
            on_g1_evict=lambda h, _w=worker.wid:
                self.router.indexer.remove_worker_block(_w, h))

    def _poa_capacities(self) -> Tuple[float, ...]:
        if self.planner is None:
            return tuple(float(self.workers[w].spec.decode_cap)
                         for w in self.decode_ids)
        return tuple(float(w.spec.decode_cap) if w.role == DECODE_ROLE
                     else 0.0 for w in self.workers)

    # ---------------------------------------------------------- events ------

    def _push(self, t: float, kind: str, payload=None):
        heapq.heappush(self._events, (t, next(self._eid), kind, payload))

    def _committed_load(self, wid: int) -> float:
        w = self.workers[wid]
        return w.running + len(w.transfer_queue)

    def _live_decode_ids(self) -> List[int]:
        return [wid for wid in self.decode_ids
                if not self.workers[wid].draining]

    # ---------------------------------------------------------- client ------

    def _maybe_submit(self):
        """Closed-loop client: top the in-flight count up to the target
        (no-op for open-loop/trace workloads, whose target is 0)."""
        target = self.workload.concurrency_at(self.now)
        while self.in_flight < target:
            template = int(self.rng.choice(
                len(self.template_probs), p=self.template_probs))
            self._submit(template, self.workload.input_tokens,
                         self.workload.output_tokens)

    def _on_arrival(self, entry):
        """Open-loop/trace arrival (a TraceEntry): submit unconditionally —
        arrivals do not wait for completions."""
        template = entry.template
        if template < 0:  # open-loop: sample from the popularity skew
            template = int(self.rng.choice(
                len(self.template_probs), p=self.template_probs))
        self._submit(template, entry.input_tokens, entry.output_tokens)

    def _submit(self, template: int, input_tokens: int, output_tokens: int):
        cached = self._template_cache.get((template, input_tokens))
        if cached is None:
            toks = template_tokens(template, input_tokens)
            cached = (toks, tuple(block_hashes(toks)))
            self._template_cache[(template, input_tokens)] = cached
        req = SimRequest(rid=next(self._rid), template=template,
                         tokens=cached[0],
                         output_tokens=output_tokens,
                         submit_t=self.now,
                         hashes=cached[1],
                         phase=self.workload.phase_of(self.now))
        self.in_flight += 1
        if self.planner is not None:   # λ telemetry: only the Planner reads
            self._arrivals.append(self.now)
        self._route(req)
        self.prefill_queue.append(req)
        self._dispatch_prefill()

    # ---------------------------------------------------------- routing -----

    def _dense(self, ids: Sequence[int], vals: Sequence[float]
               ) -> Tuple[float, ...]:
        """Spread per-live-worker values over the fixed PoA universe
        (identity on the static path, where the live set IS the universe)."""
        if list(ids) == self._poa_universe:
            return tuple(vals)
        vec = [0.0] * len(self._poa_universe)
        for wid, v in zip(ids, vals):
            vec[wid] = v
        return tuple(vec)

    def _route(self, req: SimRequest):
        """Decode-worker selection at arrival (Game 3 mechanism).  The
        request's chained block hashes are memoized on the request (once
        per template, in fact) and threaded through every router/indexer
        call — the pre-memo hot path hashed the same prompt up to four
        times per routing decision."""
        if not req.hashes:   # trace entries below one block still memoize
            req.hashes = tuple(block_hashes(req.tokens))
        live = (self._live_decode_ids()
                if self.policy is not self.router else None)
        worker, overlap, overlaps, ids = self.control.select_worker(
            req.tokens, hashes=req.hashes, now=self.now, live_ids=live,
            rid=req.rid)
        req.decode_worker = worker
        req.overlap = overlap
        req.overlaps_all = self._dense(ids, overlaps)
        if not self.lean_completed:
            # routing-time load telemetry, carried into CompletedRequest
            # for offline analysis; skipped in lean mode (it is O(workers)
            # per request and nothing on the PoA path consumes it)
            workers = self.workers
            req.loads_at_schedule = tuple(
                (w.running + len(w.transfer_queue))
                if w.role == DECODE_ROLE else 0.0
                for w in (workers[wid] for wid in self._poa_universe))
        # the chosen worker's fresh credited prefix, recovered from its
        # overlap score (overlap = fresh / len(hashes) exactly) — the
        # separate matched_blocks() walk was redundant
        fresh = int(round(overlap * len(req.hashes)))
        req.onboard_frac, req.onboard_latency = self._tier_split(
            worker, req.hashes, fresh)
        self.router.on_schedule(worker, req.tokens, decode_blocks=0.0,
                                now=self.now, hashes=req.hashes)

    def _tier_split(self, w: int, hashes: Tuple[int, ...],
                    fresh_blocks: int) -> Tuple[float, float]:
        """Split a request's prefix blocks into G1 hits, onboardable
        lower-tier residents, and true misses (the §8.4 redundant-recompute
        vs. onboarding tradeoff).

        The first ``fresh_blocks`` blocks are the router-credited fresh G1
        prefix (coherent with HBM residency by construction).  Beyond it,
        blocks resident in G2/G3/G4 are onboarded at the per-tier Eq. 6
        latency instead of recomputed.  A block whose indexer claim went
        TTL-stale models vLLM-style HBM recycling: it is recomputed (a
        miss) even if the coarse KVBM still shows it G1-resident — which
        keeps large-G1 runs on the identity path — but recomputation
        restores its KV, so the walk continues through it to deeper
        lower-tier residents.  Lower-tier copies churn on the same
        ``cache_ttl`` clock (G2/G3 are shared caches, not archives): a
        demoted block is onboardable only while still fresh — exactly the
        window in which its G1 copy would have been a free hit — so tier
        pressure can convert free hits into paid onboards but never
        misses into hits.  The chain breaks at the first non-resident
        block: prefill recomputes the entire suffix from a true hole."""
        kv = self.workers[w].kvbm
        alpha = {"G2": self.cluster.alpha_g2, "G3": self.cluster.alpha_g3,
                 "G4": self.cluster.alpha_g4}
        onboard, latency = 0, 0.0
        for h in hashes[fresh_blocks:]:
            blk = kv.blocks.get(h)
            if blk is None:
                break
            if blk.tier != "G1" and \
                    self.now - blk.last_touch <= self.cluster.cache_ttl:
                onboard += 1
                latency += alpha[blk.tier]
        return onboard / max(len(hashes), 1), latency

    # --------------------------------------------------------- prefill ------

    def _dispatch_prefill(self):
        for wid in self.prefill_ids:
            w = self.workers[wid]
            if not w.busy and self.prefill_queue:
                req = self.prefill_queue.popleft()
                w.busy = True
                req.prefill_start = self.now
                # cache-warm routing skips recomputation; onboardable
                # G2/G3 blocks are fetched, not recomputed (they pay Eq. 6
                # latency at admission instead); only true misses cost
                # extra prefill work (throughput channel of §8.4).
                miss = max(1.0 - req.overlap - req.onboard_frac, 0.0)
                work = 1.0 + self.cluster.miss_penalty * miss
                sg = self.cluster.service_sigma
                service = (work / self.cluster.prefill_rate) \
                    * float(self.rng.lognormal(-0.5 * sg * sg, sg))
                self.metrics.histogram("prefill_service", window_s=30.0
                                       ).observe(service, self.now)
                self._push(self.now + service, "prefill_busy_done",
                           (wid, req))

    def _on_prefill_busy_done(self, wid: int, req: SimRequest):
        w = self.workers[wid]
        w.busy = False
        req.prefill_worker = wid     # this NIC sources the KV transfer
        if w.pending_role == DECODE_ROLE:
            # deferred Planner flip: the worker was mid-prefill when the
            # move was decided; it joins the decode pool now that it's idle
            self._finish_flip_to_decode(w)
        self._dispatch_prefill()
        self._push(self.now + self.cluster.prefill_base, "prefill_compute_done",
                   req)

    def _on_prefill_compute_done(self, req: SimRequest):
        """Prefill finished: KV transfer to the decode worker, subject to its
        admission cap (stalls here are the herding pathology)."""
        w = self.workers[req.decode_worker]
        if w.role != DECODE_ROLE or w.draining:
            # The target flipped (or is draining) while this request was in
            # the prefill pipeline: re-route to a live decode worker.
            # Prefill work already ran discounted by the *old* target's
            # overlap — that KV is still resident on the draining worker
            # (it flushes only after its last decode), so nothing is
            # recomputed; the switching cost the request pays is the
            # re-quoted transfer, kv_transfer·(1−overlap) against the new,
            # usually colder target.
            self._route(req)
        self._deliver(req)

    def _deliver(self, req: SimRequest):
        if self.fabric is not None:
            # the KV starts moving the moment prefill hands it off —
            # admission slots gate decode, not the wire — so the
            # transmission enqueues here, before the queue-or-admit split
            n = transfer_block_count(len(req.hashes), req.overlap)
            src = (req.prefill_worker if req.prefill_worker >= 0
                   else self.fabric.route_src(self.now))
            txm = self.fabric.enqueue(req.rid, src, req.decode_worker, n,
                                      self.now)
            req.txm = txm
            if txm is not None:
                req.transfer_wait = txm.finish_t - txm.enqueue_t
                req.transfer_floor = self.fabric.floor_seconds(src, n)
                self._push(txm.finish_t, "transfer_done", txm)
            else:
                req.transfer_wait = 0.0
                req.transfer_floor = 0.0
        w = self.workers[req.decode_worker]
        if w.running >= w.spec.decode_cap:
            w.transfer_queue.append(req)
            return
        self._admit_decode(req)

    def _admit_decode(self, req: SimRequest):
        w = self.workers[req.decode_worker]
        if w.role != DECODE_ROLE or w.draining:
            raise RuntimeError(
                f"drain-protocol violation: request {req.rid} admitted to "
                f"{'draining' if w.draining else w.role} worker {w.wid}")
        spec = w.spec
        # onboarding G2/G3 blocks into HBM delays first token by the
        # per-tier Eq. 6 latency (quoted at scheduling) — cheaper than the
        # full-recompute path a true miss pays in prefill work.
        if self.fabric is not None:
            # fabric charge: remaining wire time of the live transmission
            # (zero if it already landed while the request sat in the
            # admission queue, or if every block was resident)
            wire = (max(req.txm.finish_t - self.now, 0.0)
                    if req.txm is not None else 0.0)
            transfer = wire + req.onboard_latency
        else:
            transfer = kv_hop_seconds(spec.kv_transfer, 1.0 - req.overlap) \
                + req.onboard_latency
        req.prefill_end = self.now + transfer
        req.decode_start = req.prefill_end
        self.router.indexer.insert(w.wid, req.tokens, self.now,
                                   hashes=req.hashes)
        # allocate+access+pin+onboard per block, batched (admission pins
        # active decode state in G1; see KVBlockManager.admit_blocks)
        w.kvbm.admit_blocks(req.hashes, self.now)
        w.running += 1
        w.peak_running = max(w.peak_running, w.running)
        itl = spec.itl_base + spec.itl_slope * w.running
        dur = req.output_tokens * itl
        self._push(req.decode_start + dur, "decode_done", req)

    # ---------------------------------------------------------- decode ------

    def _on_decode_done(self, req: SimRequest):
        req.finish_t = self.now
        w = self.workers[req.decode_worker]
        w.running -= 1
        # Release the decode pins: the blocks stay resident (that is the
        # prefix-cache value) but become demotion-eligible again.
        for h in req.hashes:
            w.kvbm.unpin(h)
        self.in_flight -= 1
        self.completed.append(req)
        self.metrics.histogram("ttft", window_s=30.0).observe(req.ttft, self.now)
        self.metrics.histogram("itl", window_s=30.0).observe(req.itl, self.now)
        self.metrics.histogram("decode_residency", window_s=30.0).observe(
            req.finish_t - req.decode_start, self.now)
        self.poa.record(CompletedRequest(
            request_id=str(req.rid), worker=w.wid,
            latency=req.finish_t - req.submit_t,
            overlap=req.overlaps_all, finish_time=self.now,
            loads=req.loads_at_schedule,
            transfer_wait=req.transfer_wait,
            transfer_floor=req.transfer_floor))
        if self.lean_completed:
            # the PoA window holds its own reference to the overlap/load
            # vectors; dropping the request's copy bounds memory at
            # O(window) instead of O(completed × workers)
            req.overlaps_all = ()
            req.loads_at_schedule = ()
        if w.transfer_queue:
            nxt = w.transfer_queue.popleft()
            self._admit_decode(nxt)
        elif w.draining and w.running == 0:
            # last running decode finished: complete the Planner's flip
            self._finish_flip_to_prefill(w)
        self._maybe_submit()

    def _on_transfer_done(self, txm):
        """Fabric transmission landed: release its per-link byte
        reservation (a no-op if the drain protocol already cancelled it)."""
        self.fabric.complete(txm)

    # ------------------------------------------------ Game 1 repartition ----

    def _start_drain_to_prefill(self, w: Worker):
        """Drain protocol, step 1 (decode → prefill): stop admitting — the
        router marks the worker unhealthy so no new request routes to it —
        and re-route its stalled transfers; running decodes finish on
        their own clock."""
        w.draining = True
        self.router.set_health(w.wid, False)
        stalled = list(w.transfer_queue)
        w.transfer_queue.clear()
        for req in stalled:
            if self.fabric is not None and req.txm is not None:
                # transfer refund: release the reserved link capacity
                # BEFORE re-quoting against the new worker (sanitizer N1
                # catches transmissions left pointed at a drained worker)
                self.fabric.cancel(req.txm, self.now)
                req.txm = None
            self._route(req)
            self._deliver(req)
        if w.running == 0:
            self._finish_flip_to_prefill(w)

    def _finish_flip_to_prefill(self, w: Worker):
        """Drain protocol, step 2: flush the KVBM (every freed G1 block
        fires ``on_g1_evict`` → ``remove_worker_block``) and clear any
        remaining KvIndexer claims, then join the prefill pool."""
        for h in list(w.kvbm.blocks):
            w.kvbm.free(h)
        self.router.indexer.clear_worker(w.wid)
        w.kvbm = None
        w.draining = False
        w.role = PREFILL_ROLE
        w.busy = False
        self.decode_ids.remove(w.wid)
        self.prefill_ids.append(w.wid)
        self.prefill_ids.sort()
        self.poa.capacities = self._poa_capacities()
        if self.fabric is not None:
            self.fabric.set_pool(self.prefill_ids, self.decode_ids)
        self.role_flips.append((self.now, w.wid, "to_prefill"))
        self._dispatch_prefill()     # new prefill capacity is live now

    def _start_flip_to_decode(self):
        """Prefill → decode: flip the lowest-wid idle prefill worker
        immediately, or flag the lowest-wid one to flip when its current
        prefill job finishes (prefill jobs are tens of ms)."""
        idle = [wid for wid in self.prefill_ids if not self.workers[wid].busy]
        if idle:
            self._finish_flip_to_decode(self.workers[idle[0]])
        else:
            self.workers[self.prefill_ids[0]].pending_role = DECODE_ROLE

    def _finish_flip_to_decode(self, w: Worker):
        w.pending_role = None
        w.role = DECODE_ROLE
        w.kvbm = self._new_kvbm(w)   # cache-cold: the real switching cost
        w.running = 0
        w.peak_running = 0           # a fresh stint, not the pre-flip one
        w.transfer_queue.clear()
        self.prefill_ids.remove(w.wid)
        self.decode_ids.append(w.wid)
        self.decode_ids.sort()
        self.router.add_worker(w.wid, float(w.spec.decode_cap))
        self.poa.capacities = self._poa_capacities()
        if self.fabric is not None:
            self.fabric.set_pool(self.prefill_ids, self.decode_ids)
        self.role_flips.append((self.now, w.wid, "to_decode"))

    def _response_model(self) -> Optional[ResponseModel]:
        """Profiled Game 1 response curves at the measured operating point
        (arrival rate, prefill service time, decode residency)."""
        cfg = self.planner_config
        win = cfg.measure_window
        while self._arrivals and self._arrivals[0] < self.now - win:
            self._arrivals.popleft()
        span = min(self.now, win)
        if span <= 0.0 or not self._arrivals:
            return None
        lam = len(self._arrivals) / span
        s_p = self.metrics.histogram("prefill_service").mean(self.now)
        if s_p <= 0.0:
            s_p = (1.0 + 0.5 * self.cluster.miss_penalty) \
                / self.cluster.prefill_rate
        dspecs = [self.workers[wid].spec for wid in self.decode_ids] \
            or [self.workers[0].spec]
        itl_base = sum(s.itl_base for s in dspecs) / len(dspecs)
        itl_slope = sum(s.itl_slope for s in dspecs) / len(dspecs)
        cap = sum(s.decode_cap for s in dspecs) / len(dspecs)
        kv_transfer = sum(s.kv_transfer for s in dspecs) / len(dspecs)
        t_dec = self.metrics.histogram("decode_residency").mean(self.now)
        if t_dec <= 0.0:
            t_dec = self.workload.output_tokens * itl_base
        slack = max(cfg.ttft_slo - self.cluster.prefill_base - kv_transfer,
                    1e-3)
        return ResponseModel(arrival_rate=lam, prefill_service=s_p,
                             decode_residency=t_dec, itl_base=itl_base,
                             itl_slope=itl_slope, decode_cap=cap,
                             ttft_slack=slack, itl_slo=cfg.itl_slo)

    def _on_plan(self):
        """Third control-plane event (Game 1): feed the Planner the Eq. 5
        best-response marginals of the profiled response curves at the
        polled operating point; execute at most one role flip per adjust
        interval through the drain protocol."""
        busy_flip = any(w.draining or w.pending_role for w in self.workers)
        if not busy_flip:
            model = self._response_model()
            if model is not None:
                gp, gd = len(self.prefill_ids), len(self.decode_ids)
                m_p, m_d = model.marginals(gp, gd)
                if max(m_p, m_d) >= self.planner_config.min_signal:
                    move = self.planner.step(self.now, ttft_violation=m_p,
                                             itl_violation=m_d)
                    if move == "to_prefill":
                        victim = min(self._live_decode_ids(),
                                     key=lambda wid:
                                     (self._committed_load(wid), wid))
                        self._start_drain_to_prefill(self.workers[victim])
                    elif move == "to_decode":
                        self._start_flip_to_decode()
        nxt = self.now + self.planner_config.adjust_interval
        if nxt <= self.workload.total_duration() or (
                self.workload.mode != "closed" and self.in_flight > 0):
            self._push(nxt, "plan")

    # ------------------------------------------------------- controller -----

    @property
    def switch_time(self) -> Optional[float]:
        """Dual-frontend switch time (recorded by the control plane)."""
        return self.control.switch_time

    def _on_poll(self):
        ttft_p99 = self.metrics.histogram("ttft", window_s=30.0).p99(self.now)
        # include queued-but-unserved head-of-line wait so the detector sees
        # saturation forming (the paper's streamed frontend signal)
        if self.prefill_queue:
            hol = self.now - self.prefill_queue[0].submit_t
            ttft_p99 = max(ttft_p99, hol)
        regime = self.detector.observe(ttft_p99, self.now)
        poa = self.poa.current_poa(self.now)
        entry = {
            "t": self.now, "ttft_p99": ttft_p99, "regime": int(regime),
            "poa": poa, "poa_n": self.poa.window_size(self.now),
            "queue": len(self.prefill_queue),
            "decode_load": [self._committed_load(w)
                            for w in self.decode_ids],
            "concurrency": self.workload.concurrency_at(self.now),
            # Game 2 observables: Prop. 5's ρ per worker, tier residency,
            # and the demotion/promotion churn counters.
            "rho": [kv.capacity_ratio() for kv in self.kvbm],
            "tiers": [kv.tier_distribution() for kv in self.kvbm],
            "demotions": [kv.demotions for kv in self.kvbm],
            "promotions": [kv.promotions for kv in self.kvbm],
            # Game 1 observables: per-slot roles ("P"/"D", draining="d")
            # over the unified pool, and the realized P/D split.
            "roles": "".join(
                ("d" if w.draining else "D") if w.role == DECODE_ROLE
                else "P" for w in self.workers),
            "split": [len(self.prefill_ids), len(self.decode_ids)],
        }
        if self.planner is not None:
            pc = self.planner_config
            v_t, v_i = violation_rates(self.metrics, pc.ttft_slo, pc.itl_slo,
                                       self.now)
            entry["ttft_viol"] = v_t
            entry["itl_viol"] = v_i
            model = self._response_model()
            if model is not None:
                entry["resource_game"] = self.poa.resource_game(
                    model, len(self.prefill_ids), len(self.workers))
        if self.fabric is not None:
            # fourth-game observables: per-link queue depth/utilization and
            # the windowed network PoA (realized transfer wait vs the
            # social optimum's uncongested link assignment)
            entry["links"] = self.fabric.link_stats(self.now)
            entry["network_game"] = self.poa.network_game(self.now)
        self.poll_log.append(entry)
        for kv in self.kvbm:
            kv.decay()
        nxt = self.now + self.detector.config.poll_interval
        if nxt <= self.workload.total_duration():
            self._push(nxt, "poll")
        elif self.workload.mode != "closed" and self.in_flight > 0:
            # Open-loop/trace arrivals do not wait for completions, so the
            # run drains far past the arrival horizon; keep sampling the
            # detector/PoA/ρ while work is in flight — the overload tail
            # is the regime these modes exist to study.  (Closed-loop
            # keeps the legacy horizon so its outputs stay bit-exact.)
            self._push(nxt, "poll")

    # ------------------------------------------------------------- run ------

    def _on_sync(self):
        """Event-plane metric propagation: the router's load view is a
        periodic snapshot (staleness is what makes greedy τ=0 routing herd
        under saturation — the pathology τ>0 randomization suppresses)."""
        for wid in self.decode_ids:
            # b_active counts blocks ON the worker; queued NIXL transfers are
            # invisible to the router (incomplete-information pathology).
            self.router.workers[wid].active_blocks = \
                self.workers[wid].running
        if self._replica_sync_every:
            # replica views refresh every Nth sync event — the
            # deterministic event-clock staleness cadence (N = ``staleness``
            # sync intervals; the authoritative load copy above stays on
            # every sync, exactly like the single-router path)
            if self._sync_i % self._replica_sync_every == 0:
                self.control.sync_views(self.now)
            self._sync_i += 1
        nxt = self.now + self.cluster.metrics_interval
        if nxt <= self.workload.total_duration() + 30.0 or (
                self.workload.mode != "closed" and self.in_flight > 0):
            self._push(nxt, "sync")

    def run(self) -> "SimResult":
        total = self.workload.total_duration()
        self._push(0.0, "poll")
        self._push(0.0, "sync")
        if self.planner is not None:
            self._push(self.planner_config.adjust_interval, "plan")
        if self.workload.mode == "closed":
            t = 0.0
            while t < total:  # client ticks follow the ramp
                self._push(t, "tick")
                t += 1.0
        else:  # open-loop/trace: arrivals are pre-materialized events
            for entry in self.workload.arrivals(self.arrival_rng):
                self._push(entry.t, "arrive", entry)
        # Closed-loop keeps the legacy fixed drain margin (in-flight work is
        # bounded by the concurrency target).  Open-loop/trace arrivals don't
        # wait for completions, so overload — the regime these modes exist to
        # study — can queue far more than 60 s of backlog; drain it fully so
        # overall() prices every arrival instead of a survivor subset.
        closed = self.workload.mode == "closed"
        while self._events:
            t, _, kind, payload = heapq.heappop(self._events)
            if closed and t > total + 60.0:  # drain margin
                break
            self.now = t
            if kind == "tick":
                self._maybe_submit()
            elif kind == "arrive":
                self._on_arrival(payload)
            elif kind == "prefill_busy_done":
                self._on_prefill_busy_done(*payload)
            elif kind == "prefill_compute_done":
                self._on_prefill_compute_done(payload)
            elif kind == "decode_done":
                self._on_decode_done(payload)
            elif kind == "transfer_done":
                self._on_transfer_done(payload)
            elif kind == "poll":
                self._on_poll()
            elif kind == "sync":
                self._on_sync()
            elif kind == "plan":
                self._on_plan()
        return SimResult(self)


@dataclass
class PhaseStats:
    poa: float
    poa_std: float
    ttft_p99: float
    itl_p99: float
    rps: float
    n: int


class SimResult:
    def __init__(self, sim: Simulator):
        self.sim = sim
        self.completed = sim.completed
        self.poll_log = sim.poll_log
        self.switch_time = sim.switch_time
        self.role_flips = sim.role_flips

    def _phase_reqs(self, phase: int) -> List[SimRequest]:
        return [r for r in self.completed if r.phase == phase]

    def _aggregate(self, reqs: List[SimRequest],
                   polls: List[dict]) -> PhaseStats:
        """Phase-agnostic aggregation over an explicit (requests, polls)
        slice — stats never mutate shared request state."""
        # exclude warm-up polls whose Eq. 12 window has not filled yet (the
        # denominator is count-normalized); keep all polls when the load is
        # too low to ever fill it (the paper's dagger-marked artifact rows).
        full = [p for p in polls
                if p.get("poa_n", 0) >= 0.8 * self.sim.poa.window_count]
        polls_used = full if full else polls
        poas = [p["poa"] for p in polls_used if p["poa"] == p["poa"]]
        if not reqs:
            return PhaseStats(float("nan"), 0.0, 0.0, 0.0, 0.0, 0)
        ttfts = sorted(r.ttft for r in reqs)
        itls = sorted(r.itl for r in reqs)
        p99 = lambda xs: xs[min(len(xs) - 1, max(0, math.ceil(0.99 * len(xs)) - 1))]
        dur = (max(r.finish_t for r in reqs) - min(r.submit_t for r in reqs))
        return PhaseStats(
            poa=float(np.mean(poas)) if poas else float("nan"),
            poa_std=float(np.std(poas)) if poas else float("nan"),
            ttft_p99=p99(ttfts), itl_p99=p99(itls),
            rps=len(reqs) / max(dur, 1e-9), n=len(reqs))

    def phase_stats(self, phase: int) -> PhaseStats:
        return self._aggregate(
            self._phase_reqs(phase),
            [p for p in self.poll_log
             if self.sim.workload.phase_of(p["t"]) == phase])

    def overall(self) -> PhaseStats:
        """Whole-run stats over every completed request and every poll
        (previously implemented by temporarily rewriting each request's
        ``phase`` — which mutated shared state and silently dropped the
        polls of every phase but the first from multi-phase runs)."""
        return self._aggregate(self.completed, self.poll_log)
