"""Phi-3-vision 4.2B — phi3-mini backbone + CLIP frontend (stubbed).
[hf:microsoft/Phi-3-vision-128k-instruct; hf]

The vision frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings (num_patches × frontend_dim) that a learned
projection maps into the token stream as a prefill prefix.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3_072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8_192,
    vocab_size=32_064,
    head_dim=96,
    activation="swiglu",
    frontend="vision",
    num_patches=576,           # CLIP ViT-L/14 @ 336px grid
    frontend_dim=1_024,        # CLIP hidden size
    subquadratic=False,
    source="hf:microsoft/Phi-3-vision-128k-instruct; hf",
)
