"""Snowflake Arctic 480B — MoE 128e top-2 + dense residual MLP.
[hf:Snowflake/snowflake-arctic-base; hf]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7_168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4_864,
    vocab_size=32_000,
    head_dim=128,
    activation="swiglu",
    moe=MoEConfig(num_experts=128, top_k=2, d_ff_expert=4_864,
                  dense_residual=True, d_ff_dense=4_864),
    subquadratic=False,
    source="hf:Snowflake/snowflake-arctic-base; hf",
)
