"""Architecture config registry.

``get_config(name)`` returns the full published config; ``get_reduced(name)``
returns a tiny same-family config for CPU smoke tests.
"""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    ModelConfig, MoEConfig, SSMConfig, XLSTMConfig, ShapeConfig,
    SHAPES, SMOKE_SHAPE, shape_applicable, reduce_config,
)

_MODULES = {
    "nemotron-4-340b": "repro.configs.nemotron_4_340b",
    "phi4-mini-3.8b": "repro.configs.phi4_mini_3_8b",
    "minitron-4b": "repro.configs.minitron_4b",
    "stablelm-3b": "repro.configs.stablelm_3b",
    "jamba-v0.1-52b": "repro.configs.jamba_v0_1_52b",
    "arctic-480b": "repro.configs.arctic_480b",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
    "phi-3-vision-4.2b": "repro.configs.phi_3_vision_4_2b",
    "xlstm-125m": "repro.configs.xlstm_125m",
    # the paper's own second model (not in the assigned pool, used by serving
    # benchmarks):
    "llama-3.1-70b": "repro.configs.llama31_70b",
}

ASSIGNED_ARCHS = [k for k in _MODULES if k != "llama-3.1-70b"]


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[name]).CONFIG


def get_reduced(name: str, **overrides) -> ModelConfig:
    return reduce_config(get_config(name), **overrides)


def all_cells():
    """Yield every applicable (arch, shape) dry-run cell."""
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if shape_applicable(cfg, shape):
                yield arch, shape.name
