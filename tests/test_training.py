"""Training substrate: learning, grad accumulation, checkpoint/restart."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.base import ShapeConfig
from repro.models import build_model
from repro.training import optimizer as opt_lib
from repro.training.data import DataConfig, make_batch
from repro.training.train_loop import TrainConfig, Trainer, make_train_step

SHAPE = ShapeConfig("t", 64, 8, "train")


@pytest.mark.slow
def test_loss_decreases():
    tr = Trainer(get_reduced("stablelm-3b"), SHAPE, TrainConfig(remat=False))
    hist = tr.run(25)
    assert np.mean([h["loss"] for h in hist[-5:]]) < \
        np.mean([h["loss"] for h in hist[:5]]) - 0.15


@pytest.mark.slow
def test_grad_accum_equivalence():
    """grad_accum=2 must match grad_accum=1 on the same global batch."""
    cfg = get_reduced("phi4-mini-3.8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), jnp.float32)
    state = {"params": params, "opt": opt_lib.init(params)}
    batch = make_batch(DataConfig(cfg.vocab_size, 32, 8), 0)
    s1, st1 = make_train_step(model, TrainConfig(grad_accum=1, remat=False))(
        jax.tree.map(jnp.copy, state), batch)
    s2, st2 = make_train_step(model, TrainConfig(grad_accum=2, remat=False))(
        jax.tree.map(jnp.copy, state), batch)
    assert float(st1["loss"]) == pytest.approx(float(st2["loss"]), rel=1e-3)
    d = max(float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(s1["params"]),
                            jax.tree.leaves(s2["params"])))
    assert d < 1e-4


@pytest.mark.slow
def test_remat_matches_no_remat():
    cfg = get_reduced("minitron-4b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), jnp.float32)
    batch = make_batch(DataConfig(cfg.vocab_size, 32, 4), 0)
    g1 = jax.grad(lambda p: model.train_loss(p, batch, remat=True))(params)
    g2 = jax.grad(lambda p: model.train_loss(p, batch, remat=False))(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-3)


@pytest.mark.slow
def test_checkpoint_restart_bit_exact(tmp_path):
    """Train 6 steps straight vs 3 + checkpoint + restore + 3: identical."""
    cfg = get_reduced("stablelm-3b")
    tr_a = Trainer(cfg, SHAPE, TrainConfig(remat=False))
    tr_a.run(6)

    ck = str(tmp_path / "ck")
    tr_b = Trainer(cfg, SHAPE, TrainConfig(remat=False, ckpt_dir=ck,
                                           ckpt_every=3))
    tr_b.run(3)
    tr_c = Trainer(cfg, SHAPE, TrainConfig(remat=False, ckpt_dir=ck))
    assert tr_c.step == 3
    tr_c.run(3)
    for a, b in zip(jax.tree.leaves(tr_a.state["params"]),
                    jax.tree.leaves(tr_c.state["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_optimizer_clips_gradients():
    cfg = opt_lib.OptimizerConfig(clip_norm=1.0, lr=1.0, weight_decay=0.0,
                                  warmup_steps=0)
    params = {"w": jnp.zeros((4,))}
    opt = opt_lib.init(params)
    huge = {"w": jnp.full((4,), 1e6)}
    new_p, _, stats = opt_lib.update(cfg, params, huge, opt)
    assert float(stats["grad_norm"]) > 1e5
    assert float(jnp.max(jnp.abs(new_p["w"]))) < 10.0  # clip bounded the step


def test_schedule_warmup_and_cosine():
    cfg = opt_lib.OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                                  min_lr_ratio=0.1)
    assert float(opt_lib.schedule(cfg, jnp.int32(5))) == pytest.approx(0.5, rel=0.05)
    assert float(opt_lib.schedule(cfg, jnp.int32(100))) == pytest.approx(0.1, rel=0.05)
