"""Assigned-architecture configs: exact published numbers + plausible sizes."""
import pytest

from repro.configs import ASSIGNED_ARCHS, SHAPES, get_config, get_reduced, shape_applicable

EXPECTED = {
    "nemotron-4-340b": dict(num_layers=96, d_model=18432, num_heads=96,
                            num_kv_heads=8, d_ff=73728, vocab_size=256000,
                            activation="squared_relu"),
    "phi4-mini-3.8b": dict(num_layers=32, d_model=3072, num_heads=24,
                           num_kv_heads=8, d_ff=8192, vocab_size=200064,
                           activation="swiglu"),
    "minitron-4b": dict(num_layers=32, d_model=3072, num_heads=24,
                        num_kv_heads=8, d_ff=9216, vocab_size=256000),
    "stablelm-3b": dict(num_layers=32, d_model=2560, num_heads=32,
                        num_kv_heads=32, d_ff=6912, vocab_size=50304),
    "jamba-v0.1-52b": dict(num_layers=32, d_model=4096, num_heads=32,
                           num_kv_heads=8, d_ff=14336, vocab_size=65536),
    "arctic-480b": dict(num_layers=35, d_model=7168, num_heads=56,
                        num_kv_heads=8, d_ff=4864, vocab_size=32000),
    "qwen3-moe-30b-a3b": dict(num_layers=48, d_model=2048, num_heads=32,
                              num_kv_heads=4, d_ff=768, vocab_size=151936),
    "seamless-m4t-medium": dict(num_layers=12, d_model=1024, num_heads=16,
                                num_kv_heads=16, d_ff=4096, vocab_size=256206),
    "phi-3-vision-4.2b": dict(num_layers=32, d_model=3072, num_heads=32,
                              num_kv_heads=32, d_ff=8192, vocab_size=32064),
    "xlstm-125m": dict(num_layers=12, d_model=768, num_heads=4,
                       num_kv_heads=4, d_ff=0, vocab_size=50304),
}

PARAM_RANGES = {  # (min, max) in billions
    "nemotron-4-340b": (310, 370), "phi4-mini-3.8b": (3.4, 5.0),
    "minitron-4b": (3.6, 4.8), "stablelm-3b": (2.2, 3.4),
    "jamba-v0.1-52b": (46, 57), "arctic-480b": (430, 520),
    "qwen3-moe-30b-a3b": (27, 33), "seamless-m4t-medium": (0.6, 1.4),
    "phi-3-vision-4.2b": (3.3, 4.7), "xlstm-125m": (0.09, 0.2),
}


@pytest.mark.parametrize("arch", list(EXPECTED))
def test_exact_numbers(arch):
    cfg = get_config(arch)
    for k, v in EXPECTED[arch].items():
        assert getattr(cfg, k) == v, (arch, k)


def test_ten_archs_assigned():
    assert len(ASSIGNED_ARCHS) == 10


@pytest.mark.parametrize("arch", list(PARAM_RANGES))
def test_param_counts(arch):
    lo, hi = PARAM_RANGES[arch]
    n = get_config(arch).param_count() / 1e9
    assert lo <= n <= hi, f"{arch}: {n:.2f}B outside [{lo},{hi}]"


def test_moe_active_params():
    qwen = get_config("qwen3-moe-30b-a3b")
    assert 2.0e9 <= qwen.active_param_count() <= 4.0e9  # "A3B"
    jamba = get_config("jamba-v0.1-52b")
    assert 9e9 <= jamba.active_param_count() <= 15e9    # ~12B active
    arctic = get_config("arctic-480b")
    assert 12e9 <= arctic.active_param_count() <= 22e9  # ~17B active


def test_long_context_skips():
    runnable = {a for a in ASSIGNED_ARCHS
                if shape_applicable(get_config(a), SHAPES["long_500k"])}
    assert runnable == {"jamba-v0.1-52b", "xlstm-125m"}


def test_cell_count():
    from repro.configs import all_cells
    cells = list(all_cells())
    # 10 archs x 4 shapes - 8 long_500k skips
    assert len(cells) == 32


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_configs_are_small(arch):
    cfg = get_reduced(arch)
    assert cfg.d_model <= 256 and cfg.vocab_size <= 1024
    assert cfg.family == get_config(arch).family
