"""Real-model disaggregated serving engines (jitted JAX, CPU-testable).

``PrefillEngine`` runs the prompt pass and emits a per-request KV/state
cache bundle; ``DecodeEngine`` holds a fixed-slot continuous batch whose
per-slot lengths advance independently (ragged decode with masked cache
writes).  ``transfer()`` moves a prefill cache bundle into a decode slot —
on a real cluster this is a cross-mesh ``jax.device_put`` (the NIXL
analogue); on CPU it degenerates to an in-process copy.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


class PrefillEngine:
    def __init__(self, model: Model, params, max_len: int):
        self.model = model
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(
            lambda p, batch: model.prefill(p, batch, max_len=max_len))

    def prefill(self, tokens: Sequence[int], extras: Optional[dict] = None):
        """Single-request prompt pass → (last_logits (V,), cache bundle)."""
        batch = {"tokens": jnp.asarray(tokens, jnp.int32)[None, :]}
        if extras:
            batch.update({k: jnp.asarray(v)[None] for k, v in extras.items()})
        logits, caches = self._prefill(self.params, batch)
        return np.asarray(logits[0]), caches


@dataclass
class Slot:
    active: bool = False
    request_id: Optional[str] = None
    length: int = 0
    generated: List[int] = field(default_factory=list)
    max_new: int = 0


class DecodeEngine:
    """Fixed-slot continuous batcher around the jitted ragged decode step."""

    def __init__(self, model: Model, params, num_slots: int, max_len: int,
                 worker_id: int = 0):
        self.model = model
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.worker_id = worker_id
        self.slots = [Slot() for _ in range(num_slots)]
        self.caches = model.cache_init(num_slots, max_len)
        self.tokens = np.zeros((num_slots, 1), np.int32)
        self._decode = jax.jit(model.decode, donate_argnums=1)

    # -------------------------------------------------------------- admit ---

    def free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if not s.active:
                return i
        return None

    def admit(self, slot: int, request_id: str, prefill_caches,
              first_token: int, prompt_len: int, max_new: int):
        """Transfer a prefill cache bundle into `slot` (the NIXL hop)."""
        self.caches = _insert_cache(self.caches, prefill_caches, slot,
                                    self.model)
        s = self.slots[slot]
        s.active = True
        s.request_id = request_id
        s.length = prompt_len
        s.generated = [int(first_token)]
        s.max_new = max_new
        self.tokens[slot, 0] = first_token

    def release(self, slot: int):
        self.slots[slot] = Slot()
        self.tokens[slot, 0] = 0

    @property
    def active_count(self) -> int:
        return sum(s.active for s in self.slots)

    # --------------------------------------------------------------- step ---

    def step(self) -> List[Tuple[str, int, bool]]:
        """One batched decode tick. Returns [(request_id, token, done)]."""
        if self.active_count == 0:
            return []
        lengths = jnp.asarray([s.length if s.active else 0
                               for s in self.slots], jnp.int32)
        logits, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(self.tokens), lengths)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        out = []
        for i, s in enumerate(self.slots):
            if not s.active:
                continue
            tok = int(nxt[i])
            s.generated.append(tok)
            s.length += 1
            self.tokens[i, 0] = tok
            done = (len(s.generated) >= s.max_new + 1
                    or s.length >= self.max_len - 1)
            out.append((s.request_id, tok, done))
            if done:
                pass  # caller releases after collecting
        return out


def _insert_cache(dst, src, slot: int, model: Model):
    """Write a (batch=1) prefill cache bundle into decode slot `slot`.

    Cross-mesh in production: each leaf is device_put to the decode mesh's
    sharding before insertion.
    """
    def leaf(d, s):
        # d: (P, B, ...); s: (P, 1, ...) — prefill cache may have a shorter
        # sequence axis than the decode cache; pad on the right.
        if s.shape[2:] != d.shape[2:]:
            pads = [(0, 0), (0, 0)]
            for ds, ss in zip(d.shape[2:], s.shape[2:]):
                pads.append((0, ds - ss))
            s = jnp.pad(s, pads)
        return d.at[:, slot].set(s[:, 0].astype(d.dtype))
    return jax.tree.map(leaf, dst, src)
