"""Llama-3.1-70B — the paper's second serving model (Section 7.3).
[arXiv:2407.21783; hf:nvidia/Llama-3.1-70B-Instruct-FP8]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.1-70b",
    family="dense",
    num_layers=80,
    d_model=8_192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28_672,
    vocab_size=128_256,
    head_dim=128,
    activation="swiglu",
    rope_theta=500_000.0,
    subquadratic=False,
    source="arXiv:2407.21783; hf",
)
