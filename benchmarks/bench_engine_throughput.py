"""Engine fast-path throughput — batched prefill and ragged decode.

Measures, on the reduced CPU-testable models the engine backend runs:

* **prefill tokens/s** at queue depth ≥ 4: one bucketed ragged
  ``prefill_many`` pass over the queue vs the sequential batch-1 loop it
  replaced.  Two queue shapes: the *gated* point is a deep queue of
  one-block prompts — the regime batching exists for, where the ~ms
  fixed dispatch cost of a batch-1 XLA pass rivals its compute and the
  batched pass amortizes it across the queue (CI gate: ≥ 2x) — plus an
  informational point at the parity-scenario scale (48-token prompts),
  where per-token compute dominates on CPU and the win is smaller.
* **decode tokens/s/slot** for both cached-attention implementations
  (``pallas`` ragged kernel — interpret mode on CPU, compiled on TPU —
  and the XLA ``_sdpa`` path), at full slot occupancy.
* **batch-occupancy histogram** of a flood run: per-tick active-slot
  totals from ``DisaggregatedCluster.occupancy`` — how full the
  continuous-batching slots actually run under backpressure.
* **paged-KV capacity and rate** at equal HBM: how many concurrent
  decode requests a page pool sized to the dense engine's exact KV
  footprint admits on a short-request workload (gate: ≥ 2x the dense
  slot count), decode rate of the paged layout vs dense at matched
  batch width (gate: ≥ 0.9x — the page gather must stay near-free),
  KV HBM bytes committed per active request, and a page-pool
  utilization histogram from a length-skewed flood.

Output: CSV rows on stdout + ``reports/benchmarks/BENCH_engine.json``.
``--check BASELINE`` enforces the ≥ 2x batched-prefill gate, the ≥ 2x
paged-capacity gate and the ≥ 0.9x paged-rate gate, and fails on >2x
regressions of the ratio/rate metrics vs the committed baseline
(machine-robust: the primary gates are same-machine ratios, not absolute
rates).

    PYTHONPATH=src python -m benchmarks.bench_engine_throughput \
        [--smoke] [--check FILE]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, save_json
from repro.core.radix import BLOCK_SIZE
from repro.serving.disagg import DisaggregatedCluster, ServeRequest
from repro.serving.engine import DecodeEngine, PrefillEngine, kv_token_bytes
from repro.serving.workload import template_tokens

MODEL_NAME = "phi4-mini-3.8b"
MAX_LEN = 96
MIN_PREFILL_SPEEDUP = 2.0      # ISSUE gate: batched ≥ 2x at depth ≥ 4
MIN_PAGED_CAPACITY = 2.0       # ISSUE gate: ≥ 2x concurrent slots at
                               # equal KV-pool HBM on short requests
MIN_PAGED_RATE = 0.9           # ISSUE gate: ≤ 10% tokens/s/slot cost at
                               # matched batch width


def _build_model():
    from repro.configs import get_reduced
    from repro.models import build_model
    cfg = get_reduced(MODEL_NAME)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), jnp.bfloat16)
    return cfg, model, params


def _queue(cfg, depth: int, lo: int, hi: int):
    """depth distinct prompts with lengths ramping lo..hi inside one
    padded bucket, so the batched pass exercises real ragged padding."""
    out = []
    for i in range(depth):
        n = lo + ((hi - lo) * i) // max(depth - 1, 1)
        toks = [t % cfg.vocab_size for t in template_tokens(i, n)]
        out.append(toks)
    return out


def _best_of(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _prefill_point(model, params, cfg, label: str, depth: int,
                   lo: int, hi: int, repeats: int) -> dict:
    """Batched vs sequential prompt passes over one queue of ``depth``
    requests.  Prefix cache off: every repeat measures cold compute."""
    prompts = _queue(cfg, depth, lo, hi)
    tokens = sum(len(p) for p in prompts)
    eng = PrefillEngine(model, params, max_len=MAX_LEN, cache_entries=0,
                        max_batch=depth)
    lengths = sorted(set(len(p) for p in prompts))
    eng.warmup(lengths, batch_sizes=[1, depth])

    def batched():
        eng.prefill_many([(p, None, None) for p in prompts])

    def sequential():
        for p in prompts:
            eng.prefill(p)

    batched()                      # shake out any remaining first-call cost
    sequential()
    wall_b = _best_of(batched, repeats)
    wall_s = _best_of(sequential, repeats)
    out = {
        "depth": depth,
        "prompt_lengths": [lo, hi],
        "prompt_tokens": tokens,
        "batched_tokens_per_s": tokens / wall_b,
        "sequential_tokens_per_s": tokens / wall_s,
        "batched_speedup": wall_s / wall_b,
        "batches": eng.stats.batches,
        "padded_tokens": eng.stats.padded_tokens,
    }
    emit(f"bench_engine_prefill_{label}", wall_b / depth * 1e6,
         f"depth={depth};lens={lo}..{hi};"
         f"tok_per_s_batched={out['batched_tokens_per_s']:,.0f};"
         f"tok_per_s_seq={out['sequential_tokens_per_s']:,.0f};"
         f"speedup={out['batched_speedup']:.2f}x")
    return out


def bench_prefill(model, params, cfg, smoke: bool) -> dict:
    """The gated point batches one-block prompts (the dispatch-bound
    regime) at depth 16; full runs add the parity-scenario scale
    (48-token, compute-bound on CPU) as an ungated reference."""
    repeats = 3 if smoke else 5
    out = {"gated": _prefill_point(model, params, cfg, "short_d16",
                                   depth=16, lo=12, hi=16,
                                   repeats=repeats)}
    out["batched_speedup"] = out["gated"]["batched_speedup"]
    if not smoke:
        out["parity_scale"] = _prefill_point(model, params, cfg,
                                             "parity_d8", depth=8,
                                             lo=33, hi=48, repeats=repeats)
    return out


def bench_decode(model, params, cfg, steps: int) -> dict:
    """Decode tokens/s/slot at full occupancy, per attention impl.  The
    Pallas kernels (``pallas``, ``paged``) run in interpret mode on CPU —
    their absolute rates here are interpreter artifacts (compiled path is
    TPU); the `_sdpa`-math rows (``sdpa``, ``paged_sdpa``) are the
    CPU-meaningful rates, and their ratio is the paged-layout rate gate:
    same batch width, same math, the only delta is the page-table
    indirection + pool gather vs the contiguous ``max_len`` layout.  The
    paged engines run the default pool (the dense worst case), which is
    byte-identical HBM to the dense layout at this slot count."""
    slots = 4
    prompts = _queue(cfg, slots, 33, 48)
    pre = PrefillEngine(model, params, max_len=MAX_LEN, cache_entries=0)
    bundles = []
    for p in prompts:
        logits, caches = pre.prefill(p)
        bundles.append((p, int(logits.argmax()), caches))
    out = {}
    for impl in ("sdpa", "pallas", "paged_sdpa", "paged"):
        dec = DecodeEngine(model, params, num_slots=slots, max_len=MAX_LEN,
                           decode_impl=impl)
        if dec.paged:
            # pre-compile every table width growth can widen to, so the
            # timed window never pays a recompile at a block boundary
            dec.warmup(table_widths=dec.width_ladder())
        else:
            dec.warmup()
        for i, (p, first, caches) in enumerate(bundles):
            dec.admit(i, f"d{i}", caches, first, prompt_len=len(p),
                      max_new=MAX_LEN, hashes=())
        dec.step()                 # first stepped shape compiles here
        # best-of-3 windows: single-window walls on shared runners are
        # scheduler-noise-dominated at this scale, and the paged rate
        # gate is a ~10% margin
        wall = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            for _ in range(steps):
                n = len(dec.step())
                assert n == slots  # nobody finishes inside the window
            wall = min(wall, time.perf_counter() - t0)
        out[impl] = {"tokens_per_s_per_slot": steps / wall,
                     "tokens_per_s": steps * slots / wall}
        emit(f"bench_engine_decode_{impl}", wall / steps / slots * 1e6,
             f"slots={slots};tok_per_s_per_slot="
             f"{out[impl]['tokens_per_s_per_slot']:,.1f}")
    return out


def bench_paged_capacity(model, params, cfg, smoke: bool) -> dict:
    """Concurrency at equal KV HBM.  The dense layout commits
    ``num_slots × max_len`` rows up front, so 4 slots cost 24 pages of
    HBM and admit exactly 4 requests no matter how short they are.  A
    page pool of those same 24 pages admits short requests (16-token
    prompt, 4 output tokens → 2-page worst case) until the pool gate
    binds — the measured static capacity win — plus the per-request KV
    bytes actually committed and a pool-utilization histogram from a
    length-skewed flood through the full cluster."""
    dense_slots = 4
    pre = PrefillEngine(model, params, max_len=MAX_LEN, cache_entries=0)
    short = [t % cfg.vocab_size for t in template_tokens(0, 16)]
    logits, caches = pre.prefill(short)
    first = int(logits.argmax())

    pool_pages = dense_slots * (MAX_LEN // BLOCK_SIZE)
    dec = DecodeEngine(model, params, num_slots=16, max_len=MAX_LEN,
                       decode_impl="paged_sdpa", num_pages=pool_pages)
    admitted = 0
    while True:
        slot = dec.free_slot()
        if slot is None or not dec.can_admit(len(short), 4):
            break
        dec.admit(slot, f"c{admitted}", caches, first,
                  prompt_len=len(short), max_new=4, hashes=())
        admitted += 1
    capacity_ratio = admitted / dense_slots
    # bytes committed per active request: the paged pool charges mapped
    # pages; the dense layout charges every slot's full max_len rows
    paged_bytes_per_req = dec.kv_bytes_held() / max(admitted, 1)
    dense_bytes_per_req = MAX_LEN * kv_token_bytes(model)

    # rate gate at matched batch width, in the regime the capacity win
    # lives in: short requests whose worst case keeps tables narrow, so
    # the paged engine attends over its mapped pages while the dense
    # layout attends over its committed max_len rows.  Same `_sdpa` math
    # on both sides — the ratio isolates the paged layout's cost
    # (page-table gather + pool scatter) against its compute saving.
    rate_prompts = _queue(cfg, dense_slots, 16, 16)
    rate_bundles = []
    for p in rate_prompts:
        lg, cc = pre.prefill(p)
        rate_bundles.append((p, int(lg.argmax()), cc))
    steps, rates = (8 if smoke else 12), {}
    for impl, pages in (("sdpa", None), ("paged_sdpa", pool_pages)):
        d = DecodeEngine(model, params, num_slots=dense_slots,
                         max_len=MAX_LEN, decode_impl=impl,
                         num_pages=pages)
        if d.paged:
            d.warmup(table_widths=d.width_ladder(16 + 40 + 1))
        else:
            d.warmup()
        for i, (p, f, c) in enumerate(rate_bundles):
            d.admit(i, f"r{i}", c, f, prompt_len=len(p), max_new=40,
                    hashes=())
        d.step()
        wall = float("inf")
        for _ in range(3):         # best-of-3: see bench_decode
            t0 = time.perf_counter()
            for _ in range(steps):
                assert len(d.step()) == dense_slots
            wall = min(wall, time.perf_counter() - t0)
        rates[impl] = steps * dense_slots / wall
    rate_ratio = rates["paged_sdpa"] / rates["sdpa"]
    emit("bench_engine_paged_rate_ratio", rate_ratio * 100,
         f"paged_sdpa/sdpa={rate_ratio:.3f} at matched slots="
         f"{dense_slots} (gate ≥ {MIN_PAGED_RATE})")
    out = {
        "pool_pages": pool_pages,
        "dense_slots": dense_slots,
        "paged_admitted": admitted,
        "capacity_ratio": capacity_ratio,
        "rate_ratio": rate_ratio,
        "decode_tokens_per_s": {k: v for k, v in rates.items()},
        "kv_hbm_bytes_per_active_request": paged_bytes_per_req,
        "dense_kv_hbm_bytes_per_request": dense_bytes_per_req,
        "pool_utilization_at_capacity": dec.pool_utilization(),
    }
    emit("bench_engine_paged_capacity", admitted,
         f"pool_pages={pool_pages};admitted={admitted};"
         f"vs_dense={dense_slots};ratio={capacity_ratio:.1f}x (gate ≥ "
         f"{MIN_PAGED_CAPACITY});"
         f"kv_bytes_per_req={paged_bytes_per_req:,.0f}"
         f"/{dense_bytes_per_req:,.0f}")

    # length-skewed flood (mostly short, some near-max_len prompts)
    # through the cluster: how full the pool actually runs under the
    # reservation-gated admission path
    n_requests = 6 if smoke else 12
    cluster = DisaggregatedCluster(
        model, params, num_decode=1, slots_per_worker=6, max_len=MAX_LEN,
        adaptive=False, decode_impl="paged_sdpa", num_pages=12)
    for i in range(n_requests):
        n = 48 if i % 4 == 3 else 16            # 3:1 short:long skew
        toks = [t % cfg.vocab_size for t in template_tokens(i % 8, n)]
        cluster.submit(ServeRequest(f"u{i}", toks, max_new_tokens=4))
    cluster.run_until_done()
    hist = {}
    for tick in cluster.pool_utilization:
        for u in tick:
            key = f"{min(int(u * 10), 9) / 10:.1f}"
            hist[key] = hist.get(key, 0) + 1
    utils = [u for tick in cluster.pool_utilization for u in tick]
    out["flood"] = {
        "requests": n_requests,
        "pool_pages": 12,
        "utilization_histogram": dict(sorted(hist.items())),
        "mean_pool_utilization": sum(utils) / max(len(utils), 1),
        "peak_pool_utilization": max(utils, default=0.0),
    }
    emit("bench_engine_pool_utilization",
         out["flood"]["mean_pool_utilization"] * 100,
         f"requests={n_requests};mean="
         f"{out['flood']['mean_pool_utilization']:.2f};"
         f"peak={out['flood']['peak_pool_utilization']:.2f}")
    return out


def bench_occupancy(model, params, cfg, n_requests: int) -> dict:
    """Flood a 2-worker × 2-slot cluster and histogram the per-tick total
    active slots: how full continuous batching runs under backpressure."""
    cluster = DisaggregatedCluster(model, params, num_decode=2,
                                   slots_per_worker=2, max_len=MAX_LEN,
                                   adaptive=False)
    for i in range(n_requests):
        n = 33 + (15 * i) // max(n_requests - 1, 1)
        toks = [t % cfg.vocab_size for t in template_tokens(i % 8, n)]
        cluster.submit(ServeRequest(f"o{i}", toks, max_new_tokens=4))
    t0 = time.perf_counter()
    cluster.run_until_done()
    wall = time.perf_counter() - t0
    totals = [sum(occ) for occ in cluster.occupancy]
    hist = {}
    for t in totals:
        hist[str(t)] = hist.get(str(t), 0) + 1
    capacity = 4
    busy = [t for t in totals if t > 0]
    out = {
        "requests": n_requests,
        "wall_s": wall,
        "ticks": len(totals),
        "histogram": dict(sorted(hist.items())),
        "mean_active_slots": sum(totals) / max(len(totals), 1),
        "mean_busy_fill": (sum(busy) / len(busy) / capacity) if busy else 0.0,
        "prefill_batches": cluster.prefill.stats.batches,
        "prefill_batched_requests": cluster.prefill.stats.batched_requests,
    }
    emit("bench_engine_occupancy", wall / max(n_requests, 1) * 1e6,
         f"requests={n_requests};mean_active={out['mean_active_slots']:.2f};"
         f"busy_fill={out['mean_busy_fill']:.2f};"
         f"batched_requests={out['prefill_batched_requests']}")
    return out


def _flatten(payload: dict, prefix: str = "") -> dict:
    flat = {}
    for k, v in payload.items():
        key = f"{prefix}{k}"
        if isinstance(v, dict):
            flat.update(_flatten(v, f"{key}."))
        elif isinstance(v, (int, float)):
            flat[key] = float(v)
    return flat


def check_regression(payload: dict, baseline_path: str,
                     factor: float = 2.0) -> list:
    """Hard gate: batched prefill ≥ MIN_PREFILL_SPEEDUP (same-machine
    ratio, robust to runner speed).  Baseline gates: ratio and rate
    metrics may not be ``factor``× lower than the committed baseline;
    occupancy/counters are informational."""
    failures = []
    speedup = payload["prefill"]["batched_speedup"]
    if speedup < MIN_PREFILL_SPEEDUP:
        failures.append(f"prefill.batched_speedup: {speedup:.2f} < "
                        f"required {MIN_PREFILL_SPEEDUP}x")
    capacity = payload["paged"]["capacity_ratio"]
    if capacity < MIN_PAGED_CAPACITY:
        failures.append(f"paged.capacity_ratio: {capacity:.2f} < "
                        f"required {MIN_PAGED_CAPACITY}x")
    rate = payload["paged"]["rate_ratio"]
    if rate < MIN_PAGED_RATE:
        failures.append(f"paged.rate_ratio: {rate:.3f} < "
                        f"required {MIN_PAGED_RATE}")
    with open(baseline_path) as f:
        base = _flatten(json.load(f))
    cur = _flatten(payload)
    for key, ref in base.items():
        if key not in cur or ref <= 0:
            continue
        leaf = key.rsplit(".", 1)[-1]
        if leaf.startswith(("batched_speedup", "tokens_per_s",
                            "tokens_per_s_per_slot",
                            "batched_tokens_per_s",
                            "sequential_tokens_per_s", "mean_busy_fill",
                            "capacity_ratio", "rate_ratio")):
            if cur[key] < ref / factor:
                failures.append(f"{key}: {cur[key]:.2f} < baseline "
                                f"{ref:.2f} / {factor}")
    return failures


def run(smoke: bool = False) -> dict:
    cfg, model, params = _build_model()
    payload = {
        "mode": "smoke" if smoke else "full",
        "model": MODEL_NAME,
        "prefill": bench_prefill(model, params, cfg, smoke=smoke),
        # window sizing: 3 windows must finish before the longest prompt
        # (48 tokens) walks into the max_len=96 stop condition
        "decode": bench_decode(model, params, cfg,
                               steps=8 if smoke else 14),
        "occupancy": bench_occupancy(model, params, cfg,
                                     n_requests=8 if smoke else 16),
        "paged": bench_paged_capacity(model, params, cfg, smoke=smoke),
    }
    save_json("BENCH_engine", payload)
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced depths/steps (CI guard, not a "
                         "measurement)")
    ap.add_argument("--check", default=None, metavar="BASELINE",
                    help="enforce the prefill/paged-capacity/paged-rate "
                         "gates and fail on >2x regression vs this "
                         "baseline JSON")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    payload = run(smoke=args.smoke)
    if args.check:
        failures = check_regression(payload, args.check)
        if failures:
            print("REGRESSION vs baseline:", file=sys.stderr)
            for f in failures:
                print(f"  {f}", file=sys.stderr)
            sys.exit(1)
        print(f"# regression check vs {args.check}: ok", file=sys.stderr)


if __name__ == "__main__":
    main()
