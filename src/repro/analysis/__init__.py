"""repro.analysis — repo-specific correctness tooling.

Two instruments, both born from the hazard classes the serving hot-path
PRs introduced (load-cache invalidating property setters, the threaded
``hashes=`` memo, pin/unpin refcounts, the drain protocol, jit/Pallas
purity):

* a **static lint pass** (:mod:`repro.analysis.lint`, run as
  ``python -m repro.analysis src tests benchmarks examples``) with
  AST-based rules RA001-RA010 that catch those hazards at review time;
* a **runtime coherence sanitizer** (:mod:`repro.analysis.sanitize`,
  opt-in via ``REPRO_SANITIZE=1`` or ``sanitize=True`` on
  ``Simulator``/``ControlPlane``/``DisaggregatedCluster``) that asserts
  the load-bearing cross-structure invariants at event boundaries, with
  recent-event-trace context on failure.
"""
from repro.analysis.lint import (Finding, RULES, lint_file, lint_paths,
                                 rule_catalog)
from repro.analysis.sanitize import (SanitizeError, sanitize_enabled,
                                     attach_control_sanitizer,
                                     attach_engine_sanitizer,
                                     attach_sim_sanitizer)

__all__ = [
    "Finding", "RULES", "lint_file", "lint_paths", "rule_catalog",
    "SanitizeError", "sanitize_enabled", "attach_sim_sanitizer",
    "attach_engine_sanitizer", "attach_control_sanitizer",
]
