"""Causal GQA flash attention as a Pallas TPU kernel.

TPU adaptation of the flash-attention tiling (DESIGN.md §3): the grid is
(batch, q_head, q_block, kv_block) with the KV axis innermost; online-softmax
statistics (m, l) and the fp32 output accumulator live in VMEM scratch and
carry across the kv_block grid steps (TPU grids execute sequentially per
core, so scratch carries replace the CUDA warp-level loop).  Q/K/V tiles
stream HBM→VMEM per grid step; MXU-aligned block sizes (multiples of 128 on
the matmul dims) are chosen by the wrapper in ``ops.py``.

Causality is handled two ways: whole KV blocks strictly above the diagonal
are skipped via ``@pl.when`` (no compute issued), and the diagonal block is
masked elementwise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(kvlen_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            blk_q: int, blk_k: int, causal: bool, sm_scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * blk_q
    k_start = ki * blk_k
    kv_len = kvlen_ref[0]
    q_len = kvlen_ref[1]
    # causal diagonal offset: with an offset KV cache (kv_len > q_len) the
    # first query row may already attend to kv_len - q_len leading keys
    off = kv_len - q_len
    run = jnp.logical_and(
        k_start < kv_len,
        (not causal) or (k_start <= q_start + blk_q - 1 + off))

    @pl.when(run)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)          # (blk_q, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # (blk_k, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
        s = jnp.where(cols < kv_len, s, NEG_INF)           # padded keys inert
        if causal:
            rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
            s = jnp.where(cols <= rows + off, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal=True, blk_q=128, blk_k=128,
                           interpret=False, kv_len=None, q_len=None):
    """q: (B,S,H,hd); k,v: (B,T,K,hd), H = K·G, S % blk_q == 0 == T % blk_k.
    kv_len masks keys at positions ≥ kv_len (right padding).  q_len is the
    true (unpadded) query length: with kv_len > q_len the causal diagonal
    is shifted so the last query row attends to all kv_len keys (offset
    cache, matching the reference oracle)."""
    b, s, h, hd = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    blk_q = min(blk_q, s)
    blk_k = min(blk_k, t)
    assert s % blk_q == 0 and t % blk_k == 0
    grid = (b, h, s // blk_q, t // blk_k)
    sm_scale = 1.0 / np.sqrt(hd)

    kernel = functools.partial(_kernel, blk_q=blk_q, blk_k=blk_k,
                               causal=causal, sm_scale=sm_scale)
    if kv_len is None:
        kv_len = t
    if q_len is None:
        q_len = kv_len          # square case: diagonal ends at the corner
    kv_len_arr = jnp.asarray([kv_len, q_len], jnp.int32)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((2,), lambda b_, h_, q_, k_: (0,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, blk_q, 1, hd), lambda b_, h_, q_, k_: (b_, q_, h_, 0)),
            pl.BlockSpec((1, blk_k, 1, hd), lambda b_, h_, q_, k_: (b_, k_, h_ // g, 0)),
            pl.BlockSpec((1, blk_k, 1, hd), lambda b_, h_, q_, k_: (b_, k_, h_ // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, 1, hd),
                               lambda b_, h_, q_, k_: (b_, q_, h_, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len_arr, q, k, v)
