"""SeamlessM4T-medium — encoder-decoder, multimodal (audio frontend stubbed).
[arXiv:2308.11596; hf]

The modality frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings of dim ``frontend_dim``.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    num_layers=12,            # decoder layers
    num_encoder_layers=12,
    cross_attention=True,
    d_model=1_024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4_096,
    vocab_size=256_206,
    head_dim=64,
    activation="gelu",
    frontend="audio",
    frontend_dim=160,          # stub: precomputed fbank-frame embedding dim
    subquadratic=False,
    source="arXiv:2308.11596; hf",
)
