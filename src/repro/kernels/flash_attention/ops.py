"""jit'd wrapper for the flash-attention Pallas kernel.

On TPU the kernel runs compiled with MXU-aligned tiles; elsewhere it runs in
``interpret=True`` mode (the kernel body executed by XLA:CPU) so correctness
is testable in this container.  Non-multiple sequence lengths are padded on
the right (causal masking keeps padded keys inert; padded queries are
sliced off).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "blk_q", "blk_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal=True, blk_q=128, blk_k=128,
                    interpret=None):
    if interpret is None:
        interpret = not _on_tpu()
    b, s, h, hd = q.shape
    t = k.shape[1]
    blk_q = min(blk_q, max(8, s))
    blk_k = min(blk_k, max(8, t))
    pad_q = (-s) % blk_q
    pad_k = (-t) % blk_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    out = flash_attention_pallas(q, k, v, causal=causal, blk_q=blk_q,
                                 blk_k=blk_k, interpret=interpret, kv_len=t,
                                 q_len=s)
    return out[:, :s]
