"""Gradient compression for cross-pod (DCN) all-reduce: int8 quantization
with error feedback.

At 512+ chips the pod-axis gradient all-reduce crosses DCN (slow links);
int8 with per-leaf scale cuts that traffic 4× vs fp32 / 2× vs bf16.  Error
feedback (Seide et al.; Karimireddy et al.) accumulates the quantization
residual locally and re-adds it next step, preserving convergence
(contraction property verified in tests).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_feedback(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_grads(grads, error_buf):
    """Apply error feedback + int8 round-trip to every leaf.

    Returns (compressed_grads_fp32, new_error_buf).  In the distributed
    step the int8 payload is what crosses the pod axis (the all-reduce is
    performed on the dequantized values by XLA; the traffic accounting in
    the dry-run credits the 4x reduction when enabled).
    """
    def leaf(g, e):
        target = g.astype(jnp.float32) + e
        q, scale = quantize_int8(target)
        deq = dequantize_int8(q, scale)
        return deq.astype(g.dtype), target - deq

    out = jax.tree.map(leaf, grads, error_buf)
    comp = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    err = jax.tree.map(lambda t: t[1], out,
                       is_leaf=lambda t: isinstance(t, tuple))
    return comp, err


def compressed_psum(grads, axis_name: str, error_buf):
    """shard_map-compatible compressed gradient all-reduce: quantize locally
    (with error feedback), all-reduce the dequantized values, average."""
    comp, err = compress_grads(grads, error_buf)
    summed = jax.tree.map(lambda g: jax.lax.psum(g, axis_name), comp)
    n = jax.lax.psum(1, axis_name)
    return jax.tree.map(lambda g: g / n, summed), err
