"""Token-choice top-k Mixture-of-Experts with sort-based fixed-capacity dispatch.

FLOP-faithful: each token is processed by exactly its top-k experts (plus the
optional Arctic-style dense residual), so the dry-run roofline reports
*active* MoE compute, not dense all-expert compute.

Dispatch: replicate each token k times, stable-sort the (token, expert)
assignments by expert id, place each row at ``expert_id * capacity +
rank_within_expert`` (rows beyond capacity are dropped — standard
capacity-factor semantics), run the batched expert matmuls on the (E,
capacity, d) buffer, and scatter back.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import COMPUTE_DTYPE, _init, rmsnorm, rmsnorm_init
from repro.sharding import shard


def moe_init(rng, cfg, dtype):
    m = cfg.moe
    d, e, f = cfg.d_model, m.num_experts, m.d_ff_expert
    r = jax.random.split(rng, 8)
    p = {
        "norm": rmsnorm_init(d, dtype),
        "wr": _init(r[0], (d, e), d ** -0.5, dtype),
        "wu": _init(r[1], (e, d, f), d ** -0.5, dtype),
        "wd": _init(r[2], (e, f, d), f ** -0.5, dtype),
    }
    if cfg.activation == "swiglu":
        p["wg"] = _init(r[3], (e, d, f), d ** -0.5, dtype)
    if m.dense_residual:
        fd = m.d_ff_dense
        p["du"] = _init(r[4], (d, fd), d ** -0.5, dtype)
        p["dd"] = _init(r[5], (fd, d), fd ** -0.5, dtype)
        if cfg.activation == "swiglu":
            p["dg"] = _init(r[6], (d, fd), d ** -0.5, dtype)
    return p


def _capacity(num_tokens: int, m) -> int:
    cap = int(np.ceil(num_tokens * m.top_k / m.num_experts * m.capacity_factor))
    return max(8, int(np.ceil(cap / 8)) * 8)  # pad for lane alignment


def _dispatch_groups(num_tokens: int) -> int:
    """Number of data-local dispatch groups = the mesh's data-axis size (1
    when unsharded, e.g. CPU tests)."""
    from repro.sharding import current_policy
    policy = current_policy()
    if policy is None:
        return 1
    sizes = dict(zip(policy.mesh.axis_names, policy.mesh.devices.shape))
    g = sizes.get("data", 1) * sizes.get("pod", 1)
    while g > 1 and num_tokens % g:
        g //= 2
    return max(g, 1)


def _expert_ffn(params, xb, activation):
    """xb: (G, E, C, d) → (G, E, C, d) — G data-local dispatch groups."""
    wu = params["wu"].astype(COMPUTE_DTYPE)
    wd = params["wd"].astype(COMPUTE_DTYPE)
    h = jnp.einsum("gecd,edf->gecf", xb, wu)
    h = shard(h, "batch", "experts", "expert_batch", "expert_mlp")
    if activation == "swiglu":
        g = jnp.einsum("gecd,edf->gecf", xb, params["wg"].astype(COMPUTE_DTYPE))
        h = jax.nn.silu(g) * h
    elif activation == "squared_relu":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("gecf,efd->gecd", h, wd)


def moe(params, x, cfg):
    """x: (B,S,D) → (out, aux) where aux has router stats (load-balance loss,
    per-expert load) — the inner game of the paper's §10.1 'nested congestion
    game' is observable through aux["expert_load"]."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    xn = rmsnorm(params["norm"], x, cfg.norm_eps).reshape(t, d)

    logits = jnp.einsum("td,de->te", xn, params["wr"].astype(COMPUTE_DTYPE))
    logits = logits.astype(jnp.float32)
    gate_w, gate_idx = jax.lax.top_k(logits, m.top_k)          # (T,k)
    gate_w = jax.nn.softmax(gate_w, axis=-1)

    # ---- load-balance aux loss (Switch-style) + expert load metric
    probs = jax.nn.softmax(logits, axis=-1)                     # (T,E)
    me = jnp.mean(probs, axis=0)                                # mean router prob
    one_hot = jax.nn.one_hot(gate_idx[:, 0], m.num_experts, dtype=jnp.float32)
    ce = jnp.mean(one_hot, axis=0)                              # top-1 load fraction
    aux_loss = m.num_experts * jnp.sum(me * ce)
    expert_load = jnp.sum(
        jax.nn.one_hot(gate_idx, m.num_experts, dtype=jnp.float32), axis=(0, 1))

    # ---- sort-based dispatch, grouped by data shard (§Perf iteration 5):
    # each group dispatches its own tokens into its own capacity slots, so
    # the scatter/gather never crosses the data axis — without grouping,
    # XLA lowers the cross-shard scatter as replicate+all-reduce of the
    # whole (E, cap, d) buffer (~20 TB/step on qwen3 train_4k).
    groups = _dispatch_groups(t)
    tg = t // groups
    cap = _capacity(tg, m)
    rows = tg * m.top_k
    g_expert = gate_idx.reshape(groups, rows // m.top_k, m.top_k) \
        .reshape(groups, rows)                                  # (G, rows)
    g_tok = jnp.broadcast_to(
        (jnp.arange(rows, dtype=jnp.int32) // m.top_k)[None], (groups, rows))
    order = jnp.argsort(g_expert, axis=-1, stable=True)
    sorted_expert = jnp.take_along_axis(g_expert, order, axis=-1)
    sorted_tok = jnp.take_along_axis(g_tok, order, axis=-1)
    first = jax.vmap(lambda se: jnp.searchsorted(se, se, side="left"))(
        sorted_expert)
    rank = jnp.arange(rows, dtype=jnp.int32)[None] - first.astype(jnp.int32)
    valid = rank < cap
    slot = jnp.where(valid, sorted_expert * cap + rank, m.num_experts * cap)

    xg = xn.reshape(groups, tg, d)
    xg = shard(xg, "batch", None, None)
    x_sorted = jnp.take_along_axis(
        xg.astype(COMPUTE_DTYPE), sorted_tok[..., None], axis=1)
    xb = jnp.zeros((groups, m.num_experts * cap, d), COMPUTE_DTYPE)
    xb = jax.vmap(lambda b, s, x, v: b.at[s].set(
        jnp.where(v[:, None], x, 0.0), mode="drop"))(xb, slot, x_sorted, valid)
    xb = xb.reshape(groups, m.num_experts, cap, d)
    xb = shard(xb, "batch", "experts", None, None)

    yb = _expert_ffn(params, xb, cfg.activation) \
        .reshape(groups, m.num_experts * cap, d)
    y_sorted = jax.vmap(lambda b, s: b.at[s].get(mode="drop",
                                                 fill_value=0.0))(yb, slot)
    y_sorted = jnp.where(valid[..., None], y_sorted, 0.0)
    # unsort and weighted-combine the k expert outputs per token
    inv = jnp.zeros_like(order).at[
        jnp.arange(groups)[:, None], order].set(
        jnp.broadcast_to(jnp.arange(rows, dtype=jnp.int32)[None],
                         (groups, rows)))
    y_flat = jnp.take_along_axis(y_sorted, inv[..., None], axis=1)
    w_flat = gate_w.reshape(groups, rows, 1).astype(COMPUTE_DTYPE)
    y = jnp.sum((y_flat * w_flat).reshape(groups, tg, m.top_k, d), axis=2)
    y = y.reshape(t, d)

    if m.dense_residual:
        h = jnp.einsum("td,df->tf", xn, params["du"].astype(COMPUTE_DTYPE))
        if cfg.activation == "swiglu":
            g = jnp.einsum("td,df->tf", xn, params["dg"].astype(COMPUTE_DTYPE))
            h = jax.nn.silu(g) * h
        elif cfg.activation == "squared_relu":
            h = jnp.square(jax.nn.relu(h))
        else:
            h = jax.nn.gelu(h)
        y = y + jnp.einsum("tf,fd->td", h, params["dd"].astype(COMPUTE_DTYPE))

    out = y.reshape(b, s, d)
    aux = {"moe_aux_loss": aux_loss, "expert_load": expert_load}
    return shard(out, "batch", "seq", "act_embed"), aux
