"""Game 1 (Prop. 1 / Eq. 5): runtime P/D repartitioning on the unified
worker-role pool.

Two experiments on the ``elastic-*`` scenario family:

* **Stationary convergence** — start the pool decode-heavy (1P/5D) under a
  stationary closed-loop load and let the Planner's ±1 best-response
  dynamic repartition it.  Reported per scenario: the realized-split
  trajectory, the variational equilibrium G_P* of the profiled response
  curves, the fraction of post-warmup polls with |G_P − G_P*| ≤ 1 (the
  Prop. 1 convergence claim), and the resource-game PoA-hat alongside the
  routing PoA-hat (Eq. 12).

* **Diurnal re-splitting** — the same pool under a sinusoidal open-loop
  wave: the equilibrium shifts with the arrival rate and the Planner keeps
  re-splitting across the cycle (role flips, distinct splits visited).

CSV: ``derived`` carries flips, the split trajectory endpoints, the
within-±1 fraction, and both PoA-hats.
"""
from __future__ import annotations

import time

from benchmarks.common import emit, save_json

STATIONARY = ("elastic-70b", "elastic-340b")
DIURNAL = "elastic-burst"


def _trajectory(res):
    """(t, gp, ve_gp, poa_resource, poa_routing) per resource-game poll."""
    out = []
    for p in res.poll_log:
        rg = p.get("resource_game")
        if rg is None:
            continue
        out.append(dict(t=p["t"], gp=rg["gp"], ve_gp=rg["ve_gp"],
                        so_gp=rg["so_gp"], poa_resource=rg["poa_resource"],
                        poa_routing=p["poa"], roles=p["roles"]))
    return out


def _converged_frac(traj, warmup_frac: float = 0.5) -> float:
    tail = traj[int(len(traj) * warmup_frac):]
    if not tail:
        return float("nan")
    return sum(1 for e in tail if abs(e["gp"] - e["ve_gp"]) <= 1) / len(tail)


def run(hold: float = 150.0, seeds=(0, 1, 2), smoke: bool = False) -> None:
    from repro.serving.scenarios import build_simulator

    if smoke:
        hold, seeds = 60.0, (0,)
    rows = {}
    fast = hold <= 60.0

    for name in STATIONARY:
        t0 = time.perf_counter()
        trajs, flips, conv, poa_r, poa_routing, n_done = [], 0, [], [], [], 0
        for seed in seeds:
            sim = build_simulator(name, seed=seed, fast=fast,
                                  **({} if fast else {"hold_s": hold}))
            res = sim.run()
            traj = _trajectory(res)
            trajs.append(traj)
            flips += len(res.role_flips)
            conv.append(_converged_frac(traj))
            tail = traj[len(traj) // 2:]
            poa_r += [e["poa_resource"] for e in tail]
            poa_routing += [e["poa_routing"] for e in tail
                            if e["poa_routing"] == e["poa_routing"]]
            n_done += len(res.completed)
        us = (time.perf_counter() - t0) * 1e6
        ve = trajs[0][-1]["ve_gp"] if trajs[0] else -1
        conv = [c for c in conv if c == c]   # a seed with no planner polls
        conv_frac = sum(conv) / len(conv) if conv else float("nan")
        mean = lambda xs: sum(xs) / len(xs) if xs else float("nan")
        rows[name] = dict(
            flips=flips, ve_gp=ve, converged_frac=conv_frac,
            poa_resource=mean(poa_r), poa_routing=mean(poa_routing),
            n=n_done, us_per_req=us / max(n_done, 1),
            trajectory=[(e["t"], e["gp"], e["ve_gp"]) for e in trajs[0]])
        emit(f"game1_{name}", rows[name]["us_per_req"],
             f"flips={flips};ve_gp={ve};within1={conv_frac:.2f};"
             f"poa_resource={mean(poa_r):.2f};"
             f"poa_routing={mean(poa_routing):.2f}")

    # diurnal: the equilibrium moves with the wave; count re-splits
    t0 = time.perf_counter()
    flips, splits, n_done = 0, set(), 0
    poa_r = []
    for seed in seeds:
        sim = build_simulator(DIURNAL, seed=seed, fast=fast)
        res = sim.run()
        flips += len(res.role_flips)
        for p in res.poll_log:
            splits.add(tuple(p["split"]))
        poa_r += [e["poa_resource"] for e in _trajectory(res)]
        n_done += len(res.completed)
    us = (time.perf_counter() - t0) * 1e6
    mean = lambda xs: sum(xs) / len(xs) if xs else float("nan")
    rows[DIURNAL] = dict(flips=flips, splits_visited=sorted(splits),
                         poa_resource=mean(poa_r), n=n_done,
                         us_per_req=us / max(n_done, 1))
    emit(f"game1_{DIURNAL}", rows[DIURNAL]["us_per_req"],
         f"flips={flips};splits={len(splits)};"
         f"poa_resource={mean(poa_r):.2f}")
    save_json("game1_repartition", rows)


if __name__ == "__main__":
    run()
