"""RA008 good: every pin has a matching release path in the module."""


def admit(kvbm, worker, hashes, now):
    kvbm.admit_blocks(worker, hashes, now=now)


def complete(kvbm, worker, hashes):
    for h in hashes:
        kvbm.unpin(worker, h)
