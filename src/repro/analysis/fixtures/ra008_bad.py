"""RA008 bad: pins KV blocks but has no release path at all."""


def admit(kvbm, worker, hashes, now):
    kvbm.admit_blocks(worker, hashes, now=now)


def hold(kvbm, worker, h):
    kvbm.pin(worker, h)
