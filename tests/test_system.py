"""End-to-end behaviour of the paper's system: the full adaptive-serving
loop (detector → controller → router) exercised through the simulator, plus
the headline paper claims at reproduction scale."""
import numpy as np
import pytest

from repro.core.saturation import Regime
from repro.serving.simulator import ClusterConfig, Simulator
from repro.serving.workload import WorkloadConfig


def test_adaptive_loop_detects_and_switches():
    """Load spike → detector leaves BELOW → dual-frontend switch fires →
    recovery returns to BELOW (the paper's 'clean regime transitions')."""
    sim = Simulator(ClusterConfig.for_model("llama-3.1-70b", "1P/5D"),
                    WorkloadConfig.load_spike(), adaptive=True, seed=1)
    res = sim.run()
    regimes = [p["regime"] for p in res.poll_log]
    assert max(regimes) >= int(Regime.TRANSITION)
    assert res.switch_time is not None
    # recovery phase back to BELOW
    tail = [p["regime"] for p in res.poll_log[-6:]]
    assert max(tail) == int(Regime.BELOW)


def test_same_first_postknee_grid_point_both_models():
    """Paper Table 5: both models' TTFT knee lands at the C=128 grid point
    (finite difference across [64,128] ≫ across [32,64])."""
    for name in ("nemotron-4-340b", "llama-3.1-70b"):
        t = {}
        for c in (32, 64, 128):
            sim = Simulator(ClusterConfig.for_model(name, "1P/2D"),
                            WorkloadConfig.single_level(c, hold_s=60.0))
            t[c] = sim.run().overall().ttft_p99
        d_low = (t[64] - t[32]) / 32
        d_knee = (t[128] - t[64]) / 64
        assert d_knee > 4 * max(d_low, 1e-5), (name, t)


@pytest.mark.slow
def test_variance_collapse_under_adaptive():
    """Paper §8.5 'Stability': adaptive strategy has much lower
    iteration-to-iteration variance in the saturated phase."""
    def sat_ttfts(adaptive):
        out = []
        for seed in (1, 2, 3):
            sim = Simulator(ClusterConfig.for_model("llama-3.1-70b", "1P/5D"),
                            WorkloadConfig.load_spike(), adaptive=adaptive,
                            seed=seed)
            out.append(sim.run().phase_stats(1).ttft_p99)
        return np.asarray(out)
    st = sat_ttfts(False)
    ad = sat_ttfts(True)
    assert ad.std() <= st.std() * 1.2
    assert ad.mean() < st.mean()
