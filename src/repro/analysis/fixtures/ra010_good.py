"""RA010 good: interpret threaded from a platform guard, None default."""
import functools

import jax
from jax.experimental import pallas as pl


def _on_cpu():
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_step(q, k, *, interpret=None):
    interpret = _on_cpu() if interpret is None else interpret
    return pl.pallas_call(_kernel, grid=(4,),
                          interpret=interpret)(q, k)
