"""§9.2 static counterfactual analysis: round-robin, random and
power-of-two-choices vs the KV-aware greedy policy — the PoA is driven by
temporal dynamics, not assignment choice."""
from __future__ import annotations

import time

from benchmarks.common import emit, run_sim, save_json

POLICIES = ["kv", "round_robin", "random", "p2c"]


def run(hold_s: float = 90.0):
    t0 = time.perf_counter()
    out = {}
    for model, topo in [("llama-3.1-70b", "1P/2D"), ("llama-3.1-70b", "1P/5D")]:
        rows = {}
        for pol in POLICIES:
            per_c = {}
            for c in (8, 64, 128):
                s = run_sim(model, topo, c, hold_s,
                            routing_policy=pol).overall()
                per_c[c] = dict(poa=s.poa, ttft_p99=s.ttft_p99)
            rows[pol] = per_c
        out[f"{model} {topo}"] = rows
        print(f"\n# §9.2 baselines — {model} {topo} (PoA by policy)")
        print(f"{'policy':>12}" + "".join(f"{f'C={c}':>10}" for c in (8, 64, 128)))
        for pol, per_c in rows.items():
            print(f"{pol:>12}" + "".join(f"{per_c[c]['poa']:>10.2f}"
                                         for c in (8, 64, 128)))
    save_json("baselines_static_routing", out)
    # max relative deviation from the KV policy at C>=64
    devs = []
    for rows in out.values():
        for pol in POLICIES[1:]:
            for c in (64, 128):
                base = rows["kv"][c]["poa"]
                devs.append(abs(rows[pol][c]["poa"] - base) / base)
    dt = (time.perf_counter() - t0) * 1e6
    emit("baselines_static_routing", dt / (2 * len(POLICIES) * 3),
         f"max_policy_deviation={max(devs)*100:.1f}%;"
         f"paper_claim=0.3-10%")
    return out


if __name__ == "__main__":
    run()
