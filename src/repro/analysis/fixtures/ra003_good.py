"""RA003 good: jitted functions are pure; impure work stays outside the
traced boundary; local containers may be mutated freely."""
import time

import jax
import jax.numpy as jnp


@jax.jit
def pure_step(x, key):
    noise = jax.random.normal(key, x.shape)   # explicit functional RNG
    return x + noise


@jax.jit
def local_mutation_is_fine(xs):
    acc = []                                  # bound inside the trace
    for x in xs:
        acc.append(x * 2)
    return jnp.stack(acc)


def timed_call(step, x, key):
    t0 = time.perf_counter()                  # outside the jit boundary
    y = step(x, key)
    return y, time.perf_counter() - t0
