"""Game 1 closure: the Planner inside the simulator event loop.

Covers the ISSUE-3 acceptance surface: best-response convergence to the
variational equilibrium of the profiled response curves under stationary
load, deterministic replay of elastic scenarios (same seed ⇒ identical
role-flip history), and the drain-protocol invariants (no request admitted
to a draining worker; a flipped worker's KVBM and KvIndexer claims gone).
"""
import dataclasses
import math

import pytest

from repro.core.planner import (Planner, PlannerConfig, ResponseModel,
                                erlang_c, poisson_sf)
from repro.core.poa import PoATracker
from repro.serving.scenarios import build_simulator
from repro.serving.simulator import (ClusterConfig, PREFILL_ROLE, Simulator)
from repro.serving.workload import WorkloadConfig


# ------------------------------------------------------ response curves -----

def test_erlang_c_limits():
    assert erlang_c(1, 0.0) == 0.0
    assert erlang_c(1, 1.5) == 1.0          # overloaded
    assert erlang_c(0, 0.5) == 1.0          # no servers
    # single server M/M/1: C(1, a) = a
    assert erlang_c(1, 0.3) == pytest.approx(0.3, abs=1e-9)
    # more servers at fixed load wait less
    assert erlang_c(4, 2.0) < erlang_c(3, 2.0)


def test_poisson_sf_monotone_and_bounded():
    assert poisson_sf(5, 0.0) == 0.0
    assert poisson_sf(-1, 3.0) == 1.0
    assert 0.0 <= poisson_sf(10, 8.0) <= 1.0
    assert poisson_sf(10, 12.0) > poisson_sf(10, 6.0)
    assert poisson_sf(10, 3000.0) == 1.0    # deep saturation (underflow path)


def _model(lam: float = 15.0) -> ResponseModel:
    return ResponseModel(arrival_rate=lam, prefill_service=0.065,
                         decode_residency=4.0, itl_base=0.009,
                         itl_slope=4e-4, decode_cap=64.0,
                         ttft_slack=0.28, itl_slo=0.016)


def test_response_curves_strictly_decreasing():
    m = _model()
    for g in range(1, 8):
        assert m.v_ttft(g) > m.v_ttft(g + 1) - 1e-12
        assert m.v_itl(g) > m.v_itl(g + 1) - 1e-12


def test_marginals_nonnegative_and_point_at_starved_pool():
    m = _model()
    m_p, m_d = m.marginals(1, 5)
    assert m_p >= 0.0 and m_d >= 0.0
    # with one prefill worker nearly saturated, prefill's marginal dominates
    assert m_p > m_d


def test_resource_game_counterfactual():
    m = _model()
    tracker = PoATracker(num_workers=6)
    rg = tracker.resource_game(m, prefill_workers=1, total=6)
    assert rg["gp"] == 1 and rg["gd"] == 5
    assert 1 <= rg["ve_gp"] <= 5 and 1 <= rg["so_gp"] <= 5
    assert rg["poa_resource"] >= 1.0 - 1e-9  # social optimum lower-bounds
    at_opt = tracker.resource_game(m, prefill_workers=rg["so_gp"], total=6)
    assert at_opt["poa_resource"] == pytest.approx(1.0)


def test_planner_hysteresis_dampens_small_gaps():
    pl = Planner(config=PlannerConfig(adjust_interval=1.0, hysteresis=0.5),
                 prefill_workers=2, decode_workers=2)
    assert pl.step(2.0, 1.0, 0.8) is None       # within the dead-band
    assert pl.step(4.0, 1.0, 0.5) == "to_prefill"


# -------------------------------------------------- in-simulator closure ----

@pytest.fixture(scope="module")
def elastic_run():
    sim = build_simulator("elastic-70b", seed=0, fast=True)
    return sim, sim.run()


def test_planner_converges_to_variational_equilibrium(elastic_run):
    """Stationary load: the realized split stays within ±1 worker of the
    variational equilibrium of the profiled response curves (Prop. 1)."""
    _, res = elastic_run
    traj = [(p["split"][0], p["resource_game"]["ve_gp"])
            for p in res.poll_log if "resource_game" in p]
    assert len(traj) >= 6
    tail = traj[len(traj) // 2:]
    assert all(abs(gp - ve) <= 1 for gp, ve in tail)
    assert len(res.role_flips) >= 1     # it moved off the 1P/5D start


def test_poll_log_game1_fields(elastic_run):
    sim, res = elastic_run
    for p in res.poll_log:
        assert set(p["roles"]) <= {"P", "D", "d"}
        assert len(p["roles"]) == len(sim.workers)
        assert p["split"][0] + p["split"][1] == len(sim.workers)
        assert p["roles"].count("P") == p["split"][0]
    planned = [p for p in res.poll_log if "resource_game" in p]
    assert planned, "planner polls must carry the resource-game payload"
    for p in planned:
        assert 0.0 <= p["ttft_viol"] <= 1.0
        assert 0.0 <= p["itl_viol"] <= 1.0
        assert p["resource_game"]["poa_resource"] >= 1.0 - 1e-9 or \
            math.isinf(p["resource_game"]["poa_resource"])


def test_elastic_replay_deterministic():
    """Same seed ⇒ identical role-flip history and overall stats."""
    a = build_simulator("elastic-70b", seed=3, fast=True).run()
    b = build_simulator("elastic-70b", seed=3, fast=True).run()
    assert a.role_flips == b.role_flips
    assert len(a.role_flips) >= 1
    assert dataclasses.astuple(a.overall()) == dataclasses.astuple(b.overall())
    assert [r.rid for r in a.completed] == [r.rid for r in b.completed]
    assert [r.decode_worker for r in a.completed] == \
        [r.decode_worker for r in b.completed]


def test_planner_disabled_keeps_roles_static():
    sim = build_simulator("elastic-70b", seed=0, fast=True, planner=False)
    res = sim.run()
    assert res.role_flips == []
    assert {tuple(p["split"]) for p in res.poll_log} == {(1, 5)}
    assert all("resource_game" not in p for p in res.poll_log)


# ------------------------------------------------------- drain protocol -----

def _planner_sim() -> Simulator:
    cluster = ClusterConfig.for_model("llama-3.1-70b", "1P/3D")
    return Simulator(cluster, WorkloadConfig.single_level(8, hold_s=5.0),
                     planner_config=PlannerConfig(adjust_interval=5.0),
                     seed=0)


def test_drain_reroutes_and_flushes():
    """Draining a decode worker immediately stops admission (router health)
    and the completed flip leaves no KVBM and no KvIndexer claims."""
    sim = _planner_sim()
    victim = sim.workers[0]
    # warm the victim's cache so there are claims to invalidate (the first
    # request tie-breaks to worker 0 and its tokens are indexed at routing)
    sim._submit(0, 128, 256)
    assert sim.router.indexer.num_blocks(0) > 0
    sim._start_drain_to_prefill(victim)
    # nothing was running, so the flip completes synchronously
    assert victim.role == PREFILL_ROLE
    assert victim.kvbm is None
    assert not victim.draining
    assert sim.router.indexer.num_blocks(0) == 0
    assert sim.role_flips == [(0.0, 0, "to_prefill")]
    assert 0 in sim.prefill_ids and 0 not in sim.decode_ids
    # every subsequent request routes to a live decode worker
    for _ in range(8):
        sim._submit(0, 128, 256)
    queued = list(sim.prefill_queue)
    assert len(queued) >= 6        # two prefill workers grabbed the rest
    assert all(r.decode_worker in (1, 2) for r in queued)


def test_admit_to_draining_worker_raises():
    sim = _planner_sim()
    sim._submit(0, 128, 256)   # dispatched straight to the prefill worker
    sim._submit(0, 128, 256)   # second stays queued: a handle to assert on
    req = sim.prefill_queue[0]
    w = sim.workers[req.decode_worker]
    w.draining = True
    with pytest.raises(RuntimeError, match="drain-protocol violation"):
        sim._admit_decode(req)


def test_elastic_flip_leaves_no_stale_state(elastic_run):
    """After a full elastic run, every worker currently in the prefill role
    has neither a KVBM nor KvIndexer claims (flips flushed them)."""
    sim, res = elastic_run
    assert len(res.role_flips) >= 1
    for w in sim.workers:
        if w.role == PREFILL_ROLE:
            assert w.kvbm is None
            assert sim.router.indexer.num_blocks(w.wid) == 0
            assert w.running == 0 and not w.transfer_queue
        else:
            assert w.kvbm is not None
