"""Per-architecture smoke tests (deliverable f): a reduced same-family config
runs one forward/train step and one prefill→decode on CPU, asserting output
shapes and finiteness; cached decode must match the uncached forward exactly
(MoE archs: with capacity high enough that nothing drops)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_reduced
from repro.models import build_model

# full cross-architecture sweep (~4 min on CPU): excluded from the tier-1
# fast lane; per-layer correctness stays covered by test_layers/test_ssm
pytestmark = pytest.mark.slow


def _nodrop(cfg):
    if cfg.moe is None:
        return cfg
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


def _batch(model, cfg, b, s, rng):
    shp = type("S", (), {"global_batch": b, "seq_len": s, "kind": "train",
                         "name": "smoke"})()
    specs = model.input_specs(shp)
    out = {}
    for k, v in specs.items():
        if v.dtype == jnp.int32:
            out[k] = jax.random.randint(rng, v.shape, 0, cfg.vocab_size)
        else:
            out[k] = jax.random.normal(rng, v.shape, jnp.float32).astype(v.dtype)
    return out


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step_smoke(arch, rng):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(rng, jnp.float32)
    batch = _batch(model, cfg, 2, 64, rng)
    loss, grads = jax.value_and_grad(
        lambda p: model.train_loss(p, batch, remat=True))(params)
    assert jnp.isfinite(loss)
    gnorm = sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_prefill_decode_consistency(arch, rng):
    cfg = _nodrop(get_reduced(arch))
    model = build_model(cfg)
    params = model.init(rng, jnp.float32)
    batch = _batch(model, cfg, 2, 32, rng)
    ntok = batch["tokens"].shape[1]
    prefix = cfg.num_patches if cfg.family == "vlm" else 0

    logits_full, _ = model.prefill(params, batch)
    assert logits_full.shape == (2, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits_full))

    short = dict(batch)
    short["tokens"] = batch["tokens"][:, :-1]
    _, caches = model.prefill(params, short, max_len=prefix + ntok)
    logits_dec, _ = model.decode(params, caches, batch["tokens"][:, -1:],
                                 jnp.int32(prefix + ntok - 1))
    assert jnp.allclose(logits_full, logits_dec, atol=2e-2, rtol=2e-2), arch


@pytest.mark.parametrize("arch", ["stablelm-3b", "jamba-v0.1-52b", "xlstm-125m"])
def test_ragged_decode_matches_aligned(arch, rng):
    """Per-slot write indices (continuous batching) must equal the scalar
    path when all lengths align."""
    cfg = _nodrop(get_reduced(arch))
    model = build_model(cfg)
    params = model.init(rng, jnp.float32)
    tokens = jax.random.randint(rng, (2, 16), 0, cfg.vocab_size)
    _, caches = model.prefill(params, {"tokens": tokens}, max_len=20)
    tok = tokens[:, -1:]
    l1, _ = model.decode(params, caches, tok, jnp.int32(16))
    l2, _ = model.decode(params, caches, tok,
                         jnp.full((2,), 16, jnp.int32))
    assert jnp.allclose(l1, l2, atol=2e-2, rtol=2e-2)
