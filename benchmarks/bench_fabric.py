"""Fabric sweep (fourth game) — congestion win + overhead + link usage.

Three sections:

* **congestion**: the congested ``fabric-scale-64`` scenario with and
  without network-aware decode selection.  Cache-affinity-only routing
  herds cold transfers onto one decode NIC per sync window; the
  network-aware router quotes each candidate's effective transfer time
  from live link queues and spreads them.  The win gate — network-aware
  must improve TTFT P99 **and** the network PoA-hat — is the PR's
  acceptance observable and fails the run under ``--check``.
* **overhead**: wall time of the congested scenario against the same
  pool with no fabric attached (``scale-64``) — the event-model cost of
  pricing the network at all.
* **links**: per-class link utilization histogram (decode NICs, prefill
  NICs, rack switches, spine) under both routing modes — where the bytes
  actually flowed.

Output: CSV rows on stdout + ``reports/benchmarks/BENCH_fabric.json``.
``--check BASELINE`` applies bench_scale's >2x wall-time regression rule
AND the congestion win gate, exiting non-zero on either.

    PYTHONPATH=src python -m benchmarks.bench_fabric [--smoke] [--check FILE]
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks.common import emit, save_json
from benchmarks.bench_scale import check_regression
from repro.serving.scenarios import build_simulator, list_scenarios

CONGESTED = "fabric-scale-64"
UNFABRIC = "scale-64"
assert {CONGESTED, UNFABRIC, "fabric-ramp", "fabric-drain"} <= \
    set(list_scenarios()), "registry out of sync"


def _run(name: str, smoke: bool, **overrides):
    t0 = time.perf_counter()
    sim = build_simulator(name, seed=0, fast=smoke, **overrides)
    res = sim.run()
    return sim, res, time.perf_counter() - t0


def _mode_stats(sim, res, wall: float) -> dict:
    s = res.overall()
    ng = res.poll_log[-1]["network_game"]
    waits = [r.transfer_wait for r in res.completed]
    return {"wall_s": wall, "completed": len(res.completed),
            "rps": s.rps, "ttft_p99": s.ttft_p99,
            "poa_latency_index": s.poa,
            "poa_network": ng["poa_network"],
            "transfer_wait_s": sum(waits),
            "transfer_wait_max": max(waits, default=0.0),
            "transfers": sim.fabric.enqueued,
            "cancelled": sim.fabric.cancelled}


def bench_congestion(smoke: bool) -> dict:
    out: dict = {}
    for mode, aware in (("flat", False), ("aware", True)):
        sim, res, wall = _run(CONGESTED, smoke, network_aware=aware)
        out[mode] = _mode_stats(sim, res, wall)
        m = out[mode]
        emit(f"bench_fabric_{mode}",
             wall / max(m["completed"], 1) * 1e6,
             f"ttft_p99={m['ttft_p99']:.4f}s;"
             f"poa_network={m['poa_network']:.4f};"
             f"transfer_wait_s={m['transfer_wait_s']:.2f};"
             f"transfers={m['transfers']}")
    # the acceptance observable (ratios > 1 mean network-aware wins)
    out["ttft_p99_gain"] = out["flat"]["ttft_p99"] / max(
        out["aware"]["ttft_p99"], 1e-12)
    out["poa_network_gain"] = out["flat"]["poa_network"] / max(
        out["aware"]["poa_network"], 1e-12)
    emit("bench_fabric_win", 0.0,
         f"ttft_p99_gain={out['ttft_p99_gain']:.2f}x;"
         f"poa_network_gain={out['poa_network_gain']:.4f}x")
    return out


def bench_overhead(smoke: bool) -> dict:
    """Event-model cost of the fabric itself: same pool and workload,
    with and without link accounting (routing decisions identical)."""
    _, res0, wall0 = _run(UNFABRIC, smoke)
    _, res1, wall1 = _run(CONGESTED, smoke)
    out = {"wall_s_flat_charge": wall0, "wall_s_fabric": wall1,
           "overhead_x": wall1 / max(wall0, 1e-9),
           "completed": len(res1.completed)}
    emit("bench_fabric_overhead", wall1 / max(len(res1.completed), 1) * 1e6,
         f"fabric_s={wall1:.2f};flat_s={wall0:.2f};"
         f"overhead={out['overhead_x']:.2f}x")
    return out


def bench_links(smoke: bool) -> dict:
    """Per-class utilization: where cumulative transmit seconds landed
    under each routing mode.  Herding shows up as decode-NIC seconds
    concentrated on few links; spreading flattens the histogram."""
    out: dict = {}
    for mode, aware in (("flat", False), ("aware", True)):
        sim, res, _ = _run(CONGESTED, smoke, network_aware=aware)
        links = res.poll_log[-1]["links"]
        decode = set(sim.fabric.decode_ids)
        cls: dict = {}
        peak = 0.0
        for name, st in links.items():
            if name.startswith("nic:"):
                wid = int(name.split(":")[1])
                key = "nic_decode" if wid in decode else "nic_prefill"
                if wid in decode:
                    peak = max(peak, st["busy_s"])
            else:
                key = "rack" if name.startswith("rack:") else "spine"
            c = cls.setdefault(key, {"busy_s": 0.0, "bytes": 0, "links": 0})
            c["busy_s"] += st["busy_s"]
            c["bytes"] += st["bytes"]
            c["links"] += 1
        nd = cls.get("nic_decode", {"busy_s": 0.0, "links": 1})
        mean = nd["busy_s"] / max(nd["links"], 1)
        out[mode] = {"classes": cls,
                     "decode_nic_peak_busy_s": peak,
                     "decode_nic_mean_busy_s": mean,
                     "decode_nic_peak_to_mean": peak / max(mean, 1e-12)}
        emit(f"bench_fabric_links_{mode}", 0.0,
             f"decode_nic_peak_s={peak:.2f};mean_s={mean:.2f};"
             f"peak_to_mean={out[mode]['decode_nic_peak_to_mean']:.1f}x")
    return out


def check_win(payload: dict) -> list:
    """The acceptance gate: on the congested scenario, network-aware
    selection must strictly improve TTFT P99 and the network PoA-hat
    over cache-affinity-only routing."""
    c = payload["congestion"]
    failures = []
    if c["aware"]["ttft_p99"] >= c["flat"]["ttft_p99"]:
        failures.append(
            f"network-aware TTFT P99 {c['aware']['ttft_p99']:.4f}s did not "
            f"improve on flat {c['flat']['ttft_p99']:.4f}s")
    if c["aware"]["poa_network"] > c["flat"]["poa_network"] + 1e-9:
        failures.append(
            f"network-aware PoA-hat {c['aware']['poa_network']:.4f} did "
            f"not improve on flat {c['flat']['poa_network']:.4f}")
    if c["aware"]["completed"] != c["flat"]["completed"]:
        failures.append("modes completed different request counts — the "
                        "comparison is not like-for-like")
    return failures


def run(smoke: bool = False) -> dict:
    payload = {"mode": "smoke" if smoke else "full",
               "congestion": bench_congestion(smoke),
               "overhead": bench_overhead(smoke),
               "links": bench_links(smoke)}
    save_json("BENCH_fabric", payload)
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast scenario variants (CI guard, not a "
                         "measurement)")
    ap.add_argument("--check", default=None, metavar="BASELINE",
                    help="fail on >2x wall regression vs this baseline "
                         "JSON, or on a lost congestion win")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    payload = run(smoke=args.smoke)
    failures = check_win(payload) if args.check else []
    if args.check:
        failures += check_regression(payload, args.check)
    if failures:
        print("FAIL:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        sys.exit(1)
    if args.check:
        print(f"# win + regression check vs {args.check}: ok",
              file=sys.stderr)


if __name__ == "__main__":
    main()
