"""KVBM: frequency-based eviction exactly as the paper describes (§2.2) —
init 1, ×2 on hit, −1 per decay step, promotion at freq ≥ 2 — plus tier
capacities and the ρ capacity ratio of Prop. 5."""
from repro.core.kvbm import KVBlockManager, TIER_COST, RECOMPUTE_COST


def test_frequency_dynamics():
    kv = KVBlockManager({"G1": 10})
    kv.allocate(1)
    assert kv.blocks[1].frequency == 1.0
    kv.access(1)
    assert kv.blocks[1].frequency == 2.0
    kv.access(1)
    assert kv.blocks[1].frequency == 4.0
    kv.decay()
    assert kv.blocks[1].frequency == 3.0


def test_eviction_demotes_lowest_frequency():
    kv = KVBlockManager({"G1": 2, "G2": 2})
    kv.allocate(1)
    kv.allocate(2)
    kv.access(2)           # block 2 hot
    kv.allocate(3)         # G1 full → demote coldest (block 1)
    assert kv.blocks[1].tier == "G2"
    assert kv.blocks[2].tier == "G1"
    assert kv.blocks[3].tier == "G1"
    assert kv.demotions == 1


def test_promotion_on_hit():
    kv = KVBlockManager({"G1": 1, "G2": 4})
    kv.allocate(1)
    kv.allocate(2)          # 1 demoted to G2
    assert kv.blocks[1].tier == "G2"
    kv.decay()              # freq: 1→0, 2→0
    kv.access(1)            # floored to 1, doubled to 2 → promote
    assert kv.blocks[1].tier == "G1"
    assert kv.blocks[2].tier == "G2"   # evicted from G1 to make room


def test_rehit_block_regains_promotion_eligibility():
    """Regression (§2.2): decay floors frequency at 0 and access used to
    double it — 0×2=0, so a fully-decayed block could never regain
    promotion eligibility and stayed the eternal eviction victim."""
    kv = KVBlockManager({"G1": 8})
    kv.allocate(1)
    for _ in range(3):
        kv.decay()
    assert kv.blocks[1].frequency == 0.0
    kv.access(1)
    assert kv.blocks[1].frequency == 2.0   # 1 (floor) × 2, not 0 × 2
    kv.access(1)
    assert kv.blocks[1].frequency == 4.0   # normal doubling resumes


def test_capacity_cascade_to_lower_tiers():
    kv = KVBlockManager({"G1": 1, "G2": 1, "G3": 1})
    for b in range(4):
        kv.allocate(b)
    tiers = sorted(blk.tier for blk in kv.blocks.values())
    # 4 blocks across G1,G2,G3 + G4
    assert tiers == ["G1", "G2", "G3", "G4"]


def test_tier_cost_ordering():
    assert TIER_COST["G1"] < TIER_COST["G2"] < TIER_COST["G3"] < TIER_COST["G4"] < RECOMPUTE_COST


def test_access_cost_and_miss():
    kv = KVBlockManager({"G1": 4})
    kv.allocate(1)
    assert kv.access_cost(1) == TIER_COST["G1"]
    assert kv.access_cost(999) == RECOMPUTE_COST


def test_capacity_ratio_rho():
    kv = KVBlockManager({"G1": 4})
    for b in range(6):
        kv.allocate(b)
    assert kv.capacity_ratio() == 6 / 4  # ρ > 1 ⇒ contested regime (Prop. 5)


def test_pinned_block_never_demoted():
    kv = KVBlockManager({"G1": 1, "G2": 4})
    kv.allocate(1)
    kv.pin(1)
    kv.allocate(2)          # G1 full, but 1 is pinned → no victim
    assert kv.blocks[1].tier == "G1"
    # pin pressure over-subscribes G1 (the ρ > 1 contested regime)
    assert kv.tier_usage["G1"] == 2
    assert kv.demotions == 0
    kv.unpin(1)
    kv.allocate(3)          # room must be made now: unpinned blocks demote
    assert kv.blocks[3].tier == "G1"
    assert kv.demotions > 0
    assert kv.tier_usage["G1"] <= kv.capacity["G1"] + 1


def test_pin_refcount_demotion_refusal():
    """Two pins → one unpin must still refuse demotion."""
    kv = KVBlockManager({"G1": 1, "G2": 4})
    kv.allocate(1)
    kv.pin(1)
    kv.pin(1)
    kv.unpin(1)
    kv.allocate(2)
    assert kv.blocks[1].tier == "G1"   # still pinned once
    kv.unpin(1)
    kv.allocate(3)
    assert kv.blocks[1].tier != "G1"   # refcount hit 0 → demotable


def test_on_g1_evict_callback_fires_on_demotion_and_free():
    evicted = []
    kv = KVBlockManager({"G1": 1, "G2": 4}, on_g1_evict=evicted.append)
    kv.allocate(1)
    kv.allocate(2)           # demotes 1 out of G1
    assert evicted == [1]
    kv.free(2)               # freeing a G1-resident block also fires
    assert evicted == [1, 2]
    kv.free(1)               # block 1 is in G2 now: no callback
    assert evicted == [1, 2]


def test_onboard_promotes_to_g1_through_tiers():
    kv = KVBlockManager({"G1": 1, "G2": 1, "G3": 1})
    for b in range(4):
        kv.allocate(b)
    deep = next(b for b, blk in kv.blocks.items() if blk.tier in ("G3", "G4"))
    assert kv.onboard(deep) == "G1"
    assert kv.blocks[deep].tier == "G1"
    assert kv.onboard(999) == "MISS"


def test_victim_tie_break_evicts_deepest_first():
    """Equal-frequency ties evict the most recently allocated block
    (radix leaf), keeping the surviving prefix contiguous."""
    kv = KVBlockManager({"G1": 3, "G2": 8})
    kv.allocate(10)
    kv.allocate(11)
    kv.allocate(12)          # chain root→leaf: 10, 11, 12
    kv.allocate(13)          # G1 full → leaf 12 demotes, not root 10
    assert kv.blocks[12].tier == "G2"
    assert kv.blocks[10].tier == "G1"
    assert kv.blocks[11].tier == "G1"


def test_tier_usage_invariant():
    kv = KVBlockManager({"G1": 3, "G2": 3, "G3": 3})
    for b in range(10):
        kv.allocate(b)
        kv.access(b % 3)
    for t, used in kv.tier_usage.items():
        assert used <= kv.capacity[t]
        assert used == sum(1 for blk in kv.blocks.values() if blk.tier == t)


def test_admit_blocks_equivalent_to_call_sequence():
    """``admit_blocks`` is the batched admission hot path; it must leave
    the manager in the identical state as the allocate/access/pin/onboard
    sequence it replaces — tiers, frequencies, pins, counters, and
    ``on_g1_evict`` firings — across decay churn and tiny capacities
    (promotion/demotion pressure)."""
    import random

    def build():
        evicted = []
        kv = KVBlockManager({"G1": 3, "G2": 4, "G3": 4},
                            on_g1_evict=evicted.append)
        return kv, evicted

    def state(kv):
        return (sorted((b.block_id, b.tier, b.frequency, b.pin_count,
                        b.seq, b.last_touch) for b in kv.blocks.values()),
                kv.tier_usage, kv.evictions, kv.promotions, kv.demotions)

    rng = random.Random(0)
    script = []          # (op, args) replayed identically on both managers
    for step in range(300):
        r = rng.random()
        if r < 0.55:
            script.append(("admit", tuple(rng.randrange(12)
                                          for _ in range(rng.randrange(1, 5))),
                           float(step)))
        elif r < 0.75:
            script.append(("unpin", rng.randrange(12)))
        elif r < 0.9:
            script.append(("decay",))
        else:
            script.append(("free", rng.randrange(12)))

    a, a_ev = build()    # batched
    b, b_ev = build()    # legacy four-call sequence
    for op in script:
        if op[0] == "admit":
            _, ids, now = op
            a.admit_blocks(ids, now)
            for bid in ids:
                b.allocate(bid, now)
                b.access(bid, now)
                b.pin(bid)
                b.onboard(bid)
        elif op[0] == "unpin":
            a.unpin(op[1]), b.unpin(op[1])
        elif op[0] == "decay":
            a.decay(), b.decay()
        else:
            a.free(op[1]), b.free(op[1])
        assert state(a) == state(b)
        assert a_ev == b_ev
