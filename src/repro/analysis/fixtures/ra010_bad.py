"""RA010 bad: interpret-mode guard missing or hardcoded."""
import functools

import jax
from jax.experimental import pallas as pl


def ragged_decode(q, k):
    return pl.pallas_call(_kernel, grid=(4,))(q, k)          # no interpret=


def ragged_decode_cpu(q, k):
    return pl.pallas_call(_kernel, grid=(4,),
                          interpret=True)(q, k)              # hardcoded


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_step(q, k, *, interpret=False):
    return pl.pallas_call(_kernel, grid=(4,),
                          interpret=interpret)(q, k)
