"""Simulator reproduces the paper's regime structure (Sections 5/8)."""
import numpy as np
import pytest

from repro.serving.simulator import ClusterConfig, Simulator
from repro.serving.workload import WorkloadConfig


def _sweep(name, topo, levels, hold=60.0, seed=0):
    out = {}
    for c in levels:
        sim = Simulator(ClusterConfig.for_model(name, topo),
                        WorkloadConfig.single_level(c, hold_s=hold), seed=seed)
        out[c] = sim.run().overall()
    return out


@pytest.fixture(scope="module")
def sweep70():
    return _sweep("llama-3.1-70b", "1P/2D", [32, 64, 96, 256])


@pytest.fixture(scope="module")
def sweep340():
    return _sweep("nemotron-4-340b", "1P/2D", [32, 64, 96, 256])


def test_poa_plateau_below_saturation(sweep70):
    plateau = [sweep70[c].poa for c in (32, 64, 96)]
    assert np.std(plateau) / np.mean(plateau) < 0.2  # flat (Prop. 4(i))


def test_poa_grows_at_saturation(sweep70):
    assert sweep70[256].poa > 1.5 * sweep70[64].poa  # Prop. 4(ii)


def test_ttft_explodes_itl_flat(sweep340):
    """§5.2 asymmetric saturation: TTFT explodes, ITL stays flat."""
    assert sweep340[256].ttft_p99 > 10 * sweep340[64].ttft_p99
    assert sweep340[256].itl_p99 < 1.2 * sweep340[64].itl_p99


def test_throughput_ceilings(sweep70, sweep340):
    assert 15 <= sweep340[256].rps <= 21      # paper ≈ 18 rps
    assert 38 <= sweep70[256].rps <= 50       # paper ≈ 47 rps


def test_cross_model_plateau_ratio(sweep70, sweep340):
    """340B plateau ≈ 2.5× the 70B plateau (paper §8.1)."""
    ratio = sweep340[64].poa / sweep70[64].poa
    assert 1.8 <= ratio <= 3.2


def test_5d_plateau_above_2d():
    s5 = _sweep("llama-3.1-70b", "1P/5D", [64])
    s2 = _sweep("llama-3.1-70b", "1P/2D", [64])
    assert 1.5 <= s5[64].poa / s2[64].poa <= 3.5  # paper ≈ 2×


def test_detector_fires_at_saturation():
    sim = Simulator(ClusterConfig.for_model("llama-3.1-70b", "1P/2D"),
                    WorkloadConfig.single_level(256, hold_s=60.0))
    res = sim.run()
    regimes = [p["regime"] for p in res.poll_log]
    assert max(regimes) >= 1          # TRANSITION detected
    below = Simulator(ClusterConfig.for_model("llama-3.1-70b", "1P/2D"),
                      WorkloadConfig.single_level(16, hold_s=60.0)).run()
    assert max(p["regime"] for p in below.poll_log) == 0


@pytest.mark.slow
def test_adaptive_improves_saturated_ttft():
    """Experiment 3 direction: adaptive ≤ static on saturated-phase TTFT."""
    ttft = {}
    for adaptive in (False, True):
        vals = []
        for seed in (1, 2):
            sim = Simulator(ClusterConfig.for_model("llama-3.1-70b", "1P/5D"),
                            WorkloadConfig.load_spike(),
                            adaptive=adaptive, seed=seed)
            vals.append(sim.run().phase_stats(1).ttft_p99)
        ttft[adaptive] = np.mean(vals)
    assert ttft[True] < ttft[False]


def test_static_counterfactual_policies_close_to_kv():
    """§9.2: round-robin / random / p2c all land within ~10% of the KV-aware
    policy below saturation (the PoA is temporal, not assignment-driven)."""
    stats = {}
    for pol in ("kv", "round_robin", "random", "p2c"):
        sim = Simulator(ClusterConfig.for_model("llama-3.1-70b", "1P/2D"),
                        WorkloadConfig.single_level(64, hold_s=60.0),
                        routing_policy=pol)
        stats[pol] = sim.run().overall().poa
    base = stats["kv"]
    for pol in ("round_robin", "random", "p2c"):
        assert abs(stats[pol] - base) / base < 0.15


def test_little_law_consistency(sweep70):
    """Closed loop: C ≈ λ·T at steady state (sanity of the event engine)."""
    s = sweep70[64]
    # T_total ≈ ttft + decode ≈ 64/λ
    t_per_req = 64 / s.rps
    assert 1.5 <= t_per_req <= 4.5
