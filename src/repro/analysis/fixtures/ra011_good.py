"""RA011 good: a replica view that reads authoritative state only in
``sync()`` and answers every query from its own frozen snapshot."""


class ReplicaStateView:
    def __init__(self, plane, index):
        self._plane = plane              # held, never dereferenced off-sync
        self.index = index
        self._ids = []
        self._loads = []
        self._regime = None
        self._claims = {}

    def sync(self, now):
        plane = self._plane              # the one sanctioned live read
        self._ids = plane.router.healthy_ids()
        self._loads = [plane.router.workers[w].active_blocks
                       for w in self._ids]
        self._regime = plane.detector.regime
        self._claims = plane.router.indexer.snapshot_claims(now)
        self.synced_at = now

    def healthy_ids(self):
        return list(self._ids)           # snapshot field only

    @property
    def regime(self):
        return self._regime

    def best_worker(self, overlaps):
        costs = [1.0 - ov + ld for ov, ld in zip(overlaps, self._loads)]
        j = min(range(len(self._ids)), key=lambda i: (costs[i], self._ids[i]))
        return self._ids[j]
