"""Datacenter fabric model for P→D KV transfers — the fourth game.

The paper's first three games (prefill placement, KV-tier residency,
cache-affinity routing) price compute and memory; this module prices the
*network*.  Instead of a flat per-block charge, every P→D KV transfer
becomes a sized :class:`Transmission` that serializes store-and-forward
on every :class:`NetworkLink` of its path (Helix's ``NetworkLink`` /
``TransmissionObject`` event model):

    NIC(prefill) ──► rack switch ──► [spine] ──► rack switch ──► NIC(decode)

Topology: one NIC link per worker, one switch link per ``rack_size``
workers, and a single spine link between racks.  Racks are assigned by
worker id (``rack_of(wid) = wid // rack_size``); same-rack transfers skip
the spine.  Because all transfers out of one prefill worker share its
NIC, and all transfers *into* one decode worker share that NIC,
cache-affinity routing that herds requests onto one decode worker
congests exactly the link its KV transfers need — the congestion
externality the fourth game measures.

Two clocks, one model: the analytic simulator pushes a ``transfer_done``
event at ``Transmission.finish_t``; the engine backend settles lazily via
:meth:`Fabric.complete_until`.  Quoting (:meth:`Fabric.quote`) and
committing (:meth:`Fabric.enqueue`) share one scheduling routine, so the
network-aware router's quote replays exactly as the fabric charge
(sanitizer invariant N2).  Per-link byte accounting is integral and
conserved across enqueue/complete/cancel (invariant N1); the drain
protocol cancels in-flight transmissions before re-quoting
(:meth:`Fabric.cancel` refunds the untransmitted residual).

``fabric=None`` everywhere keeps the legacy flat charge, routed through
:func:`kv_hop_seconds` so both backends price the hop in one place.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Tuple

_GBPS = 1e9 / 8.0                      # bytes/second per Gbit/s


def kv_hop_seconds(per_block_s: float, blocks: float) -> float:
    """Flat (fabric-less) KV-hop charge: ``per_block_s * blocks``.

    The single pricing helper both backends use when no fabric is
    attached.  The engine passes an integral non-resident block count;
    the simulator prices the whole prompt as one block scaled by its
    miss fraction (``blocks = 1 - overlap``) — same unit, coarser grain.
    """
    return per_block_s * blocks


def transfer_block_count(total_blocks: int, overlap: float) -> int:
    """Non-resident 16-token blocks that must cross the wire for a
    request with ``total_blocks`` hashed blocks and cache ``overlap``."""
    if total_blocks <= 0:
        return 0
    return max(0, min(total_blocks,
                      int(round(total_blocks * (1.0 - overlap)))))


@dataclass(frozen=True)
class FabricConfig:
    """Static fabric shape + the network-aware scoring weight.

    Defaults calibrate the *uncongested* full-miss transfer to the
    legacy flat charge: 8 blocks × 5 MiB ≈ 42 MB over a 25 Gbps NIC is
    ~13 ms of NIC serialization — the seed's ``kv_transfer = 0.012``.
    """
    nic_gbps: float = 25.0             # per-worker NIC bandwidth
    rack_gbps: float = 100.0           # intra-rack switch bandwidth
    spine_gbps: float = 100.0          # cross-rack spine bandwidth
    rack_size: int = 8                 # workers per rack (by wid)
    bytes_per_block: int = 5_242_880   # KV bytes per 16-token block
    net_weight: float = 25.0           # router cost units per quoted second


class NetworkLink:
    """One shared link: FIFO store-and-forward serialization.

    ``busy_until`` is the time the link's transmit queue drains;
    ``bytes_inflight`` is the integral sum of sizes of live transmissions
    whose path crosses this link (sanitizer invariant N1 recomputes it).
    """

    __slots__ = ("name", "bandwidth", "busy_until", "bytes_inflight",
                 "bytes_total", "busy_s")

    def __init__(self, name: str, gbps: float):
        self.name = name
        self.bandwidth = gbps * _GBPS          # bytes/second
        self.busy_until = 0.0
        self.bytes_inflight = 0                # live transmissions only
        self.bytes_total = 0                   # cumulative, never refunded
        self.busy_s = 0.0                      # cumulative transmit seconds

    def queue_s(self, now: float) -> float:
        return max(self.busy_until - now, 0.0)


@dataclass
class Transmission:
    """One sized P→D transfer occupying every link on its path."""
    tid: int
    rid: object
    src: int
    dst: int
    n_blocks: int
    size: int                                  # bytes
    path: Tuple[str, ...]
    enqueue_t: float
    finish_t: float
    # per-link (name, start, finish) occupancy, in path order
    segments: Tuple[Tuple[str, float, float], ...] = ()
    done: bool = False
    cancelled: bool = False


class Fabric:
    """Event-clock fabric: topology, live link state, transmissions."""

    def __init__(self, config: FabricConfig, num_decode: int,
                 num_prefill: int):
        self.config = config
        total = num_decode + num_prefill
        self.rack_size = max(1, int(config.rack_size))
        self.num_racks = (total + self.rack_size - 1) // self.rack_size
        self.links: Dict[str, NetworkLink] = {}
        for wid in range(total):
            self.links[f"nic:{wid}"] = NetworkLink(f"nic:{wid}",
                                                   config.nic_gbps)
        for r in range(self.num_racks):
            self.links[f"rack:{r}"] = NetworkLink(f"rack:{r}",
                                                  config.rack_gbps)
        if self.num_racks > 1:
            self.links["spine"] = NetworkLink("spine", config.spine_gbps)
        self.active: Dict[int, Transmission] = {}
        self.enqueued = 0
        self.completed = 0
        self.cancelled = 0
        self._tid = 0
        # uncongested per-byte path inverse-bandwidth (floor pricing)
        c = config
        self._inv_same = 2.0 / (c.nic_gbps * _GBPS) + 1.0 / (c.rack_gbps
                                                             * _GBPS)
        self._inv_cross = (2.0 / (c.nic_gbps * _GBPS)
                           + 2.0 / (c.rack_gbps * _GBPS)
                           + 1.0 / (c.spine_gbps * _GBPS))
        # default pool layout: decode 0..nd-1, prefill nd..nd+np-1 (the
        # simulator's wid convention; role flips call set_pool)
        self.set_pool(tuple(range(num_decode, total)),
                      tuple(range(num_decode)))

    # ------------------------------------------------------- topology --

    def rack_of(self, wid: int) -> int:
        return wid // self.rack_size

    def path(self, src: int, dst: int) -> List[str]:
        if src == dst:
            return []
        rs, rd = self.rack_of(src), self.rack_of(dst)
        if rs == rd:
            return [f"nic:{src}", f"rack:{rs}", f"nic:{dst}"]
        return [f"nic:{src}", f"rack:{rs}", "spine", f"rack:{rd}",
                f"nic:{dst}"]

    def set_pool(self, prefill_ids: Iterable[int],
                 decode_ids: Iterable[int]) -> None:
        """Track the current role split (the Planner flips roles)."""
        self.prefill_ids = tuple(sorted(prefill_ids))
        self.decode_ids = tuple(sorted(decode_ids))
        self._decode_racks = frozenset(self.rack_of(w)
                                       for w in self.decode_ids)

    # ----------------------------------------------------- scheduling --

    def _schedule(self, names: List[str], size: int, now: float):
        """Store-and-forward over ``names``: the message occupies each
        link in order, waiting for that link's queue to drain first.
        Pure given link state — shared by quote and enqueue (N2)."""
        t = now
        segs = []
        for name in names:
            link = self.links[name]
            start = max(t, link.busy_until)
            finish = start + size / link.bandwidth
            segs.append((name, start, finish))
            t = finish
        return t, segs

    def quote(self, src: int, dst: int, n_blocks: int, now: float) -> float:
        """Effective transfer seconds if enqueued now — pure, no commit."""
        if n_blocks <= 0:
            return 0.0
        size = n_blocks * self.config.bytes_per_block
        finish, _ = self._schedule(self.path(src, dst), size, now)
        return finish - now

    def enqueue(self, rid, src: int, dst: int, n_blocks: int,
                now: float) -> Optional[Transmission]:
        """Commit a transfer: reserve every link on the path, return the
        live :class:`Transmission` (``None`` for a fully-warm request)."""
        if n_blocks <= 0:
            return None
        size = n_blocks * self.config.bytes_per_block
        names = self.path(src, dst)
        finish, segs = self._schedule(names, size, now)
        for name, start, fin in segs:
            link = self.links[name]
            link.busy_until = fin
            link.busy_s += fin - start
            link.bytes_inflight += size
            link.bytes_total += size
        self._tid += 1
        txm = Transmission(tid=self._tid, rid=rid, src=src, dst=dst,
                           n_blocks=n_blocks, size=size, path=tuple(names),
                           enqueue_t=now, finish_t=finish,
                           segments=tuple(segs))
        self.active[txm.tid] = txm
        self.enqueued += 1
        return txm

    def complete(self, txm: Transmission) -> None:
        """Settle a finished transmission: release its byte reservation."""
        if txm.done or txm.cancelled:
            return
        txm.done = True
        for name in txm.path:
            self.links[name].bytes_inflight -= txm.size
        del self.active[txm.tid]
        self.completed += 1

    def complete_until(self, now: float) -> None:
        """Lazy settlement for the engine's tick clock."""
        finished = [t for t in self.active.values() if t.finish_t <= now]
        for txm in finished:
            self.complete(txm)

    def cancel(self, txm: Transmission, now: float) -> None:
        """Drain-protocol refund: release the *untransmitted* residual of
        every path segment so a rerouted request re-quotes against link
        state that no longer reserves its old destination (N1)."""
        if txm.done or txm.cancelled:
            return
        txm.cancelled = True
        for name, start, fin in txm.segments:
            link = self.links[name]
            remaining = max(fin - max(now, start), 0.0)
            link.busy_until -= remaining
            link.busy_s -= remaining
            link.bytes_inflight -= txm.size
        del self.active[txm.tid]
        self.cancelled += 1

    # -------------------------------------------------------- pricing --

    def route_src(self, now: float) -> int:
        """Least-queued prefill NIC (lowest wid on ties) — the source
        side of a transfer when the caller doesn't pin one."""
        return min(self.prefill_ids,
                   key=lambda w: (self.links[f"nic:{w}"].queue_s(now), w))

    def floor_seconds(self, src: int, n_blocks: int) -> float:
        """Uncongested (social-optimum) transfer time from ``src`` to the
        nearest decode rack — the per-request OPT column term for the
        network game's counterfactual."""
        if n_blocks <= 0:
            return 0.0
        size = n_blocks * self.config.bytes_per_block
        if not self._decode_racks or self.rack_of(src) in self._decode_racks:
            return size * self._inv_same
        return size * self._inv_cross

    # ------------------------------------------------------ telemetry --

    def link_stats(self, now: float) -> Dict[str, Dict[str, float]]:
        """Per-link queue depth + cumulative utilization for poll_log."""
        out: Dict[str, Dict[str, float]] = {}
        for name in sorted(self.links):
            link = self.links[name]
            out[name] = {"queue_s": round(link.queue_s(now), 6),
                         "busy_s": round(link.busy_s, 6),
                         "bytes": link.bytes_total,
                         "inflight": link.bytes_inflight}
        return out

    def freeze(self) -> "FabricSnapshot":
        """Immutable link-state copy for bounded-staleness replica views."""
        return FabricSnapshot(self)


class FabricSnapshot:
    """Frozen fabric state: replica views quote against this snapshot
    (never the live links), so routing on stale link state is exactly as
    stale as the rest of the replica's world (RA011 discipline)."""

    def __init__(self, fabric: Fabric):
        self.config = fabric.config
        self.rack_size = fabric.rack_size
        self.prefill_ids = fabric.prefill_ids
        self._busy = {name: link.busy_until
                      for name, link in fabric.links.items()}
        self._bw = {name: link.bandwidth
                    for name, link in fabric.links.items()}

    def rack_of(self, wid: int) -> int:
        return wid // self.rack_size

    def path(self, src: int, dst: int) -> List[str]:
        if src == dst:
            return []
        rs, rd = self.rack_of(src), self.rack_of(dst)
        if rs == rd:
            return [f"nic:{src}", f"rack:{rs}", f"nic:{dst}"]
        return [f"nic:{src}", f"rack:{rs}", "spine", f"rack:{rd}",
                f"nic:{dst}"]

    def quote(self, src: int, dst: int, n_blocks: int, now: float) -> float:
        if n_blocks <= 0:
            return 0.0
        size = n_blocks * self.config.bytes_per_block
        t = now
        for name in self.path(src, dst):
            start = max(t, self._busy[name])
            t = start + size / self._bw[name]
        return t - now

    def route_src(self, now: float) -> int:
        return min(self.prefill_ids,
                   key=lambda w: (max(self._busy[f"nic:{w}"] - now, 0.0), w))

    def state_key(self) -> Tuple:
        """Hash-free integrity key for sanitizer R2 (snapshot must not
        drift between syncs)."""
        return (self.prefill_ids,
                tuple(sorted(self._busy.items())))
