"""Workload generation (paper Section 7.4).

Short-chat profile: 5 prompt templates × 128 input tokens, 256 max output
tokens, deterministic generation.  Closed-loop clients hold a target
concurrency via a semaphore; each phase has a linear ramp then a hold.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Sequence, Tuple

NUM_TEMPLATES = 5
INPUT_TOKENS = 128
OUTPUT_TOKENS = 256


def template_tokens(template_id: int, n_tokens: int = INPUT_TOKENS) -> List[int]:
    """Deterministic token ids per template (shared prefixes per template)."""
    base = (template_id % NUM_TEMPLATES) * 100_000
    return [base + i for i in range(n_tokens)]


@dataclass(frozen=True)
class Phase:
    target_concurrency: int
    ramp_s: float
    hold_s: float


@dataclass(frozen=True)
class WorkloadConfig:
    phases: Tuple[Phase, ...]
    input_tokens: int = INPUT_TOKENS
    output_tokens: int = OUTPUT_TOKENS
    num_templates: int = NUM_TEMPLATES

    @classmethod
    def single_level(cls, concurrency: int, hold_s: float = 120.0,
                     ramp_s: float = 30.0) -> "WorkloadConfig":
        return cls(phases=(Phase(concurrency, ramp_s, hold_s),))

    @classmethod
    def load_spike(cls, low: int = 32, high: int = 128,
                   durations=(120.0, 180.0, 120.0)) -> "WorkloadConfig":
        """Experiment 3: C = low → high → low."""
        return cls(phases=(Phase(low, 10.0, durations[0]),
                           Phase(high, 10.0, durations[1]),
                           Phase(low, 0.0, durations[2])))

    def total_duration(self) -> float:
        return sum(p.ramp_s + p.hold_s for p in self.phases)

    def concurrency_at(self, t: float) -> int:
        """Target concurrency at absolute time t (linear ramps)."""
        t0 = 0.0
        prev = 0
        for p in self.phases:
            if t < t0 + p.ramp_s:
                frac = (t - t0) / max(p.ramp_s, 1e-9)
                return max(1, int(round(prev + frac * (p.target_concurrency - prev))))
            t0 += p.ramp_s
            if t < t0 + p.hold_s:
                return p.target_concurrency
            t0 += p.hold_s
            prev = p.target_concurrency
        return 0

    def phase_of(self, t: float):
        """Index of the phase active at time t (ramp attributed to its phase)."""
        t0 = 0.0
        for i, p in enumerate(self.phases):
            t0 += p.ramp_s + p.hold_s
            if t < t0:
                return i
        return len(self.phases) - 1
