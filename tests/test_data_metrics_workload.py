"""Data pipeline determinism, metrics registry, workload phases."""
import numpy as np

from repro.core.metrics import MetricsRegistry
from repro.serving.workload import WorkloadConfig, template_tokens
from repro.training.data import DataConfig, make_batch


def test_data_deterministic_per_step():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=8, seed=3)
    a = np.asarray(make_batch(cfg, 5)["tokens"])
    b = np.asarray(make_batch(cfg, 5)["tokens"])
    c = np.asarray(make_batch(cfg, 6)["tokens"])
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)
    assert a.min() >= 0 and a.max() < 1000


def test_data_host_slices_disjoint():
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=8)
    h0 = np.asarray(make_batch(cfg, 0, host_id=0, num_hosts=2)["tokens"])
    h1 = np.asarray(make_batch(cfg, 0, host_id=1, num_hosts=2)["tokens"])
    assert h0.shape == (4, 16) and h1.shape == (4, 16)
    assert not np.array_equal(h0, h1)


def test_data_has_learnable_structure():
    cfg = DataConfig(vocab_size=1000, seq_len=256, global_batch=4)
    t = np.asarray(make_batch(cfg, 0)["tokens"])
    # copy structure: positions repeating the token copy_period back occur
    # far above the Zipf collision baseline
    matches = (t[:, cfg.copy_period:] == t[:, :-cfg.copy_period]).mean()
    baseline = (t[:, 1:] == t[:, :-1]).mean()  # no copy structure at lag 1
    assert matches > 0.2 and matches > baseline + 0.08


def test_histogram_percentiles_and_window():
    m = MetricsRegistry()
    h = m.histogram("x", window_s=10.0)
    for i in range(100):
        h.observe(float(i), now=0.0)
    assert h.p99(0.0) == 98.0    # nearest-rank: ceil(.99·100)th sample
    assert h.percentile(50, 0.0) == 49.0
    h.observe(5.0, now=100.0)  # everything else expired
    assert h.count(100.0) == 1


def test_template_tokens_shared_prefixes():
    a = template_tokens(0)
    b = template_tokens(0)
    c = template_tokens(1)
    assert a == b and a != c and len(a) == 128


def test_workload_phases_and_ramp():
    w = WorkloadConfig.load_spike(low=32, high=128,
                                  durations=(120.0, 180.0, 120.0))
    assert w.concurrency_at(5.0) <= 32          # ramping up
    assert w.concurrency_at(50.0) == 32
    assert w.concurrency_at(135.0) in range(32, 129)  # spike ramp
    assert w.concurrency_at(200.0) == 128
    assert w.concurrency_at(400.0) == 32
    assert w.phase_of(50.0) == 0
    assert w.phase_of(200.0) == 1
    assert w.phase_of(400.0) == 2
    assert w.total_duration() == 440.0


def test_single_level_workload():
    w = WorkloadConfig.single_level(64, hold_s=100.0, ramp_s=20.0)
    assert w.concurrency_at(10.0) == 32  # halfway up the ramp
    assert w.concurrency_at(50.0) == 64
