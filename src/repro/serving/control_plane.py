"""Shared serving control plane — one runtime driving both backends.

The paper's mechanisms (Smart Router Eq. 1/2 + KvIndexer radix tree,
saturation detector Eq. 10/11, Table 2 adaptive regime params +
dual-frontend switch, Planner, PoA tracker Eq. 12, metrics registry) are
backend-agnostic: they consume routing-time token/hash streams and
telemetry, not simulated or real compute.  :class:`ControlPlane` owns that
wiring once, and two *backends* drive it:

* the **analytic backend** — :class:`repro.serving.simulator.Simulator`,
  the event-driven latency-model cluster (all calibrated experiments);
* the **engine backend** — :class:`repro.serving.disagg.DisaggregatedCluster`
  over real jitted-JAX :class:`~repro.serving.engine.PrefillEngine` /
  :class:`~repro.serving.engine.DecodeEngine` workers, where a cache-warm
  routing decision actually skips prefill recomputation.

Both backends route through :meth:`select_worker`, so a routing decision is
computed by the *same* code path given the same (tokens, hashes, indexer
state, load view) — that is what makes backend parity a testable property
(``tests/test_backend_parity.py``, ``benchmarks/bench_backend_parity.py``).

``decision_log`` (opt-in) records every routing decision for parity
comparison; it is off by default so large analytic runs carry no extra
per-request state.
"""
from __future__ import annotations

import time
from dataclasses import replace
from typing import Dict, List, Mapping, NamedTuple, Optional, Sequence, Tuple

from repro.core.controller import (REGIME_PARAMS, DualFrontend,
                                   export_game_metrics)
from repro.core.metrics import MetricsRegistry
from repro.core.planner import Planner, PlannerConfig
from repro.core.poa import PoATracker
from repro.core.router import (KvPushRouter, KvRouterConfig, PowerOfTwoRouter,
                               RandomRouter, RoundRobinRouter)
from repro.core.saturation import DetectorConfig, Regime, SaturationDetector


class RoutingDecision(NamedTuple):
    """One logged routing decision (parity comparisons key on these)."""
    rid: object            # backend request id (int rid / str request_id)
    worker: int
    overlap: float
    now: float


class ControlPlane:
    """Router + indexer + detector + adaptive params + Planner + PoA +
    metrics, wired once and shared by the analytic and engine backends."""

    def __init__(self, num_workers: int, *,
                 router_config: Optional[KvRouterConfig] = None,
                 routing_policy: str = "kv",    # kv|round_robin|random|p2c
                 seed: int = 0,
                 adaptive: bool = False,
                 detector_config: Optional[DetectorConfig] = None,
                 regime_params: Optional[Dict] = None,
                 cache_ttl: Optional[float] = None,
                 capacities: Optional[Mapping[int, float]] = None,
                 poa_num_workers: Optional[int] = None,
                 poa_window_s: float = 30.0,
                 poa_window_count: Optional[int] = None,
                 poa_capacities: Sequence[float] = (),
                 planner_config: Optional[PlannerConfig] = None,
                 num_prefill: int = 0,
                 log_decisions: bool = False,
                 sanitize: Optional[bool] = None):
        self.router = KvPushRouter(num_workers,
                                   router_config or KvRouterConfig(),
                                   seed=seed)
        if cache_ttl is not None:
            self.router.indexer.ttl = cache_ttl
        if capacities:
            for wid, cap in capacities.items():
                self.router.set_capacity(wid, cap)
        # Baselines share the router's worker table so health changes
        # propagate to every policy.
        self.routing_policy = routing_policy
        if routing_policy == "round_robin":
            self.policy = RoundRobinRouter(self.router)
        elif routing_policy == "random":
            self.policy = RandomRouter(self.router, seed)
        elif routing_policy == "p2c":
            self.policy = PowerOfTwoRouter(self.router, seed)
        else:
            self.policy = self.router

        self.adaptive = adaptive
        self.detector = SaturationDetector(detector_config or DetectorConfig())
        self.dual = DualFrontend()
        self.regime_params = dict(regime_params or REGIME_PARAMS)
        self.metrics = MetricsRegistry()
        self.switch_time: Optional[float] = None

        # Game 1: the Planner joins the control plane when configured.
        self.planner: Optional[Planner] = None
        self.planner_config: Optional[PlannerConfig] = None
        if planner_config is not None:
            self.planner_config = replace(
                planner_config, total_workers=num_workers + num_prefill)
            self.planner = Planner(config=self.planner_config,
                                   prefill_workers=num_prefill,
                                   decode_workers=num_workers)

        poa_kw = dict(num_workers=poa_num_workers or num_workers,
                      window_s=poa_window_s, capacities=tuple(poa_capacities))
        if poa_window_count is not None:
            poa_kw["window_count"] = poa_window_count
        self.poa = PoATracker(**poa_kw)

        self.log_decisions = log_decisions
        self.decision_log: List[RoutingDecision] = []
        self._last_config: KvRouterConfig = self.router.config

        # Opt-in coherence sanitizer for bare control-plane users; the
        # backends pass sanitize=False here and attach their own richer
        # sanitizers over this plane's structures.
        self.sanitizer = None
        if sanitize is not False:
            from repro.analysis.sanitize import (attach_control_sanitizer,
                                                 sanitize_enabled)
            if sanitize_enabled(sanitize):
                attach_control_sanitizer(self)

    # ------------------------------------------------------------ params ----

    def active_router_config(self, now: float) -> KvRouterConfig:
        """Table 2 regime-gated (τ, ω) override (plus the §6.4 dual-frontend
        switch bookkeeping); static config when not adaptive."""
        if not self.adaptive:
            return self.router.config
        self.dual.on_regime(self.detector.regime, now)
        if self.dual.active_port == 8001 and self.switch_time is None:
            self.switch_time = self.dual.switch_time
        return (self.regime_params.get(self.detector.regime)
                or self.router.config)

    # ----------------------------------------------------------- routing ----

    def select_worker(self, tokens: Sequence[int], *,
                      hashes: Optional[Sequence[int]] = None,
                      now: float = 0.0,
                      live_ids: Optional[Sequence[int]] = None,
                      rid: object = None, record: bool = True
                      ) -> Tuple[int, float, List[float], List[int]]:
        """One routing decision through the active policy.

        Returns ``(worker, overlap, overlaps, ids)`` where ``overlaps`` is
        positionally aligned with ``ids``.  Baseline policies (round-robin /
        random / p2c) report no overlap themselves, so their overlap vector
        is re-scored from the indexer over ``live_ids`` (the backend's live
        decode set) — the counterfactual the PoA tracker prices.

        ``record=False`` keeps the decision out of ``decision_log`` — for
        callers that may abandon the route (engine backpressure retries)
        and log only the placement that actually happened via
        :meth:`log_decision`.
        """
        cfg = self._last_config = self.active_router_config(now)
        worker, overlap, overlaps = self.policy.best_worker(
            tokens, router_config_override=cfg, now=now, hashes=hashes)
        if self.policy is not self.router:
            ids = (list(live_ids) if live_ids is not None
                   else self.router.healthy_ids())
            overlaps = self.router.indexer.overlap_scores(
                tokens, ids, now, hashes=hashes)
            overlap = overlaps[ids.index(worker)]
        else:
            ids = self.router.healthy_ids()
        if record:
            self.log_decision(rid, worker, overlap, now)
        return worker, overlap, overlaps, ids

    def log_decision(self, rid: object, worker: int, overlap: float,
                     now: float) -> None:
        if self.log_decisions:
            self.decision_log.append(
                RoutingDecision(rid, worker, overlap, now))

    def route(self, tokens: Sequence[int], *,
              hashes: Optional[Sequence[int]] = None,
              now: float = 0.0,
              live_ids: Optional[Sequence[int]] = None,
              rid: object = None, record: bool = True
              ) -> Tuple[int, float, List[float], List[int]]:
        """Engine-path routing: :meth:`select_worker` plus the Algorithm 1
        Prometheus exports (game_poa, game_saturation_state,
        game_router_temperature, game_overlap_weight, game_routing_cost)."""
        t0 = time.perf_counter()
        worker, overlap, overlaps, ids = self.select_worker(
            tokens, hashes=hashes, now=now, live_ids=live_ids, rid=rid,
            record=record)
        dt = time.perf_counter() - t0
        export_game_metrics(self.metrics, regime=self.detector.regime,
                            config=self._last_config, decision_s=dt,
                            now=now, poa_tracker=self.poa)
        return worker, overlap, overlaps, ids

    # --------------------------------------------------------- telemetry ----

    def observe(self, ttft_p99: float, now: float) -> Regime:
        """Feed one polled TTFT P99 sample to the saturation detector."""
        return self.detector.observe(ttft_p99, now)

    def regime_transitions(self) -> List[Tuple[float, int, int]]:
        """(t, from, to) regime transitions — the parity observable."""
        return list(self.detector.transitions)
