"""Post-SPMD HLO analysis: collective-byte accounting + roofline terms.

``compiled.cost_analysis()`` reports per-device FLOPs / bytes (verified by
probe — post-SPMD partitioning), but no collective traffic.  We parse the
optimized HLO text and sum the estimated per-device bytes moved by every
collective op, using standard ring-algorithm volume factors:

    all-reduce        2·(g-1)/g · bytes
    all-gather          (g-1)/g · bytes   (bytes = full gathered result)
    reduce-scatter      (g-1)/g · bytes   (bytes = unscattered input)
    all-to-all          (g-1)/g · bytes
    collective-permute        1 · bytes

Hardware constants are TPU v5e (the production target):
    197 TFLOP/s bf16 / chip, 819 GB/s HBM / chip, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict

PEAK_FLOPS = 197e12         # bf16 / chip
HBM_BW = 819e9              # bytes/s / chip
ICI_BW = 50e9               # bytes/s effective per chip (one ~50 GB/s link)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(sig: str) -> int:
    """Total bytes of all array shapes in an HLO result signature."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return default


@dataclass
class CollectiveStats:
    counts: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    bytes_by_kind: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    total_bytes: float = 0.0

    def as_dict(self):
        return {"counts": dict(self.counts),
                "bytes_by_kind": {k: float(v) for k, v in self.bytes_by_kind.items()},
                "total_bytes": float(self.total_bytes)}


def _find_collective(rhs: str):
    """Return (kind, index-of-op) if rhs applies a collective op."""
    for c in _COLLECTIVES:
        for suffix in ("", "-start"):
            token = c + suffix + "("
            idx = rhs.find(token)
            if idx < 0:
                continue
            if idx > 0 and (rhs[idx - 1].isalnum() or rhs[idx - 1] in "-_."):
                continue  # part of a longer identifier
            return c, idx
    return None, -1


def collective_bytes(hlo_text: str, num_devices: int) -> CollectiveStats:
    """Per-device collective traffic estimate from optimized HLO text.

    Shapes in post-SPMD HLO are per-device; we convert to per-device bytes
    *moved* with ring-algorithm factors (see module docstring).
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "=" not in stripped or "-done(" in stripped:
            continue  # bytes are counted at the op/-start line
        lhs, rhs = stripped.split("=", 1)
        op, idx = _find_collective(rhs)
        if op is None:
            continue
        # result signature sits between '=' and the op name
        nbytes = _shape_bytes(rhs[:idx])
        if nbytes == 0:
            nbytes = _shape_bytes(lhs)
        g = _group_size(stripped, num_devices)
        if g <= 1 or nbytes == 0:
            continue
        if op == "all-reduce":
            moved = 2.0 * (g - 1) / g * nbytes
        elif op == "collective-permute":
            moved = float(nbytes)
        elif op == "reduce-scatter":
            moved = (g - 1) * float(nbytes)     # result is the scattered shard
        else:  # all-gather / all-to-all: result is the full gathered shape
            moved = (g - 1) / g * nbytes
        stats.counts[op] += 1
        stats.bytes_by_kind[op] += moved
        stats.total_bytes += moved
    return stats


def roofline_terms(cost: dict, coll: CollectiveStats) -> dict:
    """Three roofline terms (seconds, per device == per step)."""
    flops = float(cost.get("flops", 0.0) or 0.0)
    bytes_accessed = float(cost.get("bytes accessed", 0.0) or 0.0)
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_accessed / HBM_BW
    t_coll = coll.total_bytes / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll,
             "hlo_flops_per_device": flops,
             "hlo_bytes_per_device": bytes_accessed,
             "collective_bytes_per_device": coll.total_bytes}
    dom = max(("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])
    terms["bottleneck"] = dom.replace("_s", "")
    bound = max(t_compute, t_memory, t_coll)
    terms["roofline_fraction"] = t_compute / bound if bound > 0 else 0.0
    return terms
