"""The Planner — Game 1 (prefill/decode GNEP resource allocation).

Implements both layers the paper describes:

* ``variational_equilibrium`` — the analytical solution of Prop. 1: on the
  constraint manifold G_P + G_D = G, find the split equalizing marginal SLO
  violation improvements (Eq. 5), and the *social optimum* of Remark 1 which
  additionally credits prefill's positive externality on decode.

* ``Planner`` — the runtime best-response dynamic with inertia: ±1 worker per
  adjustment interval (30 s), 3-interval grace period for newly assigned
  decode workers, driven by polled TTFT/ITL violation metrics.  Converges to
  the variational equilibrium under stationary load (validated in tests).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional, Tuple



def variational_equilibrium(v_ttft: Callable[[float], float],
                            v_itl: Callable[[float], float],
                            total: int) -> int:
    """Integer split G_P* with |marginal| balance of Eq. 5 (exhaustive scan —
    G is small; convexity makes the crossing unique)."""
    best, best_gap = 1, float("inf")
    for gp in range(1, total):
        gd = total - gp
        m_p = v_ttft(gp + 1) - v_ttft(gp)      # ≤ 0, marginal improvement
        m_d = v_itl(gd + 1) - v_itl(gd)
        gap = abs(m_p - m_d)
        if gap < best_gap:
            best, best_gap = gp, gap
    return best


def social_optimum(v_ttft: Callable[[float], float],
                   v_itl_joint: Callable[[float, float], float],
                   total: int) -> int:
    """argmin_{G_P} V_TTFT(G_P) + V_ITL(G−G_P, G_P) (Remark 1)."""
    costs = [(v_ttft(gp) + v_itl_joint(total - gp, gp), gp)
             for gp in range(1, total)]
    return min(costs)[1]


@dataclass
class PlannerConfig:
    total_workers: int = 3
    adjust_interval: float = 30.0     # seconds
    grace_intervals: int = 3          # grace for newly assigned decode workers
    ttft_slo: float = 1.0             # seconds
    itl_slo: float = 0.050


@dataclass
class Planner:
    """±1-worker best-response dynamic over polled violation rates."""
    config: PlannerConfig = field(default_factory=PlannerConfig)
    prefill_workers: int = 1
    decode_workers: int = 2
    _last_adjust: float = 0.0
    _grace_until: float = 0.0
    history: List[Tuple[float, int, int]] = field(default_factory=list)

    def step(self, now: float, ttft_violation: float, itl_violation: float
             ) -> Optional[str]:
        """Called per telemetry poll; may move one worker between pools.
        Returns 'to_prefill' / 'to_decode' / None."""
        c = self.config
        if now - self._last_adjust < c.adjust_interval or now < self._grace_until:
            return None
        move = None
        if ttft_violation > itl_violation and self.decode_workers > 1:
            self.prefill_workers += 1
            self.decode_workers -= 1
            move = "to_prefill"
        elif itl_violation > ttft_violation and self.prefill_workers > 1:
            self.prefill_workers -= 1
            self.decode_workers += 1
            move = "to_decode"
            self._grace_until = now + c.grace_intervals * c.adjust_interval
        if move:
            self._last_adjust = now
            self.history.append((now, self.prefill_workers, self.decode_workers))
        return move
