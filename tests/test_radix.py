"""KvIndexer radix tree: prefix sharing, overlap scores, TTL churn."""
from repro.core.radix import BLOCK_SIZE, KvIndexer, block_hashes


def toks(base, n=64):
    return [base + i for i in range(n)]


def test_block_hashes_prefix_chained():
    a = block_hashes(toks(0, 64))
    b = block_hashes(toks(0, 48))
    assert a[:3] == b  # shared prefix ⇒ shared leading hashes
    c = block_hashes([1] + toks(0, 63))
    assert c[0] != a[0]  # first-block change changes every chained hash
    assert c[1] != a[1]


def test_partial_tail_block_ignored():
    assert len(block_hashes(list(range(70)))) == 70 // BLOCK_SIZE


def test_overlap_full_and_partial():
    ix = KvIndexer()
    ix.insert(0, toks(0, 64))
    full, = ix.overlap_scores(toks(0, 64), [0])
    assert full == 1.0
    # same first 32 tokens, different tail
    partial, = ix.overlap_scores(toks(0, 32) + toks(9000, 32), [0])
    assert partial == 0.5
    cold, = ix.overlap_scores(toks(5000, 64), [0])
    assert cold == 0.0


def test_overlap_per_worker_independent():
    ix = KvIndexer()
    ix.insert(0, toks(0, 64))
    ix.insert(1, toks(1000, 64))
    o = ix.overlap_scores(toks(0, 64), [0, 1])
    assert o == [1.0, 0.0]


def test_ttl_expiry():
    ix = KvIndexer(ttl=2.0)
    ix.insert(0, toks(0, 64), now=0.0)
    assert ix.overlap_scores(toks(0, 64), [0], now=1.0)[0] == 1.0
    assert ix.overlap_scores(toks(0, 64), [0], now=5.0)[0] == 0.0
    ix.insert(0, toks(0, 64), now=6.0)  # refresh
    assert ix.overlap_scores(toks(0, 64), [0], now=7.0)[0] == 1.0


def test_eviction_removes_worker_claim():
    ix = KvIndexer()
    ix.insert(0, toks(0, 64))
    ix.insert(1, toks(0, 64))
    ix.remove_worker_blocks(0, toks(0, 64))
    assert ix.overlap_scores(toks(0, 64), [0, 1]) == [0.0, 1.0]


def test_remove_worker_block_truncates_credited_prefix():
    """Single-block invalidation (the KVBM demotion hook): dropping a
    mid-chain claim truncates the fresh prefix right before that block."""
    ix = KvIndexer()
    ix.insert(0, toks(0, 64))                    # 4 blocks
    hs = block_hashes(toks(0, 64))
    ix.remove_worker_block(0, hs[2])
    assert ix.matched_blocks(0, toks(0, 64)) == 2
    assert ix.overlap_scores(toks(0, 64), [0]) == [0.5]
    # other workers' claims on the same block are untouched
    ix.insert(1, toks(0, 64))
    ix.remove_worker_block(0, hs[0])
    assert ix.overlap_scores(toks(0, 64), [0, 1]) == [0.0, 1.0]
    # unknown hash is a no-op
    ix.remove_worker_block(1, 0xDEAD)
    assert ix.overlap_scores(toks(0, 64), [1]) == [1.0]


def test_remove_worker_block_then_reinsert_restores_credit():
    ix = KvIndexer()
    ix.insert(0, toks(0, 64))
    hs = block_hashes(toks(0, 64))
    ix.remove_worker_block(0, hs[0])
    assert ix.matched_blocks(0, toks(0, 64)) == 0
    ix.insert(0, toks(0, 64))                    # re-onboarded / re-admitted
    assert ix.matched_blocks(0, toks(0, 64)) == 4


def test_clear_worker():
    ix = KvIndexer()
    ix.insert(0, toks(0, 64))
    ix.insert(0, toks(1000, 64))
    ix.clear_worker(0)
    assert ix.num_blocks(0) == 0
    assert ix.overlap_scores(toks(0, 64), [0]) == [0.0]


def test_clear_worker_deep_chain_iterative():
    """Regression: ``clear_worker`` recursed node-per-block, so a Game 1
    role flip after indexing a ≥16k-token prompt (≥1000 blocks) raised
    RecursionError."""
    ix = KvIndexer()
    n_blocks = 1200
    tokens = list(range(n_blocks * BLOCK_SIZE))
    ix.insert(0, tokens)
    assert ix.num_blocks(0) == n_blocks
    ix.clear_worker(0)                   # must not hit the recursion limit
    assert ix.num_blocks(0) == 0
    assert ix.overlap_scores(tokens, [0]) == [0.0]


def test_empty_nodes_and_hash_map_pruned():
    """Memory boundedness: invalidation prunes claim-free nodes, and the
    ``_node_by_hash`` lookup table shrinks with the tree instead of
    accumulating every hash ever inserted."""
    ix = KvIndexer()
    ix.insert(0, toks(0, 64))
    ix.insert(1, toks(0, 64))
    ix.insert(0, toks(1000, 64))
    assert len(ix._node_by_hash) == 8
    ix.clear_worker(0)
    # worker 1 still claims the shared chain; worker 0's private chain is
    # fully reclaimed
    assert len(ix._node_by_hash) == 4
    assert ix.overlap_scores(toks(0, 64), [0, 1]) == [0.0, 1.0]
    ix.remove_worker_blocks(1, toks(0, 64))
    assert len(ix._node_by_hash) == 0
    assert not ix.root.children


def test_aggregated_matches_legacy_walk():
    """The single-walk scoring must be value-identical to the per-worker
    walk across partial overlaps, TTL staleness and invalidation."""
    def build(aggregated):
        ix = KvIndexer(ttl=2.0, aggregated=aggregated)
        ix.insert(0, toks(0, 64), now=0.0)
        ix.insert(1, toks(0, 32) + toks(7000, 32), now=1.5)
        ix.insert(2, toks(500, 64), now=2.0)
        ix.insert(3, toks(0, 16), now=3.4)
        ix.remove_worker_block(0, block_hashes(toks(0, 64))[2])
        return ix
    queries = [toks(0, 64), toks(0, 32) + toks(7000, 32), toks(500, 64),
               toks(9999, 64), toks(0, 16), []]
    workers = [3, 0, 1, 2, 17]           # order-independent, unknown ok
    for now in (0.0, 1.6, 3.0, 3.5, 9.0):
        agg, legacy = build(True), build(False)
        for q in queries:
            assert agg.overlap_scores(q, workers, now=now) == \
                legacy.overlap_scores(q, workers, now=now)


def test_matched_blocks_monotone_under_insert():
    ix = KvIndexer()
    ix.insert(0, toks(0, 32))
    m1 = ix.matched_blocks(0, toks(0, 64))
    ix.insert(0, toks(0, 64))
    m2 = ix.matched_blocks(0, toks(0, 64))
    assert m2 >= m1
