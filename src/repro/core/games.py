"""Formal game objects (Section 4) — the analytical layer.

These are *analysis* tools (the paper's point: game theory's value here is
analytical, not algorithmic): explicit normal-form routing games with exact
social-cost/Nash computations on small instances, the potential function for
ω=0 (Rosenthal), and brute-force PoA — used by tests to verify the paper's
structural claims (existence of pure NE at ω=0, potential-game property,
PoA bounds, and the bound's failure once the singular latency term or cache
externalities enter).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.latency import LatencyParams, latency


@dataclass
class RoutingGame:
    """Atomic routing game Γ_R (Definition 3).

    num_requests players choose among num_workers resources.
    Cost (Eq. 7): C_i(σ) = f_j(n_j(σ)) − ω·o_ij.
    """
    num_requests: int
    num_workers: int
    omega: float = 0.0
    overlap: Optional[np.ndarray] = None      # (requests, workers) o_ij
    latency_fn: Callable[[np.ndarray], np.ndarray] = None

    def __post_init__(self):
        if self.latency_fn is None:
            self.latency_fn = lambda n: 1.0 * n          # affine f(n)=n
        if self.overlap is None:
            self.overlap = np.zeros((self.num_requests, self.num_workers))

    # ------------------------------------------------------------- costs ----

    def loads(self, profile: Sequence[int]) -> np.ndarray:
        n = np.zeros(self.num_workers)
        for j in profile:
            n[j] += 1
        return n

    def player_cost(self, profile: Sequence[int], i: int) -> float:
        n = self.loads(profile)
        j = profile[i]
        return float(self.latency_fn(n[j]) - self.omega * self.overlap[i, j])

    def social_cost(self, profile: Sequence[int]) -> float:
        return sum(self.player_cost(profile, i)
                   for i in range(self.num_requests))

    # -------------------------------------------------------- equilibria ----

    def is_nash(self, profile: Sequence[int]) -> bool:
        profile = list(profile)
        for i in range(self.num_requests):
            cur = self.player_cost(profile, i)
            for j in range(self.num_workers):
                if j == profile[i]:
                    continue
                dev = profile.copy()
                dev[i] = j
                if self.player_cost(dev, i) < cur - 1e-12:
                    return False
        return True

    def enumerate_profiles(self):
        return itertools.product(range(self.num_workers),
                                 repeat=self.num_requests)

    def exact_poa(self) -> Tuple[float, float, float]:
        """Brute force (worst NE cost, optimum cost, PoA). Exponential —
        small instances only (tests)."""
        worst_ne = -np.inf
        opt = np.inf
        for prof in self.enumerate_profiles():
            sc = self.social_cost(prof)
            opt = min(opt, sc)
            if self.is_nash(prof):
                worst_ne = max(worst_ne, sc)
        return worst_ne, opt, worst_ne / opt if opt > 0 else np.inf

    def potential(self, profile: Sequence[int]) -> float:
        """Rosenthal potential Φ(σ) = Σ_j Σ_{k≤n_j} f(k) — exact potential
        iff ω = 0 (Prop. 3.1/3.2)."""
        n = self.loads(profile)
        phi = 0.0
        for j in range(self.num_workers):
            for k in range(1, int(n[j]) + 1):
                phi += float(self.latency_fn(np.asarray(float(k))))
        return phi

    def best_response_dynamics(self, profile: Optional[List[int]] = None,
                               max_rounds: int = 1000) -> Tuple[List[int], int]:
        """Sequential best response; returns (profile, rounds). Converges in
        ≤ n rounds for static congestion games [Fardno & Etesami]."""
        if profile is None:
            profile = [0] * self.num_requests
        for rnd in range(max_rounds):
            changed = False
            for i in range(self.num_requests):
                costs = []
                for j in range(self.num_workers):
                    dev = profile.copy()
                    dev[i] = j
                    costs.append(self.player_cost(dev, i))
                best = int(np.argmin(costs))
                if best != profile[i]:
                    profile[i] = best
                    changed = True
            if not changed:
                return profile, rnd + 1
        return profile, max_rounds

    def greedy_sequential(self, order: Optional[Sequence[int]] = None
                          ) -> List[int]:
        """Dynamo-router-style arrival-order greedy assignment (the mechanism
        whose PoA the paper measures)."""
        order = order if order is not None else range(self.num_requests)
        profile = [-1] * self.num_requests
        loads = np.zeros(self.num_workers)
        for i in order:
            c = self.latency_fn(loads + 1) - self.omega * self.overlap[i]
            j = int(np.argmin(c))
            profile[i] = j
            loads[j] += 1
        return profile


def singular_game(num_requests: int, num_workers: int,
                  params: LatencyParams = LatencyParams(n_sat=8.0),
                  omega: float = 0.0, overlap=None) -> RoutingGame:
    """Routing game with the Eq. 9 singular latency (pole at n_sat)."""
    return RoutingGame(num_requests, num_workers, omega=omega,
                       overlap=overlap,
                       latency_fn=lambda n: latency(n, params))


@dataclass
class CacheGame:
    """Selfish caching game Γ_KV (Definition 2) on a small worker graph.

    Strategy per (worker, block): cache locally or fetch remotely/recompute.
    Used by tests to verify Prop. 2: pure NE exist; on complete graphs with
    uniform distance ≥ local cost, selfish caching is socially optimal
    (PoA=1).
    """
    num_workers: int
    num_blocks: int
    alpha: float = 1.0                        # local caching/placement cost
    gamma: float = 10.0                       # recompute cost
    distance: Optional[np.ndarray] = None     # (w, w) network cost

    def __post_init__(self):
        if self.distance is None:
            d = np.ones((self.num_workers, self.num_workers))
            np.fill_diagonal(d, 0.0)
            self.distance = d

    def worker_cost(self, placement: np.ndarray, w: int) -> float:
        """placement: bool (workers, blocks). Each worker needs every block:
        local → α; remote → min distance to a holder; none → γ."""
        total = 0.0
        for b in range(self.num_blocks):
            if placement[w, b]:
                total += self.alpha
            else:
                holders = np.where(placement[:, b])[0]
                if len(holders) == 0:
                    total += self.gamma
                else:
                    total += float(self.distance[w, holders].min())
        return total

    def social_cost(self, placement: np.ndarray) -> float:
        return sum(self.worker_cost(placement, w)
                   for w in range(self.num_workers))

    def is_nash(self, placement: np.ndarray) -> bool:
        for w in range(self.num_workers):
            cur = self.worker_cost(placement, w)
            for b in range(self.num_blocks):
                flip = placement.copy()
                flip[w, b] = ~flip[w, b]
                if self.worker_cost(flip, w) < cur - 1e-12:
                    return False
        return True

    def best_response_dynamics(self, max_rounds: int = 100) -> np.ndarray:
        placement = np.zeros((self.num_workers, self.num_blocks), dtype=bool)
        for _ in range(max_rounds):
            changed = False
            for w in range(self.num_workers):
                for b in range(self.num_blocks):
                    cur = self.worker_cost(placement, w)
                    flip = placement.copy()
                    flip[w, b] = ~flip[w, b]
                    if self.worker_cost(flip, w) < cur - 1e-12:
                        placement = flip
                        changed = True
            if not changed:
                break
        return placement
