"""End-to-end disaggregated cluster on real (reduced) models.

One prefill engine + N decode engines, glued by the paper's mechanisms:
Smart Router (Eq. 1/2) with KvIndexer overlap, adaptive controller
(saturation detector + Table 2 regime params), PoA tracker, and per-request
metrics.  This is the production pattern at test scale: the same code path
drives TPU submeshes when the engines are built on disjoint device sets.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.controller import AdaptiveRouter
from repro.core.poa import CompletedRequest, PoATracker
from repro.core.router import KvPushRouter, KvRouterConfig
from repro.core.saturation import DetectorConfig, SaturationDetector
from repro.models.model import Model
from repro.serving.engine import DecodeEngine, PrefillEngine


@dataclass
class ServeRequest:
    request_id: str
    tokens: List[int]
    max_new_tokens: int = 16
    extras: Optional[dict] = None
    submit_t: float = 0.0
    first_token_t: float = 0.0
    finish_t: float = 0.0
    output: List[int] = field(default_factory=list)
    worker: int = -1
    overlaps: Tuple[float, ...] = ()

    @property
    def ttft(self) -> float:
        return self.first_token_t - self.submit_t


class DisaggregatedCluster:
    def __init__(self, model: Model, params, *, num_decode: int = 2,
                 slots_per_worker: int = 4, max_len: int = 256,
                 adaptive: bool = True,
                 router_config: Optional[KvRouterConfig] = None,
                 detector_config: Optional[DetectorConfig] = None):
        self.model = model
        self.prefill = PrefillEngine(model, params, max_len)
        self.decoders = [DecodeEngine(model, params, slots_per_worker,
                                      max_len, worker_id=i)
                         for i in range(num_decode)]
        router = KvPushRouter(num_decode, router_config or KvRouterConfig())
        detector = SaturationDetector(
            detector_config or DetectorConfig(theta1=0.5, theta2=5.0))
        self.poa = PoATracker(num_workers=num_decode, window_s=60.0,
                              window_count=64)
        self.controller = AdaptiveRouter(
            router=router, detector=detector, poa_tracker=self.poa,
            adaptive=adaptive)
        self.metrics = self.controller.metrics
        self.pending: List[ServeRequest] = []
        self.running: Dict[str, Tuple[ServeRequest, int, int]] = {}
        self.done: List[ServeRequest] = []
        self._t0 = time.monotonic()

    # ----------------------------------------------------------- lifecycle --

    def _now(self) -> float:
        return time.monotonic() - self._t0

    def submit(self, req: ServeRequest):
        req.submit_t = self._now()
        self.pending.append(req)

    def _try_schedule(self):
        still: List[ServeRequest] = []
        for req in self.pending:
            worker, overlap = self.controller.route(req.tokens, now=self._now())
            dec = self.decoders[worker]
            slot = dec.free_slot()
            if slot is None:
                still.append(req)  # backpressure: retry next tick
                continue
            logits, caches = self.prefill.prefill(req.tokens, req.extras)
            first = int(np.argmax(logits))
            dec.admit(slot, req.request_id, caches, first,
                      prompt_len=len(req.tokens),
                      max_new=req.max_new_tokens)
            self.controller.router.on_schedule(worker, req.tokens,
                                               now=self._now())
            req.worker = worker
            req.first_token_t = self._now()
            req.output = [first]
            _, _, overlaps = self.controller.router.best_worker(
                req.tokens, now=self._now())
            req.overlaps = tuple(overlaps)
            self.running[req.request_id] = (req, worker, slot)
        self.pending = still

    def step(self) -> int:
        """One scheduler tick: admit pending, advance every decode engine.
        Returns number of completed requests this tick."""
        self._try_schedule()
        completed = 0
        for dec in self.decoders:
            for rid, tok, done in dec.step():
                req, worker, slot = self.running[rid]
                req.output.append(tok)
                if done:
                    req.finish_t = self._now()
                    dec.release(slot)
                    del self.running[rid]
                    self.done.append(req)
                    self.controller.router.on_complete(worker, req.tokens)
                    self.metrics.histogram("ttft", window_s=300.0).observe(
                        req.ttft, self._now())
                    self.poa.record(CompletedRequest(
                        request_id=rid, worker=worker,
                        latency=req.finish_t - req.submit_t,
                        overlap=req.overlaps, finish_time=self._now()))
                    completed += 1
        # controller telemetry poll (every tick at test scale)
        ttft_p99 = self.metrics.histogram("ttft", window_s=300.0).p99(self._now())
        self.controller.poll(ttft_p99, self._now())
        return completed

    def run_until_done(self, max_ticks: int = 10_000) -> List[ServeRequest]:
        ticks = 0
        while (self.pending or self.running) and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.done
