"""Simhash-bucketed approximate prefix affinity — the O(1) overlap scorer.

The exact KvIndexer scores overlap by walking the radix tree along the
request's chained block hashes.  At production pool sizes that walk is
already aggregated to O(blocks + claims-on-path), but it still touches a
tree; production router stacks (vllm-project/production-stack
``affinity/simhash_affinity.py``) go one step cheaper: hash the request's
*prefix* to a simhash bucket and keep per-bucket worker affinity, so a
routing decision is a dict lookup.

:class:`SimHashAffinity` follows that shape, adapted to this repo's
chained block hashes: the bucket key is a 64-bit bit-voting simhash over
the first ``prefix_blocks`` chained hashes (two prompts share a bucket
iff they share those leading blocks — chained hashes commit to the whole
prefix, so any earlier divergence flips every later feature), and each
bucket maps worker → (deepest fresh insert depth, last touch).  Scoring a
request estimates each worker's overlap as ``min(stored depth,
request blocks) / request blocks``, with the same TTL freshness model as
the indexer.

The approximation is exact whenever requests that share the leading
``prefix_blocks`` blocks share their whole prefix — true for template
workloads (every request of a template has the same prompt), which is
what the exact-agreement test pins on small pools.  It deliberately
over-credits a worker that cached a *long* prompt when a *short* prompt
of the same bucket arrives — the price of never walking the tree.

Signatures are memoized per leading-hash tuple (requests come from a
small template universe, so the 64×features bit-voting loop runs once
per template, not once per decision).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.radix import BLOCK_SIZE, block_hashes

_MASK64 = (1 << 64) - 1
_MIX = 0x9E3779B97F4A7C15          # splitmix64 golden-ratio multiplier


def simhash64(features: Sequence[int]) -> int:
    """Classic bit-voting simhash over integer features: each feature is
    avalanche-mixed to 64 bits, every bit votes ±1, the sign vector is
    the signature.  One feature → its mixed value; similar feature SETS
    → nearby signatures."""
    if not features:
        return 0
    votes = [0] * 64
    for f in features:
        v = ((f & _MASK64) * _MIX) & _MASK64
        v ^= v >> 29
        for b in range(64):
            votes[b] += 1 if (v >> b) & 1 else -1
    sig = 0
    for b in range(64):
        if votes[b] > 0:
            sig |= 1 << b
    return sig


class SimHashAffinity:
    """Bucketed approximate prefix-affinity index.

    ``insert(worker, hashes, now)`` — O(1): bucket the prefix, record the
    worker's insert depth and touch time (deepest fresh depth wins).

    ``overlap_depths(hashes, now)`` — O(bucket): per-worker estimated
    fresh prefix depth for the request's bucket; the router's vectorized
    argmin consumes this exactly like ``KvIndexer.overlap_depths``.

    TTL semantics mirror the indexer: a worker's bucket entry is fresh iff
    touched within ``ttl``; stale entries are dropped on the read that
    discovers them (buckets self-clean instead of accumulating every
    worker that ever touched a popular template)."""

    def __init__(self, block_size: int = BLOCK_SIZE, prefix_blocks: int = 4,
                 ttl: Optional[float] = None):
        self.block_size = block_size
        self.prefix_blocks = prefix_blocks
        self.ttl = ttl
        # signature → {worker: (depth, last_touch)}
        self._buckets: Dict[int, Dict[int, Tuple[int, float]]] = {}
        self._sig_cache: Dict[Tuple[int, ...], int] = {}

    # ------------------------------------------------------------ keying ----

    def signature(self, hashes: Sequence[int]) -> int:
        key = tuple(hashes[:self.prefix_blocks])
        sig = self._sig_cache.get(key)
        if sig is None:
            sig = self._sig_cache[key] = simhash64(key)
        return sig

    # ------------------------------------------------------------ update ----

    def insert(self, worker: int, hashes: Optional[Sequence[int]],
               now: float = 0.0) -> None:
        if not hashes:
            return
        bucket = self._buckets.setdefault(self.signature(hashes), {})
        depth = len(hashes)
        prev = bucket.get(worker)
        if prev is not None and prev[0] > depth \
                and (self.ttl is None or now - prev[1] <= self.ttl):
            depth = prev[0]        # deepest still-fresh insert wins
        bucket[worker] = (depth, now)

    def clear_worker(self, worker: int) -> None:
        """Drain-protocol flush: forget every affinity of ``worker``."""
        for bucket in self._buckets.values():
            bucket.pop(worker, None)

    # ------------------------------------------------------------- query ----

    def overlap_depths(self, hashes: Sequence[int], now: float = 0.0
                       ) -> Dict[int, int]:
        if not hashes:
            return {}
        bucket = self._buckets.get(self.signature(hashes))
        if not bucket:
            return {}
        total = len(hashes)
        out: Dict[int, int] = {}
        stale: List[int] = []
        ttl = self.ttl
        for w, (depth, touch) in bucket.items():
            if ttl is not None and now - touch > ttl:
                stale.append(w)
                continue
            out[w] = depth if depth < total else total
        for w in stale:
            del bucket[w]
        return out

    def overlap_scores(self, tokens: Sequence[int], workers: Sequence[int],
                       now: float = 0.0,
                       hashes: Optional[Sequence[int]] = None) -> List[float]:
        """Dense per-worker overlap fractions — drop-in for
        ``KvIndexer.overlap_scores`` on the router's scalar path."""
        hs = block_hashes(tokens, self.block_size) if hashes is None \
            else hashes
        total = max(len(hs), 1)
        depth = self.overlap_depths(hs, now)
        get = depth.get
        return [get(w, 0) / total for w in workers]
