"""Table 5: cross-model properties — first post-knee grid point, throughput
ceiling, ΔTTFT/ΔC finite difference across the knee."""
from __future__ import annotations

import time

from benchmarks.common import emit, run_sim, save_json

GRID = [32, 64, 128, 512]


def run(hold_s: float = 120.0):
    t0 = time.perf_counter()
    out = {}
    for name in ("nemotron-4-340b", "llama-3.1-70b"):
        t = {}
        rps = {}
        for c in GRID:
            s = run_sim(name, "1P/2D", c, hold_s).overall()
            t[c] = s.ttft_p99
            rps[c] = s.rps
        d_low = (t[64] - t[32]) / 32
        d_knee = (t[128] - t[64]) / 64
        out[name] = dict(ttft=t, ceiling_rps=rps[512],
                         dttft_dc_low=d_low, dttft_dc_knee=d_knee,
                         first_postknee_grid_point=128 if d_knee > 4 * d_low
                         else None)
    print("\n# Table 5 — cross-model knee/ceiling")
    print(f"{'property':<32}{'340B':>12}{'70B':>12}")
    a, b = out["nemotron-4-340b"], out["llama-3.1-70b"]
    print(f"{'first post-knee grid point':<32}{str(a['first_postknee_grid_point']):>12}"
          f"{str(b['first_postknee_grid_point']):>12}")
    print(f"{'throughput ceiling (rps)':<32}{a['ceiling_rps']:>12.1f}{b['ceiling_rps']:>12.1f}")
    print(f"{'ΔTTFT/ΔC across knee':<32}{a['dttft_dc_knee']:>12.4f}{b['dttft_dc_knee']:>12.4f}")
    save_json("table5_crossmodel", out)
    dt = (time.perf_counter() - t0) * 1e6
    emit("table5_crossmodel", dt / (2 * len(GRID)),
         f"knee_340b={a['first_postknee_grid_point']};"
         f"knee_70b={b['first_postknee_grid_point']};"
         f"ceilings={a['ceiling_rps']:.0f}/{b['ceiling_rps']:.0f}rps")
    return out


if __name__ == "__main__":
    run()
