import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import (jax locks the device
# count at first init) — this module is the only place the 512-device flag is
# set, so smoke tests and benchmarks keep seeing 1 device.

import argparse     # noqa: E402
import json         # noqa: E402
import pathlib      # noqa: E402
import sys          # noqa: E402
import traceback    # noqa: E402

from repro.configs import ASSIGNED_ARCHS, SHAPES, get_config, shape_applicable  # noqa: E402
from repro.launch.dryrun_lib import run_cell            # noqa: E402
from repro.launch.mesh import make_production_mesh      # noqa: E402


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Multi-pod dry-run: lower+compile every "
                    "(arch x shape x mesh) cell and extract roofline terms.")
    ap.add_argument("--arch", default="all",
                    help="architecture id or 'all'")
    ap.add_argument("--shape", default="all",
                    help="shape name or 'all'")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="reports/dryrun.jsonl")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--skip-collectives", action="store_true",
                    help="full rolled compile + memory only (multi-pod "
                         "shardability proof; roofline is single-pod)")
    ap.add_argument("--rules", default=None,
                    help="JSON dict of sharding-rule overrides (hillclimb)")
    args = ap.parse_args(argv)

    archs = ASSIGNED_ARCHS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi", make_production_mesh(multi_pod=True)))
    rules = json.loads(args.rules) if args.rules else None
    if rules:
        rules = {k: (tuple(v) if isinstance(v, list) else v)
                 for k, v in rules.items()}

    out_path = pathlib.Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    n_fail = 0
    with out_path.open("a") as f:
        for mesh_name, mesh in meshes:
            for arch in archs:
                for shape in shapes:
                    if not shape_applicable(get_config(arch), SHAPES[shape]):
                        print(f"[{mesh_name}] {arch:22s} {shape:12s} SKIP "
                              f"(full attention, long_500k)", flush=True)
                        continue
                    try:
                        rec = run_cell(arch, shape, mesh,
                                       rules=rules, remat=not args.no_remat,
                                       skip_collectives=args.skip_collectives)
                        rec["mesh_name"] = mesh_name
                        f.write(json.dumps(rec) + "\n")
                        f.flush()
                    except Exception:
                        n_fail += 1
                        print(f"[{mesh_name}] {arch} {shape} FAILED",
                              flush=True)
                        traceback.print_exc()
    if n_fail:
        sys.exit(1)


if __name__ == "__main__":
    main()
