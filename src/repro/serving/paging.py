"""Block-granular KV page allocator for the paged decode engine.

A :class:`PageAllocator` manages the *logical* side of a global KV page
pool: a free list over page ids ``1..num_pages`` (page id 0 is the reserved
trash page — inactive slots' writes land there and are masked by length, so
it is never allocated), an ownership map ``slot -> [page ids]``, and a
reservation ledger that holds back the worst-case growth pages of admitted
requests so a mid-generation block-boundary crossing can never fail.

Lifecycle mirrors the engine's slot lifecycle:

  ``reserve(slot, n_pages)``  — at scheduling time, promise the request its
      worst-case page count; admission gating checks ``available_pages``
      (free minus everyone else's reservations), so two requests admitted
      in the same tick cannot both count the same free pages.
  ``admit(slot, n_map, n_total)`` — map the prompt's pages now; the
      remaining ``n_total - n_map`` stay reserved for ``grow``.
  ``grow(slot)``  — one page when generation crosses a block boundary,
      drawn from the slot's reservation.
  ``release(slot)`` — return every owned page and drop any reservation.

Pure Python/stdlib on purpose: the hypothesis property suite and the
sanitizer's page invariants exercise it without touching JAX.
"""
from __future__ import annotations

from typing import Dict, List, Optional

TRASH_PAGE = 0


class PageAllocator:
    def __init__(self, num_pages: int, block: int):
        if num_pages < 1:
            raise ValueError("need at least one allocatable page")
        self.num_pages = num_pages
        self.block = block
        # Descending so pop() hands out 1, 2, 3, ... on a fresh pool;
        # released pages go to the tail and are reused LIFO (deterministic).
        self._free: List[int] = list(range(num_pages, 0, -1))
        self.owned: Dict[int, List[int]] = {}
        self.reserved: Dict[int, int] = {}

    # -- accounting ----------------------------------------------------

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` KV entries."""
        return max(1, -(-n_tokens // self.block))

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def reserved_pages(self) -> int:
        return sum(self.reserved.values())

    @property
    def available_pages(self) -> int:
        """Free pages not promised to an already-scheduled request."""
        return len(self._free) - self.reserved_pages

    @property
    def used_pages(self) -> int:
        return sum(len(p) for p in self.owned.values())

    def free_list(self) -> List[int]:
        return list(self._free)

    def all_pages(self) -> frozenset:
        return frozenset(range(1, self.num_pages + 1))

    # -- lifecycle -----------------------------------------------------

    def can_admit(self, n_total: int) -> bool:
        return n_total <= self.available_pages

    def reserve(self, slot: int, n_total: int) -> bool:
        """Promise ``n_total`` pages to ``slot``; False if the pool cannot
        honour it (caller must not admit)."""
        assert slot not in self.owned and slot not in self.reserved, slot
        if n_total > self.available_pages:
            return False
        self.reserved[slot] = n_total
        return True

    def admit(self, slot: int, n_map: int,
              n_total: Optional[int] = None) -> Optional[List[int]]:
        """Map ``n_map`` pages to ``slot`` now, keeping the rest of its
        ``n_total`` worst case reserved for :meth:`grow`.  Returns the page
        ids, or None if the pool cannot cover an unreserved admission."""
        assert slot not in self.owned, slot
        if n_total is None:
            n_total = n_map
        n_total = max(n_total, n_map)
        if slot not in self.reserved:
            if n_total > self.available_pages:
                return None
            self.reserved[slot] = n_total
        pages = [self._free.pop() for _ in range(n_map)]
        self.owned[slot] = pages
        left = self.reserved[slot] - n_map
        if left > 0:
            self.reserved[slot] = left
        else:
            del self.reserved[slot]
        return pages

    def grow(self, slot: int) -> int:
        """One more page for ``slot`` (generation crossed a block boundary).
        Draws on the slot's reservation — gated admission guarantees it."""
        assert slot in self.owned, slot
        left = self.reserved.get(slot, 0)
        if left == 0 and self.available_pages <= 0:
            raise RuntimeError(
                f"page pool exhausted growing slot {slot}: admission was "
                "not gated on the worst-case page count")
        page = self._free.pop()
        if left:
            if left == 1:
                del self.reserved[slot]
            else:
                self.reserved[slot] = left - 1
        self.owned[slot].append(page)
        return page

    def release(self, slot: int) -> List[int]:
        """Return every page owned by ``slot`` (and drop any outstanding
        reservation).  Safe on a slot that only ever reserved."""
        self.reserved.pop(slot, None)
        pages = self.owned.pop(slot, [])
        self._free.extend(pages)
        return pages

    # -- invariants ----------------------------------------------------

    def audit(self) -> List[str]:
        """Internal-consistency problems, empty when healthy.  The engine
        sanitizer layers the slot-lifecycle invariants (released slots hold
        zero pages, table rows match ownership) on top of this."""
        problems = []
        held = [p for pages in self.owned.values() for p in pages]
        if len(set(held)) != len(held):
            problems.append("page owned by two live slots")
        if TRASH_PAGE in held or TRASH_PAGE in self._free:
            problems.append("trash page 0 entered circulation")
        if set(self._free) & set(held):
            problems.append("page simultaneously free and owned")
        if set(self._free) | set(held) != self.all_pages():
            problems.append("free list + owned pages do not cover the pool")
        if self.reserved_pages > len(self._free):
            problems.append("reservations exceed the free list")
        return problems
