"""Paged cached-decode attention (one new token per sequence) in Pallas.

Same online-softmax recurrence as ``decode_attention``, but the KV cache is
a *global page pool* ``(num_pages, block, K, hd)`` shared by every slot and
indirected through a per-slot page table ``(B, pages_per_slot)``: grid step
``(b, h, p)`` streams page ``table[b, p]`` of the pool through VMEM.  The
page table and ragged lengths ride in as scalar-prefetch operands so the
table lookup can happen inside the k/v ``BlockSpec`` index maps — the whole
point of the kernel: the pool is never gathered into a dense per-slot view.

Conventions shared with the serving engine: page id 0 is the reserved trash
page (unmapped table entries point at it and are masked by ``length``), and
rows with ``length == 0`` return finite zeros (inactive slots).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, block: int, sm_scale: float):
    b_ = pl.program_id(0)
    pi = pl.program_id(2)
    np_ = pl.num_programs(2)

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b_]
    k_start = pi * block

    @pl.when(k_start < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                # (G, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # (block, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(cols < length, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(pi == np_ - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def paged_attention_pallas(q, k_pool, v_pool, page_table, lengths, *,
                           interpret=False):
    """q: (B,K,G,hd) grouped queries; k_pool, v_pool: (N, block, K, hd)
    global page pools; page_table: (B, W) int32 page ids (entries must be
    valid pool indices — masked-off ones conventionally point at the trash
    page 0); lengths: (B,) valid KV entries per slot."""
    b, kh, g, hd = q.shape
    block = k_pool.shape[1]
    w = page_table.shape[1]
    grid = (b, kh, w)
    sm_scale = 1.0 / np.sqrt(hd)
    kernel = functools.partial(_kernel, block=block, sm_scale=sm_scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, hd),
                         lambda b_, h_, p_, tbl, lens: (b_, h_, 0, 0)),
            pl.BlockSpec((1, block, 1, hd),
                         lambda b_, h_, p_, tbl, lens: (tbl[b_, p_], 0, h_, 0)),
            pl.BlockSpec((1, block, 1, hd),
                         lambda b_, h_, p_, tbl, lens: (tbl[b_, p_], 0, h_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda b_, h_, p_, tbl, lens: (b_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kh, g, hd), q.dtype),
        interpret=interpret,
    )(page_table, lengths, q, k_pool, v_pool)
