"""Prop. 5 (Game 2): sweep per-worker G1 HBM capacity and watch the
PoA_KV = 1 → contested transition.

PoA_KV is measured as the Eq. 6 aggregate cache cost of the run divided by
the cost of the seed-matched coordinated counterfactual (the same workload
on effectively-unbounded G1 — the social optimum proxy).  With G1 large
enough for the whole working set, ρ stays below 1, no block is ever
demoted, the trajectory is bit-identical to the counterfactual and
PoA_KV = 1 exactly.  Shrinking G1 past the working set pushes ρ over 1:
the KVBM demotes, overlap claims are invalidated for coherence, and
requests pay Eq. 6 onboarding latency (G2/G3 hits) or full recompute
(misses) — PoA_KV rises above 1.

CSV: one row per G1 capacity; ``derived`` carries ρ_max, demotions,
onboarded-request count, TTFT P99, and PoA_KV.
"""
from __future__ import annotations

import time

from benchmarks.common import emit, save_json

G1_SWEEP = (16, 32, 48, 96, 256, 100_000)
UNBOUNDED = G1_SWEEP[-1]


def _eq6_cost(res) -> float:
    """Aggregate Eq. 6 cache cost of a run (G1 hits at α_G1, onboards at
    their quoted latency, misses at the γ recompute cost)."""
    from repro.core.kvbm import RECOMPUTE_COST, TIER_COST
    total = 0.0
    for r in res.completed:
        n = max(len(r.hashes), 1)
        g1_hits = r.overlap * n
        onboarded = r.onboard_frac * n
        misses = max(n - g1_hits - onboarded, 0.0)
        total += (g1_hits * TIER_COST["G1"] + r.onboard_latency
                  + misses * RECOMPUTE_COST)
    return total


def run(hold: float = 40.0, seeds=(0, 1, 2), concurrency: int = 96) -> None:
    from repro.serving.scenarios import build_simulator

    rows = {}
    for g1 in G1_SWEEP:
        t0 = time.perf_counter()
        per_seed, ttfts, n_done = [], [], 0
        rho_max, demotions, onboarded = 0.0, 0, 0
        for seed in seeds:
            sim = build_simulator("cache-pressure-70b", seed=seed,
                                  g1_blocks=g1, hold_s=hold,
                                  concurrency=concurrency)
            res = sim.run()
            per_seed.append(_eq6_cost(res))
            ttfts.append(res.overall().ttft_p99)
            n_done += len(res.completed)
            rho_max = max(rho_max, max(max(p["rho"]) for p in res.poll_log))
            demotions += sum(kv.demotions for kv in sim.kvbm)
            onboarded += sum(1 for r in res.completed if r.onboard_frac > 0)
        us = (time.perf_counter() - t0) * 1e6
        rows[g1] = dict(cost=per_seed, ttft_p99=sum(ttfts) / len(ttfts),
                        rho_max=rho_max, demotions=demotions,
                        onboarded=onboarded, n=n_done,
                        us_per_req=us / max(n_done, 1))
    # PoA_KV: seed-matched cost ratio against the unbounded-G1 run (the
    # coordinated social-optimum proxy named in the module docstring)
    base = rows[UNBOUNDED]["cost"]
    for g1 in G1_SWEEP:
        ratios = [c / max(b, 1e-12)
                  for c, b in zip(rows[g1]["cost"], base)]
        r = rows[g1]
        r["poa_kv"] = sum(ratios) / len(ratios)
        del r["cost"]
        emit(f"prop5_g1_{g1}", r["us_per_req"],
             f"rho_max={r['rho_max']:.2f};demotions={r['demotions']};"
             f"onboarded={r['onboarded']};ttft_p99={r['ttft_p99']:.3f}s;"
             f"poa_kv={r['poa_kv']:.3f}")
    save_json("prop5_g1_sweep", rows)


if __name__ == "__main__":
    run()
