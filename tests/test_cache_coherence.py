"""Game 2 wired end-to-end: tier-coherent KV cache in the simulator.

The coherence invariant under test: the router's overlap scores must never
credit a prefix whose blocks are not G1-resident on that worker.  The
KVBM fires ``on_g1_evict`` whenever a block leaves G1 (demotion or free),
which invalidates the corresponding KvIndexer claim — so cache-affinity
routing follows actual HBM residency even when ρ > 1 and the frequency
policy is churning blocks through G2/G3.
"""
import dataclasses

import pytest

from repro.core.radix import KvIndexer, block_hashes
from repro.serving.scenarios import build_simulator, list_scenarios
from repro.serving.workload import template_tokens

PRESSURE = [n for n in list_scenarios() if n.startswith("cache-pressure")]


def _assert_coherent(sim):
    """No fresh overlap claim may point at a non-G1-resident block."""
    ix = sim.router.indexer
    for w in range(sim.cluster.num_decode):
        for t in range(sim.workload.num_templates):
            toks = template_tokens(t, sim.workload.input_tokens)
            matched = ix.matched_blocks(w, toks, now=sim.now)
            for h in block_hashes(toks)[:matched]:
                blk = sim.kvbm[w].blocks.get(h)
                assert blk is None or blk.tier == "G1", (
                    f"worker {w} template {t}: credited block resides "
                    f"in {blk.tier}")


def test_registry_includes_cache_pressure_family():
    assert len(PRESSURE) >= 2


@pytest.mark.parametrize("name", PRESSURE)
def test_overlap_never_credits_non_g1_blocks(name):
    sim = build_simulator(name, seed=3, fast=True)
    sim.run()
    # non-vacuous: the eviction policy actually churned tiers
    assert sum(kv.demotions for kv in sim.kvbm) > 0
    _assert_coherent(sim)


def test_cache_pressure_reaches_contested_regime():
    """Acceptance: a registered cache-pressure scenario crosses ρ = 1
    mid-run with nonzero demotions (Prop. 5 contested regime)."""
    sim = build_simulator("cache-pressure-70b", seed=3, fast=True)
    res = sim.run()
    rho0 = max(res.poll_log[0]["rho"])
    rho_max = max(max(p["rho"]) for p in res.poll_log)
    assert rho0 <= 1.0 < rho_max
    assert sum(kv.demotions for kv in sim.kvbm) > 0
    # blocks really moved out of G1: some worker holds lower-tier blocks
    assert any(kv.tier_usage["G2"] + kv.tier_usage["G3"]
               + kv.tier_usage["G4"] > 0 for kv in sim.kvbm)


def test_pinned_blocks_survive_pressure():
    """While a request decodes, its blocks stay G1-resident no matter how
    over-subscribed G1 is; poll_log tier counters stay consistent."""
    sim = build_simulator("cache-pressure-70b", seed=1, fast=True,
                          g1_blocks=16)
    res = sim.run()
    assert len(res.completed) > 0
    for p in res.poll_log:
        for _w, tiers in enumerate(p["tiers"]):
            assert all(n >= 0 for n in tiers.values())
    # after the drain every pin must have been released
    for kv in sim.kvbm:
        assert all(b.pin_count == 0 for b in kv.blocks.values())


def test_onboarding_cheaper_than_recompute_on_ttft():
    """G2/G3 hits pay Eq. 6 onboarding latency, bounded above by what the
    same blocks would cost as full misses (§8.4 tradeoff)."""
    sim = build_simulator("cache-pressure-70b", seed=3, fast=True)
    res = sim.run()
    c = sim.cluster
    for r in res.completed:
        n = max(len(r.hashes), 1)
        assert 0.0 <= r.onboard_frac <= 1.0
        assert r.overlap + r.onboard_frac <= 1.0 + 1e-9
        # per-block onboarding latency never exceeds the α_G4 ceiling,
        # which sits below the per-block recompute cost γ
        assert r.onboard_latency <= r.onboard_frac * n * c.alpha_g4 + 1e-9


def test_reinsert_after_demotion_does_not_credit_deep_blocks():
    """Regression: ``remove_worker_block`` used to pop the claim on the
    one invalidated node but leave stale ``workers[worker]`` timestamps on
    all deeper nodes.  A later re-insert of just the prefix re-opened the
    walk from the root and overlap scoring credited the demoted deep
    blocks again — blocks whose KV had left G1 long ago."""
    ix = KvIndexer()
    seq = template_tokens(0, 64)                 # 4 blocks
    hs = block_hashes(seq)
    ix.insert(0, seq)
    ix.remove_worker_block(0, hs[1])             # KVBM demoted block 1
    assert ix.matched_blocks(0, seq) == 1
    # a new request recomputes only the first two blocks (32 tokens) and
    # re-inserts that prefix; blocks 2-3 must stay uncredited
    ix.insert(0, seq[:32])
    assert ix.matched_blocks(0, seq) == 2
    assert ix.overlap_scores(seq, [0]) == [0.5]
    assert ix.num_blocks(0) == 2
    # other workers' claims on the demoted chain are untouched
    ix2 = KvIndexer()
    ix2.insert(0, seq)
    ix2.insert(1, seq)
    ix2.remove_worker_block(0, hs[0])
    assert ix2.overlap_scores(seq, [0, 1]) == [0.0, 1.0]


def test_identity_path_large_g1():
    """Homogeneous large-G1 scenarios never touch the tier machinery:
    no demotions, no onboarding, ρ ≪ 1, and same-seed determinism."""
    a = build_simulator("70b-1p2d-ramp", seed=7, fast=True).run()
    b = build_simulator("70b-1p2d-ramp", seed=7, fast=True).run()
    assert dataclasses.astuple(a.overall()) == dataclasses.astuple(b.overall())
    sim = a.sim
    assert sum(kv.demotions for kv in sim.kvbm) == 0
    assert sum(kv.promotions for kv in sim.kvbm) == 0
    assert all(r.onboard_frac == 0.0 and r.onboard_latency == 0.0
               for r in a.completed)
    assert max(max(p["rho"]) for p in a.poll_log) < 1.0
    _assert_coherent(sim)


def test_open_loop_polls_cover_the_drain_tail():
    """Open-loop/trace runs drain past the arrival horizon; the poll loop
    must keep sampling detector/PoA/ρ until the backlog clears instead of
    stopping at total_duration() (the overload tail is the point)."""
    sim = build_simulator("cache-pressure-burst", seed=0, fast=True)
    res = sim.run()
    horizon = sim.workload.total_duration()
    last_finish = max(r.finish_t for r in res.completed)
    assert last_finish > horizon  # the scenario genuinely over-drives
    last_poll = max(p["t"] for p in res.poll_log)
    assert last_poll > horizon
    # polls stop once in-flight work is gone
    assert last_poll <= last_finish + sim.detector.config.poll_interval


def test_closed_loop_poll_horizon_unchanged():
    """Closed-loop keeps the legacy poll horizon (bit-exactness pin)."""
    sim = build_simulator("70b-1p2d-ramp", seed=0, fast=True)
    res = sim.run()
    assert max(p["t"] for p in res.poll_log) <= sim.workload.total_duration()
