"""Lint rule corpus: every RA rule proven by its good/bad fixture pair,
plus suppression, allowlist, CLI exit codes, and the clean-tree gate."""
from pathlib import Path

import pytest

from repro.analysis.lint import (RULES, iter_python_files, lint_file,
                                 lint_paths, lint_source)
from repro.analysis.__main__ import main as lint_main

FIXTURES = Path(__file__).resolve().parent.parent / "src" / "repro" / \
    "analysis" / "fixtures"

# RA009 is scoped by module path (event-clock modules only), so its
# fixtures are linted under a spoofed in-scope path.
_SPOOF_PATH = {"RA009": "src/repro/serving/simulator.py"}

# minimum finding count the bad fixture must produce (distinct shapes)
_MIN_BAD = {"RA001": 4, "RA002": 3, "RA003": 4, "RA004": 1, "RA005": 4,
            "RA006": 3, "RA007": 3, "RA008": 1, "RA009": 3, "RA010": 3,
            "RA011": 5}

ALL_CODES = sorted(r.code for r in RULES)


def _lint_fixture(code: str, kind: str):
    stem = code.lower()
    path = FIXTURES / f"{stem}_{kind}.py"
    source = path.read_text()
    lint_as = _SPOOF_PATH.get(code, str(path))
    return lint_source(lint_as, source, select=[code])


@pytest.mark.parametrize("code", ALL_CODES)
def test_bad_fixture_fires(code):
    findings = _lint_fixture(code, "bad")
    assert len(findings) >= _MIN_BAD[code], \
        f"{code} bad fixture produced {findings}"
    assert all(f.rule == code for f in findings)


@pytest.mark.parametrize("code", ALL_CODES)
def test_good_fixture_clean(code):
    assert _lint_fixture(code, "good") == []


def test_every_rule_has_fixture_pair():
    for rule in RULES:
        stem = rule.code.lower()
        assert (FIXTURES / f"{stem}_bad.py").is_file()
        assert (FIXTURES / f"{stem}_good.py").is_file()


def test_catalog_covers_at_least_eight_rules():
    assert len(RULES) >= 8
    assert len({r.code for r in RULES}) == len(RULES)


# ------------------------------------------------------------ suppression ---


def test_pragma_suppresses_single_rule():
    src = "def f(w):\n    w._healthy = False   # ra: allow[RA001]\n"
    assert lint_source("src/repro/x.py", src, select=["RA001"]) == []


def test_pragma_with_wrong_code_does_not_suppress():
    src = "def f(w):\n    w._healthy = False   # ra: allow[RA005]\n"
    assert len(lint_source("src/repro/x.py", src, select=["RA001"])) == 1


def test_blanket_pragma_suppresses_everything():
    src = "def f(w):\n    w._healthy = False   # ra: allow\n"
    assert lint_source("src/repro/x.py", src, select=["RA001"]) == []


def test_allowlist_drops_matching_findings(tmp_path):
    bad = tmp_path / "src" / "repro" / "mod.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("def f(w):\n    w._healthy = False\n")
    assert len(lint_paths([str(tmp_path)], select=["RA001"])) == 1
    allowed = lint_paths([str(tmp_path)], select=["RA001"],
                         allowlist=[f"RA001 {bad.name}"])
    assert allowed == []
    # a different rule code in the allowlist must not mask RA001
    still = lint_paths([str(tmp_path)], select=["RA001"],
                       allowlist=[f"RA005 {bad.name}"])
    assert len(still) == 1


# -------------------------------------------------------------------- CLI ---


def test_cli_clean_tree_exits_zero(tmp_path, capsys):
    ok = tmp_path / "clean.py"
    ok.write_text("def f():\n    return 1\n")
    assert lint_main([str(tmp_path)]) == 0


def test_cli_findings_exit_one(tmp_path, capsys):
    bad = tmp_path / "src" / "repro" / "mod.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("def f(w):\n    w._healthy = False\n")
    assert lint_main([str(tmp_path)]) == 1
    out = capsys.readouterr()
    assert "RA001" in out.out


def test_cli_usage_error_exits_two(capsys):
    assert lint_main([]) == 2


def test_cli_list_rules(capsys):
    assert lint_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule in RULES:
        assert rule.code in out


def test_cli_select(tmp_path):
    bad = tmp_path / "src" / "repro" / "mod.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("def f(w):\n    w._healthy = False\n")
    assert lint_main(["--select", "RA005", str(tmp_path)]) == 0
    assert lint_main(["--select", "RA001", str(tmp_path)]) == 1


# ------------------------------------------------------------- clean tree ---

REPO = Path(__file__).resolve().parent.parent


def test_repo_tree_is_lint_clean():
    """The acceptance gate: the final tree lints clean with NO allowlist."""
    paths = [str(REPO / d)
             for d in ("src", "tests", "benchmarks", "examples")
             if (REPO / d).is_dir()]
    findings = lint_paths(paths)
    assert findings == [], "\n".join(f.format() for f in findings)


def test_fixture_corpus_is_excluded_from_tree_walk():
    files = iter_python_files([str(REPO / "src")])
    assert not any("fixtures" in f.as_posix() for f in files)
    # ... but is still lintable file-by-file
    assert lint_file(FIXTURES / "ra001_good.py") == []
