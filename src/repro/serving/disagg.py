"""End-to-end disaggregated cluster on real (reduced) models — the
**engine backend** of the shared :class:`~repro.serving.control_plane.ControlPlane`.

One prefill engine + N decode engines, glued by the same control plane the
analytic simulator runs on: Smart Router (Eq. 1/2) with KvIndexer overlap,
adaptive controller (saturation detector + Table 2 regime params), PoA
tracker, and per-request metrics.  This is the production pattern at test
scale: the same code path drives TPU submeshes when the engines are built
on disjoint device sets.

What makes this backend *real* rather than modeled:

* the prefill engine holds a block-granular prefix cache keyed by the same
  chained ``block_hashes`` the router scores overlap with, so a cache-warm
  routing decision resumes prefill from the matched block boundary and
  skips actual jitted compute (cold requests pay the full pass);
* the prefill→decode ``transfer()`` hop is charged per **non-resident**
  block on the chosen decode worker (``kv_transfer_per_block`` seconds per
  block, added to the recorded TTFT/latency): on CPU the hop is an
  in-process copy, and the per-block charge reintroduces the KV-movement
  cost NetKV shows dominates decode-instance selection;
* per-token inter-token latencies are observed into the metrics registry,
  so ``violation_rates``' ITL side and the Planner's v_ITL signal are
  non-degenerate on real engines.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.poa import CompletedRequest
from repro.core.radix import block_hashes
from repro.core.router import KvRouterConfig
from repro.core.saturation import DetectorConfig
from repro.models.model import Model
from repro.serving.control_plane import ControlPlane
from repro.serving.engine import DecodeEngine, PrefillEngine
from repro.serving.fabric import Fabric, FabricConfig, kv_hop_seconds


@dataclass
class ServeRequest:
    request_id: str
    tokens: List[int]
    max_new_tokens: int = 16
    extras: Optional[dict] = None
    submit_t: float = 0.0
    first_token_t: float = 0.0
    last_token_t: float = 0.0
    finish_t: float = 0.0
    output: List[int] = field(default_factory=list)
    worker: int = -1
    overlap: float = 0.0
    overlaps: Tuple[float, ...] = ()
    hashes: Tuple[int, ...] = ()
    transfer_blocks: int = 0          # non-resident blocks the hop moved
    transfer_charge: float = 0.0      # seconds charged for that movement
    # fourth game (0.0 without a fabric): fabric service incl. link
    # queueing, and the uncongested (OPT) transfer time
    transfer_wait: float = 0.0
    transfer_floor: float = 0.0

    @property
    def ttft(self) -> float:
        """Wall-clock time to first token (compute only)."""
        return self.first_token_t - self.submit_t

    @property
    def charged_ttft(self) -> float:
        """TTFT including the per-block KV-transfer charge — what the
        metrics registry and PoA tracker observe."""
        return self.ttft + self.transfer_charge


class DisaggregatedCluster:
    """Engine backend: real jitted engines driven by the shared control
    plane.  ``control`` may be injected (scenario runners do, to share
    decision logging); otherwise one is built from the kwargs."""

    def __init__(self, model: Model, params, *, num_decode: int = 2,
                 slots_per_worker: int = 4, max_len: int = 256,
                 adaptive: bool = True,
                 router_config: Optional[KvRouterConfig] = None,
                 detector_config: Optional[DetectorConfig] = None,
                 routing_policy: str = "kv",
                 cache_ttl: Optional[float] = None,
                 seed: int = 0,
                 prefill_cache_entries: int = 16,
                 kv_transfer_per_block: float = 0.0015,
                 batch_prefill: bool = True,
                 max_prefill_batch: int = 8,
                 decode_impl: str = "pallas",
                 num_pages: Optional[int] = None,
                 replicas: Optional[int] = None,
                 staleness_ticks: int = 0,
                 fabric: Optional[FabricConfig] = None,
                 network_aware: bool = False,
                 control: Optional[ControlPlane] = None,
                 sanitize: Optional[bool] = None):
        self.model = model
        self.batch_prefill = batch_prefill
        # Fourth game: decode NICs 0..N-1 plus one prefill node at wid=N
        # (the engine runs a single prefill engine); transfers serialize on
        # the shared links instead of the flat per-block charge.  Only used
        # when ``control`` is built here — an injected plane brings its own.
        self.fabric = (Fabric(fabric, num_decode=num_decode, num_prefill=1)
                       if fabric is not None else None)
        self.prefill = PrefillEngine(model, params, max_len,
                                     cache_entries=prefill_cache_entries,
                                     max_batch=max_prefill_batch)
        # num_pages sizes each paged decoder's KV page pool (None = the
        # dense worst case, where the page gate never binds); dense impls
        # ignore it.
        self.decoders = [DecodeEngine(model, params, slots_per_worker,
                                      max_len, worker_id=i,
                                      decode_impl=decode_impl,
                                      num_pages=num_pages)
                         for i in range(num_decode)]
        # Replica-view sync cadence on the engine backend: the scheduler
        # tick IS the event clock, so views refresh every
        # ``staleness_ticks`` step() calls (0 = fresh pass-through views —
        # bit-exact with the single-router plane for any replica count).
        self.staleness_ticks = staleness_ticks if replicas is not None else 0
        self._ticks = 0
        if control is not None:
            self.control = control
        else:
            plane_kw = dict(
                router_config=router_config,
                routing_policy=routing_policy,
                seed=seed,
                adaptive=adaptive,
                detector_config=(detector_config
                                 or DetectorConfig(theta1=0.5, theta2=5.0)),
                cache_ttl=cache_ttl,
                poa_window_s=60.0, poa_window_count=64,
                log_decisions=True,
                fabric=self.fabric,
                network_aware=network_aware,
                sanitize=False)   # the cluster attaches its own, richer one
            if replicas is None:
                self.control = ControlPlane(num_decode, **plane_kw)
            else:
                from repro.serving.control_plane import ReplicatedControlPlane
                plane_kw["capacities"] = {
                    i: float(slots_per_worker) for i in range(num_decode)}
                self.control = ReplicatedControlPlane(
                    num_decode, replicas=replicas,
                    staleness_s=float(staleness_ticks), **plane_kw)
        self.router = self.control.router
        self.poa = self.control.poa
        self.metrics = self.control.metrics
        self.kv_transfer_per_block = kv_transfer_per_block
        self.pending: List[ServeRequest] = []
        self.running: Dict[str, Tuple[ServeRequest, int, int]] = {}
        self.done: List[ServeRequest] = []
        # per-tick decode occupancy snapshot (active slots per worker),
        # recorded by step(): the batch-occupancy observable
        # bench_engine_throughput histograms.  pool_utilization mirrors it
        # for paged decoders (fraction of each worker's page pool mapped
        # to live slots); empty for dense layouts.
        self.occupancy: List[Tuple[int, ...]] = []
        self.pool_utilization: List[Tuple[float, ...]] = []
        self._t0 = time.monotonic()

        # Opt-in runtime coherence sanitizer (repro.analysis.sanitize):
        # slot-lifecycle guards on every decoder + a control-plane sweep
        # per tick; the default (off) path carries no per-tick branch.
        self.sanitizer = None
        if sanitize is not False:
            from repro.analysis.sanitize import (attach_engine_sanitizer,
                                                 sanitize_enabled)
            if sanitize_enabled(sanitize):
                attach_engine_sanitizer(self)

    # ----------------------------------------------------------- lifecycle --

    def _now(self) -> float:
        return time.monotonic() - self._t0

    def submit(self, req: ServeRequest):
        req.submit_t = self._now()
        if not req.hashes:
            req.hashes = tuple(block_hashes(req.tokens))
        self.pending.append(req)

    def _try_schedule(self):
        still: List[ServeRequest] = []
        placed: List[Tuple[ServeRequest, int, int]] = []
        for req in self.pending:
            # ONE routing call: its overlap vector is the pre-insert view —
            # the recorded PoA counterfactual must not self-credit the
            # request's own about-to-be-inserted blocks (the old second
            # ``best_worker`` call after ``on_schedule`` did exactly that).
            # record=False: backpressure retries re-route every tick, and
            # the decision_log must hold one entry per *placement*, not
            # one per abandoned attempt.
            now = self._now()
            worker, overlap, overlaps, _ids = self.control.route(
                req.tokens, hashes=req.hashes, now=now,
                rid=req.request_id, record=False)
            dec = self.decoders[worker]
            slot = dec.free_slot()
            if slot is None or not dec.can_admit(len(req.tokens),
                                                 req.max_new_tokens):
                # backpressure: no slot row, or (paged) the request's
                # worst-case page count is not coverable — retry next tick
                still.append(req)
                continue
            self.control.log_decision(req.request_id, worker, overlap, now)
            # reserve before the next request routes, so one tick's
            # placements see consistent slot accounting (paged engines
            # also reserve the worst-case page count here); the jitted
            # compute for ALL of this tick's placements runs as one
            # bucketed prompt pass below.
            dec.reserve(slot, req.request_id, prompt_len=len(req.tokens),
                        max_new=req.max_new_tokens)
            self.control.router.on_schedule(worker, req.tokens,
                                            now=self._now(),
                                            hashes=req.hashes)
            req.worker = worker
            req.overlap = overlap
            req.overlaps = tuple(overlaps)
            placed.append((req, worker, slot))
        self.pending = still
        if not placed:
            return
        if self.batch_prefill:
            outs = self.prefill.prefill_many(
                [(req.tokens, req.extras, req.hashes)
                 for req, _, _ in placed])
        else:
            outs = [self.prefill.prefill(req.tokens, req.extras,
                                         hashes=req.hashes) + (0,)
                    for req, _, _ in placed]
        for (req, worker, slot), (logits, caches, row) in zip(placed, outs):
            first = int(np.argmax(logits))
            moved = self.decoders[worker].admit(
                slot, req.request_id, caches, first,
                prompt_len=len(req.tokens), max_new=req.max_new_tokens,
                hashes=req.hashes, src_row=row)
            req.transfer_blocks = moved
            if self.fabric is not None:
                # enqueue the sized transmission on the shared links; the
                # charge is the quoted-and-committed fabric service time
                # (store-and-forward over NIC/rack/spine incl. queueing)
                now2 = self._now()
                src = self.fabric.route_src(now2)
                txm = self.fabric.enqueue(req.request_id, src, worker,
                                          moved, now2)
                if txm is not None:
                    req.transfer_charge = txm.finish_t - now2
                    req.transfer_wait = txm.finish_t - txm.enqueue_t
                    req.transfer_floor = self.fabric.floor_seconds(src,
                                                                   moved)
                else:
                    req.transfer_charge = 0.0
            else:
                req.transfer_charge = kv_hop_seconds(
                    self.kv_transfer_per_block, moved)
            req.first_token_t = self._now()
            req.last_token_t = req.first_token_t
            req.output = [first]
            self.running[req.request_id] = (req, worker, slot)

    def step(self) -> int:
        """One scheduler tick: admit pending, advance every decode engine.
        Returns number of completed requests this tick."""
        if self.fabric is not None:
            # lazy settlement: the engine has no event queue, so landed
            # transmissions release their link reservations at tick start
            self.fabric.complete_until(self._now())
        if self.staleness_ticks > 0:
            if self._ticks % self.staleness_ticks == 0:
                self.control.sync_views(self._now())
            self._ticks += 1
        self._try_schedule()
        self.occupancy.append(tuple(d.active_count for d in self.decoders))
        if any(d.paged for d in self.decoders):
            self.pool_utilization.append(
                tuple(d.pool_utilization() for d in self.decoders))
        completed = 0
        for dec in self.decoders:
            for rid, tok, done in dec.step():
                req, worker, _slot = self.running[rid]
                now = self._now()
                req.output.append(tok)
                # per-token ITL: every decode step contributes a sample, so
                # the ITL histogram (and the Planner's v_ITL) is live on
                # the engine path, not just TTFT
                self.metrics.histogram("itl", window_s=300.0).observe(
                    now - req.last_token_t, now)
                req.last_token_t = now
                if done:
                    # slot already released inside dec.step() (returned-slot
                    # contract: done=True means re-admittable this tick)
                    req.finish_t = now
                    del self.running[rid]
                    self.done.append(req)
                    self.control.router.on_complete(worker, req.tokens)
                    self.metrics.histogram("ttft", window_s=300.0).observe(
                        req.charged_ttft, now)
                    self.poa.record(CompletedRequest(
                        request_id=rid, worker=worker,
                        latency=(req.finish_t - req.submit_t
                                 + req.transfer_charge),
                        overlap=req.overlaps, finish_time=now,
                        transfer_wait=req.transfer_wait,
                        transfer_floor=req.transfer_floor))
                    completed += 1
        # controller telemetry poll (every tick at test scale)
        ttft_p99 = self.metrics.histogram("ttft", window_s=300.0).p99(self._now())
        self.control.observe(ttft_p99, self._now())
        return completed

    def run_until_done(self, max_ticks: int = 10_000) -> List[ServeRequest]:
        ticks = 0
        while (self.pending or self.running) and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.done
