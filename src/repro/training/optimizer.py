"""AdamW with global-norm clipping and a linear-warmup cosine schedule.

Pure-pytree implementation (no optax dependency); the optimizer state mirrors
the parameter tree so the FSDP parameter shardings apply leaf-for-leaf
(ZeRO-style distributed optimizer state).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: OptimizerConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init(params):
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(cfg: OptimizerConfig, params, grads, opt_state):
    """Returns (new_params, new_opt_state, stats)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def leaf(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        upd = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * upd).astype(p.dtype), m, v

    out = jax.tree.map(leaf, params, grads, opt_state["m"], opt_state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    stats = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, stats
