"""Quickstart: serve a small model through the disaggregated cluster.

Builds a reduced phi4-mini, stands up 1 prefill + 2 decode engines glued by
the paper's Smart Router + adaptive controller, pushes a batch of requests
through, and prints per-request latencies plus the game-theoretic metrics
(game_poa, game_saturation_state, ...).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.models import build_model
from repro.serving.disagg import DisaggregatedCluster, ServeRequest
from repro.serving.workload import template_tokens


def main():
    cfg = get_reduced("phi4-mini-3.8b")
    model = build_model(cfg)
    print(f"model: {cfg.name} ({cfg.num_layers}L d={cfg.d_model})")
    params = model.init(jax.random.PRNGKey(0), jnp.bfloat16)

    cluster = DisaggregatedCluster(model, params, num_decode=2,
                                   slots_per_worker=3, max_len=96,
                                   adaptive=True)
    for i in range(8):
        toks = [t % cfg.vocab_size for t in template_tokens(i % 3, 32)]
        cluster.submit(ServeRequest(request_id=f"req-{i}", tokens=toks,
                                    max_new_tokens=8))
    done = cluster.run_until_done()

    print(f"\ncompleted {len(done)} requests:")
    for r in done:
        print(f"  {r.request_id}: worker={r.worker} "
              f"ttft={r.ttft*1000:7.1f}ms "
              f"kv_moved={r.transfer_blocks}blk tokens={r.output}")

    st = cluster.prefill.stats
    print(f"\nprefix cache: {st.reused_blocks}/{st.total_blocks} blocks "
          f"resumed, {st.computed_tokens}/{st.total_tokens} prompt tokens "
          f"actually computed (cache-warm routing skips real compute)")
    print("\ngame-theoretic metrics (Prometheus exposition):")
    print(cluster.metrics.export_text())


if __name__ == "__main__":
    main()
