"""Game-theoretic adaptive controller (Section 6, Algorithm 1).

Maps the detected saturation regime to router parameters (Table 2):

    BELOW       τ=0.0, ω=1.0   exploit cache locality (PoA bounded)
    TRANSITION  τ=0.7, ω=1.0   calibrated optimum from the 70B 1P/5D sweep
    SATURATED   τ=0.8, ω=0.1   conjectural row (flagged; never fired in the
                               paper's Exp. 3 — kept for completeness)

and applies them per-request through the router's
``router_config_override`` hook.  Also exports the paper's four Prometheus
metrics (game_poa, game_saturation_state, game_router_temperature,
game_routing_cost) and supports the zero-downtime dual-frontend variant
(two pre-configured routers; the workload switches target on detection).

:class:`AdaptiveRouter` is the standalone Algorithm-1 wrapper; the serving
stacks route through :class:`repro.serving.control_plane.ControlPlane`,
which folds the same regime gating + metric exports into the shared
backend-agnostic runtime (and adds baseline-policy overlap re-scoring and
decision logging).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

from repro.core.metrics import MetricsRegistry
from repro.core.poa import PoATracker
from repro.core.router import KvPushRouter, KvRouterConfig
from repro.core.saturation import Regime, SaturationDetector

REGIME_PARAMS: Dict[Regime, KvRouterConfig] = {
    Regime.BELOW: KvRouterConfig(temperature=0.0, overlap_weight=1.0),
    Regime.TRANSITION: KvRouterConfig(temperature=0.7, overlap_weight=1.0),
    # Conjectural (paper Table 2 §): interpolated, never fired in Exp. 3.
    Regime.SATURATED: KvRouterConfig(temperature=0.8, overlap_weight=0.1),
}


def export_game_metrics(metrics: MetricsRegistry, *, regime: Regime,
                        config: KvRouterConfig, decision_s: float,
                        now: float,
                        poa_tracker: Optional[PoATracker] = None) -> None:
    """The paper's Algorithm-1 Prometheus exports, shared by
    :class:`AdaptiveRouter` and the serving ControlPlane so both runtimes
    publish identical signals."""
    if poa_tracker is not None:
        poa = poa_tracker.current_poa(now)
        if poa == poa:  # not NaN
            metrics.gauge("game_poa", "estimated Price of Anarchy").set(poa)
    metrics.gauge("game_saturation_state",
                  "0=below 1=transition 2=saturated").set(int(regime))
    metrics.gauge("game_router_temperature", "active tau"
                  ).set(config.temperature)
    metrics.gauge("game_overlap_weight", "active omega"
                  ).set(config.overlap_weight)
    metrics.histogram("game_routing_cost", "router decision latency (s)",
                      window_s=60.0).observe(decision_s, now)


def violation_rates(metrics: MetricsRegistry, ttft_slo: float, itl_slo: float,
                    now: float) -> Tuple[float, float]:
    """Polled TTFT/ITL SLO-violation rates from the registry's windowed
    histograms — the Game 1 control-plane signal the Planner reads every
    adjust interval (the paper's per-pool objective V_TTFT / V_ITL)."""
    return (metrics.histogram("ttft", window_s=30.0).frac_above(ttft_slo, now),
            metrics.histogram("itl", window_s=30.0).frac_above(itl_slo, now))


@dataclass
class AdaptiveRouter:
    """Algorithm 1: regime-gated per-request parameter override."""
    router: KvPushRouter
    detector: SaturationDetector
    poa_tracker: Optional[PoATracker] = None
    metrics: MetricsRegistry = field(default_factory=MetricsRegistry)
    regime_params: Dict[Regime, KvRouterConfig] = field(
        default_factory=lambda: dict(REGIME_PARAMS))
    adaptive: bool = True                    # False ⇒ static baseline
    static_config: KvRouterConfig = field(default_factory=KvRouterConfig)

    def route(self, tokens: Sequence[int], now: Optional[float] = None,
              hashes: Optional[Sequence[int]] = None) -> Tuple[int, float]:
        """Returns (worker_id, overlap) and exports the game metrics.

        ``hashes`` is the per-request block-hash memo: callers that
        already chained the prompt's block hashes (serving backends do,
        once per request) pass them through so the router/indexer do not
        rehash the same tokens per decision."""
        now = time.monotonic() if now is None else now
        if self.adaptive:
            cfg = self.regime_params[self.detector.regime]
        else:
            cfg = self.static_config
        t0 = time.perf_counter()
        # ``now`` must reach the router: the indexer evaluates TTL claim
        # freshness against it, and defaulting to t=0 meant cache-claim
        # expiry never fired through the adaptive controller.
        worker, overlap, _ = self.router.best_worker(
            tokens, router_config_override=cfg, now=now, hashes=hashes)
        dt = time.perf_counter() - t0
        export_game_metrics(self.metrics, regime=self.detector.regime,
                            config=cfg, decision_s=dt, now=now,
                            poa_tracker=self.poa_tracker)
        return worker, overlap

    def poll(self, ttft_p99: float, now: float) -> Regime:
        """5 s Prometheus poll → saturation detector update."""
        return self.detector.observe(ttft_p99, now)


@dataclass
class DualFrontend:
    """Zero-downtime switch (Section 6.4): two frontends with fixed configs;
    the workload generator flips the target port on regime detection."""
    default: KvRouterConfig = field(
        default_factory=lambda: KvRouterConfig(temperature=0.0, overlap_weight=1.0))
    optimal: KvRouterConfig = field(
        default_factory=lambda: KvRouterConfig(temperature=0.7, overlap_weight=1.0))
    active_port: int = 8000
    switch_time: Optional[float] = None

    def on_regime(self, regime: Regime, now: float):
        if regime >= Regime.TRANSITION and self.active_port == 8000:
            self.active_port = 8001
            self.switch_time = now
        elif regime == Regime.BELOW and self.active_port == 8001:
            self.active_port = 8000

    def active_config(self) -> KvRouterConfig:
        return self.optimal if self.active_port == 8001 else self.default
