"""Jamba-v0.1 52B — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2.
[arXiv:2403.19887; hf]

Layer layout (period 8): attention at offset 4 within each period (as in the
published config: attn_layer_offset=4, attn_layer_period=8); MoE on every
second layer (expert_layer_period=2, offset=1).
Mamba layers use the Mamba-2 SSD chunked formulation (TPU adaptation).
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4_096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14_336,
    vocab_size=65_536,
    head_dim=128,
    activation="swiglu",
    attn_layer_period=8,
    attn_layer_offset=4,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=14_336,
                  every_k_layers=2, moe_layer_offset=1),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64, chunk=128),
    subquadratic=True,
    source="arXiv:2403.19887; hf",
)
