"""int8 gradient compression with error feedback."""
import jax.numpy as jnp
import numpy as np

from repro.training import compression as C


def test_quantize_roundtrip_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(128,)) * 3, jnp.float32)
    q, scale = C.quantize_int8(x)
    err = jnp.abs(C.dequantize_int8(q, scale) - x)
    assert float(jnp.max(err)) <= float(scale) / 2 + 1e-6
    assert q.dtype == jnp.int8


def test_error_feedback_accumulates_residual():
    grads = {"w": jnp.asarray([1e-4, 2e-4, 0.5], jnp.float32)}
    err = C.init_error_feedback(grads)
    comp, err = C.compress_grads(grads, err)
    # tiny components are quantized to zero, but the residual remembers them
    assert float(jnp.abs(err["w"][0])) > 0
    total = comp["w"] + err["w"]
    np.testing.assert_allclose(np.asarray(total), np.asarray(grads["w"]),
                               atol=1e-7)


def test_compressed_sgd_converges_like_exact():
    """Quadratic bowl: error-feedback SGD must reach the optimum."""
    target = jnp.asarray([1.0, -2.0, 3.0])

    def grad(w):
        return {"w": 2 * (w["w"] - target)}

    for compressed in (False, True):
        w = {"w": jnp.zeros(3)}
        err = C.init_error_feedback(w)
        for _ in range(300):
            g = grad(w)
            if compressed:
                g, err = C.compress_grads(g, err)
            w = {"w": w["w"] - 0.05 * g["w"]}
        np.testing.assert_allclose(np.asarray(w["w"]), np.asarray(target),
                                   atol=0.05)


def test_compression_traffic_ratio():
    """int8 payload is 4× smaller than fp32 per element."""
    x = jnp.zeros((1024,), jnp.float32)
    q, _ = C.quantize_int8(x)
    assert q.size * q.dtype.itemsize * 4 == x.size * x.dtype.itemsize
