"""jaxpr cost counter: exactness on known primitives, scan multiplication,
remat recompute visibility."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.jaxpr_cost import cost_of


def test_matmul_flops_exact():
    a = jnp.zeros((8, 16))
    b = jnp.zeros((16, 32))
    c = cost_of(lambda x, y: x @ y, a, b)
    assert c.flops == 2 * 8 * 16 * 32
    # bytes: operands + result
    assert c.bytes == (8 * 16 + 16 * 32 + 8 * 32) * 4


def test_batched_einsum_flops():
    a = jnp.zeros((4, 8, 16))
    b = jnp.zeros((4, 16, 32))
    c = cost_of(lambda x, y: jnp.einsum("bij,bjk->bik", x, y), a, b)
    assert c.flops == 2 * 4 * 8 * 16 * 32


def test_scan_multiplies_by_length():
    w = jnp.zeros((16, 16))

    def one(x):
        return x @ w

    def scanned(x):
        def body(carry, _):
            return carry @ w, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    x = jnp.zeros((16, 16))
    c1 = cost_of(one, x)
    c10 = cost_of(scanned, x)
    assert c10.flops == pytest.approx(10 * c1.flops, rel=0.01)


def test_grad_includes_backward():
    w = jnp.ones((32, 32))
    x = jnp.ones((4, 32))

    def loss(w):
        return jnp.sum((x @ w) ** 2)

    fwd = cost_of(loss, w)
    both = cost_of(jax.grad(loss), w)
    assert both.flops >= 1.9 * fwd.flops  # fwd + bwd matmul(s)


def test_remat_adds_recompute():
    w = jnp.ones((32, 32))
    x = jnp.ones((4, 32))

    def block(w):
        h = x @ w
        for _ in range(4):
            h = jnp.tanh(h @ w)
        return jnp.sum(h)

    plain = cost_of(jax.grad(block), w)
    remat = cost_of(jax.grad(jax.checkpoint(block)), w)
    assert remat.flops > plain.flops  # recompute visible in the jaxpr


def test_elementwise_and_reduce():
    x = jnp.zeros((100,))
    c = cost_of(lambda x: jnp.sum(x * 2.0), x)
    assert 100 <= c.flops <= 310  # mul (100) + reduce (100) (+ broadcasting)
