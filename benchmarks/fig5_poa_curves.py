"""Figure 5: PoA vs concurrency (log-log) for 340B 1P/2D, 70B 1P/2D and
70B 1P/5D — the three-regime structure."""
from __future__ import annotations

import time

from benchmarks.common import emit, run_sim, save_json

LEVELS = [1, 4, 8, 16, 32, 64, 128, 256, 512]
SERIES = [("nemotron-4-340b", "1P/2D"), ("llama-3.1-70b", "1P/2D"),
          ("llama-3.1-70b", "1P/5D")]


def run(hold_s: float = 90.0):
    t0 = time.perf_counter()
    out = {}
    for model, topo in SERIES:
        out[f"{model} {topo}"] = [
            dict(C=c, poa=run_sim(model, topo, c, hold_s).overall().poa)
            for c in LEVELS]
    print("\n# Figure 5 — PoA vs concurrency")
    header = f"{'C':>5}" + "".join(f"{k.split()[0][:12]+' '+k.split()[1]:>22}"
                                   for k in out)
    print(header)
    for i, c in enumerate(LEVELS):
        row = f"{c:>5}" + "".join(f"{v[i]['poa']:>22.2f}" for v in out.values())
        print(row)
    save_json("fig5_poa_curves", out)
    plat = {k: [r["poa"] for r in v if 32 <= r["C"] <= 96]
            for k, v in out.items()}
    mean = lambda xs: sum(xs) / max(len(xs), 1)
    p340 = mean(plat["nemotron-4-340b 1P/2D"])
    p70 = mean(plat["llama-3.1-70b 1P/2D"])
    p70_5 = mean(plat["llama-3.1-70b 1P/5D"])
    dt = (time.perf_counter() - t0) * 1e6
    emit("fig5_poa_curves", dt / (3 * len(LEVELS)),
         f"plateaus_340b/70b2d/70b5d={p340:.1f}/{p70:.1f}/{p70_5:.1f};"
         f"paper=18.7/7.5/14.9")
    return out


if __name__ == "__main__":
    run()
