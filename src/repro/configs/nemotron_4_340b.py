"""Nemotron-4-340B — dense GQA, squared-ReLU MLP. [arXiv:2402.16819; unverified]

The paper's primary serving model (Section 7.3, FP8 TP=8 in the original;
bf16 on TPU here).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    num_layers=96,
    d_model=18_432,
    num_heads=96,
    num_kv_heads=8,
    d_ff=73_728,
    vocab_size=256_000,
    head_dim=192,
    activation="squared_relu",
    subquadratic=False,
    source="arXiv:2402.16819; unverified",
)
