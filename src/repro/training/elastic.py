"""Elastic scaling, fault tolerance, and straggler mitigation.

At 1000+ nodes the run must survive node loss and slow hosts:

* ``HeartbeatMonitor`` — lease-backed liveness (the etcd pattern from the
  paper's event plane): hosts that miss ``timeout`` are declared failed.
* ``ElasticMesh`` — given the surviving device count, picks the largest
  valid (data, model) mesh ≤ available devices (model-parallel degree is
  fixed by the sharding policy; the data axis shrinks/grows), and reshards
  a checkpointed state onto it.  Combined with the counter-mode data
  pipeline, a shrink/grow is: checkpoint → remesh → restore → continue.
* ``StragglerMitigator`` — deadline-based: per-step host durations are
  tracked; hosts slower than ``factor``× the rolling median get flagged and
  (in the driver) their microbatches reassigned / host cordoned.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.sharding import ShardingPolicy
from repro.sharding.specs import param_shardings


@dataclass
class HeartbeatMonitor:
    timeout: float = 30.0
    _last: Dict[int, float] = field(default_factory=dict)

    def beat(self, host_id: int, now: Optional[float] = None):
        self._last[host_id] = time.monotonic() if now is None else now

    def failed_hosts(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        return [h for h, t in self._last.items() if now - t > self.timeout]

    def alive_hosts(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        return [h for h, t in self._last.items() if now - t <= self.timeout]


class ElasticMesh:
    """Rebuild the mesh when the healthy device set changes."""

    def __init__(self, model_parallel: int, axis_names=("data", "model")):
        self.model_parallel = model_parallel
        self.axis_names = axis_names

    def best_shape(self, num_devices: int) -> Tuple[int, int]:
        mp = self.model_parallel
        if num_devices < mp:
            raise RuntimeError(
                f"need >= {mp} devices for model parallelism, have {num_devices}")
        data = num_devices // mp
        return (data, mp)

    def make_mesh(self, devices=None) -> Mesh:
        devices = devices if devices is not None else jax.devices()
        shape = self.best_shape(len(devices))
        n = shape[0] * shape[1]
        arr = np.asarray(devices[:n]).reshape(shape)
        return Mesh(arr, self.axis_names)

    def reshard_state(self, state, old_mesh: Mesh, new_mesh: Mesh):
        """Move a train state onto a new mesh (device_put with the policy's
        specs recomputed for the new topology)."""
        policy = ShardingPolicy(new_mesh)
        p_sh = param_shardings(state["params"], policy)
        sh = {"params": p_sh,
              "opt": {"m": p_sh, "v": p_sh,
                      "step": NamedSharding(new_mesh,
                                            jax.sharding.PartitionSpec())}}
        return jax.tree.map(jax.device_put, state, sh)


@dataclass
class StragglerMitigator:
    factor: float = 1.5
    window: int = 16
    _durations: Dict[int, List[float]] = field(default_factory=dict)

    def record(self, host_id: int, step_duration: float):
        buf = self._durations.setdefault(host_id, [])
        buf.append(step_duration)
        if len(buf) > self.window:
            buf.pop(0)

    def medians(self) -> Dict[int, float]:
        return {h: float(np.median(v)) for h, v in self._durations.items() if v}

    def stragglers(self) -> List[int]:
        meds = self.medians()
        if len(meds) < 2:
            return []
        overall = float(np.median(list(meds.values())))
        return [h for h, m in meds.items() if m > self.factor * overall]

    def reassignment(self, num_microbatches: int) -> Dict[int, int]:
        """Deadline-aware microbatch shares ∝ 1/median-duration."""
        meds = self.medians()
        if not meds:
            return {}
        inv = {h: 1.0 / m for h, m in meds.items()}
        tot = sum(inv.values())
        raw = {h: num_microbatches * w / tot for h, w in inv.items()}
        out = {h: int(np.floor(r)) for h, r in raw.items()}
        rem = num_microbatches - sum(out.values())
        for h, _ in sorted(raw.items(), key=lambda kv: -(kv[1] % 1)):
            if rem <= 0:
                break
            out[h] += 1
            rem -= 1
        return out
