"""Chunked SSD / mLSTM / sLSTM against naive per-step recurrences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import ssm as S


# ------------------------------------------------------------------ SSD ----

def _ssd_naive(x, dt, a_log, b_in, c_in):
    """Per-step recurrence: h_t = a_t h_{t-1} + dt_t B_t ⊗ x_t; y = C_t h."""
    b, s, h, p = x.shape
    n = b_in.shape[-1]
    a = np.exp(-np.exp(np.asarray(a_log, np.float64)))  # placeholder shape (h,)
    state = np.zeros((b, h, n, p))
    ys = np.zeros((b, s, h, p))
    xf = np.asarray(x, np.float64)
    dtf = np.asarray(dt, np.float64)
    bf = np.asarray(b_in, np.float64)
    cf = np.asarray(c_in, np.float64)
    A = np.exp(np.asarray(a_log, np.float64))
    for t in range(s):
        decay = np.exp(-dtf[:, t, :] * A[None, :])       # (b,h)
        upd = np.einsum("bn,bh,bhp->bhnp", bf[:, t], dtf[:, t], xf[:, t])
        state = state * decay[:, :, None, None] + upd
        ys[:, t] = np.einsum("bn,bhnp->bhp", cf[:, t], state)
    return ys, state


@pytest.mark.parametrize("s,chunk", [(32, 8), (40, 16), (16, 16), (24, 64)])
def test_ssd_chunked_matches_naive(s, chunk):
    rng = np.random.default_rng(0)
    b, h, p, n = 2, 3, 4, 5
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(b, s, h)), jnp.float32)
    a_log = jnp.asarray(np.log(rng.uniform(1, 8, size=(h,))), jnp.float32)
    b_in = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    c_in = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    y, st = S.ssd_chunked(x, dt, a_log, b_in, c_in, chunk)
    y_ref, st_ref = _ssd_naive(x, dt, a_log, b_in, c_in)
    np.testing.assert_allclose(np.asarray(y, np.float64), y_ref,
                               atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(st, np.float64), st_ref,
                               atol=2e-3, rtol=2e-3)


def test_ssd_chunk_size_invariance():
    rng = np.random.default_rng(1)
    b, s, h, p, n = 1, 48, 2, 4, 3
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(b, s, h)), jnp.float32)
    a_log = jnp.zeros((h,), jnp.float32)
    b_in = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    c_in = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    y1, s1 = S.ssd_chunked(x, dt, a_log, b_in, c_in, 8)
    y2, s2 = S.ssd_chunked(x, dt, a_log, b_in, c_in, 24)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)


def test_mamba_block_decode_matches_fullseq():
    cfg = get_reduced("jamba-v0.1-52b")
    params = S.mamba_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 17, cfg.d_model),
                          jnp.float32)
    y_full, cache_full = S.mamba_block(params, x, cfg)
    # run first 16 tokens, then decode token 17 with the cache
    _, cache = S.mamba_block(params, x[:, :16], cfg)
    y_step, _ = S.mamba_block(params, x[:, 16:17], cfg, cache=cache)
    np.testing.assert_allclose(np.asarray(y_step[:, 0]),
                               np.asarray(y_full[:, 16]),
                               atol=3e-2, rtol=3e-2)


# ---------------------------------------------------------------- mLSTM ----

def _mlstm_naive(q, k, v, log_i, log_f):
    b, s, h, p = q.shape
    qf = np.asarray(q, np.float64) * (p ** -0.5)
    kf = np.asarray(k, np.float64)
    vf = np.asarray(v, np.float64)
    li = np.asarray(log_i, np.float64)
    lf = np.asarray(log_f, np.float64)
    C = np.zeros((b, h, p, p))
    n = np.zeros((b, h, p))
    m = np.full((b, h), -np.inf)
    hs = np.zeros((b, s, h, p))
    for t in range(s):
        m_new = np.maximum(lf[:, t] + m, li[:, t])
        dec = np.exp(lf[:, t] + m - m_new)
        inp = np.exp(li[:, t] - m_new)
        C = C * dec[..., None, None] + inp[..., None, None] * np.einsum(
            "bhp,bhq->bhpq", kf[:, t], vf[:, t])
        n = n * dec[..., None] + inp[..., None] * kf[:, t]
        num = np.einsum("bhp,bhpq->bhq", qf[:, t], C)
        den = np.maximum(np.abs(np.einsum("bhp,bhp->bh", qf[:, t], n)),
                         np.exp(-m_new))
        hs[:, t] = num / den[..., None]
        m = m_new
    return hs, (C, n, m)


@pytest.mark.parametrize("s,chunk", [(24, 8), (32, 16), (16, 64)])
def test_mlstm_chunked_matches_naive(s, chunk):
    rng = np.random.default_rng(2)
    b, h, p = 2, 2, 6
    q = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    log_i = jnp.asarray(rng.normal(size=(b, s, h)), jnp.float32)
    log_f = jnp.asarray(np.log(rng.uniform(0.5, 0.99, size=(b, s, h))),
                        jnp.float32)
    hs, (C, n, m) = S.mlstm_chunked(q, k, v, log_i, log_f, chunk)
    hs_ref, (C_ref, n_ref, m_ref) = _mlstm_naive(q, k, v, log_i, log_f)
    np.testing.assert_allclose(np.asarray(hs, np.float64), hs_ref,
                               atol=2e-3, rtol=2e-3)
    # states match up to the shared stabilizer normalization
    np.testing.assert_allclose(
        np.asarray(C, np.float64) * np.exp(np.asarray(m))[..., None, None],
        C_ref * np.exp(m_ref)[..., None, None], atol=2e-3, rtol=2e-3)


def test_mlstm_step_continues_chunked():
    rng = np.random.default_rng(3)
    b, s, h, p = 1, 16, 2, 4
    mk = lambda shape: jnp.asarray(rng.normal(size=shape), jnp.float32)
    q, k, v = mk((b, s + 1, h, p)), mk((b, s + 1, h, p)), mk((b, s + 1, h, p))
    log_i = mk((b, s + 1, h))
    log_f = jnp.asarray(np.log(rng.uniform(0.5, 0.99, size=(b, s + 1, h))),
                        jnp.float32)
    full, _ = S.mlstm_chunked(q, k, v, log_i, log_f, 8)
    _, st = S.mlstm_chunked(q[:, :s], k[:, :s], v[:, :s],
                            log_i[:, :s], log_f[:, :s], 8)
    h_step, _ = S.mlstm_step(q[:, s], k[:, s], v[:, s],
                             log_i[:, s], log_f[:, s], st)
    np.testing.assert_allclose(np.asarray(h_step), np.asarray(full[:, s]),
                               atol=2e-3, rtol=2e-3)


# ---------------------------------------------------------------- sLSTM ----

def test_slstm_step_vs_scan():
    cfg = get_reduced("xlstm-125m")
    params = S.slstm_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 9, cfg.d_model),
                          jnp.float32)
    y_full, cache_full = S.slstm_block(params, x, cfg)
    _, cache = S.slstm_block(params, x[:, :8], cfg)
    y_step, cache_step = S.slstm_block(params, x[:, 8:9], cfg, cache=cache)
    np.testing.assert_allclose(np.asarray(y_step[:, 0]),
                               np.asarray(y_full[:, 8]),
                               atol=3e-2, rtol=3e-2)
    np.testing.assert_allclose(np.asarray(cache_step["c"]),
                               np.asarray(cache_full["c"]),
                               atol=2e-3, rtol=2e-3)
