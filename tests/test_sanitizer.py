"""Sanitized runs are bit-exact with un-instrumented ones.

Every check in ``repro.analysis.sanitize`` is a pure read (no RNG draws,
no event pushes, no lazy sweeps), so enabling the sanitizer must not
change a single routed worker, timestamp, or poll entry.  This suite pins
that over the whole scenario registry, plus the enablement contract
(argument > environment, zero-cost when off) and an engine-backend parity
scenario under full instrumentation.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis.sanitize import sanitize_enabled
from repro.serving.control_plane import ControlPlane
from repro.serving.scenarios import (build_backend, build_simulator,
                                     list_scenarios, parity_scenarios)
from repro.serving.simulator import ClusterConfig, Simulator
from repro.serving.workload import WorkloadConfig

ALL_SCENARIOS = list_scenarios()


def _fingerprint(res):
    """Everything observable about a run.  ``repr`` so NaN poll entries
    (early PoA windows) compare equal between identical runs."""
    return (
        tuple((r.rid, r.decode_worker, r.overlap, r.prefill_end, r.finish_t)
              for r in res.completed),
        repr(res.overall()),
        repr(res.poll_log),
        tuple(res.role_flips),
    )


@pytest.mark.parametrize("name", ALL_SCENARIOS)
def test_sanitized_run_bit_exact(name):
    base = build_simulator(name, seed=0, fast=True, sanitize=False)
    san = build_simulator(name, seed=0, fast=True, sanitize=True)
    assert base.sanitizer is None
    assert san.sanitizer is not None
    assert _fingerprint(base.run()) == _fingerprint(san.run())


# ---------------------------------------------------------- enablement ------


def _tiny(**kw):
    return Simulator(ClusterConfig.for_model("llama-3.1-70b", "1P/2D"),
                     WorkloadConfig.single_level(8, hold_s=2.0),
                     seed=0, **kw)


def test_default_off_is_zero_cost():
    """Without opt-in, nothing is attached: the event handlers stay plain
    class methods (no per-event wrapper indirection at all)."""
    sim = _tiny()
    assert sim.sanitizer is None
    for name in ("_route", "_admit_decode", "_on_poll", "_on_sync"):
        assert name not in vars(sim)


def test_env_var_enables(monkeypatch):
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    assert sanitize_enabled() is True
    sim = _tiny()
    assert sim.sanitizer is not None
    sim.run()                                 # green under instrumentation


def test_explicit_argument_beats_env(monkeypatch):
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    assert _tiny(sanitize=False).sanitizer is None
    monkeypatch.delenv("REPRO_SANITIZE")
    assert _tiny(sanitize=True).sanitizer is not None


@pytest.mark.parametrize("value,expect", [
    ("1", True), ("true", True), ("YES", True), ("on", True),
    ("0", False), ("", False), ("off", False), ("no", False),
])
def test_env_var_spellings(monkeypatch, value, expect):
    monkeypatch.setenv("REPRO_SANITIZE", value)
    assert sanitize_enabled() is expect


def test_control_plane_sanitizer_checks_each_decision():
    cp = ControlPlane(4, sanitize=True)
    assert cp.sanitizer is not None
    tokens = list(range(64))
    w, ov, overlaps, ids = cp.select_worker(tokens, now=0.0, rid=0)
    assert w in ids and len(overlaps) == len(ids)
    assert ControlPlane(4).sanitizer is None


def test_simulator_inner_control_plane_not_double_attached(monkeypatch):
    """The simulator attaches its own richer sanitizer; the inner
    ControlPlane must not stack a second one on the same router."""
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    sim = _tiny()
    assert sim.sanitizer is not None
    assert sim.control.sanitizer is None


# ------------------------------------------------------------- engine -------

pytest_slow = pytest.mark.slow


@pytest_slow
def test_engine_parity_scenario_bit_exact_under_sanitizer():
    """One parity scenario on the real-JAX engine backend, instrumented:
    identical decisions, tokens, and regime transitions."""
    from repro.configs import get_reduced
    from repro.models import build_model

    cfg = get_reduced("phi4-mini-3.8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), jnp.bfloat16)
    name = parity_scenarios()[0]

    runs = {}
    for sanitize in (False, True):
        eng = build_backend(name, backend="engine", seed=0,
                            model=model, params=params, sanitize=sanitize)
        assert (eng.cluster.sanitizer is not None) is sanitize
        res = eng.run()
        runs[sanitize] = (
            [(i, w, round(ov, 12)) for i, w, ov in res.decisions],
            [(r.request_id, tuple(r.output)) for r in
             sorted(res.requests, key=lambda r: r.request_id)],
            [(a, b) for _, a, b in res.regime_transitions],
        )
    assert runs[False] == runs[True]
