"""Engine backend of the scenario registry.

Materializes a named scenario's request stream onto the real-JAX
:class:`~repro.serving.disagg.DisaggregatedCluster` (reduced CPU-testable
models), so every registered scenario can run against actual jitted
compute instead of the analytic latency model::

    from repro.serving.scenarios import build_backend

    runner = build_backend("parity-2d-warm", backend="engine", seed=0)
    result = runner.run()
    result.decisions          # [(index, worker, overlap)] routing record
    result.regime_transitions # saturation-regime transition sequence
    result.prefill_stats      # warm-vs-cold prefix-cache accounting

The adapter necessarily *reduces* the workload — engine runs execute real
forward passes on CPU, so prompt/output lengths and request counts are
capped (``input_tokens``/``output_tokens``/``num_requests``) — but the
control-plane stream is faithful: templates come from the same
:func:`~repro.serving.workload.template_mix` popularity skew (or the
trace's explicit template sequence), each template maps to a
deterministic in-vocab prompt that is distinct per template (prime
re-striding — a plain ``template_tokens % vocab`` would alias templates
16 apart on the 512-token reduced vocab), and routing runs through the
same :class:`~repro.serving.control_plane.ControlPlane` code path the
analytic simulator uses.

``serialize=True`` (default) runs each request to completion before
submitting the next.  That is the backend-parity protocol: with zero
concurrent load on both backends, a τ=0 routing decision depends only on
the indexer's insert history, which both backends build identically — so
decision sequences are comparable request-by-request
(``tests/test_backend_parity.py``).  ``serialize=False`` floods the
cluster (backpressure + continuous batching exercise the real engines).
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from repro.core.saturation import DetectorConfig
from repro.serving.disagg import DisaggregatedCluster, ServeRequest
from repro.serving.workload import template_mix


@dataclass(frozen=True)
class EngineRequestSpec:
    """One materialized request (template resolved, tokens in-vocab)."""
    template: int
    tokens: Tuple[int, ...]
    max_new: int


@dataclass
class EngineRunResult:
    """What an engine-backend scenario run reports for parity analysis."""
    requests: List[ServeRequest]              # completion order
    decisions: List[Tuple[int, int, float]]   # (req index, worker, overlap)
    regime_transitions: List[Tuple[float, int, int]]
    final_regime: int
    prefill_stats: dict
    transferred_blocks: List[int]             # per decode worker

    def ttfts(self) -> List[float]:
        return [r.charged_ttft for r in self.requests]


class EngineScenarioRunner:
    """Drives one named scenario through the engine backend."""

    def __init__(self, scenario, *, seed: int = 0,
                 model_name: str = "phi4-mini-3.8b",
                 num_requests: Optional[int] = None,
                 input_tokens: int = 48,
                 output_tokens: int = 4,
                 slots_per_worker: int = 2,
                 serialize: bool = True,
                 warmup: bool = True,
                 model=None, params=None,
                 **cluster_kw):
        import jax            # deferred: scenario listing stays jax-free
        import jax.numpy as jnp
        from repro.configs import get_reduced
        from repro.models import build_model

        self.scenario = scenario
        self.serialize = serialize
        self.warmup_enabled = warmup
        sim_kw = dict(scenario.sim_kwargs)
        cluster_kw.setdefault("routing_policy",
                              sim_kw.get("routing_policy", "kv"))
        cluster_kw.setdefault("adaptive", sim_kw.get("adaptive", False))
        if sim_kw.get("router_config") is not None:
            cluster_kw.setdefault("router_config", sim_kw["router_config"])
        # Mirror the analytic backend's control-plane defaults, so the
        # regime-sequence parity observable compares like against like:
        # same saturation thresholds (DetectorConfig.for_model) and the
        # scenario's own cache TTL (claim churn on the engine clock).
        cluster_kw.setdefault(
            "detector_config",
            sim_kw.get("detector_config")
            or DetectorConfig.for_model(scenario.cluster.name))
        cluster_kw.setdefault("cache_ttl", scenario.cluster.cache_ttl)
        # fabric scenarios carry the FabricConfig in sim_kwargs; the engine
        # cluster builds its own Fabric instance from the same config
        if sim_kw.get("fabric") is not None:
            cluster_kw.setdefault("fabric", sim_kw["fabric"])
            cluster_kw.setdefault("network_aware",
                                  sim_kw.get("network_aware", False))
        if model is None:
            cfg = get_reduced(model_name)
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0), jnp.bfloat16)
        self.model = model
        self.vocab = model.cfg.vocab_size
        self.specs = self._materialize(seed, num_requests, input_tokens,
                                       output_tokens)
        max_len = max((len(s.tokens) + s.max_new for s in self.specs),
                      default=input_tokens + output_tokens) + 4
        self.cluster = DisaggregatedCluster(
            model, params,
            num_decode=scenario.cluster.num_decode,
            slots_per_worker=slots_per_worker,
            max_len=max_len, seed=seed, **cluster_kw)

    # ------------------------------------------------------- request stream --

    def _materialize(self, seed: int, num_requests: Optional[int],
                     input_tokens: int, output_tokens: int
                     ) -> List[EngineRequestSpec]:
        wl = self.scenario.workload
        specs: List[EngineRequestSpec] = []
        if wl.mode == "trace":
            # default: replay the full trace (parity runs must see every
            # decision the analytic backend makes)
            entries = list(wl.trace)[:num_requests]
            probs = template_mix(wl.num_templates)
            rng = np.random.default_rng(seed)
            for e in entries:
                template = e.template
                if template < 0:
                    template = int(rng.choice(len(probs), p=probs))
                specs.append(self._spec(template,
                                        min(e.input_tokens, input_tokens),
                                        min(e.output_tokens, output_tokens)))
        else:
            # closed-loop / open-loop: same popularity skew as the analytic
            # backend's template sampling, reduced to a fixed request count
            probs = template_mix(wl.num_templates)
            rng = np.random.default_rng(seed)
            for _ in range(num_requests if num_requests is not None else 12):
                template = int(rng.choice(len(probs), p=probs))
                specs.append(self._spec(
                    template, min(wl.input_tokens, input_tokens),
                    min(wl.output_tokens, output_tokens)))
        return specs

    def _spec(self, template: int, n_in: int, n_out: int) -> EngineRequestSpec:
        # In-vocab reduction must stay injective ACROSS templates: the
        # naive `token % vocab` aliases templates 16 apart on a 512-vocab
        # reduced model (16·100_000 ≡ 0 mod 512), silently merging distinct
        # templates' prefix caches and overlap claims.  Re-striding the
        # template id by a large prime keeps templates distinct mod any
        # realistic vocab (collision needs Δt·1_000_003 ≡ 0 mod vocab).
        toks = tuple((template * 1_000_003 + 7 * i) % self.vocab
                     for i in range(n_in))
        return EngineRequestSpec(template, toks, max(n_out, 1))

    # ---------------------------------------------------------------- run ---

    def _warmup(self) -> None:
        """Compile every jitted/XLA shape this run will hit, outside the
        measured path (compile walls would otherwise read as multi-second
        TTFTs and drive the saturation detector across θ1)."""
        block = self.cluster.prefill.block_size
        lengths = sorted(set(len(s.tokens) for s in self.specs))
        suffixes = set()
        for n in lengths:
            for m in range(1, n // block + 1):
                start = min(m * block, n - 1)
                suffixes.add(n - start)
        # serialized runs only ever issue width-1 batched passes; flood
        # runs can fill a whole tick's admissions, so pre-compile every
        # power-of-two width the bucketing can emit
        widths = [1]
        cap = min(self.cluster.prefill.max_batch, max(len(self.specs), 1))
        while self.cluster.batch_prefill and not self.serialize \
                and widths[-1] * 2 <= cap:
            widths.append(widths[-1] * 2)
        self.cluster.prefill.warmup(lengths, sorted(suffixes),
                                    batch_sizes=widths)
        # the admit path (cache insertion scatter) and the decode step
        # compile on first use too; run one dummy admit→step→auto-release
        # per decoder (empty hash list: no residency/transfer pollution)
        caches = self.cluster.prefill.dummy_caches(lengths[-1])
        for dec in self.cluster.decoders:
            if dec.paged:
                # paged decode recompiles per page-table width: pre-compile
                # every ladder width up to the widest table this run's
                # longest (prompt + output) span can grow a slot to, so a
                # mid-run block-boundary crossing never pays a compile wall
                span = max((len(s.tokens) + s.max_new + 1
                            for s in self.specs), default=lengths[-1] + 2)
                dec.warmup(table_widths=dec.width_ladder(span))
                # the adopt scatter compiles per mapped-page count: one
                # dummy admit+release per distinct count the prompts map
                reps = {}
                for n in lengths:
                    reps.setdefault(dec.pages_for_prompt(n), n)
                top = dec.pages_for_prompt(lengths[-1])
                for n_map, n in sorted(reps.items()):
                    if n_map == top:
                        continue    # covered by the shared admit below
                    dec.admit(0, "__warmup__", caches, 0,
                              prompt_len=n, max_new=1, hashes=())
                    dec.release(0)
            else:
                dec.warmup()
            dec.admit(0, "__warmup__", caches, 0,
                      prompt_len=lengths[-1], max_new=1, hashes=())
            dec.step()                      # done=True → slot auto-released
            assert dec.active_count == 0
        # the first non-empty PoA evaluation lazily imports scipy's
        # Hungarian solver (~1 s) inside route()'s gauge export — a wall
        # the detector would read as a saturating TTFT; PoA falls back to
        # its pure-python solve when scipy is absent
        with contextlib.suppress(ImportError):
            import scipy.optimize  # noqa: F401

    def run(self) -> EngineRunResult:
        if self.warmup_enabled:
            self._warmup()
        cl = self.cluster
        for i, spec in enumerate(self.specs):
            cl.submit(ServeRequest(f"r{i}", list(spec.tokens),
                                   max_new_tokens=spec.max_new))
            if self.serialize:
                cl.run_until_done()
        cl.run_until_done()
        decisions = [(int(d.rid[1:]), d.worker, d.overlap)
                     for d in cl.control.decision_log]
        return EngineRunResult(
            requests=list(cl.done),
            decisions=decisions,
            regime_transitions=cl.control.regime_transitions(),
            final_regime=int(cl.control.detector.regime),
            prefill_stats=cl.prefill.stats.as_dict(),
            transferred_blocks=[d.transferred_blocks for d in cl.decoders])
